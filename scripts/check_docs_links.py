"""Fail on broken intra-repo links in README.md and docs/*.md.

Checks every markdown link whose target is a relative path: the target
file must exist, and a `#fragment` (if any) must match a heading in the
target file under GitHub's slugification rules.  External links
(http/https/mailto) are not fetched — CI must not flake on the network.

Usage:  python scripts/check_docs_links.py [files...]
        (no args: README.md + docs/*.md relative to the repo root)
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub anchor slug: lowercase, drop punctuation, spaces -> dashes."""
    text = re.sub(r"[`*_]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set[str]:
    return {slugify(h) for h in _HEADING_RE.findall(path.read_text())}


def _label(path: Path) -> str:
    try:
        return str(path.relative_to(REPO))
    except ValueError:
        return str(path)


def check_file(path: Path) -> list[str]:
    errors = []
    text = _CODE_FENCE_RE.sub("", path.read_text())
    for target in _LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, fragment = target.partition("#")
        dest = path if not ref else (path.parent / ref).resolve()
        if not dest.exists():
            errors.append(f"{_label(path)}: broken link -> {target}")
            continue
        if fragment and dest.suffix == ".md" and slugify(fragment) not in anchors_of(dest):
            errors.append(f"{_label(path)}: missing anchor -> {target}")
    return errors


def main(argv: list[str]) -> int:
    files = ([Path(a).resolve() for a in argv]
             if argv else [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))])
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(f"BROKEN: {e}")
    checked = ", ".join(_label(f) for f in files)
    if errors:
        print(f"{len(errors)} broken link(s) across {checked}")
        return 1
    print(f"all intra-repo links OK in {checked}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
