"""Disaggregated serving fleet: KV handoff, crash recovery, elasticity.

Everything runs on FakeReplica (repro.serving.replica): a real paged
pool whose page contents are the fed token values, a recurrent
state/conv row per slot, and next-token = (prev + 1) % vocab — so a
lost page, a mis-scattered handoff, or a dropped SSM row turns into a
hard failure, and the expected token chain for any request is exact.
All clocks are fake; every scenario is deterministic.
"""

import numpy as np
import pytest

from repro.ft import (
    StragglerConfig,
    StragglerDetector,
    Supervisor,
    SupervisorConfig,
)
from repro.launch.serve import DECODING, DONE, Request, Scheduler
from repro.serving import (
    ACTIVE,
    DRAINED,
    JOINING,
    ElasticController,
    FakeFleetEngine,
    FakeReplica,
    FleetScheduler,
)
from repro.tuning.bundle import BundleFormatError, KVHandoff

VOCAB = 16


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def expected_tokens(req: Request) -> list[int]:
    last = int(req.prompt[-1])
    return [(last + k) % VOCAB for k in range(1, req.max_new + 1)]


def make_fleet(clock, *, prefill=1, decode=2, controller=None, **rep_kw):
    kw = dict(slots=2, max_len=40, chunk=4)
    kw.update(rep_kw)

    def factory(role, host_id):
        rep = FakeReplica(host_id, role, clock=clock, **kw)
        rep.set_latency(0.01)
        return rep

    return FleetScheduler(factory, prefill=prefill, decode=decode,
                          clock=clock, controller=controller)


def drive(fleet, clock, *, max_ticks=500, per_tick=None):
    for _ in range(max_ticks):
        if fleet.idle:
            return
        fleet.tick()
        clock.t += 1.0
        if per_tick is not None:
            per_tick(clock.t)
    raise AssertionError("fleet did not drain")


def seeded_requests(n, *, seed=7, max_new=5, lo=3, hi=12):
    rng = np.random.default_rng(seed)
    return [Request(rid=rid,
                    prompt=rng.integers(0, VOCAB,
                                        int(rng.integers(lo, hi))).astype(np.int32),
                    max_new=max_new)
            for rid in range(n)]


# ------------------------------------------------------- KVHandoff bytes --
def _sample_handoff():
    return KVHandoff(
        rid=3, source="prefill-0", next_pos=11, pages_used=3, page_size=4,
        arrays={"kv": np.arange(12, dtype=np.int64).reshape(3, 4),
                "state": np.array([42], np.int64)},
    )


def test_kv_handoff_round_trip():
    h = _sample_handoff()
    out = KVHandoff.from_bytes(h.to_bytes())
    assert (out.rid, out.source, out.next_pos) == (3, "prefill-0", 11)
    assert (out.pages_used, out.page_size) == (3, 4)
    np.testing.assert_array_equal(out.arrays["kv"], h.arrays["kv"])
    np.testing.assert_array_equal(out.arrays["state"], h.arrays["state"])
    assert out.arrays["kv"].dtype == np.int64


def test_kv_handoff_rejects_truncation_and_noise():
    blob = _sample_handoff().to_bytes()
    with pytest.raises(BundleFormatError):
        KVHandoff.from_bytes(blob[: len(blob) // 2])
    with pytest.raises(BundleFormatError):
        KVHandoff.from_bytes(b"not a tarball at all")


def test_kv_handoff_rejects_payload_corruption():
    # flip bytes in the payload region until the checksum path trips —
    # any accepted artifact must have a verified state member
    blob = bytearray(_sample_handoff().to_bytes())
    rejected = False
    for i in range(len(blob) - 1, len(blob) - 200, -1):
        tampered = bytearray(blob)
        tampered[i] ^= 0xFF
        try:
            KVHandoff.from_bytes(bytes(tampered))
        except BundleFormatError:
            rejected = True
            break
    assert rejected


def test_kv_handoff_rejects_bad_geometry():
    with pytest.raises(BundleFormatError):
        # 2 pages x 4 tokens cannot cover next_pos=11
        KVHandoff.from_bytes(KVHandoff(
            rid=1, source="x", next_pos=11, pages_used=2, page_size=4,
            arrays={"kv": np.zeros((2, 4), np.int64)},
        ).to_bytes())


# ------------------------------------------------- engine-level handoff --
def test_fake_engine_slot_export_import_moves_state():
    src, dst = (FakeFleetEngine(slots=2, max_len=16, chunk=4) for _ in range(2))
    src.pool.assign(0, src.pool.alloc("a", 2))
    src.prefill_step(0, np.array([3, 5, 7, 9], np.int32), 0)
    src.prefill_step(0, np.array([2], np.int32), 4)
    arrays, pages_used = src.export_slot(0, 5)
    assert pages_used == 2
    blob = KVHandoff(rid=0, source="s", next_pos=5, pages_used=pages_used,
                     page_size=4, arrays=arrays).to_bytes()
    h = KVHandoff.from_bytes(blob)
    dst.pool.assign(1, dst.pool.alloc("b", 2))
    dst.import_slot(1, dict(h.arrays), h.pages_used)
    # recurrent rows and every written position crossed intact
    assert dst.state[1] == 3 + 5 + 7 + 9 + 2
    assert dst.conv[1] == 2
    got = [dst.kv[dst.pool.block_tables[1][p // 4], p % 4] for p in range(5)]
    assert got == [3, 5, 7, 9, 2]


# ----------------------------------------------------------- fleet paths --
def test_fleet_token_identical_to_single_host():
    clock = FakeClock()
    fleet = make_fleet(clock, prefill=1, decode=2)
    fleet_reqs = seeded_requests(8)
    for r in fleet_reqs:
        assert fleet.submit(r)
    drive(fleet, clock)

    # same seeded set through one single-host chunked scheduler
    sclock = FakeClock()
    sched = Scheduler(FakeFleetEngine(slots=2, max_len=40, chunk=4),
                      queue_depth=64, clock=sclock)
    solo_reqs = seeded_requests(8)
    for r in solo_reqs:
        assert sched.submit(r)
    for _ in range(500):
        if sched.idle:
            break
        sched.tick()
        sclock.t += 1.0
    assert sched.idle

    for f, s in zip(fleet_reqs, solo_reqs):
        assert f.tokens == s.tokens == expected_tokens(f)
    assert fleet.completed == 8
    assert fleet.handoffs == fleet.adoptions == 8
    assert all(r.state == DONE for r in fleet_reqs)


def test_fleet_ttft_and_steps_accounting():
    clock = FakeClock()
    fleet = make_fleet(clock, prefill=1, decode=1)
    req = Request(rid=0, prompt=np.arange(1, 8, dtype=np.int32), max_new=4)
    assert fleet.submit(req)
    drive(fleet, clock)
    # chunked invariants survive the migration: ceil(7/4) prefill steps,
    # max_new - 1 decode steps (first token falls out of prefill)
    assert req.prefill_steps == 2
    assert req.decode_steps == 3
    assert req.ttft is not None and req.ttft >= 0


def test_fleet_rejects_unservable_and_queue_full():
    clock = FakeClock()
    fleet = make_fleet(clock, prefill=1, decode=1, max_len=16)
    fleet.queue_depth = 2
    too_long = Request(rid=99, prompt=np.zeros(64, np.int32), max_new=4)
    assert not fleet.submit(too_long)
    ok = [Request(rid=i, prompt=np.arange(1, 5, dtype=np.int32), max_new=2)
          for i in range(3)]
    assert fleet.submit(ok[0]) and fleet.submit(ok[1])
    assert not fleet.submit(ok[2])          # queue full
    assert fleet.rejected == {"too-long": 1, "queue-full": 1}


# ------------------------------------------------------------- fault paths --
def storm_controller(*, rescale=True, heartbeat_timeout=2.5,
                     provision_delay=2.0, max_decode=4):
    return ElasticController(
        Supervisor(0, SupervisorConfig(heartbeat_timeout=heartbeat_timeout)),
        detector=StragglerDetector(0, StragglerConfig(evict_after=10 ** 6)),
        min_decode=1, max_decode=max_decode,
        rescale=rescale, provision_delay=provision_delay)


def test_replica_kill_mid_decode_recovers_token_identical():
    clock = FakeClock()
    fleet = make_fleet(clock, prefill=1, decode=2,
                       controller=storm_controller(rescale=False))
    reqs = seeded_requests(8, max_new=8)
    for r in reqs:
        assert fleet.submit(r)

    state = {"killed": None}

    def maybe_kill(_t):
        if state["killed"] is None:
            busy = next((rep for rep in fleet.decode_pool
                         if any(r.state == DECODING and r.tokens
                                for r in rep.active_requests())), None)
            if busy is not None:
                state["killed"] = busy
                busy.kill()

    drive(fleet, clock, per_tick=maybe_kill)
    assert state["killed"] is not None
    assert fleet.recovered > 0              # requests really were in flight
    assert len(fleet.decode_pool) == 1      # static fleet: not replaced
    for r in reqs:
        assert r.tokens == expected_tokens(r), (r.rid, r.tokens)
    assert any("dead; recovering" in e for e in fleet.events)
    assert any("requeue rid=" in e for e in fleet.events)


def test_kill_and_rescale_storm_replaces_capacity():
    clock = FakeClock()
    ctl = storm_controller(rescale=True, max_decode=2)
    fleet = make_fleet(clock, prefill=1, decode=2, controller=ctl)
    reqs = seeded_requests(10, max_new=8)
    for r in reqs:
        assert fleet.submit(r)

    state = {"killed": None}

    def maybe_kill(t):
        if state["killed"] is None and t >= 4.0:
            busy = max(fleet.decode_pool, key=lambda rep: len(rep.active_requests()))
            state["killed"] = busy
            busy.kill()

    drive(fleet, clock, per_tick=maybe_kill)
    assert ctl.provisioned >= 1             # pool grew back
    assert any(e for e in fleet.events if "rescale: decode pool" in e)
    alive_decode = [r for r in fleet.decode_pool if r.alive]
    assert len(alive_decode) >= 1
    for r in reqs:
        assert r.tokens == expected_tokens(r), (r.rid, r.tokens)


def test_straggler_evicted_via_graceful_drain():
    clock = FakeClock()
    ctl = ElasticController(
        Supervisor(0, SupervisorConfig(heartbeat_timeout=100.0)),
        detector=StragglerDetector(0, StragglerConfig(
            threshold=2.0, patience=2, evict_after=4)),
        min_decode=1, max_decode=3, rescale=False)
    fleet = make_fleet(clock, prefill=1, decode=3, controller=ctl)
    slow = fleet.decode_pool[1]
    slow.set_latency(0.2)                   # ~20x the healthy 0.01
    reqs = [Request(rid=i, prompt=np.arange(1, 6, dtype=np.int32), max_new=8)
            for i in range(8)]
    for r in reqs:
        assert fleet.submit(r)
    drive(fleet, clock)
    assert slow.state == DRAINED
    assert fleet.recovered == 0             # graceful: no recomputation
    assert any("drain" in e and "straggler" in e for e in fleet.events)
    for r in reqs:
        assert r.tokens == expected_tokens(r)
    # drained slots re-entered the pool as handoffs and were re-adopted
    assert fleet.adoptions > fleet.completed - fleet.recovered - 1


def test_out_of_pages_handoff_waits_then_drains():
    clock = FakeClock()

    def factory(role, host_id):
        # decode pool sized for one request at a time (1 park + 4 pages)
        pages = None if role == "prefill" else 5
        rep = FakeReplica(host_id, role, slots=2, max_len=16, chunk=4,
                          num_pages=pages, clock=clock)
        rep.set_latency(0.01)
        return rep

    fleet = FleetScheduler(factory, prefill=1, decode=1, clock=clock)
    reqs = [Request(rid=i, prompt=np.arange(2, 9, dtype=np.int32), max_new=4)
            for i in range(3)]
    for r in reqs:
        assert fleet.submit(r)
    drive(fleet, clock)
    assert any("waiting for decode capacity" in e for e in fleet.events)
    for r in reqs:
        assert r.tokens == expected_tokens(r)
    assert fleet.stats()["pending-handoffs"] == 0


def test_provisioned_replica_joins_after_delay():
    clock = FakeClock()
    ctl = storm_controller(rescale=True, provision_delay=3.0, max_decode=2)
    fleet = make_fleet(clock, prefill=1, decode=1, controller=ctl)
    for r in seeded_requests(6, max_new=6):
        assert fleet.submit(r)
    fleet.tick()                            # demand forces a grow plan
    joiner = fleet.decode_pool[-1]
    assert joiner.state == JOINING
    assert joiner.tick() == []              # joining replicas take no work
    clock.t = 4.0
    fleet.tick()
    assert joiner.state == ACTIVE
    drive(fleet, clock)


def test_warm_start_event_logged_on_provision():
    clock = FakeClock()

    def factory(role, host_id):
        rep = FakeReplica(host_id, role, slots=2, max_len=40, chunk=4,
                          clock=clock)
        rep.set_latency(0.01)
        rep.warm_start = {"bundle-imported": 3, "searched": 0}
        return rep

    ctl = storm_controller(rescale=True, max_decode=3, provision_delay=0.0)
    fleet = FleetScheduler(factory, prefill=1, decode=1, clock=clock,
                           controller=ctl)
    for r in seeded_requests(8, max_new=6):
        assert fleet.submit(r)
    drive(fleet, clock)
    warm = [e for e in fleet.events if "warm-start" in e]
    assert warm and all("bundle-imported=3" in e for e in warm)


def test_replica_role_validation():
    clock = FakeClock()
    with pytest.raises(ValueError):
        FakeReplica(0, "training", clock=clock)
    decode = FakeReplica(1, "decode", clock=clock)
    with pytest.raises(ValueError):
        decode.set_handoff_hook(lambda req: None)


# ---------------------------------------------------------------------------
# end-to-end: real JaxEngines on the pod-sim deployment
# ---------------------------------------------------------------------------

from repro.configs import get_config                     # noqa: E402
from repro.core import Runtime                           # noqa: E402
from repro.launch.mesh import make_host_mesh             # noqa: E402
from repro.launch.serve import JaxEngine, Server         # noqa: E402
from repro.launch.train import make_bundle               # noqa: E402
from repro.serving import Replica                        # noqa: E402


@pytest.fixture(scope="module", params=["qwen2.5-14b", "mamba2-780m"])
def fleet_container(request):
    """One attention arch and one SSM arch: the handoff artifact must
    carry paged KV pages for the former and state/conv recurrent rows
    for the latter."""
    rt = Runtime(host_env={})
    container = rt.deploy(make_bundle(request.param, reduced=True),
                          mesh=make_host_mesh(data=1))
    yield get_config(request.param).reduced(), container
    rt.cleanup()


def test_e2e_fleet_token_identical_to_single_host(fleet_container):
    """Real engines, real handoffs: a 1-prefill + 1-decode fleet emits
    exactly the tokens of one single-host paged chunked server over the
    same seeded request set, and the decode pool drains clean."""
    cfg, container = fleet_container
    clock = FakeClock()

    def factory(role, host_id):
        eng = JaxEngine(cfg, container, slots=2, max_len=32, chunk=4,
                        prefill_mode="chunked", paged=True)
        return Replica(host_id, role, eng, clock=clock)

    fleet = FleetScheduler(factory, prefill=1, decode=1, clock=clock)
    rng = np.random.default_rng(11)
    lens = [4, 6, 9, 3]
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]
    reqs = [Request(rid=i, prompt=p.copy(), max_new=3)
            for i, p in enumerate(prompts)]
    for r in reqs:
        assert fleet.submit(r)
    drive(fleet, clock)
    assert fleet.handoffs == fleet.adoptions == len(lens)

    server = Server(cfg, container, slots=2, max_len=32, chunk=4,
                    prefill_mode="chunked", paged=True)
    for i, p in enumerate(prompts):
        assert server.submit(Request(rid=i, prompt=p.copy(), max_new=3))
    server.run()
    solo = {r.rid: list(r.tokens) for r in server.requests}
    for r in reqs:
        assert r.tokens == solo[r.rid], (r.rid, r.tokens, solo[r.rid])
    for rep in fleet.replicas():
        pool = rep.engine.pool
        assert pool.allocator.available == pool.allocator.capacity
