"""Per-arch smoke tests (assignment): reduced config of the same family,
one forward/train step on CPU, output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, SyntheticStream
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update

SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")


def _batch(cfg, key):
    stream = SyntheticStream(cfg, SMOKE_SHAPE, DataConfig(seed=0))
    return {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    # one full train step (grad + adamw) stays finite and updates params
    def step(p, b):
        (l, m), g = jax.value_and_grad(model.loss_fn, has_aux=True)(p, b)
        new_p, _, stats = adamw_update(g, adamw_init(p), p, AdamWConfig(lr=1e-3))
        return l, new_p, stats

    loss2, new_params, stats = jax.jit(step)(params, batch)
    assert bool(jnp.isfinite(stats["grad_norm"]))
    changed = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_params),
    )
    assert changed, f"{arch}: train step did not update parameters"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_prefill_logits_shape(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    key = jax.random.PRNGKey(2)
    if cfg.is_enc_dec:
        batch = {"frames": jax.random.normal(key, (b, s, cfg.d_model)) * 0.1,
                 "tokens": jnp.ones((b, s), jnp.int32)}
    elif cfg.modality == "vision":
        batch = {"patch_embeds": jax.random.normal(key, (b, cfg.n_patches, cfg.d_model)) * 0.1,
                 "tokens": jnp.ones((b, s - cfg.n_patches), jnp.int32)}
    else:
        batch = {"tokens": jnp.ones((b, s), jnp.int32)}
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (b, model.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[:, : cfg.vocab_size])))
    assert cache, f"{arch}: prefill returned empty cache"


def test_param_counts_match_published_scale():
    """Full configs should land near their nameplate parameter counts."""
    expect = {
        "qwen2-72b": (69e9, 76e9),
        "qwen2.5-14b": (13e9, 16e9),
        "minitron-8b": (7.5e9, 10.5e9),
        "granite-3-8b": (7.5e9, 9e9),
        "mamba2-780m": (0.7e9, 0.9e9),
        # assigned 48L config; hf Moonlight is 27L/15B — we follow the
        # assignment's dims, which total ~28B
        "moonshot-v1-16b-a3b": (26e9, 30e9),
        "phi3.5-moe-42b-a6.6b": (40e9, 44e9),
        "jamba-1.5-large-398b": (350e9, 420e9),
        "llava-next-34b": (32e9, 38e9),
        "whisper-base": (0.05e9, 0.12e9),
    }
    for arch, (lo, hi) in expect.items():
        total, active = ARCHS[arch].param_count()
        assert lo <= total <= hi, f"{arch}: {total/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
        assert active <= total


def test_moe_active_params():
    total, active = ARCHS["moonshot-v1-16b-a3b"].param_count()
    # assigned 48L config: ~4.8B active (routed top-6 + 2 shared + embeddings)
    assert 3e9 <= active <= 5.5e9, f"active {active/1e9:.2f}B"
    assert active < 0.25 * total
