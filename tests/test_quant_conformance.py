"""Quantization conformance grid: int8/fp8 kernels vs the fp32 oracle.

Extends the attention-conformance grid (same `_seed` cell-id recipe, same
paged-layout harness) to the quantized serving path:

  * `quant_matmul` — fp32 activations against per-output-channel int8/fp8
    weights, pinned two ways per cell: tight against the quantized jnp
    ref (same codes, same math) and inside a per-format error ENVELOPE
    against the full-precision fp32 matmul;
  * quantized-KV `decode_attention` / `chunk_attention` — int8/fp8 cache
    pools with per-row fp32 scales riding the kernel meta, over ragged
    geometry x contiguous/paged x windowed/full, each cell pinned tight
    against the quantized ref and enveloped against the fp32 oracle
    computed on the ORIGINAL (pre-quantization) cache;
  * bit-identity pins: W >= kv_len quantized-windowed == quantized-full,
    paged == contiguous on identical codes;
  * tuner synthesizer round-trips for the composite "float32+int8" /
    "float32+fp8" buckets, so autotune can rebuild every quantized
    geometry the serving paths emit.

The per-format envelopes double as documentation: they are the measured
worst-case dequantization error (~3x headroom) for normal-distributed
data, quoted in docs/quantization.md — a kernel change that silently
degrades quantized accuracy fails here before it ships.
"""

import itertools
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.platform import POD_SIM
from repro.kernels.flash_attention_ref import (
    chunk_attention_ref,
    decode_attention_ref,
)
from repro.kernels.ops import _NATIVES_INTERPRET, tuners
from repro.kernels.quant import (
    FP8_MAX,
    INT8_MAX,
    FORMATS,
    dequantize,
    quantize_per_channel,
    storage_dtype,
)
from repro.kernels.quant_matmul_ref import quant_matmul_ref
from repro.tuning import bucket_shapes
from repro.tuning.config import BlockConfig

TOL = 2e-5        # fp32 interpret-mode tolerance (kernel vs quantized ref)
POISON = 50.0     # park-page fill: loud if it ever leaks into an output

# Per-format error envelopes vs the fp32 oracle, for normal-distributed
# inputs at the grid's sizes.  Measured worst cases: attention int8
# ~0.02-0.05, fp8 ~0.05-0.1; matmul (D=64 contraction) int8 ~0.1, fp8
# ~0.5.  The envelopes carry ~3x headroom — loose enough to be stable,
# tight enough that a broken dequant (wrong scale, wrong axis, missing
# clip) blows straight through them.
ATTN_ENVELOPE = {"int8": 0.12, "fp8": 0.30}
QMM_ENVELOPE = {"int8": 0.35, "fp8": 1.50}


def _seed(*parts) -> int:
    """Cell-id -> stable 31-bit seed (see test_attention_conformance)."""
    return zlib.crc32(":".join(map(str, parts)).encode()) & 0x7FFFFFFF


def _mk(key, shape, dtype="float32"):
    return jax.random.normal(key, shape, jnp.dtype(dtype))


def _close(got, want, scale=1):
    tol = scale * TOL
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


def _envelope(got, want, fmt, table):
    err = float(np.max(np.abs(
        np.asarray(got, np.float32) - np.asarray(want, np.float32))))
    assert err <= table[fmt], (
        f"{fmt} max-abs error {err:.4f} exceeds the {table[fmt]} envelope")
    return err


def _quant_cache(x, fmt):
    """Quantize a (B, S, KV, Dh) fp32 cache per batch row — the same
    symmetric amax scaling `layers._quant_update` applies on cache write,
    with the (B,) fp32 scale the serving path threads as a cache leaf."""
    m = INT8_MAX if fmt == "int8" else FP8_MAX
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=(1, 2, 3)), 1e-6)
    s = (amax / m).astype(jnp.float32)
    y = x.astype(jnp.float32) / s.reshape(-1, 1, 1, 1)
    if fmt == "int8":
        q = jnp.clip(jnp.round(y), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    else:
        q = jnp.clip(y, -FP8_MAX, FP8_MAX).astype(storage_dtype(fmt))
    return q, s


def _paged_layout(k, v, page, seed):
    """Shuffled-permutation page pools with a poisoned park page 0 (see
    test_attention_conformance._paged_layout) — here the pools inherit
    the QUANTIZED storage dtype, so the kernels' int8/fp8 page DMAs and
    in-VMEM dequant are what is under test."""
    b, s = k.shape[:2]
    assert s % page == 0
    n = s // page
    npages = 1 + b * n
    perm = np.random.default_rng(seed).permutation(np.arange(1, npages))
    bt = jnp.asarray(perm.reshape(b, n), jnp.int32)
    pool_shape = (npages, page) + k.shape[2:]
    pool_k = jnp.full(pool_shape, POISON, k.dtype)
    pool_v = jnp.full(pool_shape, POISON, v.dtype)
    kb = k.reshape(b * n, page, *k.shape[2:])
    vb = v.reshape(b * n, page, *v.shape[2:])
    pool_k = pool_k.at[bt.reshape(-1)].set(kb)
    pool_v = pool_v.at[bt.reshape(-1)].set(vb)
    return pool_k, pool_v, bt


# ---------------------------------------------------------------------------
# quant_matmul: ragged geometry x format, kernel == ref, ref ~ fp32
# ---------------------------------------------------------------------------

# (t, d, f) — token extents off the 8-wide tiles, rectangular weights
QMM_GEOMS = [
    (8, 32, 32),       # tile-exact
    (60, 64, 64),      # multi-tile with tail rows
    (7, 48, 32),       # sub-tile token count
    (16, 32, 64),      # wide output, the decode microbatch shape
]


def _qmm_args(geom, fmt):
    t, d, f = geom
    ks = jax.random.split(jax.random.PRNGKey(_seed("qmm", geom, fmt)), 2)
    x = _mk(ks[0], (t, d))
    w = _mk(ks[1], (d, f))
    qw, scale = quantize_per_channel(w, axis=-2, fmt=fmt)
    return x, w, qw, scale


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("geom", QMM_GEOMS, ids=lambda g: "x".join(map(str, g)))
def test_quant_matmul_grid(geom, fmt):
    x, w, qw, scale = _qmm_args(geom, fmt)
    out = _NATIVES_INTERPRET["quant_matmul"](x, qw, scale)
    _close(out, quant_matmul_ref(x, qw, scale), scale=5)
    _envelope(out, x @ w, fmt, QMM_ENVELOPE)


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("geom", QMM_GEOMS, ids=lambda g: "x".join(map(str, g)))
def test_quant_matmul_equals_dequantized_einsum(geom, fmt):
    """The kernel's fused dequant must equal materialize-then-matmul on
    the same codes — the storage-form weights are semantics-free layout."""
    x, _, qw, scale = _qmm_args(geom, fmt)
    out = _NATIVES_INTERPRET["quant_matmul"](x, qw, scale)
    dense = x @ dequantize(qw, scale, axis=-2, dtype=jnp.float32)
    _close(out, dense, scale=5)


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("geom", QMM_GEOMS, ids=lambda g: "x".join(map(str, g)))
def test_quant_matmul_synth_roundtrip(geom, fmt):
    x, _, qw, scale = _qmm_args(geom, fmt)
    _roundtrip("quant_matmul", (x, qw, scale))


# ---------------------------------------------------------------------------
# decode_attention: geometry x layout x window x format
# ---------------------------------------------------------------------------

# (b, smax, h, kv, dh, pos) — reused from the attention grid: vector and
# scalar positions, GQA groups, first/last-slot edges
DECODE_GEOMS = [
    (2, 32, 2, 2, 8, (5, 17)),
    (1, 24, 2, 1, 8, 10),
    (3, 48, 4, 2, 16, (0, 47, 20)),
]

WINDOWS = ("win", "full")


def _decode_args(geom, fmt, tag="qdecode"):
    b, smax, h, kv, dh, pos = geom
    ks = jax.random.split(jax.random.PRNGKey(_seed(tag, geom, fmt)), 3)
    q = _mk(ks[0], (b, 1, h, dh))
    k = _mk(ks[1], (b, smax, kv, dh))
    v = _mk(ks[2], (b, smax, kv, dh))
    qk, k_scale = _quant_cache(k, fmt)
    qv, v_scale = _quant_cache(v, fmt)
    return q, k, v, qk, qv, k_scale, v_scale, jnp.asarray(pos, jnp.int32)


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("wtag", WINDOWS)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("geom", DECODE_GEOMS, ids=lambda g: f"smax{g[1]}b{g[0]}")
def test_quant_decode_grid(geom, layout, wtag, fmt):
    q, k, v, qk, qv, ks_, vs_, pos = _decode_args(geom, fmt)
    smax = geom[1]
    w = jnp.asarray(8 if wtag == "win" else smax, jnp.int32)
    want = decode_attention_ref(q, k, v, pos, None, w)   # fp32 oracle
    if layout == "paged":
        pool_k, pool_v, bt = _paged_layout(
            qk, qv, 8, _seed("qdecode", geom, fmt, "pool"))
        out = _NATIVES_INTERPRET["decode_attention"](
            q, pool_k, pool_v, pos, bt, w, ks_, vs_)
        qref = decode_attention_ref(q, pool_k, pool_v, pos, bt, w, ks_, vs_)
        full = _NATIVES_INTERPRET["decode_attention"](
            q, pool_k, pool_v, pos, bt, None, ks_, vs_)
    else:
        out = _NATIVES_INTERPRET["decode_attention"](
            q, qk, qv, pos, None, w, ks_, vs_)
        qref = decode_attention_ref(q, qk, qv, pos, None, w, ks_, vs_)
        full = _NATIVES_INTERPRET["decode_attention"](
            q, qk, qv, pos, None, None, ks_, vs_)
    _close(out, qref, scale=5)                  # kernel == quantized ref
    _envelope(out, want, fmt, ATTN_ENVELOPE)    # quantization error bound
    if wtag == "full":                          # W >= smax: same skip set,
        assert np.array_equal(np.asarray(out), np.asarray(full))


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("geom", DECODE_GEOMS, ids=lambda g: f"smax{g[1]}b{g[0]}")
def test_quant_decode_paged_matches_contiguous(geom, fmt):
    """Identical codes through the paged DMA route and the contiguous
    route must agree to fp32 interpret tolerance — the block table only
    changes the gather, never the dequant math."""
    q, _, _, qk, qv, ks_, vs_, pos = _decode_args(geom, fmt, tag="qd-layout")
    cont = _NATIVES_INTERPRET["decode_attention"](
        q, qk, qv, pos, None, None, ks_, vs_)
    pool_k, pool_v, bt = _paged_layout(
        qk, qv, 8, _seed("qd-layout", geom, fmt, "pool"))
    paged = _NATIVES_INTERPRET["decode_attention"](
        q, pool_k, pool_v, pos, bt, None, ks_, vs_)
    _close(paged, cont, scale=5)


def test_quant_decode_park_page_is_inert():
    """Parked (poisoned) pages past the written prefix must stay
    unobservable in the quantized path too: POISON codes dequantize to a
    loud 50*scale, so any mask slip shows up immediately."""
    geom = (2, 32, 2, 2, 8, (5, 9))
    q, k, v, qk, qv, ks_, vs_, pos = _decode_args(geom, "int8", tag="qpark")
    pool_k, pool_v, bt = _paged_layout(qk, qv, 8, _seed("qpark", "pool"))
    bt = bt.at[:, 2:].set(0)                    # park everything past page 1
    out = _NATIVES_INTERPRET["decode_attention"](
        q, pool_k, pool_v, pos, bt, None, ks_, vs_)
    want = decode_attention_ref(q, k, v, pos)   # pos < 16: prefix only
    assert np.all(np.isfinite(np.asarray(out)))
    _envelope(out, want, "int8", ATTN_ENVELOPE)


@pytest.mark.parametrize("fmt", FORMATS)
def test_quant_decode_scalar_scale_broadcasts(fmt):
    """A () scale must mean the same thing as the equal-valued (B,)
    vector — both ride the kernel meta, one broadcast earlier."""
    geom = (2, 32, 2, 2, 8, (5, 17))
    q, _, _, qk, qv, _, _, pos = _decode_args(geom, fmt, tag="qscalar")
    s = jnp.asarray(0.03, jnp.float32)
    vec = jnp.full((2,), 0.03, jnp.float32)
    a = _NATIVES_INTERPRET["decode_attention"](
        q, qk, qv, pos, None, None, s, s)
    b = _NATIVES_INTERPRET["decode_attention"](
        q, qk, qv, pos, None, None, vec, vec)
    _close(a, b, scale=5)


# ---------------------------------------------------------------------------
# chunk_attention: geometry x layout x window x format
# ---------------------------------------------------------------------------

# (c, smax, h, kv, dh, pos) — chunk at the window start, mid-cache, zero
CHUNK_GEOMS = [
    (8, 32, 2, 2, 8, 8),
    (16, 48, 2, 1, 8, 16),
    (8, 24, 4, 2, 16, 0),
]


def _chunk_args(geom, fmt, tag="qchunk"):
    c, smax, h, kv, dh, pos = geom
    ks = jax.random.split(jax.random.PRNGKey(_seed(tag, geom, fmt)), 3)
    q = _mk(ks[0], (1, c, h, dh))
    k = _mk(ks[1], (1, smax, kv, dh))
    v = _mk(ks[2], (1, smax, kv, dh))
    qk, k_scale = _quant_cache(k, fmt)
    qv, v_scale = _quant_cache(v, fmt)
    return q, k, v, qk, qv, k_scale, v_scale, pos


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("wtag", WINDOWS)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("geom", CHUNK_GEOMS, ids=lambda g: f"c{g[0]}pos{g[5]}")
def test_quant_chunk_grid(geom, layout, wtag, fmt):
    q, k, v, qk, qv, ks_, vs_, pos = _chunk_args(geom, fmt)
    c, smax = geom[0], geom[1]
    w = jnp.asarray(c if wtag == "win" else smax, jnp.int32)
    want = chunk_attention_ref(q, k, v, pos, None, w)    # fp32 oracle
    if layout == "paged":
        page = c                                # serving invariant: page == C
        pool_k, pool_v, bt = _paged_layout(
            qk, qv, page, _seed("qchunk", geom, fmt, "pool"))
        out = _NATIVES_INTERPRET["chunk_attention"](
            q, pool_k, pool_v, pos, bt, w, ks_, vs_)
        qref = chunk_attention_ref(q, pool_k, pool_v, pos, bt, w, ks_, vs_)
        full = _NATIVES_INTERPRET["chunk_attention"](
            q, pool_k, pool_v, pos, bt, None, ks_, vs_)
    else:
        out = _NATIVES_INTERPRET["chunk_attention"](
            q, qk, qv, pos, None, w, ks_, vs_)
        qref = chunk_attention_ref(q, qk, qv, pos, None, w, ks_, vs_)
        full = _NATIVES_INTERPRET["chunk_attention"](
            q, qk, qv, pos, None, None, ks_, vs_)
    _close(out, qref, scale=5)
    _envelope(out, want, fmt, ATTN_ENVELOPE)
    if wtag == "full":
        assert np.array_equal(np.asarray(out), np.asarray(full))


# ---------------------------------------------------------------------------
# tuner synthesizer round-trip: quantized composite buckets rebuildable
# ---------------------------------------------------------------------------

def _no_scalars(shapes: str) -> str:
    return ",".join(p for p in shapes.split(",")
                    if p and p != "scalar" and "x" in p)


def _roundtrip(op, args, expect_feasible=True):
    t = tuners()[op]
    shapes, dtype = bucket_shapes(args)
    # composite buckets carry the STORAGE dtype suffix, not the format tag
    storage_names = {str(jnp.dtype(storage_dtype(f))) for f in FORMATS}
    assert "+" not in str(dtype) or str(dtype).split("+")[1] in storage_names
    synth = t.args_from_shapes(POD_SIM, shapes, dtype)
    assert synth is not None, f"{op}: no synth for bucket {shapes}"
    shapes2, dtype2 = bucket_shapes(synth)
    assert _no_scalars(shapes2) == _no_scalars(shapes), (shapes2, shapes)
    assert dtype2 == dtype
    feasible = [
        cfg for cfg in (
            BlockConfig.make(**dict(zip(t.space, vals)))
            for vals in itertools.product(*t.space.values()))
        if t.feasible(cfg, POD_SIM, synth)
    ]
    if expect_feasible:
        assert feasible, f"{op}: no feasible config for bucket {shapes}"


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("geom", DECODE_GEOMS, ids=lambda g: f"smax{g[1]}b{g[0]}")
def test_quant_decode_synth_roundtrip(geom, layout, fmt):
    q, _, _, qk, qv, ks_, vs_, pos = _decode_args(geom, fmt, tag="qd-rt")
    if layout == "paged":
        page = 16                               # >= the space's smallest bk
        s = -(-qk.shape[1] // page) * page
        pad = ((0, 0), (0, s - qk.shape[1]), (0, 0), (0, 0))
        pool_k, pool_v, bt = _paged_layout(
            jnp.pad(qk, pad), jnp.pad(qv, pad), page,
            _seed("qd-rt", geom, fmt, "pool"))
        _roundtrip("decode_attention",
                   (q, pool_k, pool_v, pos, bt, None, ks_, vs_))
    else:
        _roundtrip("decode_attention",
                   (q, qk, qv, pos, None, None, ks_, vs_))


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("geom", CHUNK_GEOMS, ids=lambda g: f"c{g[0]}pos{g[5]}")
def test_quant_chunk_synth_roundtrip(geom, layout, fmt):
    q, _, _, qk, qv, ks_, vs_, pos = _chunk_args(geom, fmt, tag="qc-rt")
    w = jnp.asarray(16, jnp.int32)
    ok = geom[0] >= 16                          # smallest chunk block_q is 16
    if layout == "paged":
        page = max(geom[0], 16)
        s = -(-qk.shape[1] // page) * page
        pad = ((0, 0), (0, s - qk.shape[1]), (0, 0), (0, 0))
        pool_k, pool_v, bt = _paged_layout(
            jnp.pad(qk, pad), jnp.pad(qv, pad), page,
            _seed("qc-rt", geom, fmt, "pool"))
        _roundtrip("chunk_attention",
                   (q, pool_k, pool_v, pos, bt, w, ks_, vs_),
                   expect_feasible=ok)
    else:
        _roundtrip("chunk_attention", (q, qk, qv, pos, None, w, ks_, vs_),
                   expect_feasible=ok)
