"""Quantization stack units: shared numerics, checkpoint schema, serving.

Complements the conformance grid (tests/test_quant_conformance.py) with
the non-kernel layers of the quantized serving path:

  * `repro.kernels.quant` — the one shared numerics module: symmetric
    int8 (clip at +/-127, never -128), fp8 e4m3fn grids, per-channel
    scales, the EPS floor, and the compress/decompress aliases the DCN
    gradient compressor rides;
  * the checkpoint schema — per-channel (axis=-2) weight scales, the
    name-aware quantizable filter, transparent dequantize on restore and
    the `{"q", "scale"}` storage form a quantized deploy consumes;
  * serving admission — `estimate_footprint` priced from abstract shapes
    and `DeploymentRejected` firing BEFORE allocation, with the int8
    deploy fitting where fp32 is rejected;
  * `calibrate_dtype_penalty` — the measured quantized<->full-precision
    borrow penalty replacing the fixed DTYPE_PENALTY guess.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    quantize_tree,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import ARCHS
from repro.core import Runtime
from repro.kernels.quant import (
    EPS,
    FORMATS,
    FP8_DTYPE,
    FP8_MAX,
    INT8_MAX,
    compress_int8,
    decompress_int8,
    dequantize,
    quantize,
    quantize_per_channel,
    storage_dtype,
)
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import (
    DeploymentRejected,
    JaxEngine,
    Request,
    Server,
    estimate_footprint,
)
from repro.launch.train import make_bundle
from repro.models import build_model
from repro.tuning import calibrate_dtype_penalty

# ---------------------------------------------------------------------------
# shared numerics
# ---------------------------------------------------------------------------


def _x(shape=(32, 16), seed=0, scale=3.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape)


def test_int8_roundtrip_error_bound():
    x = _x()
    q, s = quantize(x, "int8")
    assert q.dtype == jnp.int8
    err = jnp.abs(dequantize(q, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-7


def test_int8_clip_symmetric_never_minus_128():
    """-128 has no positive counterpart; the symmetric clip must never
    produce it, so negating codes is always exact."""
    x = jnp.asarray([-10.0, 10.0, -9.99, 5.0])
    q, s = quantize(x, "int8")
    qn, sn = quantize(-x, "int8")
    assert int(q.min()) >= -127 and int(q.max()) <= 127
    assert np.array_equal(np.asarray(qn), -np.asarray(q))
    assert float(sn) == float(s)


def test_fp8_storage_and_scale():
    x = _x(seed=1)
    q, s = quantize(x, "fp8")
    assert q.dtype == FP8_DTYPE
    assert float(s) == pytest.approx(float(jnp.abs(x).max()) / FP8_MAX)
    # e4m3fn: ~2^-4 relative error near the grid, absolute floor ~scale
    err = jnp.abs(dequantize(q, s) - x)
    assert float(err.max()) <= 0.08 * float(jnp.abs(x).max())


def test_per_channel_axis_minus2_schema():
    """The checkpoint convention: reduce axis -2 (the contraction dim of
    a (D, F) weight) -> one fp32 scale per OUTPUT channel, and for
    layer-stacked (NB, D, F) leaves the stack axis survives in the scale
    so it scans alongside the codes."""
    w = _x((8, 16), seed=2)
    q, s = quantize_per_channel(w, axis=-2, fmt="int8")
    assert s.shape == (16,) and s.dtype == jnp.float32
    back = dequantize(q, s, axis=-2)
    assert float(jnp.abs(back - w).max()) <= float(s.max()) / 2 + 1e-7
    ws = _x((3, 8, 16), seed=3)
    qs, ss = quantize_per_channel(ws, axis=-2, fmt="int8")
    assert ss.shape == (3, 16)          # leading stack axis preserved


def test_zero_tensor_quantizes_safely():
    q, s = quantize(jnp.zeros((4, 4)), "int8")
    assert float(s) > 0 and float(s) <= EPS / INT8_MAX * 1.01
    assert not np.any(np.asarray(q))
    assert np.all(np.isfinite(np.asarray(dequantize(q, s))))


def test_unknown_format_raises():
    with pytest.raises(ValueError):
        storage_dtype("int4")
    with pytest.raises(ValueError):
        quantize(_x(), "int4")


def test_formats_vocabulary():
    assert FORMATS == ("int8", "fp8")
    assert storage_dtype("int8") == jnp.int8
    assert storage_dtype("fp8") == FP8_DTYPE


# ---------------------------------------------------------------------------
# DCN gradient compressor: shared module is THE implementation
# ---------------------------------------------------------------------------


def test_collectives_import_shared_compressor():
    """Regression pin: the hierarchical all-reduce's int8 DCN leg must
    keep compressing through the shared quant module (the extraction
    target), not a private reimplementation."""
    from repro.distributed import collectives

    assert collectives._compress_int8 is compress_int8


def test_dcn_compressor_roundtrip_bound():
    g = _x((64,), seed=4, scale=0.02)
    q, s = compress_int8(g)
    assert q.dtype == jnp.int8
    err = jnp.abs(decompress_int8(q, s) - g)
    # the bound the multi-process all-reduce test asserts end to end
    assert float(err.max()) <= float(jnp.abs(g).max()) / 127


def test_compress_aliases_are_int8_quantize():
    x = _x(seed=5)
    qa, sa = compress_int8(x)
    qb, sb = quantize(x, "int8")
    assert np.array_equal(np.asarray(qa), np.asarray(qb))
    assert float(sa) == float(sb)


# ---------------------------------------------------------------------------
# checkpoint schema: per-channel scales, name filter, storage form
# ---------------------------------------------------------------------------


def _ckpt_tree(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    return {
        "embed": {"tok": jax.random.normal(ks[0], (16, 8))},
        "decoder": {
            "attn": {"wq": jax.random.normal(ks[1], (2, 8, 8))},   # stacked
            "mlp": {"w_in": jax.random.normal(ks[2], (2, 8, 16))},
            "norm": {"scale": jax.random.normal(ks[3], (2, 8))},   # gains
            "moe": {"w_up": jax.random.normal(ks[4], (2, 8, 8))},  # excluded
            "bias": {"b": jnp.zeros((2, 8))},
        },
        "step": jnp.int32(3),
    }


def test_quantized_checkpoint_dequantizes_on_restore(tmp_path):
    tree = _ckpt_tree()
    save_checkpoint(tmp_path, 1, tree, quantize="int8")
    restored, step = restore_checkpoint(tmp_path, tree)
    assert step == 1
    # quantized leaves come back dense in their original dtype, within
    # the per-channel step; untouched leaves are bit-exact
    wq = restored["decoder"]["attn"]["wq"]
    assert wq.shape == (2, 8, 8) and wq.dtype == jnp.float32
    assert float(jnp.abs(wq - tree["decoder"]["attn"]["wq"]).max()) < 0.05
    np.testing.assert_array_equal(
        np.asarray(restored["decoder"]["moe"]["w_up"]),
        np.asarray(tree["decoder"]["moe"]["w_up"]))
    np.testing.assert_array_equal(
        np.asarray(restored["decoder"]["norm"]["scale"]),
        np.asarray(tree["decoder"]["norm"]["scale"]))
    assert int(restored["step"]) == 3


def test_quantized_checkpoint_storage_form(tmp_path):
    """dequantize=False: quantizable leaves restore as {"q", "scale"}
    dicts — codes in the storage dtype, axis=-2 per-channel scales with
    the layer-stack axis preserved."""
    tree = _ckpt_tree(seed=1)
    save_checkpoint(tmp_path, 2, tree, quantize="fp8")
    restored, _ = restore_checkpoint(tmp_path, tree, dequantize=False)
    wq = restored["decoder"]["attn"]["wq"]
    assert set(wq) == {"q", "scale"}
    assert wq["q"].shape == (2, 8, 8) and wq["q"].dtype == FP8_DTYPE
    assert wq["scale"].shape == (2, 8) and wq["scale"].dtype == jnp.float32
    tok = restored["embed"]["tok"]
    assert set(tok) == {"q", "scale"} and tok["scale"].shape == (8,)
    # excluded subtrees and non-weight leaves stay plain arrays
    assert isinstance(restored["decoder"]["moe"]["w_up"], jnp.ndarray)
    assert isinstance(restored["decoder"]["bias"]["b"], jnp.ndarray)
    assert isinstance(restored["decoder"]["norm"]["scale"], jnp.ndarray)


def test_quantize_tree_matches_checkpoint_storage_form(tmp_path):
    """The in-memory quantizer (the quantized deploy's path) must pick
    the same leaves and produce the same codes as a quantized save
    followed by a storage-form restore."""
    tree = _ckpt_tree(seed=2)
    save_checkpoint(tmp_path, 3, tree, quantize="int8")
    from_ckpt, _ = restore_checkpoint(tmp_path, tree, dequantize=False)
    in_mem = quantize_tree(tree, "int8")
    def flatten(t, prefix=""):
        if isinstance(t, dict):
            out = {}
            for k, v in t.items():
                out.update(flatten(v, f"{prefix}/{k}"))
            return out
        return {prefix: t}
    a, b = flatten(from_ckpt), flatten(in_mem)
    assert set(a) == set(b)
    for path in a:
        np.testing.assert_array_equal(np.asarray(a[path]),
                                      np.asarray(b[path]), err_msg=path)


def test_quantize_tree_rejects_unknown_format():
    with pytest.raises(ValueError):
        quantize_tree(_ckpt_tree(), "int4")


# ---------------------------------------------------------------------------
# serving admission: footprint pricing + budget rejection before alloc
# ---------------------------------------------------------------------------

ARCH = "qwen2.5-14b"


@pytest.fixture(scope="module")
def served_container():
    rt = Runtime(host_env={})
    container = rt.deploy(make_bundle(ARCH, reduced=True),
                          mesh=make_host_mesh(data=1))
    yield ARCHS[ARCH].reduced(), container
    rt.cleanup()


def _footprints(cfg):
    fp32 = estimate_footprint(build_model(cfg), slots=2, max_len=32)
    int8 = estimate_footprint(build_model(cfg, kv_quantize="int8"),
                              slots=2, max_len=32, quantize="int8")
    return fp32, int8


def test_estimate_footprint_quantized_shrinks():
    cfg = ARCHS[ARCH].reduced()
    fp32, int8 = _footprints(cfg)
    # 4B -> 1B codes + fp32 scales: ~3x on weights, ~3.5x on KV
    assert int8["weight_bytes"] * 2.5 < fp32["weight_bytes"]
    assert int8["kv_bytes"] * 2.5 < fp32["kv_bytes"]
    assert int8["total_bytes"] < fp32["total_bytes"]
    assert fp32["quantize"] == "none" and int8["quantize"] == "int8"
    for fp in (fp32, int8):
        assert fp["total_bytes"] == fp["weight_bytes"] + fp["kv_bytes"]


def test_budget_rejects_fp32_admits_int8(served_container):
    """The deployment scenario the tentpole exists for: a budget between
    the two footprints rejects fp32 BEFORE any allocation and admits the
    int8 deploy of the same config."""
    cfg, container = served_container
    fp32, int8 = _footprints(cfg)
    budget = (fp32["total_bytes"] + int8["total_bytes"]) // 2
    with pytest.raises(DeploymentRejected) as ei:
        JaxEngine(cfg, container, slots=2, max_len=32, chunk=4,
                  memory_budget=budget)
    assert ei.value.footprint["total_bytes"] == fp32["total_bytes"]
    assert ei.value.budget == budget
    assert str(budget) in str(ei.value) or f"{budget:,}" in str(ei.value)
    eng = JaxEngine(cfg, container, slots=2, max_len=32, chunk=4,
                    quantize="int8", memory_budget=budget)
    assert eng.footprint["total_bytes"] <= budget
    # weights really are storage-form subtrees
    w_in = eng.params["decoder"]["p0"]["mlp"]["w_in"]
    assert set(w_in) == {"q", "scale"} and w_in["q"].dtype == jnp.int8


def test_quantized_server_completes(served_container):
    """An int8 server completes real traffic end to end — the tokens are
    not pinned to the fp32 reference (quantization legitimately moves
    near-ties), table7 quantifies the quality delta instead."""
    cfg, container = served_container
    server = Server(cfg, container, slots=2, max_len=32, chunk=4,
                    prefill_mode="chunked", paged=True, quantize="int8")
    rng = np.random.default_rng(7)
    for rid, plen in enumerate((4, 6, 3)):
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        assert server.submit(Request(rid=rid, prompt=prompt, max_new=3))
    server.run()
    assert all(r.done for r in server.requests)
    assert all(len(r.tokens) == 3 for r in server.requests)
    assert server.engine.quantize == "int8"
    # the KV pools really store int8 codes with fp32 scale leaves
    entry = next(iter(server.engine.cache.values()))
    assert entry["k"].dtype == jnp.int8
    assert entry["k_scale"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# calibrated dtype-crossing borrow penalty
# ---------------------------------------------------------------------------


def test_calibrate_penalty_none_without_cross_pairs():
    assert calibrate_dtype_penalty({}) is None
    assert calibrate_dtype_penalty(
        {("64x64", "float32"): 10.0, ("32x32", "float32"): 5.0}) is None


def test_calibrate_penalty_median_of_observed_ratios():
    measured = {
        ("64x64,64x64,64", "float32"): 40.0,
        ("64x64,64x64,64", "float32+int8"): 10.0,   # 4x -> 2 doublings
        ("32x32,32x32,32", "float32"): 16.0,
        ("32x32,32x32,32", "float32+int8"): 2.0,    # 8x -> 3 doublings
    }
    assert calibrate_dtype_penalty(measured) == pytest.approx(2.5)


def test_calibrate_penalty_clamped():
    near = {("s", "a"): 10.0, ("s", "b"): 10.5}      # ~0.07 doublings
    assert calibrate_dtype_penalty(near) == 1.0
    far = {("s", "a"): 1.0, ("s", "b"): 5000.0}      # ~12 doublings
    assert calibrate_dtype_penalty(far) == 8.0
