"""Serving engine: chunked-prefill equivalence, scheduler policy, e2e.

Three layers, cheapest first:

  * numeric — `Model.prefill_into` chunk-by-chunk into one slot of a
    batched cache must equal whole-sequence `prefill` AND the old
    prefill-by-decode loop, including partial final chunks and slot
    reuse over stale state;
  * policy — `Scheduler` driven by a fake engine and a fake clock:
    admission control, FCFS, interleave, refill, TTFT accounting, and
    the compiled-step invariants (prefill_steps == ceil(L/C),
    decode_steps == max_new - 1 on the chunked path);
  * end-to-end — a real `Server` on the pod-sim deployment: every
    request completes and its greedy tokens match an unbatched
    single-request reference.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core import Runtime
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import (
    DECODING,
    PREFILLING,
    REJECT_QUEUE_FULL,
    REJECT_TOO_LONG,
    SERVING_STATS_SCHEMA,
    PagedPool,
    Request,
    Scheduler,
    Server,
)
from repro.launch.train import make_bundle
from repro.models import build_model

FAMILIES = [
    "qwen2.5-14b",            # dense GQA
    "mamba2-780m",            # pure SSM (state injection + conv tail)
    "jamba-1.5-large-398b",   # hybrid attn/mamba/moe
]


# ---------------------------------------------------------------------------
# numeric: chunked prefill == whole prefill == prefill-by-decode
# ---------------------------------------------------------------------------

def _chunked_prefill(model, params, prompt, cache, slot, chunk):
    """Drive prefill_into the way JaxEngine does: C-wide windows, the
    last one padded; returns (last-token logits (vocab,), cache)."""
    prefill = jax.jit(model.prefill_into)
    logits = None
    for start in range(0, len(prompt), chunk):
        n = min(chunk, len(prompt) - start)
        buf = np.zeros((1, chunk), np.int32)
        buf[0, :n] = prompt[start : start + n]
        logits, cache = prefill(params, jnp.asarray(buf), cache,
                                jnp.int32(slot), jnp.int32(start), jnp.int32(n))
    return np.asarray(logits[0]), cache


@pytest.mark.parametrize("arch", FAMILIES)
def test_chunked_prefill_matches_whole_prefill(arch):
    """ceil(14/4) chunks (partial tail) into slot 1 of a 3-slot cache ==
    whole-sequence prefill — after the slot served a longer prompt, so
    the pos==0 chunk must also reset the stale recurrent state."""
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    L, chunk, slots, max_len = 14, 4, 3, 32
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (L,), 0, cfg.vocab_size),
        np.int32)

    logits_full, _ = jax.jit(model.prefill)(params, {"tokens": prompt[None]})
    want = np.asarray(logits_full)         # prefill returns (b, vocab): last token

    cache = model.init_cache(slots, max_len)
    stale = np.asarray(
        jax.random.randint(jax.random.PRNGKey(2), (max_len - 2,), 0,
                           cfg.vocab_size), np.int32)
    _, cache = _chunked_prefill(model, params, stale, cache, 1, chunk)
    got, cache = _chunked_prefill(model, params, prompt, cache, 1, chunk)
    np.testing.assert_allclose(got[None], want, atol=5e-4, rtol=5e-4)

    # continuation: one batched decode tick in the slot == the reference
    nxt = int(np.argmax(got))
    tok = np.zeros((slots, 1), np.int32)
    tok[1, 0] = nxt
    pos = np.full(slots, max_len - 1, np.int32)
    pos[1] = L
    act = np.zeros(slots, bool)
    act[1] = True
    logits_dec, _ = jax.jit(model.decode)(
        params, jnp.asarray(tok), cache, jnp.asarray(pos), jnp.asarray(act))
    ref_full, _ = jax.jit(model.prefill)(
        params, {"tokens": np.concatenate([prompt, [nxt]])[None]})
    np.testing.assert_allclose(np.asarray(logits_dec[1])[None],
                               np.asarray(ref_full), atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "mamba2-780m"])
def test_chunked_prefill_matches_prefill_by_decode(arch):
    """The new path == the old server's loop: prompt pushed one token at
    a time through the decode step into the same slot, then the last
    token's logits read off the final tick."""
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    L, chunk, slots, max_len = 9, 4, 2, 16
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(3), (L,), 0, cfg.vocab_size),
        np.int32)

    got, _ = _chunked_prefill(model, params, prompt, model.init_cache(slots, max_len),
                              0, chunk)

    cache = model.init_cache(slots, max_len)
    decode = jax.jit(model.decode)
    logits = None
    for i in range(L):
        tok = np.zeros((slots, 1), np.int32)
        tok[0, 0] = int(prompt[i])
        pos = np.full(slots, max_len - 1, np.int32)
        pos[0] = i
        act = np.zeros(slots, bool)
        act[0] = True
        logits, cache = decode(params, jnp.asarray(tok), cache,
                               jnp.asarray(pos), jnp.asarray(act))
    np.testing.assert_allclose(np.asarray(logits[0]), got, atol=5e-4, rtol=5e-4)


# ---------------------------------------------------------------------------
# policy: Scheduler against a fake engine + fake clock (no jax)
# ---------------------------------------------------------------------------

class FakeClock:
    """Reads return the current time; the fake engine advances it one
    unit per compiled step, so TTFT == compiled steps before the first
    token."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class FakeEngine:
    """Duck-typed JaxEngine: deterministic logits (argmax == fed token +
    1 mod vocab), a call log, and a clock hook — everything the
    scheduler touches and nothing jax."""

    vocab = 16

    def __init__(self, *, slots=2, max_len=32, chunk=4,
                 prefill_mode="chunked", clock=None, paged=False,
                 num_pages=None):
        self.slots = slots
        self.max_len = max_len
        self.chunk = chunk
        self.prefill_mode = prefill_mode
        self.clock = clock
        self.paged = paged
        if paged:
            self.pool = PagedPool(slots, max_len, chunk, num_pages)
        self.log = []

    @property
    def prefill_unit(self):
        return self.chunk if self.prefill_mode == "chunked" else 1

    def _logits(self, token):
        v = np.zeros(self.vocab)
        v[(int(token) + 1) % self.vocab] = 1.0
        return v

    def prefill_step(self, slot, tokens, pos):
        self.log.append(("prefill", slot, len(tokens), pos))
        if self.clock is not None:
            self.clock.t += 1.0
        return self._logits(tokens[-1]) if self.prefill_mode == "chunked" else None

    def decode_step(self, tokens, pos, active):
        self.log.append(("decode", tuple(np.flatnonzero(active))))
        if self.clock is not None:
            self.clock.t += 1.0
        out = np.zeros((self.slots, self.vocab))
        for s in np.flatnonzero(active):
            out[s] = self._logits(tokens[s, 0])
        return out


def _mk(rid, plen, max_new=3):
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32), max_new=max_new)


def _drain(sched, max_ticks=10_000):
    while not sched.idle:
        sched.tick()
        max_ticks -= 1
        assert max_ticks > 0, "scheduler failed to drain"


def test_admission_rejects_on_queue_depth():
    sched = Scheduler(FakeEngine(slots=1), queue_depth=2)
    assert sched.submit(_mk(0, 4))
    assert sched.submit(_mk(1, 4))
    assert not sched.submit(_mk(2, 4))
    assert not sched.submit(_mk(3, 4))
    assert sched.rejected[REJECT_QUEUE_FULL] == 2
    _drain(sched)
    assert sched.completed == 2


def test_admission_rejects_unservable_budget():
    """A request whose prompt+generation window cannot fit one slot is
    bounced at submit — never queued, never deadlocked."""
    sched = Scheduler(FakeEngine(chunk=4, max_len=16))
    assert not sched.submit(_mk(0, 10, max_new=8))    # 10 + 8 > 16
    assert not sched.submit(_mk(1, 0))                # empty prompt
    assert sched.submit(_mk(2, 15, max_new=1))        # exactly fits: 16
    assert sched.rejected[REJECT_TOO_LONG] == 2

    # the baseline path re-feeds the last prompt token, costing one slot
    base = Scheduler(FakeEngine(chunk=4, max_len=16, prefill_mode="decode"))
    assert not base.submit(_mk(0, 15, max_new=1))     # 15 + 1 + 1 > 16
    assert base.submit(_mk(1, 14, max_new=1))


def test_max_new_clamped_to_cap():
    sched = Scheduler(FakeEngine(), max_new_cap=3)
    req = _mk(0, 4, max_new=100)
    assert sched.submit(req)
    assert req.max_new == 3
    _drain(sched)
    assert len(req.tokens) == 3


def test_fcfs_admission_and_slot_refill():
    """One slot, three requests: served strictly in submit order, the
    freed slot re-admitting the next request on the following tick."""
    eng = FakeEngine(slots=1, chunk=4)
    sched = Scheduler(eng)
    reqs = [_mk(i, 4, max_new=2) for i in (7, 3, 5)]   # rids are NOT the order
    for r in reqs:
        assert sched.submit(r)
    _drain(sched)
    finish = sorted(reqs, key=lambda r: r.finish_t)
    assert [r.rid for r in finish] == [7, 3, 5]
    # every request prefilled its whole prompt into the recycled slot 0
    assert eng.log.count(("prefill", 0, 4, 0)) == 3
    assert {e[1] for e in eng.log if e[0] == "prefill"} == {0}


def test_interleave_bounds_prefill_and_keeps_decode_flowing():
    """interleave=1: at most one prefill unit per tick, while the
    already-decoding request still gets its token every tick
    (continuous batching, not phases)."""
    eng = FakeEngine(slots=2, chunk=2)
    sched = Scheduler(eng, interleave=1)
    sched.submit(_mk(0, 2, max_new=6))    # finishes prefill on tick 1
    sched.submit(_mk(1, 6, max_new=2))    # 3 chunks, one per tick
    per_tick = []
    for _ in range(100):
        if sched.idle:
            break
        eng.log.clear()
        sched.tick()
        per_tick.append(list(eng.log))
    assert sched.completed == 2
    # never more than `interleave` prefill units in one quantum
    assert all(sum(e[0] == "prefill" for e in t) <= 1 for t in per_tick)
    # ticks 2-3: request 1 still prefilling WHILE request 0 decodes —
    # continuous batching, not prefill-then-decode phases
    for t in per_tick[1:3]:
        kinds = [e[0] for e in t]
        assert "prefill" in kinds and "decode" in kinds


def test_compiled_step_invariants_chunked():
    """The regression pin: chunked prefill costs ceil(L/C) compiled
    steps and the final chunk's logits ARE the first token, so decode
    pays max_new - 1 ticks — no wasted re-feed step."""
    eng = FakeEngine(slots=2, chunk=4, max_len=64)
    sched = Scheduler(eng)
    reqs = [_mk(0, 4, 3), _mk(1, 7, 3), _mk(2, 9, 5), _mk(3, 1, 2)]
    for r in reqs:
        assert sched.submit(r)
    _drain(sched)
    for r in reqs:
        assert r.prefill_steps == math.ceil(r.prompt_len / 4), r
        assert r.decode_steps == r.max_new - 1, r
        assert len(r.tokens) == r.max_new


def test_compiled_step_invariants_baseline():
    """The priced inefficiency: prefill-by-decode pays L ticks with the
    logits discarded, then max_new decode ticks (the first one re-feeds
    the last prompt token)."""
    eng = FakeEngine(slots=2, chunk=4, max_len=64, prefill_mode="decode")
    sched = Scheduler(eng)
    reqs = [_mk(0, 4, 3), _mk(1, 7, 2)]
    for r in reqs:
        assert sched.submit(r)
    _drain(sched)
    for r in reqs:
        assert r.prefill_steps == r.prompt_len, r
        assert r.decode_steps == r.max_new, r
        assert len(r.tokens) == r.max_new


def test_ttft_accounting_with_fake_clock():
    """TTFT in engine-step units: chunked pays ceil(L/C) steps to first
    token; the baseline pays L prefill ticks plus one decode tick."""
    clock = FakeClock()
    eng = FakeEngine(slots=1, chunk=4, clock=clock)
    sched = Scheduler(eng, clock=clock)
    req = _mk(0, 8, max_new=2)
    sched.submit(req)
    _drain(sched)
    assert req.ttft == 2.0            # ceil(8/4) compiled steps
    assert req.finish_t >= req.first_token_t >= req.submit_t

    clock = FakeClock()
    eng = FakeEngine(slots=1, chunk=4, clock=clock, prefill_mode="decode")
    sched = Scheduler(eng, clock=clock)
    req = _mk(0, 8, max_new=2)
    sched.submit(req)
    _drain(sched)
    assert req.ttft == 9.0            # 8 prefill ticks + 1 decode tick


def test_modes_generate_identical_tokens():
    """Policy-level equivalence: with a deterministic engine both
    prefill modes must emit the same greedy chain for every request."""
    outs = {}
    for mode in ("chunked", "decode"):
        eng = FakeEngine(slots=2, chunk=4, prefill_mode=mode)
        sched = Scheduler(eng)
        reqs = [_mk(0, 5, 4), _mk(1, 8, 3), _mk(2, 3, 2)]
        for r in reqs:
            assert sched.submit(r)
        _drain(sched)
        outs[mode] = {r.rid: list(r.tokens) for r in reqs}
    assert outs["chunked"] == outs["decode"]


# ---------------------------------------------------------------------------
# policy: paged admission — budgets in pages, queue on pressure
# ---------------------------------------------------------------------------

def test_paged_budget_accepts_what_contiguous_rejects():
    """Regression pin for the contiguous budget's conservatism AND the
    paged fix.  L=17, C=8, max_len=18: the last chunk's C-wide write
    window ends at ceil(17/8)*8 = 24 > 18, so the contiguous path must
    keep rejecting (its slot really would overflow).  The paged path
    counts pages: ceil(24/8) = 3 pages == the block table's 3 rows, so
    the same request is admitted and served."""
    cont = Scheduler(FakeEngine(chunk=8, max_len=18))
    assert not cont.submit(_mk(0, 17, max_new=1))
    assert cont.rejected[REJECT_TOO_LONG] == 1

    paged = Scheduler(FakeEngine(chunk=8, max_len=18, paged=True))
    req = _mk(0, 17, max_new=1)
    assert paged.submit(req)
    _drain(paged)
    assert req.done and len(req.tokens) == 1
    # never-satisfiable still bounced at submit, not queued
    assert not paged.submit(_mk(1, 30, max_new=10))   # 5 pages > 3 rows
    assert not paged.submit(_mk(2, 0))                # empty prompt
    assert paged.rejected[REJECT_TOO_LONG] == 2


def test_paged_short_runs_alongside_long():
    """Pages are the admission currency: a long request holding most of
    the pool does not block a short one whose pages still fit — both
    run concurrently in separate slots."""
    eng = FakeEngine(slots=2, chunk=4, max_len=16, paged=True)
    sched = Scheduler(eng)
    long_req = _mk(0, 12, max_new=4)      # budget 16 -> 4 pages
    short_req = _mk(1, 4, max_new=1)      # ceil(max(4, 5)/4) = 2 pages
    assert sched.submit(long_req) and sched.submit(short_req)
    sched.tick()
    assert long_req.slot is not None and short_req.slot is not None
    assert eng.pool.allocator.used == 6
    _drain(sched)
    assert sched.peak_active == 2
    assert long_req.tokens and short_req.tokens
    assert eng.pool.allocator.available == eng.pool.allocator.capacity


def test_paged_out_of_pages_queues_then_admits():
    """Pool exhaustion is back-pressure, not rejection: a satisfiable
    request that finds no free pages stays queued — even with a free
    slot — and is admitted as soon as a completion frees pages."""
    eng = FakeEngine(slots=2, chunk=4, max_len=16, paged=True,
                     num_pages=1 + 5)     # park + 5: one long OR one short
    sched = Scheduler(eng)
    long_req = _mk(0, 12, max_new=1)      # budget 13 -> 4 pages
    short_req = _mk(1, 4, max_new=1)      # 2 pages > the 1 page left
    assert sched.submit(long_req)
    assert sched.submit(short_req)        # accepted: satisfiable, queues
    sched.tick()
    assert long_req.slot is not None
    assert short_req.slot is None and sched.queue, "short must wait, not reject"
    assert sched.rejected == {}
    while not long_req.done:
        sched.tick()
    _drain(sched)
    assert short_req.done and len(short_req.tokens) == 1
    assert eng.pool.allocator.available == eng.pool.allocator.capacity


def test_paged_admission_is_head_of_line():
    """FCFS in pages: when the head of the queue cannot get its pages,
    later (smaller) requests must NOT jump ahead even though they would
    fit and a slot is free — skipping would starve long requests."""
    eng = FakeEngine(slots=2, chunk=4, max_len=16, paged=True,
                     num_pages=1 + 5)
    sched = Scheduler(eng)
    first = _mk(0, 12, max_new=4)         # 4 pages, holds the pool a while
    second = _mk(1, 12, max_new=1)        # 4 pages: cannot fit alongside
    tiny = _mk(2, 3, max_new=1)           # 1 page: would fit — must wait
    for r in (first, second, tiny):
        assert sched.submit(r)
    sched.tick()
    assert first.slot is not None
    assert second.slot is None and tiny.slot is None
    assert [r.rid for r in sched.queue] == [1, 2]
    _drain(sched)
    assert first.finish_t <= second.finish_t <= tiny.finish_t


def test_paged_mode_generates_identical_tokens_and_samples_pages():
    """The paged scheduler is a pure layout change: same greedy chains
    as the contiguous chunked path, with the fragmentation series
    (allocated vs written pages) recorded every tick."""
    outs = {}
    for paged in (False, True):
        eng = FakeEngine(slots=2, chunk=4, paged=paged)
        sched = Scheduler(eng)
        reqs = [_mk(0, 5, 4), _mk(1, 8, 3), _mk(2, 3, 2)]
        for r in reqs:
            assert sched.submit(r)
        _drain(sched)
        outs[paged] = {r.rid: list(r.tokens) for r in reqs}
    assert outs[True] == outs[False]
    assert sched.page_samples, "paged runs must record the page series"
    assert all(used <= alloc for alloc, used in sched.page_samples)
    assert np.all(eng.pool.block_tables == PagedPool.PARK)   # fully released


def test_consolidated_stats_schema_pinned():
    """Every SERVING_STATS_SCHEMA key is always present — zeroed pool
    keys on the contiguous path, the per-tick page samples aggregated on
    the paged path — so the stats printer can iterate the schema and a
    new counter cannot be silently dropped from any consumer."""
    sched = Scheduler(FakeEngine(slots=2, chunk=4))
    for r in (_mk(0, 5, 2), _mk(1, 3, 2)):
        assert sched.submit(r)
    _drain(sched)
    stats = sched.consolidated_stats()
    assert set(stats) == SERVING_STATS_SCHEMA
    assert stats["completed"] == 2
    assert stats["ticks"] == sched.ticks > 0
    assert stats["pages-capacity"] == stats["pages-allocated-mean"] == 0

    eng = FakeEngine(slots=2, chunk=4, paged=True)
    sp = Scheduler(eng)
    for r in (_mk(0, 5, 4), _mk(1, 8, 3)):
        assert sp.submit(r)
    _drain(sp)
    stats = sp.consolidated_stats()
    assert set(stats) == SERVING_STATS_SCHEMA
    assert stats["pages-capacity"] == eng.pool.allocator.capacity
    assert 0 < stats["pages-written-mean"] <= stats["pages-allocated-mean"]
    assert stats["pages-allocated-peak"] >= stats["pages-allocated-mean"]
    assert 0 <= stats["fragmentation-pct"] < 100


# ---------------------------------------------------------------------------
# end-to-end: real Server on the pod-sim deployment
# ---------------------------------------------------------------------------

ARCH = "qwen2.5-14b"


@pytest.fixture(scope="module")
def served_container():
    rt = Runtime(host_env={})
    container = rt.deploy(make_bundle(ARCH, reduced=True),
                          mesh=make_host_mesh(data=1))
    yield get_config(ARCH).reduced(), container
    rt.cleanup()


def _pad_kv(cache, extra):
    out = {}
    for pk, entry in cache.items():
        e = {}
        for k, v in entry.items():
            if k in ("k", "v"):
                e[k] = jnp.pad(v, ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0)))
            else:
                e[k] = v
        out[pk] = e
    return out


def _reference_tokens(model, params, prompt, max_new):
    """Unbatched greedy generation via the whole-sequence prefill path."""
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompt)[None]})
    cache = _pad_kv(cache, max_new)
    toks = [int(np.argmax(logits[0]))]     # prefill returns (b, vocab)
    decode = jax.jit(model.decode)
    pos = len(prompt)
    for _ in range(max_new - 1):
        lg, cache = decode(params, jnp.asarray([[toks[-1]]], jnp.int32),
                           cache, jnp.int32(pos))
        toks.append(int(np.argmax(lg[0])))
        pos += 1
    return toks


def test_e2e_serving_matches_unbatched_reference(served_container):
    """Full pod-sim run: continuous batching over 2 slots with partial
    chunks and slot reuse; every request completes, the compiled-step
    ledger matches the ceil(L/C) invariant, and every request's greedy
    tokens equal the unbatched single-request reference."""
    cfg, container = served_container
    server = Server(cfg, container, slots=2, max_len=32, chunk=4,
                    prefill_mode="chunked")
    rng = np.random.default_rng(11)
    lens = [4, 6, 9, 3]                      # multiple, partial, sub-chunk
    for rid, plen in enumerate(lens):
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        assert server.submit(Request(rid=rid, prompt=prompt, max_new=3))
    server.run()

    done = [r for r in server.requests if r.done]
    assert len(done) == len(lens)
    assert server.engine.prefill_calls == sum(math.ceil(n / 4) for n in lens)
    model, params = server.engine.model, server.engine.params
    for r in done:
        assert r.prefill_steps == math.ceil(r.prompt_len / 4)
        assert r.decode_steps == r.max_new - 1
        assert r.finish_t >= r.first_token_t >= r.submit_t
        assert r.tokens == _reference_tokens(model, params, r.prompt, r.max_new)


def test_e2e_paged_matches_contiguous(served_container):
    """The paged cache is a layout, not a policy: the same traffic served
    through page pools + block tables must emit exactly the contiguous
    chunked path's tokens (== the unbatched reference), and the pool
    must be fully drained when the server goes idle."""
    cfg, container = served_container
    rng = np.random.default_rng(11)
    lens = [4, 6, 9, 3]
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]
    tokens = {}
    for paged in (False, True):
        server = Server(cfg, container, slots=2, max_len=32, chunk=4,
                        prefill_mode="chunked", paged=paged)
        for rid, p in enumerate(prompts):
            assert server.submit(Request(rid=rid, prompt=p.copy(), max_new=3))
        server.run()
        assert all(r.done for r in server.requests)
        tokens[paged] = {r.rid: list(r.tokens) for r in server.requests}
        if paged:
            pool = server.engine.pool
            assert pool.allocator.available == pool.allocator.capacity
            assert np.all(pool.block_tables == PagedPool.PARK)
            assert server.scheduler.page_samples
    assert tokens[True] == tokens[False]
    model, params = server.engine.model, server.engine.params
    for r in server.requests:
        assert r.tokens == _reference_tokens(model, params, r.prompt, r.max_new)


def _old_loop_tokens(model, params, prompt, max_new, max_len):
    """Unbatched replay of the pre-scheduler server: every prompt token
    pushed through decode with the logits discarded, then generation
    seeded by RE-FEEDING the last prompt token at position L — the
    duplicated-context quirk the chunked path fixes (its final chunk's
    logits are the true first token)."""
    cache = model.init_cache(1, max_len)
    decode = jax.jit(model.decode)
    pos = 0
    for t in prompt:
        _, cache = decode(params, jnp.asarray([[int(t)]], jnp.int32),
                          cache, jnp.int32(pos))
        pos += 1
    toks, last = [], int(prompt[-1])
    for _ in range(max_new):
        lg, cache = decode(params, jnp.asarray([[last]], jnp.int32),
                           cache, jnp.int32(pos))
        pos += 1
        last = int(np.argmax(lg[0]))
        toks.append(last)
    return toks


def test_e2e_baseline_replays_old_server_loop(served_container):
    """prefill_mode='decode' must be a faithful replay of the old
    prefill-by-decode server — including its duplicated-last-token
    seeding — so table7's baseline row prices exactly the behaviour the
    chunked path replaced."""
    cfg, container = served_container
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 6)]
    server = Server(cfg, container, slots=2, max_len=32, chunk=4,
                    prefill_mode="decode")
    for rid, p in enumerate(prompts):
        assert server.submit(Request(rid=rid, prompt=p.copy(), max_new=3))
    server.run()
    assert server.engine.prefill_calls == 0       # never the chunked path
    model, params = server.engine.model, server.engine.params
    for r in server.requests:
        assert r.done
        assert r.tokens == _old_loop_tokens(model, params, r.prompt,
                                            r.max_new, 32)
