"""Runtime deployment stages: swap, env export, freeze, numerics."""

import jax.numpy as jnp
import pytest

from repro.core.abi import AbiString
from repro.core.bundle import Bundle
from repro.core.platform import LAPTOP, Platform
from repro.core.registry import ImplKind, OpImpl, OpRegistry
from repro.core.runtime import DeploymentError, Runtime

FAKE_TPU = Platform(
    name="fake-tpu",
    hardware=LAPTOP.hardware,
    mesh_shape=(1,),
    mesh_axes=("data",),
    native_features=frozenset({"pallas_kernels"}),
)


def _registry(native_scale=2.0, *, bad_abi=False):
    reg = OpRegistry()
    abi = AbiString.make("scale", {"args": ["x"]})
    reg.register(OpImpl(abi=abi, kind=ImplKind.REFERENCE,
                        fn=lambda x: x * 1.0, provider="ref"))
    nat_abi = AbiString.make("scale", {"args": ["x", "oops"]} if bad_abi else {"args": ["x"]},
                             minor=1)
    reg.register(
        OpImpl(abi=nat_abi, kind=ImplKind.NATIVE, fn=lambda x: x * native_scale,
               requires_feature="pallas_kernels", provider="fake-pallas"),
        strict=False,
    )
    return reg, abi


def _bundle(abi):
    return Bundle(
        name="m", tag="latest", model_config={}, recipe={},
        required_ops={"scale": str(abi)}, env={"FOO": "bundle"},
    )


def test_deploy_swap_and_numerics():
    reg, abi = _registry(native_scale=1.0)   # ABI-compatible, same numerics
    rt = Runtime(registry=reg, host_env={})
    c = rt.deploy(_bundle(abi), native_ops=True, platform=FAKE_TPU)
    assert c.binding.reports[0].swapped
    # the paper's Tables III-V claim: native == reference results
    assert float(c.binding["scale"](jnp.float32(3.0))) == 3.0
    rt.cleanup()
    c2 = rt.deploy(_bundle(abi), native_ops=False, platform=FAKE_TPU)
    assert not c2.binding.reports[0].swapped
    rt.cleanup()


def test_abi_refusal_falls_back_to_reference():
    reg, abi = _registry(native_scale=99.0, bad_abi=True)
    rt = Runtime(registry=reg, host_env={})
    c = rt.deploy(_bundle(abi), native_ops=True, platform=FAKE_TPU)
    assert not c.binding.reports[0].swapped           # refusal
    assert float(c.binding["scale"](jnp.float32(2.0))) == 2.0
    rt.cleanup()


def test_missing_required_op_fails_deployment():
    reg, _ = _registry()
    rt = Runtime(registry=reg, host_env={})
    other = AbiString.make("ghost_op", "nope")
    bad = Bundle(name="m", tag="t", model_config={}, recipe={},
                 required_ops={"ghost_op": str(other)}, env={})
    with pytest.raises(DeploymentError):
        rt.deploy(bad, native_ops=False, platform=LAPTOP)


def test_required_abi_mismatch_fails_deployment():
    reg, _ = _registry()
    rt = Runtime(registry=reg, host_env={})
    wrong = AbiString.make("scale", {"args": ["different"]})
    bad = Bundle(name="m", tag="t", model_config={}, recipe={},
                 required_ops={"scale": str(wrong)}, env={})
    with pytest.raises(DeploymentError):
        rt.deploy(bad, native_ops=False, platform=LAPTOP)


def test_env_export_allowlist():
    reg, abi = _registry()
    rt = Runtime(registry=reg, host_env={
        "REPRO_PLATFORM": "laptop", "SECRET": "x", "REPRO_CHECKPOINT_DIR": "/ckpt",
    })
    c = rt.deploy(_bundle(abi), native_ops=False)
    assert c.env["FOO"] == "bundle"                 # bundle vars exported
    assert c.env["REPRO_CHECKPOINT_DIR"] == "/ckpt"  # allowlisted host var
    assert "SECRET" not in c.env                     # host junk filtered
    rt.cleanup()


def test_single_container_per_runtime():
    reg, abi = _registry()
    rt = Runtime(registry=reg, host_env={})
    rt.deploy(_bundle(abi), native_ops=False, platform=LAPTOP)
    with pytest.raises(DeploymentError):
        rt.deploy(_bundle(abi), native_ops=False, platform=LAPTOP)
    rt.cleanup()
    rt.deploy(_bundle(abi), native_ops=False, platform=LAPTOP)
    rt.cleanup()


def test_freeze_during_execution():
    reg, abi = _registry()
    rt = Runtime(registry=reg, host_env={})
    rt.deploy(_bundle(abi), native_ops=False, platform=LAPTOP)
    assert reg.frozen
    rt.cleanup()
    assert not reg.frozen
