"""Multi-device behaviour (8 forced host devices, subprocess-isolated:
device count locks at backend init, so each scenario runs in its own
python)."""

import subprocess
import sys
import textwrap

import pytest

from conftest import subprocess_env


def _run(code: str, devices: int = 8, timeout: int = 600):
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=subprocess_env(devices),
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_hierarchical_equals_flat_allreduce():
    """The vendor-collective swap changes the schedule, not the numbers
    (Tables III/IV: ratio == 1.0)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import flat_grad_allreduce, hierarchical_grad_allreduce

        from repro.distributed.collectives import compat_shard_map
        from repro.launch.mesh import make_compat_mesh
        mesh = make_compat_mesh((2, 4), ("pod", "data"))
        grads = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4),
                 "b": jnp.ones((5,), jnp.float32)}

        def run(fn):
            return jax.jit(compat_shard_map(
                fn, mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
            ))(grads)

        flat = run(lambda g: flat_grad_allreduce(g, data_axis="data", pod_axis="pod"))
        hier = run(lambda g: hierarchical_grad_allreduce(g, data_axis="data", pod_axis="pod"))
        for k in grads:
            np.testing.assert_allclose(np.asarray(flat[k]), np.asarray(hier[k]),
                                       atol=1e-6, rtol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_compressed_dcn_allreduce_close_to_exact():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import flat_grad_allreduce, hierarchical_grad_allreduce

        from repro.distributed.collectives import compat_shard_map
        from repro.launch.mesh import make_compat_mesh
        mesh = make_compat_mesh((2, 4), ("pod", "data"))
        g = {"w": jnp.linspace(-1, 1, 64, dtype=jnp.float32).reshape(8, 8)}

        def run(fn):
            return jax.jit(compat_shard_map(
                fn, mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
            ))(g)

        exact = run(lambda t: flat_grad_allreduce(t, data_axis="data", pod_axis="pod"))
        comp = run(lambda t: hierarchical_grad_allreduce(
            t, data_axis="data", pod_axis="pod", compress_dcn=True))
        err = float(jnp.abs(exact["w"] - comp["w"]).max())
        rng = float(jnp.abs(exact["w"]).max())
        assert err <= rng / 64, (err, rng)   # int8 quantization error bound
        print("OK", err)
    """)
    assert "OK" in out


def test_pipeline_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import pipeline_apply

        from repro.distributed.collectives import compat_shard_map
        from repro.launch.mesh import make_compat_mesh
        mesh = make_compat_mesh((4,), ("pipe",))
        S, M, mb, d = 4, 6, 3, 8
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (S, d, d)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))

        def stage(w, h):
            return jnp.tanh(h @ w)

        got = pipeline_apply(stage, ws, x, mesh, axis="pipe")

        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ ws[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5, rtol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_moe_shard_map_matches_local_path():
    """Expert-TP under a real (data x model) mesh == single-device gmm."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.models import build_model, ParallelCtx
        from repro.models.moe import moe_apply

        cfg = ARCHS["phi3.5-moe-42b-a6.6b"].reduced()
        # data=1 so both paths see identical per-shard token counts (the
        # capacity cutoff C = cf*T/E depends on the local T; with data>1
        # the reference may drop different overflow rows than the
        # single-device run — documented capacity semantics, not a bug).
        from repro.distributed.collectives import compat_shard_map
        from repro.launch.mesh import make_compat_mesh
        mesh = make_compat_mesh((1, 8), ("data", "model"))
        pctx = ParallelCtx(mesh=mesh, batch_axes=("data",), model_axis="model")
        model = build_model(cfg, pctx=pctx)
        params = model.init(jax.random.PRNGKey(0))
        moe_params = jax.tree.map(lambda x: x[0], params["decoder"]["p0"]["moe"])

        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * 0.3
        local_pctx = ParallelCtx()
        y_local, _ = moe_apply(moe_params, x, cfg, local_pctx, model.binding)
        y_mesh, _ = jax.jit(
            lambda p, h: moe_apply(p, h, cfg, pctx, model.binding)
        )(moe_params, x)
        np.testing.assert_allclose(np.asarray(y_local, np.float32),
                                   np.asarray(y_mesh, np.float32),
                                   atol=2e-4, rtol=2e-4)
        print("OK")
    """)
    assert "OK" in out


def test_small_mesh_train_step_compiles_and_runs():
    """A miniature of the production dry-run that actually EXECUTES: a
    reduced arch on a (2 data x 4 model) mesh, two real train steps."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.configs.base import ShapeConfig
        from repro.data import DataConfig, SyntheticStream
        from repro.launch.steps import DeployOptions, make_deployment
        from repro.optim import adamw_init

        cfg = ARCHS["qwen2.5-14b"].reduced()
        from repro.distributed.collectives import compat_shard_map
        from repro.launch.mesh import make_compat_mesh
        mesh = make_compat_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("t", 32, 4, "train")
        dep = make_deployment(cfg, shape, mesh, options=DeployOptions(donate=False))
        params = jax.device_put(dep.model.init(jax.random.PRNGKey(0)), dep.param_sharding)
        opt = jax.device_put(adamw_init(params), dep.opt_sharding)
        stream = SyntheticStream(cfg, shape, DataConfig())
        l0 = None
        for step in range(2):
            batch = jax.device_put(stream.global_batch_at(step), dep.batch_sharding)
            params, opt, metrics = dep.train_step(params, opt, batch)
            assert bool(jnp.isfinite(metrics["loss"]))
            l0 = float(metrics["loss"])
        print("OK", l0)
    """)
    assert "OK" in out


def test_elastic_restore_8_to_4_devices():
    """Save on 8 devices, restore+reshard on 4 — the downscale path."""
    env8 = """
        import jax, jax.numpy as jnp
        from repro.checkpoint import save_checkpoint
        from repro.ft import rescale_plan
        plan = rescale_plan(8, model=4)
        mesh = plan.build_mesh()
        from jax.sharding import NamedSharding, PartitionSpec as P
        w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                           NamedSharding(mesh, P("data", "model")))
        save_checkpoint("{d}", 7, {{"w": w}})
        print("SAVED")
    """
    env4 = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import restore_checkpoint
        from repro.ft import rescale_plan
        plan = rescale_plan(4, model=4)
        mesh = plan.build_mesh()
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = NamedSharding(mesh, P(None, "model"))
        tree, step = restore_checkpoint("{d}", {{"w": np.zeros((8, 8), np.float32)}},
                                        sharding_fn=lambda p, a: sh)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(tree["w"]),
                                      np.arange(64, dtype=np.float32).reshape(8, 8))
        assert tree["w"].sharding == sh
        print("RESTORED")
    """
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        out1 = _run(env8.format(d=d), devices=8)
        assert "SAVED" in out1
        out2 = _run(env4.format(d=d), devices=4)
        assert "RESTORED" in out2
