"""Sharding-rule builder: divisibility, fallbacks, axis uniqueness."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.distributed.sharding import (
    BASELINE_RULES,
    batch_spec,
    cache_specs,
    param_specs,
)
from repro.models import build_model
from repro.models.schema import LeafSpec, leaf_items


class FakeMesh:
    """Just enough mesh for the spec builders (no jax devices needed)."""

    def __init__(self, shape, axes):
        self.axis_names = axes
        self.devices = np.zeros(shape)


MESH = FakeMesh((16, 16), ("data", "model"))
MESH_MP = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def _flat_specs(arch, mesh):
    model = build_model(ARCHS[arch])
    schema = model.schema()
    specs = param_specs(schema, BASELINE_RULES, mesh)
    flat_schema = dict(leaf_items(schema))
    out = []

    def walk(tree, prefix=""):
        for k, v in tree.items():
            path = f"{prefix}/{k}" if prefix else k
            if isinstance(v, P):
                out.append((path, flat_schema[path], v))
            else:
                walk(v, path)

    walk(specs)
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["single", "multi"])
def test_all_assignments_divisible_and_unique(arch, mesh):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for path, leaf, spec in _flat_specs(arch, mesh):
        used = []
        for dim, assignment in enumerate(spec):
            if assignment is None:
                continue
            axes = assignment if isinstance(assignment, tuple) else (assignment,)
            prod = int(np.prod([sizes[a] for a in axes]))
            assert leaf.shape[dim] % prod == 0, (
                f"{arch} {path}: dim {dim} ({leaf.shape[dim]}) not divisible by {prod}"
            )
            used.extend(axes)
        assert len(used) == len(set(used)), f"{arch} {path}: mesh axis reused {spec}"


def _norm(assignment):
    """PartitionSpec normalizes 1-tuples to bare names."""
    if isinstance(assignment, tuple) and len(assignment) == 1:
        return assignment[0]
    return assignment


def test_whisper_heads_fall_back_to_head_dim():
    specs = dict(
        (p, s) for p, _, s in _flat_specs("whisper-base", MESH)
    )
    wq = specs["decoder/p0/attn/wq"]       # (layers, embed, heads, head_dim)
    # 8 heads % 16 != 0 -> heads dim unsharded, head_dim (64) takes model
    assert wq[2] is None and wq[3] == "model"


def test_qwen_heads_on_model():
    specs = dict((p, s) for p, _, s in _flat_specs("qwen2-72b", MESH))
    assert specs["decoder/p0/attn/wq"][2] == "model"
    assert specs["decoder/p0/mlp/w_in"][2] == "model"
    # FSDP storage on the embed dim (pod absent -> data only)
    assert _norm(specs["decoder/p0/mlp/w_in"][1]) == "data"


def test_experts_fsdp_over_data():
    specs = dict((p, s) for p, _, s in _flat_specs("moonshot-v1-16b-a3b", MESH))
    w_in = specs["decoder/p0/moe/w_in"]      # (layers, E, D, F)
    assert _norm(w_in[1]) == "data" and w_in[3] == "model"


def test_batch_spec_divisibility():
    assert batch_spec(256, MESH) == ("data",)
    assert batch_spec(256, MESH_MP) == ("pod", "data")
    assert batch_spec(2, MESH_MP) == ("pod",)      # 2 divides pod only
    assert batch_spec(1, MESH) == ()


def test_cache_specs_long_context_shards_sequence():
    model = build_model(ARCHS["jamba-1.5-large-398b"])
    cache = model.abstract_cache(1, 1 << 16)
    specs = cache_specs(cache, 1, MESH)
    kspec = specs["p0"]["k"]                 # (nb, B=1, S, KV=8, dh=128)
    assert kspec[1] is None                  # B=1 unshardable
    # kv=8 < 16: the sequence absorbs BOTH the free DP axis and the model
    # axis (seq-sharded decode beats head_dim sharding: tiny logsumexp
    # psum instead of multi-GB score all-reduces)
    assert kspec[2] == ("data", "model")
    assert kspec[3] is None and kspec[4] is None


def test_cache_specs_batched_decode():
    model = build_model(ARCHS["qwen2-72b"])
    cache = model.abstract_cache(128, 1 << 15)
    specs = cache_specs(cache, 128, MESH)
    kspec = specs["p0"]["k"]
    assert _norm(kspec[1]) == "data"


@settings(max_examples=25, deadline=None)
@given(
    dims=st.lists(st.sampled_from([1, 2, 3, 8, 16, 64, 128, 100]), min_size=1, max_size=4),
    axes=st.lists(
        st.sampled_from(["embed", "heads", "ff", "vocab", "experts", None]),
        min_size=1, max_size=4,
    ),
)
def test_builder_never_breaks_divisibility(dims, axes):
    n = min(len(dims), len(axes))
    leaf = LeafSpec(tuple(dims[:n]), tuple(axes[:n]))
    spec = param_specs({"x": leaf}, BASELINE_RULES, MESH)["x"]
    sizes = {"data": 16, "model": 16}
    for dim, assignment in enumerate(spec):
        if assignment is None:
            continue
        ax = assignment if isinstance(assignment, tuple) else (assignment,)
        prod = int(np.prod([sizes[a] for a in ax]))
        assert leaf.shape[dim] % prod == 0
