"""Data pipeline: determinism, host sharding, straggler rebalance."""

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, SyntheticStream

SHAPE = ShapeConfig("t", 16, 8, "train")


def _stream(num_hosts=1, host_id=0, arch="qwen2.5-14b"):
    return SyntheticStream(ARCHS[arch].reduced(), SHAPE,
                           DataConfig(seed=7, num_hosts=num_hosts, host_id=host_id))


def test_deterministic_by_step():
    s = _stream()
    b1, b2 = s.batch_at(3), s.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s.batch_at(3)["tokens"], s.batch_at(4)["tokens"])


def test_restart_replays_sequence():
    """The FT property: a restarted stream reproduces the batch for step N."""
    ref = [_stream().batch_at(i)["tokens"] for i in range(5)]
    fresh = _stream()
    for i, expect in enumerate(ref):
        np.testing.assert_array_equal(fresh.batch_at(i)["tokens"], expect)


def test_host_slices_differ_and_partition():
    h0 = _stream(num_hosts=4, host_id=0).batch_at(0)
    h1 = _stream(num_hosts=4, host_id=1).batch_at(0)
    assert h0["tokens"].shape[0] == 2          # 8 / 4 hosts
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_global_batch_shape():
    s = _stream(num_hosts=4)
    g = s.global_batch_at(0)
    assert g["tokens"].shape[0] == 8
    # host 2's slice sits at rows 4:6
    h2 = _stream(num_hosts=4, host_id=2).batch_at(0)
    np.testing.assert_array_equal(g["tokens"][4:6], h2["tokens"])


def test_skip_hosts_rebalances_without_shape_change():
    s = _stream(num_hosts=4)
    g = s.global_batch_at(0, skip_hosts=frozenset({1}))
    assert g["tokens"].shape[0] == 8           # compiled shape preserved
    # the skipped host's rows were re-sourced from a healthy host
    h1 = _stream(num_hosts=4, host_id=1).batch_at(0)
    assert not np.array_equal(g["tokens"][2:4], h1["tokens"])


def test_all_hosts_skipped_raises():
    s = _stream(num_hosts=2)
    with pytest.raises(RuntimeError):
        s.global_batch_at(0, skip_hosts=frozenset({0, 1}))


def test_indivisible_batch_rejected():
    with pytest.raises(ValueError):
        SyntheticStream(ARCHS["qwen2.5-14b"].reduced(), SHAPE,
                        DataConfig(num_hosts=3))


@pytest.mark.parametrize("arch", ["whisper-base", "llava-next-34b"])
def test_modality_batches_match_input_specs(arch):
    cfg = ARCHS[arch].reduced()
    s = SyntheticStream(cfg, SHAPE, DataConfig())
    b = s.batch_at(0)
    if cfg.is_enc_dec:
        assert b["frames"].shape == (8, 16, cfg.d_model)
    else:
        assert b["patch_embeds"].shape == (8, cfg.n_patches, cfg.d_model)
        assert b["tokens"].shape[1] == 16 - cfg.n_patches
