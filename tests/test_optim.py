"""AdamW: convergence, clipping, schedules, state mirroring."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    constant,
    global_norm,
    make_optimizer,
    warmup_cosine,
)


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array([2.0])}
    target = {"w": jnp.array([1.0, 1.0]), "b": jnp.array([0.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=100.0)
    state = adamw_init(params)

    def loss_fn(p):
        return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)

    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss_fn(params)) < 1e-3


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(3)}
    huge = {"w": jnp.full(3, 1e9)}
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    new, _, stats = adamw_update(huge, adamw_init(params), params, cfg)
    assert float(stats["grad_norm"]) > 1e8
    # clipped first step magnitude is bounded by lr / (1-b1) scale-ish
    assert float(jnp.max(jnp.abs(new["w"]))) < 2.0


def test_state_mirrors_params_structure():
    params = {"a": {"b": jnp.zeros((2, 3))}, "c": jnp.zeros(4)}
    st = adamw_init(params)
    assert jax.tree.structure(st.m) == jax.tree.structure(params)
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(st.m))


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6


def test_warmup_cosine_shape():
    fn = warmup_cosine(10, 100)
    s0 = float(fn(jnp.int32(0)))
    s10 = float(fn(jnp.int32(10)))
    s100 = float(fn(jnp.int32(100)))
    assert s0 == 0.0 and abs(s10 - 1.0) < 1e-6 and s100 < 0.2
    assert float(constant()(jnp.int32(7))) == 1.0


def test_make_optimizer_applies_schedule():
    init, update = make_optimizer(AdamWConfig(lr=1.0, weight_decay=0.0),
                                  lr_fn=lambda c: jnp.where(c < 1, 0.0, 1.0))
    params = {"w": jnp.ones(2)}
    g = {"w": jnp.ones(2)}
    # first step: lr scale 0 -> params unchanged
    new, st, _ = update(g, init(params), params)
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(params["w"]))
