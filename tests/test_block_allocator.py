"""Property suite for the paged KV-cache page pool.

`BlockAllocator` is the only mutable bookkeeping between the Scheduler
and the physical cache pools — an aliasing bug here silently corrupts
another request's KV state.  Random alloc/free traces check the
invariants that make paging safe:

  * no page is ever owned by two requests (or handed out twice),
  * reserved (park) pages are never allocated,
  * free() returns exactly the pages alloc() handed out, and they
    become reallocatable,
  * used + available == capacity at every step,
  * a failed (oversubscribed) alloc changes nothing.

The trace checker always runs against deterministic seeded traces; when
hypothesis is installed (it is a declared dev dependency but not in
every container image) the same checker is additionally driven by a
shrinking fuzzer.  `PagedPool` assign/release round-trips are checked
on top: block-table rows hold the owned pages zero-padded with the
park page.
"""

import random

import numpy as np
import pytest

from repro.launch.serve import BlockAllocator, PagedPool

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def check_trace(num_pages, reserved, steps):
    """Replay an alloc/free trace against a shadow model, asserting the
    allocator invariants after every step."""
    if num_pages <= reserved:
        with pytest.raises(ValueError):
            BlockAllocator(num_pages, reserved=reserved)
        return
    alloc = BlockAllocator(num_pages, reserved=reserved)
    held: dict[int, list[int]] = {}
    for step in steps:
        if step[0] == "free":
            owner = step[1]
            got = alloc.free(owner)
            assert sorted(got) == sorted(held.pop(owner, []))
        else:
            owner, n = step
            if owner in held:
                with pytest.raises(ValueError):
                    alloc.alloc(owner, n)
                continue
            before = alloc.available
            pages = alloc.alloc(owner, n)
            if pages is None:
                assert n > before
                assert alloc.available == before   # failed alloc is a no-op
            else:
                assert len(pages) == n
                held[owner] = list(pages)
        # global invariants after every step
        flat = [p for ps in held.values() for p in ps]
        assert len(flat) == len(set(flat)), "page owned twice"
        assert not set(flat) & set(alloc.reserved), "park page leased out"
        assert all(0 <= p < alloc.num_pages for p in flat)
        assert alloc.used == len(flat)
        assert alloc.used + alloc.available == alloc.capacity
        assert alloc.owned == held
    # drain: everything comes back and the pool is whole again
    for owner in list(held):
        alloc.free(owner)
        held.pop(owner)
    assert alloc.available == alloc.capacity


def _random_trace(rng, length=40):
    steps = []
    for _ in range(rng.randrange(length + 1)):
        if rng.random() < 0.35:
            steps.append(("free", rng.randrange(8)))
        else:
            steps.append((rng.randrange(8), rng.randrange(1, 7)))
    return steps


@pytest.mark.parametrize("seed", range(25))
def test_allocator_invariants_seeded(seed):
    rng = random.Random(seed)
    num_pages = rng.randrange(2, 25)
    reserved = rng.randrange(0, 4)
    check_trace(num_pages, reserved, _random_trace(rng))


if HAVE_HYPOTHESIS:
    _steps = st.lists(
        st.one_of(
            st.tuples(st.integers(0, 7), st.integers(1, 6)),
            st.tuples(st.just("free"), st.integers(0, 7)),
        ),
        max_size=40,
    )

    @settings(max_examples=200, deadline=None)
    @given(num_pages=st.integers(2, 24), reserved=st.integers(0, 3),
           steps=_steps)
    def test_allocator_invariants_fuzzed(num_pages, reserved, steps):
        check_trace(num_pages, reserved, steps)


def test_alloc_rejects_bad_requests():
    alloc = BlockAllocator(4, reserved=1)
    with pytest.raises(ValueError):
        alloc.alloc(0, 0)
    alloc.alloc(0, 2)
    with pytest.raises(ValueError):
        alloc.alloc(0, 1)          # owner already holds pages
    assert alloc.free(99) == []    # unknown owner: harmless no-op


def test_free_makes_pages_reallocatable():
    alloc = BlockAllocator(5, reserved=1)
    first = alloc.alloc("a", 4)
    assert first is not None and alloc.available == 0
    assert alloc.alloc("b", 1) is None
    alloc.free("a")
    second = alloc.alloc("b", 4)
    assert sorted(second) == sorted(first)


@pytest.mark.parametrize("seed", range(10))
def test_paged_pool_tables_point_at_owned_pages(seed):
    rng = random.Random(100 + seed)
    slots = rng.randrange(1, 5)
    max_len = rng.randrange(4, 33)
    page = rng.choice([2, 4, 8])
    pool = PagedPool(slots, max_len, page)
    assert pool.block_tables.shape == (slots, pool.max_blocks)
    live: dict[int, list[int]] = {}
    for slot in range(slots):
        n = rng.randrange(1, pool.max_blocks + 1)
        pages = pool.alloc(slot, n)
        if pages is None:
            continue
        pool.assign(slot, pages)
        live[slot] = list(pages)
        row = pool.block_tables[slot]
        assert list(row[:n]) == list(pages)
        assert np.all(row[n:] == PagedPool.PARK), "tail not parked"
        assert PagedPool.PARK not in pages
    # rows of distinct slots never share a physical page
    flat = [p for ps in live.values() for p in ps]
    assert len(flat) == len(set(flat))
    for slot in list(live):
        pool.free(slot)
        pool.release(slot)
        assert np.all(pool.block_tables[slot] == PagedPool.PARK)
    assert pool.allocator.available == pool.allocator.capacity
