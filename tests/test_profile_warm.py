"""Workload-profile subsystem: geometry capture round-trip, warm-from-profile
cache pre-warming, profile-keyed binding, and ABI-bump cache expiry."""

import json

import jax
import jax.numpy as jnp
import pytest

from repro.core.abi import AbiString
from repro.core.bundle import Bundle
from repro.core.platform import POD_SIM, Platform
from repro.core.registry import ImplKind, OpImpl, OpRegistry
from repro.core.runtime import Runtime
from repro.kernels.ops import ABIS, register_all
from repro.tuning import (
    BlockConfig,
    CacheKey,
    GeometryKey,
    OpTuner,
    TuningCache,
    TuningContext,
    WorkloadProfile,
    expire_stale,
    platform_fingerprint,
    profiled_binding,
    resolve_profile_path,
)
from repro.tuning.warm import warm_cache

# ---------------------------------------------------------------- profile --


def test_geometry_key_roundtrip():
    key = GeometryKey(op="moe_gmm", shapes="64x64,4x64x64,4", dtype="float32")
    assert GeometryKey.decode(key.encode()) == key
    x = jnp.zeros((60, 33))      # buckets to powers of two
    got = GeometryKey.from_args("rmsnorm", (x,))
    assert got.shapes == "64x64" and got.dtype == "float32"


def test_profile_record_save_load_roundtrip(tmp_path):
    path = tmp_path / "deep" / "workload.json"
    prof = WorkloadProfile(path)
    x = jnp.zeros((48, 32))
    w = jnp.zeros((32,))
    for _ in range(3):
        prof.record("rmsnorm", (x, w))
    prof.record("rmsnorm", (jnp.zeros((128, 32)), w))
    assert prof.dirty and len(prof) == 2
    prof.save()
    assert not prof.dirty

    reloaded = WorkloadProfile.load(path)
    top = reloaded.top(op="rmsnorm")
    assert top[0][0].shapes == "64x32,32" and top[0][1] == 3
    assert top[1][0].shapes == "128x32,32" and top[1][1] == 1
    assert reloaded.ops() == ("rmsnorm",)


def test_profile_save_merges_deltas_not_baselines(tmp_path):
    """Two processes that loaded the same baseline must add only their own
    new counts on save — not re-add the baseline they both read."""
    path = tmp_path / "workload.json"
    seed = WorkloadProfile(path)
    seed.record("op_a", (jnp.zeros((8, 8)),), weight=10)
    seed.save()

    a = WorkloadProfile.load(path)
    b = WorkloadProfile.load(path)
    a.record("op_a", (jnp.zeros((8, 8)),), weight=2)
    b.record("op_a", (jnp.zeros((8, 8)),), weight=5)
    a.save()
    b.save()
    merged = WorkloadProfile.load(path)
    key = GeometryKey(op="op_a", shapes="8x8", dtype="float32")
    assert merged.count(key) == 17    # 10 + 2 + 5, baseline counted once


def test_profile_corrupted_file_falls_back_empty(tmp_path):
    path = tmp_path / "workload.json"
    path.write_text("{ nope")
    prof = WorkloadProfile.load(path)
    assert len(prof) == 0
    prof.record("x", (jnp.zeros((4, 4)),))
    prof.save()                        # recoverable in place
    assert len(WorkloadProfile.load(path)) == 1


def test_profile_malformed_entries_dropped(tmp_path):
    path = tmp_path / "workload.json"
    path.write_text(json.dumps({
        "schema": 1,
        "counts": {"rmsnorm|8x8|float32": 3, "noseparators": 1,
                   "op|8x8|float32": "not-a-number"},
    }))
    prof = WorkloadProfile.load(path)
    assert len(prof) == 1


def test_profile_path_env_override(tmp_path):
    assert resolve_profile_path(
        {"REPRO_WORKLOAD_PROFILE": str(tmp_path / "p.json")}
    ) == tmp_path / "p.json"
    assert resolve_profile_path({}).name == "workload.json"


# ------------------------------------------------------- profiled binding --


def test_profiled_binding_records_per_compiled_geometry(tmp_path):
    reg = OpRegistry()
    abi = AbiString.make("ident", {"args": ["x"]})
    reg.register(OpImpl(abi=abi, kind=ImplKind.REFERENCE,
                        fn=lambda x: x * 2, provider="ref"))
    binding = reg.bind(["ident"], POD_SIM, native=False, freeze=False)
    prof = WorkloadProfile(tmp_path / "workload.json")
    wrapped = profiled_binding(binding, prof)

    fn = jax.jit(wrapped["ident"])
    for _ in range(4):
        fn(jnp.zeros((16, 16)))       # one trace -> one record
    fn(jnp.zeros((32, 16)))           # new geometry -> second record
    assert wrapped["ident"](jnp.ones((2, 2)))[0, 0] == 2.0  # math unchanged

    shapes = {g.shapes for g, _ in prof.top(op="ident")}
    assert shapes == {"16x16", "32x16", "2x2"}
    # reports and impl metadata survive the wrap
    assert wrapped.reports == binding.reports
    assert wrapped.impl("ident").provider == "ref"


# ------------------------------------------------------------------ warm --


def test_capture_warm_redeploy_zero_misses(tmp_path):
    """The PR acceptance loop: a profiling serve-style deployment captures
    live geometries; repro.tuning.warm pre-warms the cache; the next
    autotuned deploy binds every op with a cache hit (zero misses)."""
    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(tmp_path / "tuning.json"),
        "REPRO_WORKLOAD_PROFILE": str(tmp_path / "workload.json"),
    }
    bundle = Bundle(name="cap", tag="t", model_config={}, recipe={},
                    required_ops={"rmsnorm": str(ABIS["rmsnorm"])}, env={})

    # capture
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c1 = rt.deploy(bundle, native_ops=True, autotune=False, profile=True)
    assert c1.profile and c1.workload is not None
    x = jax.random.normal(jax.random.PRNGKey(0), (48, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32,))
    for _ in range(3):
        jax.block_until_ready(c1.binding["rmsnorm"](x, w))
    rt.cleanup()   # persists

    prof = WorkloadProfile.load(tmp_path / "workload.json")
    assert prof.top(op="rmsnorm")[0][0].shapes == "64x32,32"

    # warm
    cache = TuningCache.load(tmp_path / "tuning.json")
    results = warm_cache(prof, cache, POD_SIM,
                         registry=register_all(OpRegistry()))
    cache.save()
    assert [r.status for r in results if r.op == "rmsnorm"] == ["warmed"]

    # warm is idempotent: second run finds the entry already cached
    again = warm_cache(prof, TuningCache.load(cache.path), POD_SIM,
                       registry=register_all(OpRegistry()))
    assert [r.status for r in again if r.op == "rmsnorm"] == ["already-cached"]

    # redeploy: zero misses
    rt2 = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c2 = rt2.deploy(bundle, native_ops=True, autotune=True)
    report = next(r for r in c2.binding.reports if r.op == "rmsnorm")
    rt2.cleanup()
    assert report.tuning == "cache-hit"
    # and the hit is keyed on the *recorded* geometry, not the canonical one
    fingerprint = platform_fingerprint(POD_SIM)
    recorded_key = CacheKey(abi=str(ABIS["rmsnorm"]), platform=fingerprint,
                            shapes="64x32,32", dtype="float32")
    assert TuningCache.load(cache.path).get(recorded_key) is not None


def test_windowed_capture_warm_redeploy_zero_misses(tmp_path):
    """The windowed ops ride the same capture -> warm -> redeploy loop:
    the traced window puts a scalar part in the bucket key, so windowed
    traffic warms (and later dispatches) under its own geometry-exact
    cache entries — zero misses on the second deploy."""
    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(tmp_path / "tuning.json"),
        "REPRO_WORKLOAD_PROFILE": str(tmp_path / "workload.json"),
    }
    bundle = Bundle(
        name="wcap", tag="t", model_config={}, recipe={},
        required_ops={"windowed_attention": str(ABIS["windowed_attention"])},
        env={})

    # capture: one windowed geometry, window as a traced int32 scalar
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c1 = rt.deploy(bundle, native_ops=True, autotune=False, profile=True)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 32))
    k = jax.random.normal(ks[1], (1, 32, 2, 32))
    v = jax.random.normal(ks[2], (1, 32, 2, 32))
    win = jnp.asarray(16, jnp.int32)
    for _ in range(3):
        jax.block_until_ready(c1.binding["windowed_attention"](q, k, v, win))
    rt.cleanup()   # persists

    prof = WorkloadProfile.load(tmp_path / "workload.json")
    top = prof.top(op="windowed_attention")
    assert top and top[0][0].shapes.endswith(",scalar")   # window in the key

    # warm
    cache = TuningCache.load(tmp_path / "tuning.json")
    results = warm_cache(prof, cache, POD_SIM,
                         registry=register_all(OpRegistry()))
    cache.save()
    assert [r.status for r in results
            if r.op == "windowed_attention"] == ["warmed"]

    # redeploy: cache-hit, and live traffic dispatches geometry-exact
    rt2 = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c2 = rt2.deploy(bundle, native_ops=True, autotune=True)
    report = next(r for r in c2.binding.reports
                  if r.op == "windowed_attention")
    assert report.tuning == "cache-hit"
    jax.block_until_ready(c2.binding["windowed_attention"](q, k, v, win))
    stats = c2.binding.impl("windowed_attention").fn.stats
    rt2.cleanup()
    assert stats["exact"] >= 1 and not stats["nearest"] and not stats["default"]


def test_quantized_capture_warm_redeploy_zero_misses(tmp_path):
    """A quantized op rides the same capture -> warm -> redeploy loop:
    the composite bucket dtype ("float32+int8") keys its own cache
    entries, warm synthesizes storage-dtype weights with representative
    scales, and the second deploy dispatches the live quantized
    geometry exactly — zero misses."""
    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(tmp_path / "tuning.json"),
        "REPRO_WORKLOAD_PROFILE": str(tmp_path / "workload.json"),
    }
    bundle = Bundle(name="qcap", tag="t", model_config={}, recipe={},
                    required_ops={"quant_matmul": str(ABIS["quant_matmul"])},
                    env={})

    # capture: one quantized geometry (fp32 activations, int8 weights)
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c1 = rt.deploy(bundle, native_ops=True, autotune=False, profile=True)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (48, 32))
    qw = jax.random.randint(ks[1], (32, 64), -127, 128, jnp.int8)
    scale = jax.random.uniform(ks[2], (64,), jnp.float32, 0.01, 0.1)
    for _ in range(3):
        jax.block_until_ready(c1.binding["quant_matmul"](x, qw, scale))
    rt.cleanup()   # persists

    prof = WorkloadProfile.load(tmp_path / "workload.json")
    top = prof.top(op="quant_matmul")
    assert top and top[0][0].dtype == "float32+int8"   # composite bucket

    # warm
    cache = TuningCache.load(tmp_path / "tuning.json")
    results = warm_cache(prof, cache, POD_SIM,
                         registry=register_all(OpRegistry()))
    cache.save()
    assert [r.status for r in results
            if r.op == "quant_matmul"] == ["warmed"]

    # redeploy: cache-hit, live quantized traffic dispatches exactly
    rt2 = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c2 = rt2.deploy(bundle, native_ops=True, autotune=True)
    report = next(r for r in c2.binding.reports if r.op == "quant_matmul")
    assert report.tuning == "cache-hit"
    jax.block_until_ready(c2.binding["quant_matmul"](x, qw, scale))
    stats = c2.binding.impl("quant_matmul").fn.stats
    rt2.cleanup()
    assert stats["exact"] >= 1 and not stats["nearest"] and not stats["default"]


def test_warm_moe_narrow_d_geometry_searches(tmp_path):
    """moe_gmm geometries with D below the block_k space minimum must still
    search (the kernel degrades block_k via gcd), not silently persist the
    untuned default as a failed search."""
    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("moe_gmm", (jnp.zeros((64, 32), jnp.float32),
                            jnp.zeros((4, 32, 32), jnp.float32),
                            jnp.full((4,), 16, jnp.int32)))
    cache = TuningCache(tmp_path / "t.json")
    results = warm_cache(prof, cache, POD_SIM,
                         registry=register_all(OpRegistry()))
    assert [r.status for r in results] == ["warmed"]
    assert "block_k=" in results[0].config


def test_warm_moe_tiny_token_geometry_searches(tmp_path):
    """t below the smallest block_m (8) must still search — the kernel
    clamps block_m to max(t, 8) — and with e > t the synthesized
    group_sizes must still route every row."""
    from repro.kernels.ops import tuners

    args = tuners()["moe_gmm"].args_from_shapes(POD_SIM, "4x32,8x32x32,8",
                                                "float32")
    assert args is not None
    assert args[2].shape == (8,) and int(args[2].sum()) == 4  # all rows routed

    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("moe_gmm", (jnp.zeros((4, 32), jnp.float32),
                            jnp.zeros((8, 32, 32), jnp.float32),
                            jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.int32)))
    cache = TuningCache(tmp_path / "t.json")
    results = warm_cache(prof, cache, POD_SIM,
                         registry=register_all(OpRegistry()))
    assert [r.status for r in results] == ["warmed"]


def test_warm_skips_unsynthesizable_geometry(tmp_path):
    prof = WorkloadProfile(tmp_path / "workload.json")
    # one array where rmsnorm's signature expects (x, weight)
    prof.record("rmsnorm", (jnp.zeros((8, 8)),))
    cache = TuningCache(tmp_path / "tuning.json")
    results = warm_cache(prof, cache, POD_SIM,
                         registry=register_all(OpRegistry()))
    assert [r.status for r in results] == ["unsynthesizable"]
    assert len(cache) == 0


def test_warm_reports_ops_without_native_impl(tmp_path):
    prof = WorkloadProfile(tmp_path / "workload.json")
    prof.record("rmsnorm", (jnp.zeros((8, 8)), jnp.zeros((8,))))
    cache = TuningCache(tmp_path / "tuning.json")
    laptop = Platform(name="laptop-x", hardware=POD_SIM.hardware,
                      mesh_shape=(1,), mesh_axes=("data",),
                      native_features=frozenset())   # no pallas at all
    results = warm_cache(prof, cache, laptop,
                         registry=register_all(OpRegistry()))
    assert [r.status for r in results] == ["no-native-impl"]


# ---------------------------------------------------------------- expiry --

FAKE_SIM = Platform(
    name="fake-sim",
    hardware=POD_SIM.hardware,
    mesh_shape=(1,),
    mesh_axes=("data",),
    native_features=frozenset({"pallas_interpret"}),
)


def _registry_at_minor(minor: int):
    abi = AbiString.make("scale", {"args": ["x"]}, major=1, minor=minor)
    reg = OpRegistry()
    reg.register(OpImpl(abi=abi, kind=ImplKind.REFERENCE,
                        fn=lambda x: x, provider="ref"))
    tuner = OpTuner(
        op="scale",
        space={"block": (2, 4)},
        example_args=lambda platform: (1.5,),
        iters=1, warmup=0,
    )
    reg.register(OpImpl(
        abi=abi, kind=ImplKind.NATIVE,
        fn=lambda x, config=None: x * config["block"],
        requires_feature="pallas_interpret", provider="fake-native", tuner=tuner,
    ))
    return reg, abi


def test_abi_bump_expires_entry_and_researches(tmp_path):
    """A cache tuned at kernel minor 0 must be evicted and re-searched when
    the site's kernel bumps to minor 1, with the SwapReport saying so."""
    fingerprint = platform_fingerprint(FAKE_SIM)
    reg0, abi0 = _registry_at_minor(0)
    cache = TuningCache(tmp_path / "tuning.json")
    ctx0 = TuningContext(cache, FAKE_SIM, current_abis={"scale": abi0})
    reg0.bind(["scale"], FAKE_SIM, native=True, freeze=False, tuning=ctx0)
    ctx0.flush()
    stale_key = CacheKey(abi=str(abi0), platform=fingerprint,
                         shapes="", dtype="none")
    assert TuningCache.load(cache.path).get(stale_key) is not None

    # kernel revision bumps: same op, minor 1
    reg1, abi1 = _registry_at_minor(1)
    cache1 = TuningCache.load(tmp_path / "tuning.json")
    ctx1 = TuningContext(cache1, FAKE_SIM, current_abis={"scale": abi1})
    assert ctx1.expiry is not None and len(ctx1.expiry) == 1
    assert ctx1.expiry.ops == frozenset({"scale"})
    assert "scale" in ctx1.expiry.describe()
    binding = reg1.bind(["scale"], FAKE_SIM, native=True, freeze=False,
                        tuning=ctx1)
    assert binding.reports[0].tuning == "cache-expired-searched"
    ctx1.flush()

    # the stale entry is gone from disk (tombstone survived the merge)
    reloaded = TuningCache.load(tmp_path / "tuning.json")
    assert reloaded.get(stale_key) is None
    fresh_key = CacheKey(abi=str(abi1), platform=fingerprint,
                         shapes="", dtype="none")
    assert reloaded.get(fresh_key) is not None

    # third deploy at minor 1: plain hit, no expiry
    ctx2 = TuningContext(reloaded, FAKE_SIM, current_abis={"scale": abi1})
    assert ctx2.expiry is not None and len(ctx2.expiry) == 0
    b2 = reg1.bind(["scale"], FAKE_SIM, native=True, freeze=False, tuning=ctx2)
    assert b2.reports[0].tuning == "cache-hit"


def test_expire_stale_leaves_foreign_ops_alone(tmp_path):
    cache = TuningCache(tmp_path / "t.json")
    mine = CacheKey(abi="scale/1:0/" + "a" * 12, platform="p", shapes="8", dtype="f")
    other = CacheKey(abi="other_op/1:0/" + "b" * 12, platform="p", shapes="8", dtype="f")
    unparsable = CacheKey(abi="not-an-abi", platform="p", shapes="8", dtype="f")
    for k in (mine, other, unparsable):
        cache.put(k, BlockConfig.make(block=2))
    new_abi = AbiString.make("scale", {"args": ["x"]}, major=1, minor=3)
    report = expire_stale(cache, {"scale": new_abi})
    assert len(report) == 1 and report.ops == frozenset({"scale"})
    assert cache.get(mine) is None
    assert cache.get(other) is not None
    assert cache.get(unparsable) is not None


def test_moe_gmm_abi_minor_is_bumped():
    """The k-loop extension (minor 1) and the dropless-reference fix
    (minor 2) are compatible revisions: old bundles still deploy but
    caches tuned on older revisions expire."""
    assert ABIS["moe_gmm"].minor == 2
    old = AbiString(name="moe_gmm", major=1, minor=0,
                    digest=ABIS["moe_gmm"].digest)
    assert old.compatible_with(ABIS["moe_gmm"])       # bundle side still fine
    assert not ABIS["moe_gmm"].compatible_with(old)   # old impl refused


# ------------------------------------------------- profile-keyed context --


def test_tuning_context_prefers_profiled_geometry(tmp_path):
    """With a profile present, the cache key (and searched workload) come
    from the hottest recorded geometry, not the canonical example."""
    reg = register_all(OpRegistry())
    prof = WorkloadProfile(tmp_path / "w.json")
    x = jnp.zeros((48, 32), jnp.float32)
    w = jnp.zeros((32,), jnp.float32)
    prof.record("rmsnorm", (x, w))

    cache = TuningCache(tmp_path / "t.json")
    ctx = TuningContext(cache, POD_SIM, profile=prof, ops={"rmsnorm"})
    reg.bind(["rmsnorm"], POD_SIM, native=True, freeze=False, tuning=ctx)
    assert len(ctx.events) == 1
    assert "|64x32,32|float32" in ctx.events[0].key
    # the searched winner fits the recorded geometry (64 rows), not the
    # canonical 128-row example's larger space
    assert ctx.events[0].config["block_rows"] <= 64


def test_tuning_context_without_profile_uses_canonical(tmp_path):
    reg = register_all(OpRegistry())
    cache = TuningCache(tmp_path / "t.json")
    ctx = TuningContext(cache, POD_SIM, ops=set())   # no search, default path
    reg.bind(["rmsnorm"], POD_SIM, native=True, freeze=False, tuning=ctx)
    assert "|128x256,256|float32" in ctx.events[0].key


@pytest.mark.parametrize("op", ["rmsnorm", "attention", "decode_attention",
                                "chunk_attention", "windowed_attention",
                                "ssd_scan", "moe_gmm", "quant_matmul"])
def test_synthesizers_roundtrip_canonical_bucket(op):
    """Every op's args_from_shapes must rebuild args whose bucket equals the
    recorded one — otherwise warm would persist under a key deploys never
    look up."""
    from repro.kernels.ops import tuners
    from repro.tuning import bucket_shapes

    t = tuners()[op]
    assert t.args_from_shapes is not None
    shapes, dtype = bucket_shapes(t.workload_spec(POD_SIM))
    args = t.args_from_shapes(POD_SIM, shapes, dtype)
    assert args is not None
    re_shapes, re_dtype = bucket_shapes(args)
    assert (re_shapes, re_dtype) == (shapes, dtype)
