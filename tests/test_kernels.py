"""Per-kernel allclose vs the jnp oracle (interpret=True), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention_ref import attention_ref, decode_attention_ref
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.moe_gmm_ref import moe_gmm_exact, moe_gmm_ref
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm_ref import rmsnorm_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan_ref import ssd_decode_step_ref, ssd_scan_ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------- rmsnorm --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 32), (2, 5, 64), (1, 3, 7, 128)])
def test_rmsnorm_matches_ref(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = _rand(k1, shape, dtype)
    w = _rand(k2, shape[-1:], dtype)
    out = rmsnorm(x, w, interpret=True, block_rows=4)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype],
    )


# ----------------------------------------------------------- flash attn --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kv,dh,causal",
    [
        (1, 16, 2, 2, 8, True),
        (2, 32, 4, 2, 16, True),    # GQA
        (2, 16, 4, 1, 8, False),    # MQA, bidirectional
        (1, 24, 2, 2, 8, True),     # non-divisible by block
    ],
)
def test_flash_attention_matches_ref(b, s, h, kv, dh, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (b, s, h, dh), dtype)
    k = _rand(ks[1], (b, s, kv, dh), dtype)
    v = _rand(ks[2], (b, s, kv, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=8, block_k=8, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=5 * TOLS[dtype], rtol=5 * TOLS[dtype],
    )


def test_flash_decode_matches_decode_ref():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (2, 1, 4, 8), jnp.float32)
    k = _rand(ks[1], (2, 32, 2, 8), jnp.float32)
    v = _rand(ks[2], (2, 32, 2, 8), jnp.float32)
    pos = jnp.int32(17)
    out = flash_attention(q, k, v, kv_len=pos + 1, causal=False,
                          block_q=8, block_k=8, interpret=True)
    ref = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_chunked_ref_matches_plain_ref():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (2, 64, 4, 8), jnp.float32)
    k = _rand(ks[1], (2, 64, 2, 8), jnp.float32)
    v = _rand(ks[2], (2, 64, 2, 8), jnp.float32)
    a = attention_ref(q, k, v, causal=True)
    b = attention_ref(q, k, v, causal=True, chunk_kv=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-6, rtol=2e-6)


# ---------------------------------------------------------------- ssd -----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 16, 2, 4, 1, 8, 4),
    (2, 32, 4, 8, 2, 16, 8),
    (1, 24, 2, 4, 1, 8, 8),
])
def test_ssd_kernel_matches_ref(b, s, h, p, g, n, chunk, dtype):
    if s % chunk:
        pytest.skip("chunk must divide s")
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = _rand(ks[0], (b, s, h, p), dtype) * 0.5
    dt = jax.nn.softplus(_rand(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(_rand(ks[2], (h,), jnp.float32) * 0.3)
    Bm = _rand(ks[3], (b, s, g, n), dtype) * 0.3
    Cm = _rand(ks[4], (b, s, g, n), dtype) * 0.3
    yr, sr = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk)
    yk, sk = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(yk, np.float32), np.asarray(yr, np.float32),
                               atol=10 * TOLS[dtype], rtol=10 * TOLS[dtype])
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), atol=1e-3, rtol=1e-3)


def test_ssd_ref_matches_sequential_decode():
    """Chunked SSD == step-by-step recurrence (the decode path)."""
    b, s, h, p, g, n = 2, 16, 2, 4, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = _rand(ks[0], (b, s, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(_rand(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(_rand(ks[2], (h,), jnp.float32) * 0.3)
    Bm = _rand(ks[3], (b, s, g, n), jnp.float32) * 0.3
    Cm = _rand(ks[4], (b, s, g, n), jnp.float32) * 0.3
    yr, sr = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=4)
    state = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        y, state = ssd_decode_step_ref(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)), np.asarray(yr),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(sr), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- gmm -----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d,e,f", [(16, 8, 2, 8), (24, 16, 3, 24), (8, 8, 8, 16)])
def test_moe_gmm_kernel_matches_exact(t, d, e, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    x = _rand(ks[0], (t, d), dtype)
    w = _rand(ks[1], (e, d, f), dtype)
    splits = jnp.sort(jax.random.randint(ks[2], (e - 1,), 0, t + 1))
    gs = jnp.diff(jnp.concatenate([jnp.zeros(1, jnp.int32), splits.astype(jnp.int32),
                                   jnp.full(1, t, jnp.int32)]))
    exact = moe_gmm_exact(x, w, gs)
    out = moe_gmm(x, w, gs, block_m=8, block_n=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exact, np.float32),
                               atol=10 * TOLS[dtype], rtol=10 * TOLS[dtype])
    # capacity ref with enough capacity equals the exact oracle too
    ref = moe_gmm_ref(x, w, gs, capacity_factor=float(e))
    np.testing.assert_allclose(np.asarray(ref, np.float32), np.asarray(exact, np.float32),
                               atol=10 * TOLS[dtype], rtol=10 * TOLS[dtype])


def test_moe_gmm_dropless_at_decode_scale():
    """Unspecified capacity_factor (the binding's call convention) is
    dropless at <= _EXACT_ROWS_MAX rows: geometry-dependent capacity
    drops broke prefill/decode consistency (moonshot, docs/kernels.md)."""
    x = jnp.ones((12, 4))
    w = jnp.ones((2, 4, 4))
    gs = jnp.array([10, 2], jnp.int32)
    y = moe_gmm_ref(x, w, gs)
    assert int((jnp.abs(y).sum(axis=1) == 0).sum()) == 0


def test_moe_gmm_explicit_capacity_factor_drops():
    """An explicit capacity_factor always runs the capacity formulation
    (with its documented overflow drop), at any row count."""
    x = jnp.ones((12, 4))
    w = jnp.ones((2, 4, 4))
    gs = jnp.array([10, 2], jnp.int32)
    y = moe_gmm_ref(x, w, gs, capacity_factor=1.0)   # cap = 6 per expert
    dropped = int((jnp.abs(y).sum(axis=1) == 0).sum())
    assert dropped == 4                              # 10 - 6 overflow rows


@pytest.mark.parametrize("block_k", [16, 32, 64])
def test_moe_gmm_kloop_matches_single_block(block_k):
    """Chunking the contraction must not change the math: every block_k,
    including non-dividing values (gcd degrade), equals the full-D result."""
    t, d, e, f = 24, 64, 3, 16
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    x = _rand(ks[0], (t, d), jnp.float32)
    w = _rand(ks[1], (e, d, f), jnp.float32)
    gs = jnp.array([10, 0, 14], jnp.int32)
    exact = moe_gmm_exact(x, w, gs)
    out = moe_gmm(x, w, gs, block_m=8, block_n=8, block_k=block_k,
                  interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exact),
                               atol=2e-5, rtol=2e-5)


def test_moe_gmm_kloop_nondividing_block_k_degrades():
    t, d, e, f = 16, 48, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(12), 2)
    x = _rand(ks[0], (t, d), jnp.float32)
    w = _rand(ks[1], (e, d, f), jnp.float32)
    gs = jnp.array([9, 7], jnp.int32)
    out = moe_gmm(x, w, gs, block_m=8, block_n=8, block_k=32,  # gcd(32,48)=16
                  interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(moe_gmm_exact(x, w, gs)),
                               atol=2e-5, rtol=2e-5)


def test_moe_gmm_wide_d_searched_block_k():
    """The PR acceptance geometry: D=16384 — far beyond the old single-block
    kernel's VMEM working set (bm*D + D*bn alone would be ~16.8 MB at
    128x128 tiles) — matches the fp32 oracle under a *searched* block_k."""
    from repro.tuning import search

    t, d, e, f = 8, 16384, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(13), 2)
    x = _rand(ks[0], (t, d), jnp.float32) * 0.05
    w = _rand(ks[1], (e, d, f), jnp.float32) * 0.05
    gs = jnp.array([5, 3], jnp.int32)

    result = search(
        lambda cfg: jax.block_until_ready(
            moe_gmm(x, w, gs, config=cfg, interpret=True)),
        {"block_m": (8,), "block_n": (16,), "block_k": (2048, 4096, 8192)},
        iters=1, warmup=1,
    )
    assert result.best is not None
    assert result.best["block_k"] in (2048, 4096, 8192)
    out = moe_gmm(x, w, gs, config=result.best, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(moe_gmm_exact(x, w, gs)),
                               atol=2e-4, rtol=2e-4)
