"""Fault tolerance: straggler detection, supervisor decisions, rescaling."""

import pytest

from repro.ft import (
    DecisionKind,
    RescalePlan,
    StragglerConfig,
    StragglerDetector,
    Supervisor,
    SupervisorConfig,
    rescale_plan,
)


# ------------------------------------------------------------- straggler --
def test_straggler_flagged_after_patience():
    det = StragglerDetector(4, StragglerConfig(threshold=2.0, patience=2, evict_after=5))
    base = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    assert det.observe(base).clean
    slow = {**base, 3: 5.0}
    p1 = det.observe(slow)
    assert 3 not in p1.skip_hosts          # patience not reached
    p2 = det.observe(slow)
    assert 3 in p2.skip_hosts
    assert 3 not in p2.evict_hosts


def test_straggler_eviction_after_persistent_slowness():
    det = StragglerDetector(2, StragglerConfig(threshold=1.5, patience=1, evict_after=3))
    for _ in range(3):
        plan = det.observe({0: 1.0, 1: 10.0})
    assert 1 in plan.evict_hosts


def test_recovered_host_unflagged():
    det = StragglerDetector(2, StragglerConfig(threshold=2.0, patience=1, ema=1.0))
    det.observe({0: 1.0, 1: 9.0})
    plan = det.observe({0: 1.0, 1: 1.0})
    assert plan.clean


# ------------------------------------------------------------ supervisor --
def test_supervisor_heartbeat_failure_downscale():
    sup = Supervisor(4, SupervisorConfig(heartbeat_timeout=10.0))
    for h in range(4):
        sup.heartbeat(h, 0.0)
    sup.checkpoint_published(100)
    for h in range(3):                      # host 3 goes silent
        sup.heartbeat(h, 20.0)
    d = sup.poll(25.0)
    assert d.kind is DecisionKind.DOWNSCALE
    assert d.world_size == 3
    assert d.restore_step == 100


def test_supervisor_restart_with_spares():
    sup = Supervisor(4, SupervisorConfig(heartbeat_timeout=10.0, spare_hosts=1))
    for h in range(4):
        sup.heartbeat(h, 0.0)
    sup.checkpoint_published(50)
    for h in range(3):
        sup.heartbeat(h, 20.0)
    d = sup.poll(25.0)
    assert d.kind is DecisionKind.RESTART
    assert d.world_size == 4
    # spare consumed: a second failure (host 2 silent since its t=25
    # replacement beat) downscales
    for h in (0, 1, 3):
        sup.heartbeat(h, 40.0)
    d2 = sup.poll(45.0)
    assert d2.kind is DecisionKind.DOWNSCALE
    assert d2.world_size == 3


def test_supervisor_abort_below_min():
    sup = Supervisor(2, SupervisorConfig(heartbeat_timeout=5.0, min_hosts=2))
    sup.heartbeat(0, 0.0)
    sup.heartbeat(1, 0.0)
    sup.heartbeat(0, 10.0)
    d = sup.poll(20.0)
    assert d.kind is DecisionKind.ABORT


def test_supervisor_healthy_noop():
    sup = Supervisor(2)
    sup.heartbeat(0, 0.0)
    sup.heartbeat(1, 0.0)
    assert sup.poll(1.0).kind is DecisionKind.NONE


# --------------------------------------------------------------- elastic --
def test_rescale_plans():
    assert rescale_plan(512, model=16, pods=2).mesh_shape == (2, 16, 16)
    assert rescale_plan(256, model=16).mesh_shape == (16, 16)
    # lost a host: 248 devices, model degree halves until it divides
    p = rescale_plan(248, model=16)
    assert p.mesh_shape[-1] in (8, 4, 2, 1)
    assert p.mesh_shape[0] * p.mesh_shape[-1] == 248
    assert rescale_plan(1).mesh_shape == (1,)


def test_rescale_plan_single_device_mesh():
    plan = rescale_plan(1, model=1)
    mesh = plan.build_mesh()
    assert mesh.devices.size == 1
