"""Fault tolerance: straggler detection, supervisor decisions, rescaling."""

import pytest

from repro.ft import (
    DecisionKind,
    RescalePlan,
    StragglerConfig,
    StragglerDetector,
    Supervisor,
    SupervisorConfig,
    pool_rescale_plan,
    rescale_plan,
)


# ------------------------------------------------------------- straggler --
def test_straggler_flagged_after_patience():
    det = StragglerDetector(4, StragglerConfig(threshold=2.0, patience=2, evict_after=5))
    base = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    assert det.observe(base).clean
    slow = {**base, 3: 5.0}
    p1 = det.observe(slow)
    assert 3 not in p1.skip_hosts          # patience not reached
    p2 = det.observe(slow)
    assert 3 in p2.skip_hosts
    assert 3 not in p2.evict_hosts


def test_straggler_eviction_after_persistent_slowness():
    det = StragglerDetector(2, StragglerConfig(threshold=1.5, patience=1, evict_after=3))
    for _ in range(3):
        plan = det.observe({0: 1.0, 1: 10.0})
    assert 1 in plan.evict_hosts


def test_recovered_host_unflagged():
    det = StragglerDetector(2, StragglerConfig(threshold=2.0, patience=1, ema=1.0))
    det.observe({0: 1.0, 1: 9.0})
    plan = det.observe({0: 1.0, 1: 1.0})
    assert plan.clean


def test_straggler_tracks_hosts_beyond_initial_size():
    # an elastic pool grows past the constructed num_hosts: a late
    # joiner is judged against the same fleet median as everyone else
    det = StragglerDetector(2, StragglerConfig(threshold=2.0, patience=1, ema=1.0))
    for _ in range(2):
        plan = det.observe({0: 1.0, 1: 1.0, 7: 9.0})
    assert 7 in plan.skip_hosts


def test_straggler_forget_clears_record():
    det = StragglerDetector(3, StragglerConfig(threshold=2.0, patience=1,
                                               evict_after=2, ema=1.0))
    det.observe({0: 1.0, 1: 1.0, 2: 9.0})
    det.forget(2)
    # a fresh process behind the same id starts with a clean flag count
    plan = det.observe({0: 1.0, 1: 1.0, 2: 9.0})
    assert 2 not in plan.evict_hosts


# ------------------------------------------------------------ supervisor --
def test_supervisor_heartbeat_failure_downscale():
    sup = Supervisor(4, SupervisorConfig(heartbeat_timeout=10.0))
    for h in range(4):
        sup.heartbeat(h, 0.0)
    sup.checkpoint_published(100)
    for h in range(3):                      # host 3 goes silent
        sup.heartbeat(h, 20.0)
    d = sup.poll(25.0)
    assert d.kind is DecisionKind.DOWNSCALE
    assert d.world_size == 3
    assert d.restore_step == 100


def test_supervisor_restart_with_spares():
    sup = Supervisor(4, SupervisorConfig(heartbeat_timeout=10.0, spare_hosts=1))
    for h in range(4):
        sup.heartbeat(h, 0.0)
    sup.checkpoint_published(50)
    for h in range(3):
        sup.heartbeat(h, 20.0)
    d = sup.poll(25.0)
    assert d.kind is DecisionKind.RESTART
    assert d.world_size == 4
    # spare consumed: a second failure (host 2 silent since its t=25
    # replacement beat) downscales
    for h in (0, 1, 3):
        sup.heartbeat(h, 40.0)
    d2 = sup.poll(45.0)
    assert d2.kind is DecisionKind.DOWNSCALE
    assert d2.world_size == 3


def test_supervisor_abort_below_min():
    sup = Supervisor(2, SupervisorConfig(heartbeat_timeout=5.0, min_hosts=2))
    sup.heartbeat(0, 0.0)
    sup.heartbeat(1, 0.0)
    sup.heartbeat(0, 10.0)
    d = sup.poll(20.0)
    assert d.kind is DecisionKind.ABORT


def test_supervisor_healthy_noop():
    sup = Supervisor(2)
    sup.heartbeat(0, 0.0)
    sup.heartbeat(1, 0.0)
    assert sup.poll(1.0).kind is DecisionKind.NONE


def test_supervisor_register_and_dead_hosts():
    sup = Supervisor(0, SupervisorConfig(heartbeat_timeout=5.0))
    sup.register(0, 0.0)
    sup.register(1, 0.0)
    assert sup.num_hosts == 2
    sup.heartbeat(0, 10.0)                  # host 1 goes silent
    sup.poll(10.0)
    assert sup.dead_hosts() == frozenset({1})
    # registering a fresh process behind the same id revives it
    sup.register(1, 11.0)
    assert sup.dead_hosts() == frozenset()
    assert sup.num_hosts == 2


def test_supervisor_evicted_host_stays_dead():
    sup = Supervisor(2, SupervisorConfig(heartbeat_timeout=5.0))
    sup.heartbeat(0, 0.0)
    sup.heartbeat(1, 0.0)
    sup.evict(1, 1.0, reason="straggler")
    sup.heartbeat(1, 2.0)                   # dead hosts can't heartbeat back
    sup.poll(3.0)
    assert sup.dead_hosts() == frozenset({1})


def test_pool_rescale_grow_shrink_steady():
    grow = pool_rescale_plan(2, demand=10, slots_per_replica=2, max_replicas=8)
    assert grow.target == 5 and grow.delta == 3
    assert "rescale: decode pool 2 -> 5" in grow.describe()
    shrink = pool_rescale_plan(5, demand=2, slots_per_replica=2)
    assert shrink.target == 1 and shrink.delta == -4
    steady = pool_rescale_plan(2, demand=4, slots_per_replica=2)
    assert steady.delta == 0
    assert "==" in steady.describe()


def test_pool_rescale_clamps():
    assert pool_rescale_plan(3, demand=100, slots_per_replica=1,
                             max_replicas=4).target == 4
    assert pool_rescale_plan(3, demand=0, slots_per_replica=2,
                             min_replicas=2).target == 2


def test_pool_rescale_validation():
    with pytest.raises(ValueError):
        pool_rescale_plan(1, demand=1, slots_per_replica=0)
    with pytest.raises(ValueError):
        pool_rescale_plan(1, demand=1, slots_per_replica=2,
                          min_replicas=3, max_replicas=2)


# --------------------------------------------------------------- elastic --
def test_rescale_plans():
    assert rescale_plan(512, model=16, pods=2).mesh_shape == (2, 16, 16)
    assert rescale_plan(256, model=16).mesh_shape == (16, 16)
    # lost a host: 248 devices, model degree halves until it divides
    p = rescale_plan(248, model=16)
    assert p.mesh_shape[-1] in (8, 4, 2, 1)
    assert p.mesh_shape[0] * p.mesh_shape[-1] == 248
    assert rescale_plan(1).mesh_shape == (1,)


def test_rescale_plan_single_device_mesh():
    plan = rescale_plan(1, model=1)
    mesh = plan.build_mesh()
    assert mesh.devices.size == 1
