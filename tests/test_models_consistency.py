"""Prefill-vs-decode logits equality — the cache-correctness invariant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model

FAMILIES = [
    "qwen2.5-14b",            # dense GQA + qkv bias
    "granite-3-8b",           # tied embeddings
    "moonshot-v1-16b-a3b",    # MoE + shared experts
    "mamba2-780m",            # pure SSM
    "jamba-1.5-large-398b",   # hybrid attn/mamba/moe
    "whisper-base",           # enc-dec, layernorm/gelu
    "llava-next-34b",         # vlm patch stub
]


def _pad_kv(cache, extra):
    out = {}
    for pk, entry in cache.items():
        e = {}
        for k, v in entry.items():
            if k in ("k", "v"):
                e[k] = jnp.pad(v, ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0)))
            else:
                e[k] = v
        out[pk] = e
    return out


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_decode_consistency(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    extra = {}
    if cfg.is_enc_dec:
        extra = {"frames": jax.random.normal(key, (b, s, cfg.d_model)) * 0.1}
    elif cfg.modality == "vision":
        extra = {"patch_embeds": jax.random.normal(key, (b, cfg.n_patches, cfg.d_model)) * 0.1}

    logits_full, _ = jax.jit(model.prefill)(params, {**extra, "tokens": toks})
    _, cache = jax.jit(model.prefill)(params, {**extra, "tokens": toks[:, :-1]})
    cache = _pad_kv(cache, 1)
    pos = s - 1 + (cfg.n_patches if cfg.modality == "vision" else 0)
    logits_dec, _ = jax.jit(model.decode)(params, toks[:, -1:], cache, jnp.int32(pos))
    np.testing.assert_allclose(
        np.asarray(logits_full), np.asarray(logits_dec), atol=5e-4, rtol=5e-4
    )


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "mamba2-780m"])
def test_multi_step_decode_matches_prefill(arch):
    """Decode N tokens one-by-one == prefill over the whole sequence."""
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s, n_dec = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)

    logits_full, _ = jax.jit(model.prefill)(params, {"tokens": toks})

    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, : s - n_dec]})
    cache = _pad_kv(cache, n_dec)
    decode = jax.jit(model.decode)
    logits = None
    for i in range(n_dec):
        pos = s - n_dec + i
        logits, cache = decode(params, toks[:, pos : pos + 1], cache, jnp.int32(pos))
    np.testing.assert_allclose(
        np.asarray(logits_full), np.asarray(logits), atol=5e-4, rtol=5e-4
    )


def test_moe_gmm_ref_dropless_at_decode_scale():
    """Pinned repro for the moonshot prefill/decode divergence: the
    capacity-truncated reference computed cap = cf*T/E, so a decode
    microbatch (T*k = 4 rows, cap = 2) dropped rows a prefill (T*k = 64,
    cap = 20) kept — adversarially skewed routing must now be exact at
    decode scale (docs/kernels.md, "Dropless reference at decode scale")."""
    from repro.kernels.moe_gmm_ref import moe_gmm_exact, moe_gmm_ref

    # all 4 pairs to one expert: the old cap=2 path zeroed two of them
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    gs = jnp.array([4, 0, 0, 0], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(moe_gmm_ref(x, w, gs)),
        np.asarray(moe_gmm_exact(x, w, gs)),
        atol=1e-6, rtol=1e-6,
    )


def test_moe_gmm_path_matches_dense_oracle():
    cfg = ARCHS["phi3.5-moe-42b-a6.6b"].reduced()
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, cfg.vocab_size)
    m_gmm = build_model(cfg, moe_oracle=False)
    m_dense = build_model(cfg, moe_oracle=True)
    params = m_gmm.init(jax.random.PRNGKey(0))
    l1, _ = jax.jit(m_gmm.loss_fn)(params, {"tokens": toks, "labels": toks})
    l2, _ = jax.jit(m_dense.loss_fn)(params, {"tokens": toks, "labels": toks})
    # gmm path uses a generous capacity at tiny T; tolerances cover the
    # rare dropped token when routing is very unbalanced
    np.testing.assert_allclose(float(l1), float(l2), atol=5e-3, rtol=5e-3)
