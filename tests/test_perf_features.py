"""Numerical-equivalence tests for the beyond-paper performance features:
head padding, MoE token chunking, carry-cache decode."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models.model import Model


def test_head_padding_exact_equivalence():
    """Padded model == unpadded model even with GARBAGE in the pad slots
    (the output mask kills forward contribution and gradients)."""
    cfg = ARCHS["qwen2.5-14b"].reduced()        # 4 heads, kv 2, group 2
    m_plain = Model(cfg)
    m_pad = Model(cfg, head_pad_multiple=3)     # group 2 -> 3, heads 4 -> 6
    assert m_pad.padded_heads == 6 and m_plain.padded_heads == 4

    params = m_plain.init(jax.random.PRNGKey(0))
    pp = jax.tree.map(lambda x: x, params)
    g, gp, kv = m_pad.q_group, m_pad.q_group_padded, cfg.num_kv_heads
    at = dict(params["decoder"]["p0"]["attn"])
    rng = np.random.default_rng(0)
    for name, axis in (("wq", 2), ("wo", 1), ("bq", 1)):
        if name not in at:
            continue
        w = np.asarray(at[name], np.float32)
        resh = w.reshape(w.shape[:axis] + (kv, g) + w.shape[axis + 1:])
        out = rng.standard_normal(
            w.shape[:axis] + (kv, gp) + w.shape[axis + 1:], dtype=np.float32
        )  # garbage in the padded slots
        out[tuple([slice(None)] * axis + [slice(None), slice(0, g)])] = resh
        at[name] = jnp.asarray(
            out.reshape(w.shape[:axis] + (kv * gp,) + w.shape[axis + 1:])
        )
    pp["decoder"] = dict(pp["decoder"])
    pp["decoder"]["p0"] = dict(pp["decoder"]["p0"])
    pp["decoder"]["p0"]["attn"] = at

    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l1, _ = jax.jit(m_plain.loss_fn)(params, batch)
    l2, _ = jax.jit(m_pad.loss_fn)(pp, batch)
    np.testing.assert_allclose(float(l1), float(l2), atol=1e-5, rtol=1e-6)

    # gradients through the padded model leave pad slots untouched
    g2 = jax.grad(lambda p, b: m_pad.loss_fn(p, b)[0])(pp, batch)
    gwo = np.asarray(g2["decoder"]["p0"]["attn"]["wo"], np.float32)
    gwo_r = gwo.reshape(gwo.shape[0], kv, gp, *gwo.shape[2:])
    assert np.abs(gwo_r[:, :, g:]).max() == 0.0


def test_moe_token_chunking_matches_unchunked():
    cfg = ARCHS["phi3.5-moe-42b-a6.6b"].reduced()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    m1 = Model(cfg)
    m4 = Model(cfg, moe_token_chunks=4)
    params = m1.init(jax.random.PRNGKey(0))
    l1, _ = jax.jit(m1.loss_fn)(params, batch)
    l4, _ = jax.jit(m4.loss_fn)(params, batch)
    # per-chunk capacity can differ at tiny T; tolerance covers rare drops
    np.testing.assert_allclose(float(l1), float(l4), atol=5e-3, rtol=5e-3)


def test_decode_carry_cache_multi_block():
    """The carry-cache decode path updates every block's cache slice."""
    cfg = ARCHS["qwen2.5-14b"].reduced()        # 2 layers -> 2 blocks
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    _, cache = jax.jit(m.prefill)(params, {"tokens": toks[:, :-1]})
    cache = {
        pk: {k: (jnp.pad(v, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
                 if k in ("k", "v") else v)
             for k, v in e.items()}
        for pk, e in cache.items()
    }
    before = np.asarray(cache["p0"]["k"][:, :, -1]).copy()
    _, new_cache = jax.jit(m.decode)(params, toks[:, -1:], cache, jnp.int32(7))
    after = np.asarray(new_cache["p0"]["k"][:, :, -1])
    # position 7 now written for BOTH stacked blocks
    assert np.abs(after).sum() > 0 and np.abs(before).sum() == 0
    assert np.abs(after[0]).sum() > 0 and np.abs(after[1]).sum() > 0
