"""Property-based invariants for geometry dispatch (hypothesis).

`bucket_distance` must behave like a metric on structure-matched buckets
(symmetry, identity-is-zero) and return None — never a number — for
structurally incomparable ones; `ConfigTable.resolve` must be consistent
with it (the nearest-neighbour fallback really picks a minimum-distance
bucket); and the dtype-crossing borrow must never hand out a config the
borrowing dtype's feasibility check rejects.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.tuning import (  # noqa: E402
    BlockConfig,
    ConfigTable,
    GeometryOutcome,
    bucket_distance,
)

_dim = st.integers(min_value=0, max_value=10).map(lambda e: 2 ** e)
_shape = st.lists(_dim, min_size=1, max_size=3)
_bucket = st.lists(_shape, min_size=1, max_size=3).map(
    lambda shapes: ",".join("x".join(str(d) for d in s) for s in shapes)
)


@st.composite
def _matched(draw, n_min=1, n_max=5):
    """A query bucket plus n tuned buckets, all over ONE structure (same
    arg count and ranks), so every pair is comparable."""
    ranks = draw(st.lists(st.integers(1, 3), min_size=1, max_size=3))

    def bucket():
        return ",".join(
            "x".join(str(2 ** draw(st.integers(0, 10))) for _ in range(r))
            for r in ranks
        )

    n = draw(st.integers(n_min, n_max))
    return [bucket() for _ in range(n)], bucket()


@given(_bucket, _bucket)
@settings(max_examples=80, deadline=None)
def test_bucket_distance_symmetry(a, b):
    assert bucket_distance(a, b) == bucket_distance(b, a)


@given(_bucket)
@settings(max_examples=50, deadline=None)
def test_bucket_distance_identity_is_zero(a):
    assert bucket_distance(a, a) == 0.0


@given(_matched())
@settings(max_examples=80, deadline=None)
def test_structure_matched_buckets_are_always_comparable(data):
    buckets, query = data
    for b in buckets:
        d = bucket_distance(query, b)
        assert d is not None and d >= 0.0


@given(_matched())
@settings(max_examples=80, deadline=None)
def test_nearest_neighbor_consistency(data):
    """resolve() agrees with bucket_distance: an exact bucket resolves to
    its own config, anything else to a minimum-distance tuned bucket."""
    buckets, query = data
    table = ConfigTable(
        "op",
        [GeometryOutcome(shapes=b, dtype="float32", status="cache-hit",
                         config=BlockConfig.make(block=i + 1),
                         count=len(buckets) - i)
         for i, b in enumerate(buckets)],
        default=BlockConfig.make(block=999),
    )
    cfg, how = table.resolve(shapes=query, dtype="float32")
    assert cfg["block"] != 999                  # comparable => never default
    chosen = buckets[cfg["block"] - 1]
    if query in buckets:
        assert how == "exact" and chosen == query
    else:
        assert how == "nearest"
        dists = {bucket_distance(query, b) for b in buckets}
        assert bucket_distance(query, chosen) == min(dists)


@given(_matched(), st.integers(2, 64))
@settings(max_examples=80, deadline=None)
def test_borrowed_config_never_exceeds_vmem_for_borrowing_dtype(data, budget):
    """The near-dtype acceptance property: every tuned bucket is fp32, the
    query is bf16, and the validator models a VMEM budget in the
    borrowing dtype — whatever resolve() hands back either passed that
    check or is the (never-validated) platform default."""
    buckets, query = data

    def validate(config, shapes, dtype):
        itemsize = {"float32": 4, "bfloat16": 2}[dtype]
        return config["block"] * itemsize <= budget

    table = ConfigTable(
        "op",
        [GeometryOutcome(shapes=b, dtype="float32", status="cache-hit",
                         config=BlockConfig.make(block=i + 1),
                         count=len(buckets) - i)
         for i, b in enumerate(buckets)],
        default=BlockConfig.make(block=10 ** 6),
        validate=validate,
    )
    cfg, how = table.resolve(shapes=query, dtype="bfloat16")
    assert how in ("near-dtype", "default")     # no bf16 entries exist
    if how == "near-dtype":
        assert validate(cfg, query, "bfloat16")
    else:
        # default only when EVERY structural candidate failed validation
        assert all(not validate(BlockConfig.make(block=i + 1), query,
                                "bfloat16")
                   for i in range(len(buckets)))


@given(_matched(n_min=2))
@settings(max_examples=60, deadline=None)
def test_bounded_table_resolves_within_kept_head(data):
    """Bounded-mode invariant: a capped table only ever resolves to one of
    the K hottest (first-listed) buckets' configs, never a trimmed one."""
    buckets, query = data
    cap = max(1, len(set(buckets)) - 1)
    table = ConfigTable(
        "op",
        [GeometryOutcome(shapes=b, dtype="float32", status="cache-hit",
                         config=BlockConfig.make(block=i + 1),
                         count=len(buckets) - i)
         for i, b in enumerate(buckets)],
        default=BlockConfig.make(block=999),
        max_entries=cap,
    )
    assert len(table) <= cap
    kept_configs = {o.config["block"] for o in table.outcomes}
    cfg, how = table.resolve(shapes=query, dtype="float32")
    if how != "default":
        assert cfg["block"] in kept_configs
