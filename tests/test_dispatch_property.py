"""Property-based invariants for geometry dispatch + tuning bundles.

`bucket_distance` must behave like a metric on structure-matched buckets
(symmetry, identity-is-zero) and return None — never a number — for
structurally incomparable ones; `ConfigTable.resolve` must be consistent
with it (the nearest-neighbour fallback really picks a minimum-distance
bucket); the dtype-crossing borrow must never hand out a config the
borrowing dtype's feasibility check rejects; and a tuning-bundle
export→import round trip of a randomly generated cache must be lossless
when fingerprints match (entry set, configs, `last_used` recency order
all preserved) and idempotent (a second import is a byte-level no-op).
"""

import tempfile
from pathlib import Path

import pytest

pytest.importorskip("hypothesis")

import jax  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.abi import AbiString  # noqa: E402
from repro.core.platform import POD_SIM, Platform  # noqa: E402
from repro.core.registry import ImplKind, OpImpl, OpRegistry  # noqa: E402
from repro.tuning import (  # noqa: E402
    BlockConfig,
    CacheKey,
    ConfigTable,
    GeometryOutcome,
    OpTuner,
    TuningCache,
    bucket_distance,
    export_bundle,
    import_bundle,
    platform_fingerprint,
)

_dim = st.integers(min_value=0, max_value=10).map(lambda e: 2 ** e)
_shape = st.lists(_dim, min_size=1, max_size=3)
_bucket = st.lists(_shape, min_size=1, max_size=3).map(
    lambda shapes: ",".join("x".join(str(d) for d in s) for s in shapes)
)


@st.composite
def _matched(draw, n_min=1, n_max=5):
    """A query bucket plus n tuned buckets, all over ONE structure (same
    arg count and ranks), so every pair is comparable."""
    ranks = draw(st.lists(st.integers(1, 3), min_size=1, max_size=3))

    def bucket():
        return ",".join(
            "x".join(str(2 ** draw(st.integers(0, 10))) for _ in range(r))
            for r in ranks
        )

    n = draw(st.integers(n_min, n_max))
    return [bucket() for _ in range(n)], bucket()


@given(_bucket, _bucket)
@settings(max_examples=80, deadline=None)
def test_bucket_distance_symmetry(a, b):
    assert bucket_distance(a, b) == bucket_distance(b, a)


@given(_bucket)
@settings(max_examples=50, deadline=None)
def test_bucket_distance_identity_is_zero(a):
    assert bucket_distance(a, a) == 0.0


@given(_matched())
@settings(max_examples=80, deadline=None)
def test_structure_matched_buckets_are_always_comparable(data):
    buckets, query = data
    for b in buckets:
        d = bucket_distance(query, b)
        assert d is not None and d >= 0.0


@given(_matched())
@settings(max_examples=80, deadline=None)
def test_nearest_neighbor_consistency(data):
    """resolve() agrees with bucket_distance: an exact bucket resolves to
    its own config, anything else to a minimum-distance tuned bucket."""
    buckets, query = data
    table = ConfigTable(
        "op",
        [GeometryOutcome(shapes=b, dtype="float32", status="cache-hit",
                         config=BlockConfig.make(block=i + 1),
                         count=len(buckets) - i)
         for i, b in enumerate(buckets)],
        default=BlockConfig.make(block=999),
    )
    cfg, how = table.resolve(shapes=query, dtype="float32")
    assert cfg["block"] != 999                  # comparable => never default
    chosen = buckets[cfg["block"] - 1]
    if query in buckets:
        assert how == "exact" and chosen == query
    else:
        assert how == "nearest"
        dists = {bucket_distance(query, b) for b in buckets}
        assert bucket_distance(query, chosen) == min(dists)


@given(_matched(), st.integers(2, 64))
@settings(max_examples=80, deadline=None)
def test_borrowed_config_never_exceeds_vmem_for_borrowing_dtype(data, budget):
    """The near-dtype acceptance property: every tuned bucket is fp32, the
    query is bf16, and the validator models a VMEM budget in the
    borrowing dtype — whatever resolve() hands back either passed that
    check or is the (never-validated) platform default."""
    buckets, query = data

    def validate(config, shapes, dtype):
        itemsize = {"float32": 4, "bfloat16": 2}[dtype]
        return config["block"] * itemsize <= budget

    table = ConfigTable(
        "op",
        [GeometryOutcome(shapes=b, dtype="float32", status="cache-hit",
                         config=BlockConfig.make(block=i + 1),
                         count=len(buckets) - i)
         for i, b in enumerate(buckets)],
        default=BlockConfig.make(block=10 ** 6),
        validate=validate,
    )
    cfg, how = table.resolve(shapes=query, dtype="bfloat16")
    assert how in ("near-dtype", "default")     # no bf16 entries exist
    if how == "near-dtype":
        assert validate(cfg, query, "bfloat16")
    else:
        # default only when EVERY structural candidate failed validation
        assert all(not validate(BlockConfig.make(block=i + 1), query,
                                "bfloat16")
                   for i in range(len(buckets)))


# ------------------------------------------------- bundle round trip ------

_FAKE_SIM = Platform(name="prop-sim", hardware=POD_SIM.hardware,
                     mesh_shape=(1,), mesh_axes=("data",),
                     native_features=frozenset({"pallas_interpret"}))
_SCALE_ABI = AbiString.make("scale", {"args": ["x"]})


def _struct_synth(platform, shapes, dtype):
    """Allocation-free synthesizer: the import's structural check only
    inspects shapes/dtypes, so ShapeDtypeStructs suffice."""
    parts = [p for p in shapes.split(",") if p]
    if len(parts) != 1 or parts[0] == "scalar":
        return None
    try:
        dims = tuple(int(d) for d in parts[0].split("x"))
    except ValueError:
        return None
    return (jax.ShapeDtypeStruct(dims, dtype),)


def _scale_registry():
    reg = OpRegistry()
    reg.register(OpImpl(abi=_SCALE_ABI, kind=ImplKind.REFERENCE,
                        fn=lambda x: x, provider="ref"))
    reg.register(OpImpl(
        abi=_SCALE_ABI, kind=ImplKind.NATIVE,
        fn=lambda x, config=None: x,
        requires_feature="pallas_interpret", provider="fake-native",
        tuner=OpTuner(op="scale", space={"block": (2, 4)},
                      example_args=lambda p: (jax.ShapeDtypeStruct((4, 4),
                                                                   "float32"),),
                      args_from_shapes=_struct_synth, iters=1, warmup=0),
    ))
    return reg


_prop_dim = st.integers(min_value=0, max_value=5).map(lambda e: 2 ** e)
_prop_bucket = st.lists(_prop_dim, min_size=1, max_size=2).map(
    lambda dims: "x".join(str(d) for d in dims))
_prop_geom = st.tuples(_prop_bucket,
                       st.sampled_from(["float32", "bfloat16"]))
_prop_entries = st.dictionaries(
    _prop_geom, st.integers(min_value=1, max_value=64),
    min_size=1, max_size=6,
).map(lambda d: list(d.items()))


@given(_prop_entries, st.randoms(use_true_random=False))
@settings(max_examples=25, deadline=None)
def test_bundle_round_trip_is_lossless_and_idempotent(entries, rng):
    """Matching fingerprints => export→import preserves the entry set,
    every config, and the `last_used` recency ORDER (absolute stamps are
    re-issued, relative order is the LRU-visible property); a second
    import of the same bundle changes nothing, byte for byte."""
    rng.shuffle(entries)                       # insertion order IS the
    reg = _scale_registry()                    # recency order under test
    fp = platform_fingerprint(_FAKE_SIM)
    with tempfile.TemporaryDirectory() as tmp:
        tmp = Path(tmp)
        cache = TuningCache(tmp / "a.json")
        keys = []
        for (shapes, dtype), block in entries:
            key = CacheKey(abi=str(_SCALE_ABI), platform=fp,
                           shapes=shapes, dtype=dtype)
            cache.put(key, BlockConfig.make(block=block),
                      metrics={"best_us": float(block)})
            keys.append(key)
        cache.save()
        out, manifest = export_bundle(tmp / "a.tgz", cache_path=cache.path,
                                      platform=_FAKE_SIM)
        assert manifest["entries"]["count"] == len(entries)

        report = import_bundle(out, cache_path=tmp / "b.json",
                               platform=_FAKE_SIM, registry=reg)
        assert not report.cross_site
        assert report.counts()["imported"] == len(entries)
        imported = TuningCache.load(tmp / "b.json")
        # entry set and configs are preserved exactly
        assert set(imported.raw_keys()) == set(cache.raw_keys())
        for key, ((_, _), block) in zip(keys, entries):
            assert imported.get(key, touch=False) == \
                BlockConfig.make(block=block)
            assert not imported.is_demoted(key)
        # recency ORDER is preserved (stamps are re-issued monotonically)
        order = sorted(keys, key=lambda k: cache.last_used(k))
        order_b = sorted(keys, key=lambda k: imported.last_used(k))
        assert [k.encode() for k in order] == [k.encode() for k in order_b]

        # idempotence: the second import is a no-op, byte for byte
        before = (tmp / "b.json").read_bytes()
        again = import_bundle(out, cache_path=tmp / "b.json",
                              platform=_FAKE_SIM, registry=reg)
        assert not again.saved
        assert all(r.status == "already-present" for r in again.results)
        assert (tmp / "b.json").read_bytes() == before


@given(_matched(n_min=2))
@settings(max_examples=60, deadline=None)
def test_bounded_table_resolves_within_kept_head(data):
    """Bounded-mode invariant: a capped table only ever resolves to one of
    the K hottest (first-listed) buckets' configs, never a trimmed one."""
    buckets, query = data
    cap = max(1, len(set(buckets)) - 1)
    table = ConfigTable(
        "op",
        [GeometryOutcome(shapes=b, dtype="float32", status="cache-hit",
                         config=BlockConfig.make(block=i + 1),
                         count=len(buckets) - i)
         for i, b in enumerate(buckets)],
        default=BlockConfig.make(block=999),
        max_entries=cap,
    )
    assert len(table) <= cap
    kept_configs = {o.config["block"] for o in table.outcomes}
    cfg, how = table.resolve(shapes=query, dtype="float32")
    if how != "default":
        assert cfg["block"] in kept_configs
