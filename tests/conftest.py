import os
import sys

# Tests run on the single real CPU device — the 512-device dry-run env var
# is set ONLY inside repro.launch.dryrun subprocesses, never here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def subprocess_env(num_devices: int) -> dict:
    """Env for multi-device subprocess tests."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={num_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return env
