"""Single-manifest checkpoints: roundtrip, atomicity, async, Fig.3 counts."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    file_op_counts,
    latest_step,
    load_naive,
    restore_checkpoint,
    save_checkpoint,
    save_naive,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {
            "w": jax.random.normal(k, (4, 8)),
            "b": jnp.zeros(8, jnp.bfloat16),
        },
        "step_count": jnp.int32(17),
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 10, tree)
    restored, step = restore_checkpoint(tmp_path, tree, verify=True)
    assert step == 10
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        tree, restored,
    )
    # dtypes preserved (bf16 survives the blob)
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_latest_pointer_progression(tmp_path):
    save_checkpoint(tmp_path, 1, _tree())
    save_checkpoint(tmp_path, 5, _tree(1))
    assert latest_step(tmp_path) == 5
    _, step = restore_checkpoint(tmp_path, _tree())
    assert step == 5
    # explicit older step restorable
    _, step1 = restore_checkpoint(tmp_path, _tree(), step=1)
    assert step1 == 1


def test_corruption_detected(tmp_path):
    save_checkpoint(tmp_path, 2, _tree())
    blob = tmp_path / "step_0000000002" / "data.blob"
    raw = bytearray(blob.read_bytes())
    raw[0] ^= 0xFF
    blob.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        restore_checkpoint(tmp_path, _tree(), verify=True)


def test_crash_mid_save_preserves_previous(tmp_path):
    """Atomicity: a temp dir left behind never becomes LATEST."""
    save_checkpoint(tmp_path, 1, _tree())
    # simulate a crashed save: temp dir exists, LATEST untouched
    (tmp_path / ".tmp_step_0000000009").mkdir()
    (tmp_path / ".tmp_step_0000000009" / "data.blob").write_bytes(b"junk")
    assert latest_step(tmp_path) == 1
    restored, step = restore_checkpoint(tmp_path, _tree(), verify=True)
    assert step == 1


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    tree = _tree()
    ck.save(3, tree)
    ck.wait()
    assert latest_step(tmp_path) == 3
    restored, _ = restore_checkpoint(tmp_path, tree)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"])
    )


def test_reshard_on_restore(tmp_path):
    """sharding_fn places leaves; single-device smoke of the elastic path."""
    tree = _tree()
    save_checkpoint(tmp_path, 1, tree)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    restored, _ = restore_checkpoint(
        tmp_path, tree, sharding_fn=lambda path, arr: sh
    )
    assert restored["params"]["w"].sharding == sh


def test_naive_vs_manifest_op_counts(tmp_path):
    tree = _tree()
    n_files = save_naive(tmp_path / "naive", tree)
    assert n_files == 3
    counts = file_op_counts(tree)
    # the Fig. 3 claim: manifest metadata ops are O(1), naive are O(leaves)
    assert counts["manifest_metadata_ops"] == 3
    assert counts["naive_metadata_ops"] == 2 * n_files
    loaded = load_naive(tmp_path / "naive", tree)
    np.testing.assert_array_equal(
        np.asarray(loaded["params"]["w"]), np.asarray(tree["params"]["w"])
    )


def test_manifest_is_single_metadata_object(tmp_path):
    ckpt_dir = save_checkpoint(tmp_path, 4, _tree())
    files = sorted(p.name for p in ckpt_dir.iterdir())
    assert files == ["data.blob", "manifest.json"]
    manifest = json.loads((ckpt_dir / "manifest.json").read_text())
    assert manifest["format"] == "repro-manifest-v1"
    assert len(manifest["entries"]) == 3
