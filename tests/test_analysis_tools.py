"""Unit tests for the dry-run analysis tooling (HLO parsing, roofline)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.core.platform import TPU_V5E
from repro.launch.hlo_analysis import (
    collective_stats,
    cost_stats,
    memory_stats,
    roofline_terms,
)

HLO_SAMPLE = """
ENTRY %main {
  %ar = f32[16,512]{1,0} all-reduce(%x), replica_groups=[4,4]<=[16], to_apply=%add
  %ag = bf16[64,128]{1,0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={0}
  %rs = f32[8,32]{1,0} reduce-scatter(%z), replica_groups=[4,4]<=[16], to_apply=%add
  %a2a = f32[4,4]{1,0} all-to-all(%w), replica_groups={{0,1},{2,3}}
  %cp = f32[100]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %done = f32[16,512]{1,0} all-reduce-done(%ar)
}
"""


def test_collective_stats_parses_kinds_and_bytes():
    s = collective_stats(HLO_SAMPLE)
    c = s["count_by_kind"]
    assert c["all-reduce"] == 1 and c["all-gather"] == 1
    assert c["reduce-scatter"] == 1 and c["all-to-all"] == 1
    assert c["collective-permute"] == 1
    b = s["bytes_by_kind"]
    assert b["all-reduce"] == 16 * 512 * 4                  # operand == result
    assert b["all-gather"] == 64 * 128 * 2 // 8             # result / group
    assert b["reduce-scatter"] == 8 * 32 * 4 * 4            # result * group
    assert b["all-to-all"] == 4 * 4 * 4
    assert b["collective-permute"] == 100 * 4
    assert s["total_bytes"] == sum(b.values())
    # -done lines are not double counted
    assert s["total_count"] == 5


def test_collective_wire_bytes_ring_factors():
    s = collective_stats(HLO_SAMPLE)
    w = s["wire_by_kind"]
    # all-reduce ring: 2 * bytes * (g-1)/g with g=4
    assert w["all-reduce"] == pytest.approx(2 * 16 * 512 * 4 * 3 / 4)
    assert w["collective-permute"] == 100 * 4


def test_roofline_terms_and_dominance():
    t = roofline_terms(197e12, 819e9, 50e9, chips=256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)
    t2 = roofline_terms(1e12, 819e9 * 5, 0.0, chips=1)
    assert t2.dominant == "memory"
    assert t2.step_time_lower_bound_s == pytest.approx(5.0)


def test_cost_and_memory_stats_on_real_compile():
    f = jax.jit(lambda x: (x @ x).sum())
    compiled = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    c = cost_stats(compiled)
    assert c["flops"] >= 2 * 64 * 64 * 64 * 0.9
    m = memory_stats(compiled)
    assert m["argument_size_in_bytes"] == 64 * 64 * 4


def test_shape_applicability_matrix():
    """The assignment's long_500k rule: runs only for ssm/hybrid."""
    runnable = {
        a for a in ARCHS
        if shape_applicable(ARCHS[a], SHAPES["long_500k"])[0]
    }
    assert runnable == {"mamba2-780m", "jamba-1.5-large-398b"}
    for a in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(ARCHS[a], SHAPES[s])[0]


def test_total_cell_count_is_40():
    assert len(ARCHS) * len(SHAPES) == 40


def test_perf_variants_registry():
    from repro.launch.perf_variants import VARIANTS, get_rules

    assert "baseline" in VARIANTS
    assert get_rules("baseline") is VARIANTS["baseline"]
    with pytest.raises(KeyError):
        get_rules("nope")
    # no_fsdp drops the embed rule
    assert all(r[0] != "embed" for r in get_rules("no_fsdp").rules)
