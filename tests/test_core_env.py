"""CUDA_VISIBLE_DEVICES-analogue parsing + renumbering semantics."""

import jax
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, strategies as st  # noqa: E402

from repro.core.env import (
    ENV_PLATFORM,
    native_ops_default,
    parse_visible_devices,
    resolve_platform,
    select_devices,
)


@pytest.mark.parametrize(
    "value,active,indices",
    [
        (None, False, None),
        ("", False, None),
        ("all", True, None),
        ("ALL", True, None),
        ("0", True, (0,)),
        ("0,2", True, (0, 2)),
        (" 1 , 3 ", True, (1, 3)),
        ("0,0", False, None),        # duplicates invalid
        ("-1", False, None),
        ("junk", False, None),
        ("0,junk", False, None),
    ],
)
def test_parse_visible(value, active, indices):
    v = parse_visible_devices(value)
    assert v.active == active
    assert v.indices == indices


def test_renumbering_from_zero():
    """§IV-A.3: visible devices are addressable from logical index 0."""
    devs = list(jax.devices())
    v = parse_visible_devices("0")
    sel = select_devices(v, devs)
    assert sel == [devs[0]]
    # out-of-range physical ids are dropped, order preserved
    v2 = parse_visible_devices("5,0")
    sel2 = select_devices(v2, devs)
    assert sel2 == [devs[0]]


def test_invalid_value_keeps_all_devices():
    devs = list(jax.devices())
    assert select_devices(parse_visible_devices("junk"), devs) == devs


def test_platform_override_and_detection():
    assert resolve_platform({ENV_PLATFORM: "pod-v5e"}).name == "pod-v5e"
    with pytest.raises(KeyError):
        resolve_platform({ENV_PLATFORM: "nope"})
    assert resolve_platform({}).name == "laptop"  # 1 CPU device


def test_native_ops_default():
    assert native_ops_default({"REPRO_NATIVE_OPS": "1"})
    assert not native_ops_default({"REPRO_NATIVE_OPS": "0"})
    assert not native_ops_default({})


@given(st.lists(st.integers(0, 100), min_size=1, max_size=8, unique=True))
def test_valid_lists_always_activate(ids):
    v = parse_visible_devices(",".join(map(str, ids)))
    assert v.active and v.indices == tuple(ids)
