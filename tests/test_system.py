"""End-to-end behaviour: the paper's Fig. 2 workflow + fault-tolerant
training, on this host's devices."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import Gateway, Runtime
from repro.data import DataConfig, SyntheticStream
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import DeployOptions, make_deployment
from repro.launch.train import make_bundle, train_loop
from repro.optim import adamw_init

ARCH = "qwen2.5-14b"


@pytest.fixture()
def deployed(tmp_path):
    """Fig. 2 steps 1-5: build (laptop) -> push -> pull (gateway) -> run."""
    bundle = make_bundle(ARCH, reduced=True)               # 1-2: build + test
    gw = Gateway(tmp_path / "registry", tmp_path / "cache")
    gw.push(bundle)                                        # 3: push
    flat = gw.pull(f"{bundle.name}:latest")                # 4: shifterimg pull
    rt = Runtime(host_env={})
    container = rt.deploy(flat, mesh=make_host_mesh(data=1))   # 5: shifter run
    yield container, flat
    rt.cleanup()


def _deployment(container, batch=4, seq=32):
    from repro.configs.base import ModelConfig

    cfg = ModelConfig.from_dict(container.bundle.model_config)
    shape = ShapeConfig("sys", seq, batch, "train")
    dep = make_deployment(cfg, shape, container.mesh,
                          options=DeployOptions(donate=False),
                          binding=container.binding)
    stream = SyntheticStream(cfg, shape, DataConfig(seed=3))
    return cfg, dep, stream


def test_workflow_trains_and_loss_decreases(deployed):
    container, _ = deployed
    cfg, dep, stream = _deployment(container)
    _, _, losses = train_loop(dep, stream, steps=12, ckpt_dir=None, log_every=100)
    assert all(np.isfinite(losses))
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_checkpoint_restart_resumes_identically(deployed, tmp_path):
    """Kill-and-restart: steps 0..8 == steps 0..4 + restore + 5..8 (the
    deterministic pipeline + manifest checkpoint together give exact
    resume)."""
    container, _ = deployed
    cfg, dep, stream = _deployment(container)
    ckpt = tmp_path / "ckpt"

    p_full, o_full, losses_full = train_loop(
        dep, stream, steps=8, ckpt_dir=None, log_every=100
    )

    # run 0..4 with checkpointing, then "crash" and resume 4..8
    train_loop(dep, stream, steps=4, ckpt_dir=ckpt, ckpt_every=100, log_every=100)
    assert latest_step(ckpt) == 4
    skeleton = {
        "params": jax.tree.map(np.asarray, dep.model.init(jax.random.PRNGKey(0))),
        "opt": jax.tree.map(np.asarray, adamw_init(dep.model.init(jax.random.PRNGKey(0)))),
    }
    restored, step = restore_checkpoint(ckpt, skeleton)
    p2, o2, losses_resumed = train_loop(
        dep, stream, steps=8, start_step=step, ckpt_dir=None,
        params=jax.device_put(restored["params"], dep.param_sharding),
        opt_state=jax.device_put(restored["opt"], dep.opt_sharding),
        log_every=100,
    )
    np.testing.assert_allclose(losses_resumed, losses_full[4:], atol=1e-5, rtol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-5, rtol=2e-5
        ),
        p_full, p2,
    )


def test_native_off_vs_on_same_results(deployed):
    """Table III-V in numeric form: on a platform with no native features
    the swap is a no-op; binding reports explain why."""
    container, bundle = deployed
    assert all(not r.swapped for r in container.binding.reports)
    assert any("native" in r.reason for r in container.binding.reports)


def test_container_describe_mentions_mesh_and_ops(deployed):
    container, _ = deployed
    text = container.describe()
    assert "mesh" in text and "attention" in text


def test_straggler_plan_feeds_data_pipeline(deployed):
    from repro.ft import StragglerConfig, StragglerDetector

    container, _ = deployed
    cfg, dep, _ = _deployment(container)
    stream = SyntheticStream(cfg, ShapeConfig("sys", 32, 4, "train"),
                             DataConfig(seed=3, num_hosts=4))
    det = StragglerDetector(4, StragglerConfig(threshold=2.0, patience=1))
    plan = det.observe({0: 1.0, 1: 1.0, 2: 1.0, 3: 8.0})
    plan = det.observe({0: 1.0, 1: 1.0, 2: 1.0, 3: 8.0})
    assert 3 in plan.skip_hosts
    batch = stream.global_batch_at(0, skip_hosts=plan.skip_hosts)
    assert batch["tokens"].shape[0] == 4   # shape stable under mitigation
