"""Hypothesis property tests: kernels vs oracles across random shapes."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.platform import POD_SIM
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention_ref import attention_ref, decode_attention_ref
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.moe_gmm_ref import moe_gmm_exact
from repro.kernels.ops import _NATIVES_INTERPRET, tuners
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm_ref import rmsnorm_ref
from repro.tuning import bucket_shapes
from repro.tuning.config import BlockConfig

SETTINGS = dict(max_examples=10, deadline=None)
POISON = 50.0


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 9),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_property(rows, d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (rows, d))
    w = jax.random.normal(k2, (d,))
    out = rmsnorm(x, w, interpret=True, block_rows=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rmsnorm_ref(x, w)),
                               atol=1e-5, rtol=1e-5)


@settings(**SETTINGS)
@given(
    s=st.sampled_from([8, 16, 24]),
    kv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2]),
    dh=st.sampled_from([8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_property(s, kv, group, dh, causal, seed):
    h = kv * group
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, s, h, dh))
    k = jax.random.normal(ks[1], (1, s, kv, dh))
    v = jax.random.normal(ks[2], (1, s, kv, dh))
    out = flash_attention(q, k, v, causal=causal, block_q=8, block_k=8, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    t=st.integers(1, 24),
    e=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_gmm_property(t, e, seed):
    d, f = 8, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (t, d))
    w = jax.random.normal(ks[1], (e, d, f))
    # random partition of t rows into e groups
    if e == 1:
        gs = jnp.array([t], jnp.int32)
    else:
        splits = jnp.sort(jax.random.randint(ks[2], (e - 1,), 0, t + 1))
        gs = jnp.diff(jnp.concatenate(
            [jnp.zeros(1, jnp.int32), splits.astype(jnp.int32), jnp.full(1, t, jnp.int32)]
        ))
    out = moe_gmm(x, w, gs, block_m=8, block_n=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(moe_gmm_exact(x, w, gs)),
                               atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    sk=st.sampled_from([8, 16]),
    w1=st.integers(1, 16),
    delta=st.integers(0, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_window_widening_is_monotone(sk, w1, delta, seed):
    """Widening the window never drops attended keys.

    With k == 0 every score is 0, so the masked softmax is uniform over
    the attended set; one-hot values then make the kernel emit each set's
    indicator / |set| directly.  The support at window W must be a subset
    of the support at W + delta, and its size exactly min(W, i + 1)."""
    w1 = min(w1, sk)
    dh = 16
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, sk, 1, dh))
    k = jnp.zeros((1, sk, 1, dh))
    v = jnp.eye(sk, dh)[None, :, None, :]       # v[0, s, 0, s] = 1
    sup = []
    for w in (w1, w1 + delta):
        o = flash_attention(q, k, v, window=jnp.asarray(w, jnp.int32),
                            causal=True, block_q=8, block_k=8, interpret=True)
        sup.append(np.asarray(o)[0, :, 0, :sk] > 1e-3)
    narrow, wide = sup
    assert np.all(wide | ~narrow), "widening the window dropped a key"
    want = np.minimum(w1, np.arange(sk) + 1)    # (i - W, i] clipped at 0
    assert np.array_equal(narrow.sum(-1), want)


@settings(**SETTINGS)
@given(
    pos=st.integers(0, 31),
    w=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_out_of_window_pages_are_inert(pos, w, seed):
    """Pages wholly below the window start may hold arbitrary poison (the
    scheduler PARKs and recycles exactly those pages mid-flight): decode
    output must match the windowed ref on the clean logical cache."""
    b, smax, kv, h, dh, page = 1, 32, 1, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh))
    k = jax.random.normal(ks[1], (b, smax, kv, dh))
    v = jax.random.normal(ks[2], (b, smax, kv, dh))
    posv = jnp.asarray(pos, jnp.int32)
    wv = jnp.asarray(w, jnp.int32)
    want = decode_attention_ref(q, k, v, posv, None, wv)
    n = smax // page
    pool_k = jnp.full((1 + n, page, kv, dh), POISON).at[1:].set(
        k.reshape(n, page, kv, dh))
    pool_v = jnp.full((1 + n, page, kv, dh), POISON).at[1:].set(
        v.reshape(n, page, kv, dh))
    bt = jnp.arange(1, n + 1, dtype=jnp.int32)[None]
    dead = max(0, pos + 1 - w) // page          # the scheduler's dead-page rule
    bt = bt.at[0, :dead].set(0)
    out = _NATIVES_INTERPRET["decode_attention"](q, pool_k, pool_v, posv, bt, wv)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    sq=st.sampled_from([16, 32]),
    extra=st.sampled_from([0, 16]),
    group=st.sampled_from([1, 2]),
    kv=st.sampled_from([1, 2]),
    dh=st.sampled_from([8, 16]),
    w=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_windowed_bucket_roundtrip_is_feasible(sq, extra, group, kv, dh, w, seed):
    """bucket_shapes -> args_from_shapes round-trips every windowed
    geometry into a workload with the identical bucket (the window rides
    the bucket key as a scalar part) and at least one feasible config."""
    sk = sq + extra
    h = kv * group
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, h, dh))
    k = jax.random.normal(ks[1], (1, sk, kv, dh))
    v = jax.random.normal(ks[2], (1, sk, kv, dh))
    t = tuners()["windowed_attention"]
    shapes, dtype = bucket_shapes((q, k, v, jnp.asarray(w, jnp.int32)))
    synth = t.args_from_shapes(POD_SIM, shapes, dtype)
    assert synth is not None, f"no synth for bucket {shapes}"
    shapes2, dtype2 = bucket_shapes(synth)
    assert shapes2 == shapes and dtype2 == dtype
    feasible = [
        cfg for cfg in (BlockConfig.make(**dict(zip(t.space, vals)))
                        for vals in itertools.product(*t.space.values()))
        if t.feasible(cfg, POD_SIM, synth)
    ]
    assert feasible, f"no feasible config for bucket {shapes}"
