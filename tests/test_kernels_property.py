"""Hypothesis property tests: kernels vs oracles across random shapes."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.platform import POD_SIM
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention_ref import attention_ref, decode_attention_ref
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.moe_gmm_ref import moe_gmm_exact
from repro.kernels.ops import _NATIVES_INTERPRET, tuners
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm_ref import rmsnorm_ref
from repro.tuning import bucket_shapes
from repro.tuning.config import BlockConfig

SETTINGS = dict(max_examples=10, deadline=None)
POISON = 50.0


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 9),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_property(rows, d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (rows, d))
    w = jax.random.normal(k2, (d,))
    out = rmsnorm(x, w, interpret=True, block_rows=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rmsnorm_ref(x, w)),
                               atol=1e-5, rtol=1e-5)


@settings(**SETTINGS)
@given(
    s=st.sampled_from([8, 16, 24]),
    kv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2]),
    dh=st.sampled_from([8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_property(s, kv, group, dh, causal, seed):
    h = kv * group
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, s, h, dh))
    k = jax.random.normal(ks[1], (1, s, kv, dh))
    v = jax.random.normal(ks[2], (1, s, kv, dh))
    out = flash_attention(q, k, v, causal=causal, block_q=8, block_k=8, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    t=st.integers(1, 24),
    e=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_gmm_property(t, e, seed):
    d, f = 8, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (t, d))
    w = jax.random.normal(ks[1], (e, d, f))
    # random partition of t rows into e groups
    if e == 1:
        gs = jnp.array([t], jnp.int32)
    else:
        splits = jnp.sort(jax.random.randint(ks[2], (e - 1,), 0, t + 1))
        gs = jnp.diff(jnp.concatenate(
            [jnp.zeros(1, jnp.int32), splits.astype(jnp.int32), jnp.full(1, t, jnp.int32)]
        ))
    out = moe_gmm(x, w, gs, block_m=8, block_n=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(moe_gmm_exact(x, w, gs)),
                               atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    sk=st.sampled_from([8, 16]),
    w1=st.integers(1, 16),
    delta=st.integers(0, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_window_widening_is_monotone(sk, w1, delta, seed):
    """Widening the window never drops attended keys.

    With k == 0 every score is 0, so the masked softmax is uniform over
    the attended set; one-hot values then make the kernel emit each set's
    indicator / |set| directly.  The support at window W must be a subset
    of the support at W + delta, and its size exactly min(W, i + 1)."""
    w1 = min(w1, sk)
    dh = 16
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, sk, 1, dh))
    k = jnp.zeros((1, sk, 1, dh))
    v = jnp.eye(sk, dh)[None, :, None, :]       # v[0, s, 0, s] = 1
    sup = []
    for w in (w1, w1 + delta):
        o = flash_attention(q, k, v, window=jnp.asarray(w, jnp.int32),
                            causal=True, block_q=8, block_k=8, interpret=True)
        sup.append(np.asarray(o)[0, :, 0, :sk] > 1e-3)
    narrow, wide = sup
    assert np.all(wide | ~narrow), "widening the window dropped a key"
    want = np.minimum(w1, np.arange(sk) + 1)    # (i - W, i] clipped at 0
    assert np.array_equal(narrow.sum(-1), want)


@settings(**SETTINGS)
@given(
    pos=st.integers(0, 31),
    w=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_out_of_window_pages_are_inert(pos, w, seed):
    """Pages wholly below the window start may hold arbitrary poison (the
    scheduler PARKs and recycles exactly those pages mid-flight): decode
    output must match the windowed ref on the clean logical cache."""
    b, smax, kv, h, dh, page = 1, 32, 1, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh))
    k = jax.random.normal(ks[1], (b, smax, kv, dh))
    v = jax.random.normal(ks[2], (b, smax, kv, dh))
    posv = jnp.asarray(pos, jnp.int32)
    wv = jnp.asarray(w, jnp.int32)
    want = decode_attention_ref(q, k, v, posv, None, wv)
    n = smax // page
    pool_k = jnp.full((1 + n, page, kv, dh), POISON).at[1:].set(
        k.reshape(n, page, kv, dh))
    pool_v = jnp.full((1 + n, page, kv, dh), POISON).at[1:].set(
        v.reshape(n, page, kv, dh))
    bt = jnp.arange(1, n + 1, dtype=jnp.int32)[None]
    dead = max(0, pos + 1 - w) // page          # the scheduler's dead-page rule
    bt = bt.at[0, :dead].set(0)
    out = _NATIVES_INTERPRET["decode_attention"](q, pool_k, pool_v, posv, bt, wv)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    sq=st.sampled_from([16, 32]),
    extra=st.sampled_from([0, 16]),
    group=st.sampled_from([1, 2]),
    kv=st.sampled_from([1, 2]),
    dh=st.sampled_from([8, 16]),
    w=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_windowed_bucket_roundtrip_is_feasible(sq, extra, group, kv, dh, w, seed):
    """bucket_shapes -> args_from_shapes round-trips every windowed
    geometry into a workload with the identical bucket (the window rides
    the bucket key as a scalar part) and at least one feasible config."""
    sk = sq + extra
    h = kv * group
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, h, dh))
    k = jax.random.normal(ks[1], (1, sk, kv, dh))
    v = jax.random.normal(ks[2], (1, sk, kv, dh))
    t = tuners()["windowed_attention"]
    shapes, dtype = bucket_shapes((q, k, v, jnp.asarray(w, jnp.int32)))
    synth = t.args_from_shapes(POD_SIM, shapes, dtype)
    assert synth is not None, f"no synth for bucket {shapes}"
    shapes2, dtype2 = bucket_shapes(synth)
    assert shapes2 == shapes and dtype2 == dtype
    feasible = [
        cfg for cfg in (BlockConfig.make(**dict(zip(t.space, vals)))
                        for vals in itertools.product(*t.space.values()))
        if t.feasible(cfg, POD_SIM, synth)
    ]
    assert feasible, f"no feasible config for bucket {shapes}"


# ---------------------------------------------------------------------------
# quantization numerics (repro.kernels.quant)
# ---------------------------------------------------------------------------

from repro.kernels.quant import (  # noqa: E402
    quantize,
    quantize_per_channel,
    dequantize,
)


@settings(**SETTINGS)
@given(
    d=st.sampled_from([4, 8, 16]),
    f=st.sampled_from([4, 8, 32]),
    amp=st.floats(0.01, 100.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_roundtrip_error_bounded_per_channel(d, f, amp, seed):
    """int8 quantize -> dequantize reconstructs every element to within
    half a step of ITS channel's scale, at any input magnitude."""
    w = amp * jax.random.normal(jax.random.PRNGKey(seed), (d, f))
    q, s = quantize_per_channel(w, axis=-2, fmt="int8")
    err = np.abs(np.asarray(dequantize(q, s, axis=-2) - w))
    bound = np.asarray(s)[None, :] / 2 + 1e-6 * amp
    assert np.all(err <= bound), float((err - bound).max())


@settings(**SETTINGS)
@given(
    d=st.sampled_from([4, 8]),
    f=st.sampled_from([4, 8]),
    c=st.sampled_from([0.25, 0.5, 2.0, 4.0]),   # powers of two: exact in fp
    ch=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_per_channel_scale_invariance(d, f, c, ch, seed):
    """Rescaling ONE output channel rescales only that channel's scale;
    the int8 codes are invariant — per-channel really is per-channel."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (d, f))
    q0, s0 = quantize_per_channel(w, axis=-2, fmt="int8")
    w1 = w.at[:, ch].multiply(c)
    q1, s1 = quantize_per_channel(w1, axis=-2, fmt="int8")
    assert np.array_equal(np.asarray(q0), np.asarray(q1))
    np.testing.assert_allclose(np.asarray(s1)[ch], c * np.asarray(s0)[ch],
                               rtol=1e-6)
    others = np.arange(f) != ch
    assert np.array_equal(np.asarray(s0)[others], np.asarray(s1)[others])


@settings(**SETTINGS)
@given(
    n=st.integers(1, 64),
    amp=st.floats(1e-6, 1e6),
    fmt=st.sampled_from(["int8", "fp8"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_codes_never_exceed_symmetric_clip(n, amp, fmt, seed):
    """Codes stay inside the symmetric range at any magnitude: int8 in
    [-127, 127] (-128 unreachable, so negation is exact), fp8 finite."""
    x = amp * jax.random.normal(jax.random.PRNGKey(seed), (n,))
    q, s = quantize(x, fmt)
    assert float(s) > 0
    if fmt == "int8":
        qi = np.asarray(q, np.int32)
        assert qi.min() >= -127 and qi.max() <= 127
        qn, _ = quantize(-x, fmt)
        assert np.array_equal(np.asarray(qn, np.int32), -qi)
    else:
        assert np.all(np.isfinite(np.asarray(q, np.float32)))


@settings(**SETTINGS)
@given(
    t=st.integers(1, 48),
    d=st.sampled_from([8, 16, 32]),
    f=st.sampled_from([8, 32, 64]),
    fmt=st.sampled_from(["int8", "fp8"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_bucket_roundtrip_is_feasible(t, d, f, fmt, seed):
    """Every quantized matmul geometry buckets to a composite dtype
    ("float32+int8"/"+float8_e4m3fn") that args_from_shapes rebuilds
    bit-compatibly, with at least one feasible tuning config — autotune
    can always warm what serving emits."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (t, d))
    qw, scale = quantize_per_channel(
        jax.random.normal(ks[1], (d, f)), axis=-2, fmt=fmt)
    tu = tuners()["quant_matmul"]
    shapes, dtype = bucket_shapes((x, qw, scale))
    assert "+" in str(dtype)
    synth = tu.args_from_shapes(POD_SIM, shapes, dtype)
    assert synth is not None, f"no synth for bucket {shapes}"
    shapes2, dtype2 = bucket_shapes(synth)
    assert shapes2 == shapes and dtype2 == dtype
    feasible = [
        cfg for cfg in (BlockConfig.make(**dict(zip(tu.space, vals)))
                        for vals in itertools.product(*tu.space.values()))
        if tu.feasible(cfg, POD_SIM, synth)
    ]
    assert feasible, f"no feasible config for bucket {shapes}"
