"""Hypothesis property tests: kernels vs oracles across random shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention_ref import attention_ref
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.moe_gmm_ref import moe_gmm_exact
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm_ref import rmsnorm_ref

SETTINGS = dict(max_examples=10, deadline=None)


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 9),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_property(rows, d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (rows, d))
    w = jax.random.normal(k2, (d,))
    out = rmsnorm(x, w, interpret=True, block_rows=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rmsnorm_ref(x, w)),
                               atol=1e-5, rtol=1e-5)


@settings(**SETTINGS)
@given(
    s=st.sampled_from([8, 16, 24]),
    kv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2]),
    dh=st.sampled_from([8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_property(s, kv, group, dh, causal, seed):
    h = kv * group
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, s, h, dh))
    k = jax.random.normal(ks[1], (1, s, kv, dh))
    v = jax.random.normal(ks[2], (1, s, kv, dh))
    out = flash_attention(q, k, v, causal=causal, block_q=8, block_k=8, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(
    t=st.integers(1, 24),
    e=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_gmm_property(t, e, seed):
    d, f = 8, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (t, d))
    w = jax.random.normal(ks[1], (e, d, f))
    # random partition of t rows into e groups
    if e == 1:
        gs = jnp.array([t], jnp.int32)
    else:
        splits = jnp.sort(jax.random.randint(ks[2], (e - 1,), 0, t + 1))
        gs = jnp.diff(jnp.concatenate(
            [jnp.zeros(1, jnp.int32), splits.astype(jnp.int32), jnp.full(1, t, jnp.int32)]
        ))
    out = moe_gmm(x, w, gs, block_m=8, block_n=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(moe_gmm_exact(x, w, gs)),
                               atol=1e-4, rtol=1e-4)
