"""Attention conformance grid: ref vs pallas over dtype x shape x layout.

Modeled on the xformers memory-efficient-attention test matrix: one
parametrized grid sweeps every attention entry point (`flash_attention`
prefill, `chunk_attention`, `decode_attention`) over

  * dtype (fp32 / bf16, per-dtype tolerances),
  * seq/kv geometry — including ragged Sq < Sk, non-multiple-of-block
    tails, and GQA group widths,
  * causal diagonals and dynamic q_start offsets,
  * kv_len padding masks (unwritten cache slots),
  * contiguous vs paged layout (page pools + shuffled block tables,
    poisoned park page),
  * sliding windows W in {page, 2*page, >= kv_len} for the windowed
    variants, with W >= kv_len pinned bit-identical to full attention,

against a single fp32 masked-softmax oracle.  Every geometry is also
round-tripped through the tuner synthesizer (`bucket_shapes` ->
`args_from_shapes`), pinning that autotune/dispatch/bundles can rebuild
a workload for every shape the serving paths emit — paged ones included.
"""

import itertools
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.platform import POD_SIM
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention_ref import (
    attention_ref,
    chunk_attention_ref,
    decode_attention_ref,
    windowed_attention_ref,
)
from repro.kernels.ops import _NATIVES_INTERPRET, tuners
from repro.tuning import bucket_shapes
from repro.tuning.config import BlockConfig

TOLS = {"float32": 2e-5, "bfloat16": 2e-2}
DTYPES = tuple(TOLS)
POISON = 50.0     # park-page fill: loud if it ever leaks into an output


def _seed(*parts) -> int:
    """Fold a grid cell's identifying parts (fixture name, geometry,
    dtype, ...) into a stable 31-bit PRNG seed.  Every fixture in this
    file derives its randomness from its own cell id ONLY — never from a
    shared or ad-hoc key — so the repro recipe for any failure is simply
    `pytest "tests/test_attention_conformance.py::<failing id>"`: the
    single test regenerates bit-identical tensors regardless of which
    other cells ran (or didn't) in the same process."""
    return zlib.crc32(":".join(map(str, parts)).encode()) & 0x7FFFFFFF


def _mk(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.dtype(dtype))


def _oracle(q, k, v, kv_len=None, q_start=None, causal=True):
    """fp32 masked-softmax oracle of the flash kernel's exact semantics:
    query i (global position q_start + i) sees keys j with j < kv_len
    and, when causal, j <= q_start + i."""
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    group = h // kv
    scale = dh ** -0.5
    kv_len = jnp.broadcast_to(
        jnp.asarray(sk if kv_len is None else kv_len, jnp.int32), (b,))
    q_start = jnp.broadcast_to(
        jnp.asarray(sk - sq if q_start is None else q_start, jnp.int32), (b,))
    qg = (q.reshape(b, sq, kv, group, dh) * scale).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    ki = jnp.arange(sk)
    mask = ki[None, :] < kv_len[:, None]                       # (B, Sk)
    mask = mask[:, None, :]                                    # (B, 1, Sk)
    if causal:
        qi = jnp.arange(sq)[None, :, None] + q_start[:, None, None]
        mask = mask & (ki[None, None, :] <= qi)                # (B, Sq, Sk)
    else:
        mask = jnp.broadcast_to(mask, (b, sq, sk))
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def _paged_layout(k, v, page, seed):
    """Scatter a contiguous (B, S, KV, Dh) cache into page pools through a
    SHUFFLED permutation block table, so a kernel that ignores the table
    (or mixes up rows) cannot pass by accident.  Page 0 is the reserved
    park page, poisoned with a loud constant.  `seed` must come from
    `_seed(...)` over the calling cell's id parts (see its docstring)."""
    b, s = k.shape[:2]
    assert s % page == 0
    n = s // page
    npages = 1 + b * n
    perm = np.random.default_rng(seed).permutation(np.arange(1, npages))
    bt = jnp.asarray(perm.reshape(b, n), jnp.int32)
    pool_shape = (npages, page) + k.shape[2:]
    pool_k = jnp.full(pool_shape, POISON, k.dtype)
    pool_v = jnp.full(pool_shape, POISON, v.dtype)
    kb = k.reshape(b, n, page, *k.shape[2:]).reshape(b * n, page, *k.shape[2:])
    vb = v.reshape(b, n, page, *v.shape[2:]).reshape(b * n, page, *v.shape[2:])
    pool_k = pool_k.at[bt.reshape(-1)].set(kb)
    pool_v = pool_v.at[bt.reshape(-1)].set(vb)
    return pool_k, pool_v, bt


def _close(got, want, dtype, scale=1):
    tol = scale * TOLS[dtype]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# flash (prefill): geometry x causal x kv_len padding x layout
# ---------------------------------------------------------------------------

# (b, sq, sk, h, kv, dh) — ragged Sq < Sk, tails off the 8-wide blocks,
# GQA groups, and page-divisible extents for the paged variants
FLASH_GEOMS = [
    (1, 8, 8, 2, 2, 8),        # square, block-exact
    (2, 7, 19, 2, 1, 8),       # ragged + non-multiple-of-block tails
    (1, 30, 30, 2, 2, 8),      # multi-block with tail
    (1, 5, 40, 4, 2, 16),      # short queries vs long cache, GQA group 2
]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("pad", [0, 3])
@pytest.mark.parametrize("geom", FLASH_GEOMS, ids=lambda g: "x".join(map(str, g)))
def test_flash_grid(geom, pad, causal, dtype):
    b, sq, sk, h, kv, dh = geom
    ks = jax.random.split(jax.random.PRNGKey(_seed("flash", geom, dtype)), 3)
    q = _mk(ks[0], (b, sq, h, dh), dtype)
    k = _mk(ks[1], (b, sk, kv, dh), dtype)
    v = _mk(ks[2], (b, sk, kv, dh), dtype)
    kv_len = None if pad == 0 else jnp.asarray(sk - pad, jnp.int32)
    out = flash_attention(q, k, v, kv_len=kv_len, causal=causal,
                          block_q=8, block_k=8, interpret=True)
    want = _oracle(q, k, v, kv_len=kv_len, causal=causal)
    _close(out, want, dtype, scale=5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("geom", [(1, 8, 8, 2, 2, 8), (1, 5, 40, 4, 2, 16)],
                         ids=lambda g: "x".join(map(str, g)))
def test_flash_paged_matches_contiguous(geom, dtype):
    """Paged flash through a shuffled permutation table must equal the
    contiguous kernel bit-for-bit-ish — same math, different DMA route."""
    b, sq, sk, h, kv, dh = geom
    page = 8
    ks = jax.random.split(jax.random.PRNGKey(_seed("flash-paged", geom, dtype)), 3)
    q = _mk(ks[0], (b, sq, h, dh), dtype)
    k = _mk(ks[1], (b, sk, kv, dh), dtype)
    v = _mk(ks[2], (b, sk, kv, dh), dtype)
    kv_len = jnp.asarray(sk - 2, jnp.int32)
    cont = flash_attention(q, k, v, kv_len=kv_len, causal=True,
                           block_q=8, block_k=8, interpret=True)
    pool_k, pool_v, bt = _paged_layout(k, v, page, _seed("flash-paged", geom, dtype, "pool"))
    paged = flash_attention(q, pool_k, pool_v, kv_len=kv_len, causal=True,
                            block_q=8, block_k=8, interpret=True,
                            block_tables=bt, page_size=page)
    _close(paged, cont, dtype)


# ---------------------------------------------------------------------------
# decode_attention: pos offsets x padding x layout
# ---------------------------------------------------------------------------

# (b, smax, h, kv, dh, pos) — scalar and per-row vector positions,
# non-power-of-two extents, first/last-slot edges
DECODE_GEOMS = [
    (2, 32, 2, 2, 8, (5, 17)),
    (1, 24, 2, 1, 8, 10),
    (3, 48, 4, 2, 16, (0, 47, 20)),
]


def _decode_args(geom, dtype, tag="decode"):
    b, smax, h, kv, dh, pos = geom
    ks = jax.random.split(jax.random.PRNGKey(_seed(tag, geom, dtype)), 3)
    q = _mk(ks[0], (b, 1, h, dh), dtype)
    k = _mk(ks[1], (b, smax, kv, dh), dtype)
    v = _mk(ks[2], (b, smax, kv, dh), dtype)
    posv = jnp.asarray(pos, jnp.int32)
    return q, k, v, posv


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("geom", DECODE_GEOMS, ids=lambda g: f"smax{g[1]}b{g[0]}")
def test_decode_grid(geom, layout, dtype):
    q, k, v, pos = _decode_args(geom, dtype)
    want = decode_attention_ref(q, k, v, pos)   # pinned against _oracle below
    if layout == "paged":
        page = 8
        pool_k, pool_v, bt = _paged_layout(k, v, page, _seed("decode", geom, dtype, "pool"))
        out = _NATIVES_INTERPRET["decode_attention"](q, pool_k, pool_v, pos, bt)
        ref = decode_attention_ref(q, pool_k, pool_v, pos, bt)
        _close(ref, want, dtype)                # ref gather == logical cache
    else:
        out = _NATIVES_INTERPRET["decode_attention"](q, k, v, pos)
    _close(out, want, dtype, scale=5)


def test_decode_ref_matches_oracle():
    """The decode ref itself is pinned to the flash oracle (kv_len=pos+1,
    non-causal) so the grid above is anchored to one ground truth."""
    q, k, v, pos = _decode_args(DECODE_GEOMS[0], "float32")
    want = _oracle(q, k, v, kv_len=pos + 1, causal=False)
    _close(decode_attention_ref(q, k, v, pos), want, "float32")


# ---------------------------------------------------------------------------
# chunk_attention: q_start offsets x tails x layout
# ---------------------------------------------------------------------------

# (c, smax, h, kv, dh, pos) — chunk at the window start, mid-cache, and
# at a non-multiple-of-block offset; B == 1 (the serving prefill shape)
CHUNK_GEOMS = [
    (8, 32, 2, 2, 8, 8),
    (16, 48, 2, 1, 8, 16),
    (8, 24, 4, 2, 16, 0),
]


def _chunk_args(geom, dtype, tag="chunk"):
    c, smax, h, kv, dh, pos = geom
    ks = jax.random.split(jax.random.PRNGKey(_seed(tag, geom, dtype)), 3)
    q = _mk(ks[0], (1, c, h, dh), dtype)
    k = _mk(ks[1], (1, smax, kv, dh), dtype)
    v = _mk(ks[2], (1, smax, kv, dh), dtype)
    return q, k, v, pos


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("geom", CHUNK_GEOMS, ids=lambda g: f"c{g[0]}pos{g[5]}")
def test_chunk_grid(geom, layout, dtype):
    q, k, v, pos = _chunk_args(geom, dtype)
    want = chunk_attention_ref(q, k, v, pos)
    if layout == "paged":
        page = geom[0]                          # serving invariant: page == C
        pool_k, pool_v, bt = _paged_layout(k, v, page, _seed("chunk", geom, dtype, "pool"))
        out = _NATIVES_INTERPRET["chunk_attention"](q, pool_k, pool_v, pos, bt)
        _close(chunk_attention_ref(q, pool_k, pool_v, pos, bt), want, dtype)
    else:
        out = _NATIVES_INTERPRET["chunk_attention"](q, k, v, pos)
    _close(out, want, dtype, scale=5)


def test_chunk_ref_matches_oracle():
    """chunk_attention == flash with the diagonal re-anchored at pos and
    kv_len = pos + C."""
    q, k, v, pos = _chunk_args(CHUNK_GEOMS[0], "float32")
    want = _oracle(q, k, v, kv_len=pos + q.shape[1], q_start=pos, causal=True)
    _close(chunk_attention_ref(q, k, v, pos), want, "float32")


def test_paged_park_page_is_inert():
    """Zero (park) block-table entries past the written prefix must not
    leak the park page's poison into the output: the kv_len mask discards
    those lanes even though their DMAs are issued."""
    q, k, v, pos = _decode_args((2, 32, 2, 2, 8, (5, 9)), "float32", tag="park")
    pool_k, pool_v, bt = _paged_layout(k, v, 8, _seed("park", "pool"))
    bt = bt.at[:, 2:].set(0)                    # park everything past page 1
    out = _NATIVES_INTERPRET["decode_attention"](q, pool_k, pool_v, pos, bt)
    want = decode_attention_ref(q, k, v, pos)   # pos < 16: logical prefix only
    assert np.all(np.isfinite(np.asarray(out)))
    _close(out, want, "float32", scale=5)


# ---------------------------------------------------------------------------
# tuner synthesizer round-trip: every grid geometry must be rebuildable
# ---------------------------------------------------------------------------

def _no_scalars(shapes: str) -> str:
    """pos is traced in recorded traffic ('scalar'/1-d part) but a python
    int in synthesized args (invisible to bucket_shapes) — compare the
    array parts only."""
    return ",".join(p for p in shapes.split(",")
                    if p and p != "scalar" and "x" in p)


def _roundtrip(op, args, expect_feasible=True):
    t = tuners()[op]
    shapes, dtype = bucket_shapes(args)
    synth = t.args_from_shapes(POD_SIM, shapes, dtype)
    assert synth is not None, f"{op}: no synth for bucket {shapes}"
    shapes2, dtype2 = bucket_shapes(synth)
    assert _no_scalars(shapes2) == _no_scalars(shapes), (shapes2, shapes)
    assert dtype2 == dtype
    feasible = [
        cfg for cfg in (
            BlockConfig.make(**dict(zip(t.space, vals)))
            for vals in itertools.product(*t.space.values()))
        if t.feasible(cfg, POD_SIM, synth)
    ]
    if expect_feasible:
        assert feasible, f"{op}: no feasible config for bucket {shapes}"


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("geom", DECODE_GEOMS, ids=lambda g: f"smax{g[1]}b{g[0]}")
def test_decode_synth_roundtrip(geom, layout, dtype):
    q, k, v, pos = _decode_args(geom, dtype)
    if layout == "paged":
        page = 16                               # >= the space's smallest bk
        pool_k, pool_v, bt = _paged_layout(
            jnp.tile(k, (1, -(-32 // k.shape[1]), 1, 1))[:, :32],
            jnp.tile(v, (1, -(-32 // v.shape[1]), 1, 1))[:, :32], page,
            _seed("decode-rt", geom, dtype, "pool"))
        _roundtrip("decode_attention", (q, pool_k, pool_v, pos, bt))
    else:
        _roundtrip("decode_attention", (q, k, v, pos))


# ---------------------------------------------------------------------------
# windowed (sliding-window causal) variants: dtype x geometry x layout x W
#
# Window column legend — W in {page, 2*page, full}:
#   * page:  W == page size: the sharpest cut, most KV pages skipped;
#   * 2page: window straddles a page boundary mid-page;
#   * full:  W >= kv_len: must be BIT-IDENTICAL to the unwindowed kernel
#            (same mask, same skip set, same float ops).
# ---------------------------------------------------------------------------

WINDOWS = ("page", "2page", "full")


def _win(wtag, page, full):
    """Resolve a window column tag to a concrete W (int32 scalar)."""
    w = {"page": page, "2page": 2 * page, "full": full}[wtag]
    return jnp.asarray(w, jnp.int32)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("wtag", WINDOWS)
@pytest.mark.parametrize("geom", FLASH_GEOMS, ids=lambda g: "x".join(map(str, g)))
def test_windowed_flash_grid(geom, wtag, dtype):
    b, sq, sk, h, kv, dh = geom
    ks = jax.random.split(jax.random.PRNGKey(_seed("wflash", geom, dtype)), 3)
    q = _mk(ks[0], (b, sq, h, dh), dtype)
    k = _mk(ks[1], (b, sk, kv, dh), dtype)
    v = _mk(ks[2], (b, sk, kv, dh), dtype)
    w = _win(wtag, 8, sk)
    out = flash_attention(q, k, v, window=w, causal=True,
                          block_q=8, block_k=8, interpret=True)
    want = windowed_attention_ref(q, k, v, w)
    _close(out, want, dtype, scale=5)
    if wtag == "full":
        full = flash_attention(q, k, v, causal=True,
                               block_q=8, block_k=8, interpret=True)
        assert np.array_equal(np.asarray(out), np.asarray(full))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("wtag", WINDOWS)
@pytest.mark.parametrize("geom", [(1, 8, 8, 2, 2, 8), (1, 5, 40, 4, 2, 16)],
                         ids=lambda g: "x".join(map(str, g)))
def test_windowed_flash_paged_matches_contiguous(geom, wtag, dtype):
    """Paged + windowed flash: the window-start meta row shifts the block
    table to SMEM row 3+, so this pins the shifted index maps against the
    contiguous windowed kernel."""
    b, sq, sk, h, kv, dh = geom
    page = 8
    ks = jax.random.split(jax.random.PRNGKey(_seed("wflash-paged", geom, dtype)), 3)
    q = _mk(ks[0], (b, sq, h, dh), dtype)
    k = _mk(ks[1], (b, sk, kv, dh), dtype)
    v = _mk(ks[2], (b, sk, kv, dh), dtype)
    w = _win(wtag, page, sk)
    cont = flash_attention(q, k, v, window=w, causal=True,
                           block_q=8, block_k=8, interpret=True)
    pool_k, pool_v, bt = _paged_layout(
        k, v, page, _seed("wflash-paged", geom, dtype, "pool"))
    paged = flash_attention(q, pool_k, pool_v, window=w, causal=True,
                            block_q=8, block_k=8, interpret=True,
                            block_tables=bt, page_size=page)
    _close(paged, cont, dtype)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("wtag", WINDOWS)
@pytest.mark.parametrize("geom", DECODE_GEOMS, ids=lambda g: f"smax{g[1]}b{g[0]}")
def test_windowed_decode_grid(geom, layout, wtag, dtype):
    q, k, v, pos = _decode_args(geom, dtype, tag="wdecode")
    smax = geom[1]
    w = _win(wtag, 8, smax)                     # full: W >= pos+1 for all rows
    want = decode_attention_ref(q, k, v, pos, None, w)
    if layout == "paged":
        pool_k, pool_v, bt = _paged_layout(
            k, v, 8, _seed("wdecode", geom, dtype, "pool"))
        out = _NATIVES_INTERPRET["decode_attention"](q, pool_k, pool_v, pos, bt, w)
        full = _NATIVES_INTERPRET["decode_attention"](q, pool_k, pool_v, pos, bt)
        ref = decode_attention_ref(q, pool_k, pool_v, pos, bt, w)
        _close(ref, want, dtype)                # ref gather == logical cache
    else:
        out = _NATIVES_INTERPRET["decode_attention"](q, k, v, pos, None, w)
        full = _NATIVES_INTERPRET["decode_attention"](q, k, v, pos)
    _close(out, want, dtype, scale=5)
    if wtag == "full":
        assert np.array_equal(np.asarray(out), np.asarray(full))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("wtag", WINDOWS)
@pytest.mark.parametrize("geom", CHUNK_GEOMS, ids=lambda g: f"c{g[0]}pos{g[5]}")
def test_windowed_chunk_grid(geom, layout, wtag, dtype):
    q, k, v, pos = _chunk_args(geom, dtype, tag="wchunk")
    c, smax = geom[0], geom[1]
    w = _win(wtag, c, smax)                     # full: W >= pos+C for all geoms
    want = chunk_attention_ref(q, k, v, pos, None, w)
    if layout == "paged":
        page = c                                # serving invariant: page == C
        pool_k, pool_v, bt = _paged_layout(
            k, v, page, _seed("wchunk", geom, dtype, "pool"))
        out = _NATIVES_INTERPRET["chunk_attention"](q, pool_k, pool_v, pos, bt, w)
        full = _NATIVES_INTERPRET["chunk_attention"](q, pool_k, pool_v, pos, bt)
        _close(chunk_attention_ref(q, pool_k, pool_v, pos, bt, w), want, dtype)
    else:
        out = _NATIVES_INTERPRET["chunk_attention"](q, k, v, pos, None, w)
        full = _NATIVES_INTERPRET["chunk_attention"](q, k, v, pos)
    _close(out, want, dtype, scale=5)
    if wtag == "full":
        assert np.array_equal(np.asarray(out), np.asarray(full))


def test_windowed_ref_anchors():
    """Two sharp pins on the windowed oracle itself: W >= Sk reproduces the
    causal oracle exactly, and W == 1 collapses the softmax onto each
    query's own key (output == v at the query positions when group == 1)."""
    b, sq, sk, h, kv, dh = 2, 8, 8, 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(_seed("wref", "anchors")), 3)
    q = _mk(ks[0], (b, sq, h, dh), "float32")
    k = _mk(ks[1], (b, sk, kv, dh), "float32")
    v = _mk(ks[2], (b, sk, kv, dh), "float32")
    wide = windowed_attention_ref(q, k, v, jnp.asarray(sk, jnp.int32))
    _close(wide, attention_ref(q, k, v, causal=True), "float32")
    one = windowed_attention_ref(q, k, v, jnp.asarray(1, jnp.int32))
    want = jnp.repeat(v, h // kv, axis=2)       # each query sees only key i
    _close(one, want, "float32")


def test_windowed_dead_pages_are_inert():
    """Pages wholly below the window start may be PARKed (remapped to the
    poisoned page 0) by the scheduler's sliding-window recycler — the
    kernel must never read through them: the window mask (and the skipped
    grid steps) make their contents unobservable."""
    geom = (2, 32, 2, 2, 8, (17, 20))
    q, k, v, pos = _decode_args(geom, "float32", tag="wdead")
    w = jnp.asarray(8, jnp.int32)
    pool_k, pool_v, bt = _paged_layout(k, v, 8, _seed("wdead", "pool"))
    # window starts at pos-7 (>= 10 for both rows): page 0 (keys 0..7) is
    # wholly out-of-window for every row -> park it, as the scheduler would
    bt = bt.at[:, 0].set(0)
    out = _NATIVES_INTERPRET["decode_attention"](q, pool_k, pool_v, pos, bt, w)
    want = decode_attention_ref(q, k, v, pos, None, w)
    assert np.all(np.isfinite(np.asarray(out)))
    _close(out, want, "float32", scale=5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("geom", FLASH_GEOMS, ids=lambda g: "x".join(map(str, g)))
def test_windowed_synth_roundtrip(geom, dtype):
    b, sq, sk, h, kv, dh = geom
    ks = jax.random.split(jax.random.PRNGKey(_seed("wsynth", geom, dtype)), 3)
    q = _mk(ks[0], (b, sq, h, dh), dtype)
    k = _mk(ks[1], (b, sk, kv, dh), dtype)
    v = _mk(ks[2], (b, sk, kv, dh), dtype)
    # the space's smallest block_q is 16: shorter query extents synthesize
    # fine but legitimately have no feasible tuning config
    _roundtrip("windowed_attention", (q, k, v, jnp.asarray(8, jnp.int32)),
               expect_feasible=sq >= 16)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("geom", DECODE_GEOMS, ids=lambda g: f"smax{g[1]}b{g[0]}")
def test_windowed_decode_synth_roundtrip(geom, layout, dtype):
    q, k, v, pos = _decode_args(geom, dtype, tag="wdecode-rt")
    w = jnp.asarray(16, jnp.int32)
    if layout == "paged":
        page = 16                               # >= the space's smallest bk
        pool_k, pool_v, bt = _paged_layout(
            jnp.tile(k, (1, -(-32 // k.shape[1]), 1, 1))[:, :32],
            jnp.tile(v, (1, -(-32 // v.shape[1]), 1, 1))[:, :32], page,
            _seed("wdecode-rt", geom, dtype, "pool"))
        _roundtrip("decode_attention", (q, pool_k, pool_v, pos, bt, w))
    else:
        _roundtrip("decode_attention", (q, k, v, pos, None, w))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("geom", CHUNK_GEOMS, ids=lambda g: f"c{g[0]}pos{g[5]}")
def test_windowed_chunk_synth_roundtrip(geom, layout, dtype):
    q, k, v, pos = _chunk_args(geom, dtype, tag="wchunk-rt")
    w = jnp.asarray(16, jnp.int32)
    ok = geom[0] >= 16                          # see test_chunk_synth_roundtrip
    if layout == "paged":
        page = max(geom[0], 16)
        s = -(-k.shape[1] // page) * page
        pool_k, pool_v, bt = _paged_layout(
            jnp.pad(k, ((0, 0), (0, s - k.shape[1]), (0, 0), (0, 0))),
            jnp.pad(v, ((0, 0), (0, s - v.shape[1]), (0, 0), (0, 0))), page,
            _seed("wchunk-rt", geom, dtype, "pool"))
        _roundtrip("chunk_attention", (q, pool_k, pool_v, pos, bt, w),
                   expect_feasible=ok)
    else:
        _roundtrip("chunk_attention", (q, k, v, pos, None, w),
                   expect_feasible=ok)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("geom", CHUNK_GEOMS, ids=lambda g: f"c{g[0]}pos{g[5]}")
def test_chunk_synth_roundtrip(geom, layout, dtype):
    q, k, v, pos = _chunk_args(geom, dtype)
    # the chunk space's smallest block_q is 16: c=8 buckets synthesize
    # fine but legitimately have no feasible tuning config
    ok = geom[0] >= 16
    if layout == "paged":
        page = max(geom[0], 16)
        s = -(-k.shape[1] // page) * page
        pool_k, pool_v, bt = _paged_layout(
            jnp.pad(k, ((0, 0), (0, s - k.shape[1]), (0, 0), (0, 0))),
            jnp.pad(v, ((0, 0), (0, s - v.shape[1]), (0, 0), (0, 0))), page,
            _seed("chunk-rt", geom, dtype, "pool"))
        _roundtrip("chunk_attention", (q, pool_k, pool_v, pos, bt),
                   expect_feasible=ok)
    else:
        _roundtrip("chunk_attention", (q, k, v, pos), expect_feasible=ok)
