"""ABI string construction, compatibility semantics, parsing."""

import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, strategies as st  # noqa: E402

from repro.core.abi import AbiError, AbiString, parse_abi, signature_digest


def test_roundtrip():
    a = AbiString.make("attention", {"args": ["q", "k", "v"]}, major=2, minor=3)
    assert parse_abi(str(a)) == a


def test_same_signature_same_digest():
    s1 = signature_digest({"b": 2, "a": 1})
    s2 = signature_digest({"a": 1, "b": 2})
    assert s1 == s2  # dict order canonicalised


def test_different_signature_different_digest():
    assert signature_digest({"a": 1}) != signature_digest({"a": 2})


def test_compat_rules():
    base = AbiString.make("op", "sig", major=1, minor=1)
    newer_minor = AbiString.make("op", "sig", major=1, minor=2)
    older_minor = AbiString.make("op", "sig", major=1, minor=0)
    other_major = AbiString.make("op", "sig", major=2, minor=0)
    other_sig = AbiString.make("op", "sig2", major=1, minor=1)
    other_name = AbiString.make("op2", "sig", major=1, minor=1)

    assert base.compatible_with(base)
    assert base.compatible_with(newer_minor)      # provider newer minor OK
    assert not base.compatible_with(older_minor)  # provider too old
    assert not base.compatible_with(other_major)
    assert not base.compatible_with(other_sig)
    assert not base.compatible_with(other_name)


def test_why_incompatible_messages():
    a = AbiString.make("op", "sig", major=1)
    b = AbiString.make("op", "sig", major=2)
    assert "major" in a.why_incompatible(b)
    assert a.why_incompatible(a) is None


def test_malformed_parse():
    for bad in ["", "op", "op/1:2", "op/1:2/zzz", "Op/1:2/" + "0" * 12]:
        with pytest.raises(AbiError):
            parse_abi(bad)


@given(
    name=st.from_regex(r"[a-z][a-z0-9_.]{0,10}", fullmatch=True),
    major=st.integers(0, 99),
    minor=st.integers(0, 99),
    sig=st.dictionaries(st.text(max_size=5), st.integers(), max_size=4),
)
def test_parse_roundtrip_property(name, major, minor, sig):
    a = AbiString.make(name, sig, major=major, minor=minor)
    assert parse_abi(str(a)) == a


@given(
    minor_req=st.integers(0, 20),
    minor_prov=st.integers(0, 20),
)
def test_minor_version_monotonicity(minor_req, minor_prov):
    req = AbiString.make("op", "s", minor=minor_req)
    prov = AbiString.make("op", "s", minor=minor_prov)
    assert req.compatible_with(prov) == (minor_prov >= minor_req)
