"""OpRegistry: registration, binding/swap, refusal, freeze semantics."""

import pytest

from repro.core.abi import AbiIncompatibility, AbiString
from repro.core.platform import CLUSTER, LAPTOP, POD_V5E
from repro.core.registry import ImplKind, OpImpl, OpRegistry


def _abi(name="op", sig="s", minor=0):
    return AbiString.make(name, sig, minor=minor)


def _reg_with_op():
    reg = OpRegistry()
    reg.register(OpImpl(abi=_abi(), kind=ImplKind.REFERENCE, fn=lambda: "ref",
                        provider="jnp"))
    reg.register(OpImpl(abi=_abi(minor=1), kind=ImplKind.NATIVE, fn=lambda: "native",
                        requires_feature="pallas_kernels", provider="pallas"))
    return reg


def test_swap_on_capable_platform():
    reg = _reg_with_op()
    binding = reg.bind(["op"], POD_V5E, native=True, freeze=False)
    assert binding["op"]() == "native"
    assert binding.reports[0].swapped


def test_no_swap_when_disabled():
    reg = _reg_with_op()
    binding = reg.bind(["op"], POD_V5E, native=False, freeze=False)
    assert binding["op"]() == "ref"
    assert not binding.reports[0].swapped


def test_no_swap_without_feature():
    """Shifter on a host without the vendor stack keeps the container lib."""
    reg = _reg_with_op()
    binding = reg.bind(["op"], LAPTOP, native=True, freeze=False)
    assert binding["op"]() == "ref"
    assert "pallas_kernels" in binding.reports[0].reason


def test_abi_refusal_keeps_reference():
    reg = OpRegistry()
    reg.register(OpImpl(abi=_abi(sig="s1"), kind=ImplKind.REFERENCE, fn=lambda: "ref"))
    # incompatible native: registered permissively, must NOT be swapped in
    ok = reg.register(
        OpImpl(abi=_abi(sig="s2"), kind=ImplKind.NATIVE, fn=lambda: "bad"),
        strict=False,
    )
    assert not ok
    binding = reg.bind(["op"], POD_V5E, native=True, freeze=False)
    assert binding["op"]() == "ref"


def test_strict_registration_raises():
    reg = OpRegistry()
    reg.register(OpImpl(abi=_abi(sig="s1"), kind=ImplKind.REFERENCE, fn=lambda: 0))
    with pytest.raises(AbiIncompatibility):
        reg.register(OpImpl(abi=_abi(sig="s2"), kind=ImplKind.NATIVE, fn=lambda: 0))


def test_native_first_requires_reference():
    reg = OpRegistry()
    with pytest.raises(KeyError):
        reg.register(OpImpl(abi=_abi(), kind=ImplKind.NATIVE, fn=lambda: 0))


def test_freeze_blocks_registration():
    reg = _reg_with_op()
    reg.bind(["op"], CLUSTER, native=False, freeze=True)
    with pytest.raises(RuntimeError):
        reg.register(OpImpl(abi=_abi("op2"), kind=ImplKind.REFERENCE, fn=lambda: 0))
    reg.thaw()
    reg.register(OpImpl(abi=_abi("op2"), kind=ImplKind.REFERENCE, fn=lambda: 0))


def test_binding_reports_describe():
    reg = _reg_with_op()
    binding = reg.bind(["op"], POD_V5E, native=True, freeze=False)
    assert "op" in binding.describe()


def test_unknown_op():
    reg = _reg_with_op()
    with pytest.raises(KeyError):
        reg.bind(["nope"], LAPTOP, native=False, freeze=False)
