"""Autotuner subsystem: BlockConfig, search pruning, cache persistence,
cache-key stability, registry/Runtime integration (hits, misses, fallbacks)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core.abi import AbiString
from repro.core.bundle import Bundle
from repro.core.platform import POD_SIM, Platform
from repro.core.registry import ImplKind, OpImpl, OpRegistry
from repro.core.runtime import Runtime
from repro.kernels.ops import ABIS, register_all
from repro.tuning import (
    SCHEMA_VERSION,
    BlockConfig,
    CacheKey,
    OpTuner,
    TuningCache,
    TuningContext,
    default_config,
    enumerate_space,
    resolve_cache_path,
    search,
)

# ---------------------------------------------------------------- config --


def test_block_config_roundtrip_and_hash():
    cfg = BlockConfig.make(block_q=128, block_k=64)
    assert cfg["block_q"] == 128 and cfg.get("missing", 7) == 7
    assert BlockConfig.from_dict(cfg.to_dict()) == cfg
    assert hash(BlockConfig.make(block_k=64, block_q=128)) == hash(cfg)
    assert "block_k=64" in str(cfg)


def test_block_config_rejects_junk():
    with pytest.raises(ValueError):
        BlockConfig.make(block_rows=0)
    with pytest.raises(ValueError):
        BlockConfig.from_dict({"": 4})


def test_default_config_platform_override():
    assert default_config("rmsnorm")["block_rows"] == 256
    assert default_config("rmsnorm", POD_SIM)["block_rows"] == 64
    assert default_config("rmsnorm", "pod-sim") == default_config("rmsnorm", POD_SIM)
    assert default_config("unknown_op") == BlockConfig()


# ---------------------------------------------------------------- search --


def test_enumerate_space_cartesian():
    configs = enumerate_space({"a": (1, 2), "b": (3, 4, 5)})
    assert len(configs) == 6
    assert BlockConfig.make(a=2, b=4) in configs


def test_search_prunes_and_picks_fastest():
    import time

    def run_with(cfg):
        time.sleep(0.001 * cfg["a"])

    result = search(run_with, {"a": (1, 3, 8)},
                    feasible=lambda c: c["a"] < 8, iters=1, warmup=0)
    assert result.pruned == 1
    assert result.best == BlockConfig.make(a=1)
    assert len(result.measurements) == 2


def test_search_survives_failing_candidates():
    def run_with(cfg):
        if cfg["a"] != 2:
            raise RuntimeError("boom")
        return 0

    result = search(run_with, {"a": (1, 2, 3)}, iters=1, warmup=0)
    assert result.failed == 2
    assert result.best == BlockConfig.make(a=2)


# ----------------------------------------------------------------- cache --


def _key(shapes="128x256", abi="rmsnorm/1:0/abcdefabcdef"):
    return CacheKey(abi=abi, platform="pod-sim/cpu-host/cpu",
                    shapes=shapes, dtype="float32")


def test_cache_round_trip_persistence(tmp_path):
    path = tmp_path / "deep" / "tuning.json"
    cache = TuningCache(path)
    cache.put(_key(), BlockConfig.make(block_rows=64), metrics={"best_us": 12.5})
    assert cache.dirty
    cache.save()
    assert not cache.dirty

    reloaded = TuningCache.load(path)
    assert len(reloaded) == 1
    assert reloaded.get(_key()) == BlockConfig.make(block_rows=64)
    assert reloaded.metrics(_key())["best_us"] == 12.5
    assert reloaded.get(_key(shapes="512x512")) is None


def test_cache_corrupted_file_falls_back_empty(tmp_path):
    path = tmp_path / "tuning.json"
    path.write_text("{ this is not json")
    cache = TuningCache.load(path)
    assert len(cache) == 0
    assert cache.get(_key()) is None
    cache.put(_key(), BlockConfig.make(block_rows=8))
    cache.save()                       # corrupted file is recoverable in place
    assert TuningCache.load(path).get(_key()) == BlockConfig.make(block_rows=8)


def test_cache_stale_schema_ignored(tmp_path):
    path = tmp_path / "tuning.json"
    path.write_text(json.dumps({
        "schema": SCHEMA_VERSION + 1,
        "entries": {_key().encode(): {"config": {"block_rows": 4}}},
    }))
    assert TuningCache.load(path).get(_key()) is None


def test_cache_save_merges_concurrent_writers(tmp_path):
    """Two deployments tuning different ops against one site cache must
    both keep their winners (save is read-merge-replace, not clobber)."""
    path = tmp_path / "tuning.json"
    a = TuningCache(path)
    b = TuningCache(path)
    a.put(_key(abi="op_a/1:0/aaaaaaaaaaaa"), BlockConfig.make(block=2))
    b.put(_key(abi="op_b/1:0/bbbbbbbbbbbb"), BlockConfig.make(block=4))
    a.save()
    b.save()
    merged = TuningCache.load(path)
    assert merged.get(_key(abi="op_a/1:0/aaaaaaaaaaaa")) == BlockConfig.make(block=2)
    assert merged.get(_key(abi="op_b/1:0/bbbbbbbbbbbb")) == BlockConfig.make(block=4)


def test_cache_malformed_entry_dropped(tmp_path):
    path = tmp_path / "tuning.json"
    path.write_text(json.dumps({
        "schema": SCHEMA_VERSION,
        "entries": {
            "good": {"config": {"block_rows": 4}},
            "bad": {"config": {"block_rows": "huge"}},
        },
    }))
    assert len(TuningCache.load(path)) == 1


def test_cache_path_env_override(tmp_path):
    assert resolve_cache_path({"REPRO_TUNING_CACHE": str(tmp_path / "c.json")}) \
        == tmp_path / "c.json"
    assert resolve_cache_path({}).name == "tuning.json"


def test_cache_key_stable_across_processes():
    """The key derivation must be deterministic process-to-process, or the
    site cache would never hit after a restart."""
    snippet = (
        "from repro.kernels.ops import ABIS, tuners\n"
        "from repro.core.platform import POD_SIM\n"
        "from repro.tuning import CacheKey\n"
        "t = tuners()['rmsnorm']\n"
        "key = t.cache_key(str(ABIS['rmsnorm']), POD_SIM,"
        " t.example_args(POD_SIM))\n"
        "print(key.encode())\n"
    )
    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", snippet], capture_output=True, text=True,
        env=env, check=True,
    )
    from repro.kernels.ops import tuners

    t = tuners()["rmsnorm"]
    local = t.cache_key(str(ABIS["rmsnorm"]), POD_SIM, t.example_args(POD_SIM))
    assert out.stdout.strip() == local.encode()


# ------------------------------------------------- registry integration --

FAKE_SIM = Platform(
    name="fake-sim",
    hardware=POD_SIM.hardware,
    mesh_shape=(1,),
    mesh_axes=("data",),
    native_features=frozenset({"pallas_interpret"}),
)


def _tunable_registry():
    reg = OpRegistry()
    abi = AbiString.make("scale", {"args": ["x"]})
    reg.register(OpImpl(abi=abi, kind=ImplKind.REFERENCE,
                        fn=lambda x: x, provider="ref"))
    tuner = OpTuner(
        op="scale",
        space={"block": (2, 4, 8)},
        example_args=lambda platform: (1.5,),
        feasible=lambda cfg, platform, args: cfg["block"] <= 4,
        iters=1, warmup=0,
    )
    reg.register(OpImpl(
        abi=abi, kind=ImplKind.NATIVE,
        fn=lambda x, config=None: x * config["block"],
        requires_feature="pallas_interpret", provider="fake-native", tuner=tuner,
    ))
    return reg, abi


def test_bind_records_searched_then_hit(tmp_path):
    reg, _ = _tunable_registry()
    cache = TuningCache(tmp_path / "tuning.json")

    ctx = TuningContext(cache, FAKE_SIM)
    binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False, tuning=ctx)
    r = binding.reports[0]
    assert r.swapped and r.tuning == "cache-miss-searched"
    assert r.config in ("block=2", "block=4")        # pruned space only
    assert "tune: cache-miss-searched" in binding.describe()
    # the injected config actually drives the bound callable
    assert binding["scale"](1.0) in (2.0, 4.0)

    # the resolved config is exposed for call sites that pass explicit tiles
    assert binding.tuned_config("scale") is not None
    assert f"block={binding.tuned_config('scale')['block']}" == r.config

    ctx2 = TuningContext(cache, FAKE_SIM)
    binding2 = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False, tuning=ctx2)
    assert binding2.reports[0].tuning == "cache-hit"
    assert binding2.reports[0].config == r.config


def test_untuned_binding_exposes_no_config():
    reg, _ = _tunable_registry()
    binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False)
    assert binding.tuned_config("scale") is None
    assert binding.tuned_config("never_declared") is None


def test_bind_unselected_op_falls_back_to_default(tmp_path):
    reg, _ = _tunable_registry()
    ctx = TuningContext(TuningCache(tmp_path / "t.json"), FAKE_SIM,
                        ops={"some_other_op"})
    binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False, tuning=ctx)
    assert binding.reports[0].tuning == "cache-miss-default"


def test_bind_reference_impl_reports_no_tuning(tmp_path):
    reg, _ = _tunable_registry()
    ctx = TuningContext(TuningCache(tmp_path / "t.json"), FAKE_SIM)
    binding = reg.bind(["scale"], FAKE_SIM, native=False, freeze=False, tuning=ctx)
    assert binding.reports[0].tuning == "" and binding.reports[0].config == ""


def test_search_failure_falls_back_to_default(tmp_path):
    reg = OpRegistry()
    abi = AbiString.make("boom", {"args": ["x"]})
    reg.register(OpImpl(abi=abi, kind=ImplKind.REFERENCE, fn=lambda x: x))
    tuner = OpTuner(op="boom", space={"block": (2,)},
                    example_args=lambda platform: (1.0,),
                    feasible=lambda cfg, platform, args: False,  # prunes all
                    iters=1, warmup=0)
    reg.register(OpImpl(abi=abi, kind=ImplKind.NATIVE,
                        fn=lambda x, config=None: x,
                        requires_feature="pallas_interpret", tuner=tuner))
    cache = TuningCache(tmp_path / "t.json")
    ctx = TuningContext(cache, FAKE_SIM)
    binding = reg.bind(["boom"], FAKE_SIM, native=True, freeze=False, tuning=ctx)
    assert binding.reports[0].tuning == "search-failed-default"
    # the fallback is persisted: the failed search is paid once, not per deploy
    ctx.flush()
    ctx2 = TuningContext(TuningCache.load(cache.path), FAKE_SIM)
    binding2 = reg.bind(["boom"], FAKE_SIM, native=True, freeze=False, tuning=ctx2)
    assert binding2.reports[0].tuning == "cache-hit"


def test_cache_key_from_specs_matches_materialized_args():
    """Keys derived from abstract ShapeDtypeStructs must equal keys from
    the materialized arrays, or warm-cache deploys would never hit."""
    from repro.kernels.ops import tuners

    for op, t in tuners().items():
        assert t.example_specs is not None, op
        k_spec = t.cache_key("x/1:0/" + "0" * 12, POD_SIM, t.workload_spec(POD_SIM))
        k_args = t.cache_key("x/1:0/" + "0" * 12, POD_SIM, t.example_args(POD_SIM))
        assert k_spec == k_args, op


def test_ssd_scan_tuned_chunk_degrades_to_divisor():
    """A cached chunk that doesn't divide the live sequence must fall back
    to a common divisor instead of tripping the kernel assert."""
    import jax

    from repro.kernels.ssd_scan import ssd_scan
    from repro.kernels.ssd_scan_ref import ssd_scan_ref

    b, s, h, p, g, n = 1, 24, 2, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    import jax.numpy as jnp

    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    y, st = ssd_scan(x, dt, A, Bm, Cm,
                     config=BlockConfig.make(chunk=16),  # 24 % 16 != 0 -> gcd 8
                     interpret=True)
    yr, sr = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=8)
    import numpy as np

    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5, rtol=2e-5)


# ------------------------------------------------- runtime end-to-end --


def _rmsnorm_bundle():
    return Bundle(name="tune-demo", tag="t", model_config={}, recipe={},
                  required_ops={"rmsnorm": str(ABIS["rmsnorm"])}, env={})


def test_runtime_autotune_demo_pod_sim(tmp_path):
    """The acceptance demo: tuning rmsnorm in interpret mode on pod-sim
    writes a cache entry; a second Runtime deployment binds with a cache
    hit recorded in the SwapReport."""
    cache_path = tmp_path / "site" / "tuning.json"
    host_env = {"REPRO_PLATFORM": "pod-sim",
                "REPRO_TUNING_CACHE": str(cache_path)}

    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c1 = rt.deploy(_rmsnorm_bundle(), native_ops=True, autotune=True,
                   autotune_ops=["rmsnorm"])
    r1 = next(r for r in c1.binding.reports if r.op == "rmsnorm")
    assert r1.swapped and r1.bound == "pallas-interpret"
    assert r1.tuning == "cache-miss-searched" and r1.config
    assert cache_path.is_file()
    assert c1.autotune and "autotune: on" in c1.describe()
    assert c1.env["REPRO_TUNING_CACHE"] == str(cache_path)  # allowlisted
    rt.cleanup()

    rt2 = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c2 = rt2.deploy(_rmsnorm_bundle(), native_ops=True, autotune=True)
    r2 = next(r for r in c2.binding.reports if r.op == "rmsnorm")
    assert r2.tuning == "cache-hit" and r2.config == r1.config
    rt2.cleanup()


def test_runtime_autotune_off_leaves_reports_untouched():
    rt = Runtime(registry=register_all(OpRegistry()),
                 host_env={"REPRO_PLATFORM": "pod-sim"})
    c = rt.deploy(_rmsnorm_bundle(), native_ops=True, autotune=False)
    assert all(r.tuning == "" for r in c.binding.reports)
    assert not c.autotune
    rt.cleanup()
