"""Geometry-dispatched bindings: one bound op, many tuned configs.

Covers the PR 3 acceptance loop at unit scale: a warmed cache plus one
shape-polymorphic deploy binds >= 2 *distinct* tuned configs for the
same op with zero searches; dispatch-under-jit resolves each compiled
geometry's own config without retracing blowup; and the fallback chain
(exact -> nearest bucket -> platform default) is exercised per branch.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core.abi import AbiString
from repro.core.platform import POD_SIM, Platform
from repro.core.registry import ImplKind, OpImpl, OpRegistry
from repro.core.runtime import Runtime
from repro.kernels.ops import ABIS, register_all
from repro.tuning import (
    BlockConfig,
    CacheKey,
    ConfigTable,
    GeometryOutcome,
    OpTuner,
    TunedDispatch,
    TuningCache,
    TuningContext,
    WorkloadProfile,
    bucket_distance,
    platform_fingerprint,
)

FAKE_SIM = Platform(
    name="fake-sim",
    hardware=POD_SIM.hardware,
    mesh_shape=(1,),
    mesh_axes=("data",),
    native_features=frozenset({"pallas_interpret"}),
)


# ------------------------------------------------------------- distance --


def test_bucket_distance_log_space():
    assert bucket_distance("64x32,32", "64x32,32") == 0.0
    assert bucket_distance("64x32,32", "128x32,32") == 1.0   # one doubling
    assert bucket_distance("64x32,32", "128x64,32") == 2.0
    # structural mismatches are incomparable, not "far"
    assert bucket_distance("64x32,32", "64x32") is None          # arg count
    assert bucket_distance("64x32,32", "64x32x2,32") is None     # rank
    assert bucket_distance("junk-bucket", "64x32") is None


# ---------------------------------------------------------- config table --


def _table():
    return ConfigTable(
        "scale",
        [
            GeometryOutcome(shapes="64x32,32", dtype="float32",
                            status="cache-hit",
                            config=BlockConfig.make(block=64), count=9),
            GeometryOutcome(shapes="8x32,32", dtype="float32",
                            status="cache-hit",
                            config=BlockConfig.make(block=8), count=3),
        ],
        default=BlockConfig.make(block=2),
    )


def test_config_table_fallback_chain_per_branch():
    table = _table()
    # exact: the call's bucket has its own entry
    cfg, how = table.resolve(shapes="8x32,32", dtype="float32")
    assert (cfg["block"], how) == (8, "exact")
    # nearest: same structure, unseen bucket -> closest tuned bucket wins
    cfg, how = table.resolve(shapes="16x32,32", dtype="float32")
    assert (cfg["block"], how) == (8, "nearest")
    cfg, how = table.resolve(shapes="256x32,32", dtype="float32")
    assert (cfg["block"], how) == (64, "nearest")
    # default: structurally foreign geometry
    cfg, how = table.resolve(shapes="16x16", dtype="float32")
    assert (cfg["block"], how) == (2, "default")
    # near-dtype: bf16 traffic with only fp32-warmed buckets borrows the
    # same-structure entry at a distance penalty instead of the default
    cfg, how = table.resolve(shapes="8x32,32", dtype="bfloat16")
    assert (cfg["block"], how) == (8, "near-dtype")
    # primary is the hottest geometry's config (the old top-1 view)
    assert table.primary["block"] == 64
    assert len(table) == 2 and "+1 more" in str(table)


def test_near_dtype_borrow_is_validated_and_penalized():
    from repro.tuning import DTYPE_PENALTY

    # a same-dtype bucket within the penalty radius beats an exact-shape
    # foreign-dtype bucket; beyond it, the borrow wins
    table = ConfigTable(
        "scale",
        [
            GeometryOutcome(shapes="8x32,32", dtype="bfloat16",
                            status="cache-hit",
                            config=BlockConfig.make(block=16), count=5),
            GeometryOutcome(shapes="64x32,32", dtype="float32",
                            status="cache-hit",
                            config=BlockConfig.make(block=64), count=3),
        ],
        default=BlockConfig.make(block=2),
    )
    # bf16 query at 16x32,32: own-dtype neighbour is 1 doubling away,
    # the fp32 bucket 2 + DTYPE_PENALTY — own dtype wins
    cfg, how = table.resolve(shapes="16x32,32", dtype="bfloat16")
    assert (cfg["block"], how) == (16, "nearest")
    # fp32 query at 64x32,32 hits exactly despite the hotter bf16 entry
    cfg, how = table.resolve(shapes="64x32,32", dtype="float32")
    assert (cfg["block"], how) == (64, "exact")
    assert DTYPE_PENALTY > 0

    # the validator gates the borrow: a config that fails the borrowing
    # dtype's feasibility check falls through to the next candidate
    rejected = []

    def validate(config, shapes, dtype):
        rejected.append((str(config), shapes, dtype))
        return config["block"] <= 16

    gated = ConfigTable(
        "scale",
        [GeometryOutcome(shapes="8x32,32", dtype="float32",
                         status="cache-hit",
                         config=BlockConfig.make(block=64), count=1)],
        default=BlockConfig.make(block=2),
        validate=validate,
    )
    cfg, how = gated.resolve(shapes="8x32,32", dtype="bfloat16")
    assert (cfg["block"], how) == (2, "default")     # borrow refused
    assert rejected == [("block=64", "8x32,32", "bfloat16")]


def test_resolve_shapes_without_dtype_is_dtype_agnostic():
    """Regression: an explicit ``shapes=`` lookup with no ``dtype`` used to
    assume the hottest geometry's dtype, so a bucket tuned under any OTHER
    dtype mis-resolved to a foreign nearest entry."""
    table = ConfigTable(
        "scale",
        [
            GeometryOutcome(shapes="64x32,32", dtype="float32",
                            status="cache-hit",
                            config=BlockConfig.make(block=64), count=9),
            GeometryOutcome(shapes="8x32,32", dtype="bfloat16",
                            status="cache-hit",
                            config=BlockConfig.make(block=8), count=1),
        ],
        default=BlockConfig.make(block=2),
    )
    # the bf16-tuned bucket is found even though the hottest entry is fp32
    cfg, how = table.resolve(shapes="8x32,32")
    assert (cfg["block"], how) == (8, "exact")
    # unseen bucket: nearest over ALL dtypes, no penalty (dtype unknown)
    cfg, how = table.resolve(shapes="16x32,32")
    assert (cfg["block"], how) == (8, "nearest")
    # structurally foreign still defaults
    assert table.resolve(shapes="4")[1] == "default"


def test_config_table_bounded_mode_keeps_head():
    outcomes = [
        GeometryOutcome(shapes=f"{2 ** i}x32,32", dtype="float32",
                        status="cache-hit",
                        config=BlockConfig.make(block=2 ** i), count=10 - i)
        for i in range(4)
    ]
    table = ConfigTable("scale", outcomes, default=BlockConfig.make(block=2),
                        max_entries=2)
    assert len(table) == 2
    assert {o.shapes for o in table.outcomes} == {"1x32,32", "2x32,32"}
    # a trimmed bucket now resolves through the fallback chain
    cfg, how = table.resolve(shapes="8x32,32", dtype="float32")
    assert how == "nearest" and cfg["block"] == 2


def test_config_table_resolve_from_args():
    table = _table()
    args = (jnp.zeros((60, 32)), jnp.zeros((32,)))   # buckets to 64x32,32
    cfg, how = table.resolve(args)
    assert (cfg["block"], how) == (64, "exact")


# ------------------------------------------------------ dispatch + jit --


def _seeded_registry_and_cache(tmp_path):
    """A tunable 'scale' op plus a cache holding DISTINCT configs for two
    geometries of it — the deterministic stand-in for a warmed site."""
    reg = OpRegistry()
    abi = AbiString.make("scale", {"args": ["x"]})
    reg.register(OpImpl(abi=abi, kind=ImplKind.REFERENCE,
                        fn=lambda x: x, provider="ref"))
    tuner = OpTuner(op="scale", space={"block": (3, 5)},
                    example_args=lambda platform: (jnp.zeros((4, 4)),),
                    iters=1, warmup=0)
    reg.register(OpImpl(
        abi=abi, kind=ImplKind.NATIVE,
        fn=lambda x, config=None: x * config["block"],
        requires_feature="pallas_interpret", provider="fake-native",
        tuner=tuner,
    ))
    fp = platform_fingerprint(FAKE_SIM)
    cache = TuningCache(tmp_path / "tuning.json")
    cache.put(CacheKey(abi=str(abi), platform=fp, shapes="4x4", dtype="float32"),
              BlockConfig.make(block=3))
    cache.put(CacheKey(abi=str(abi), platform=fp, shapes="8x4", dtype="float32"),
              BlockConfig.make(block=5))
    return reg, abi, cache


def test_warmed_deploy_binds_two_distinct_configs_zero_searches(tmp_path):
    """The acceptance unit test: one shape-polymorphic bind against a
    warmed cache carries >= 2 distinct tuned configs for the same op,
    pays zero searches, and surfaces both geometries in the SwapReport
    and describe()."""
    reg, _, cache = _seeded_registry_and_cache(tmp_path)
    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("scale", (jnp.zeros((4, 4)),), weight=5)
    prof.record("scale", (jnp.zeros((8, 4)),), weight=2)

    ctx = TuningContext(cache, FAKE_SIM, profile=prof, search_on_miss=False)
    binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False,
                       tuning=ctx)
    assert ctx.searches_spent == 0
    rep = binding.reports[0]
    assert rep.tuning == "cache-hit"
    assert len(rep.geometries) == 2
    assert all(g.status == "cache-hit" for g in rep.geometries)
    configs = {str(g.config) for g in rep.geometries}
    assert configs == {"block=3", "block=5"}          # distinct tuned configs
    assert "4x4/float32" in binding.describe()
    assert "8x4/float32" in binding.describe()
    # per-geometry tuned_config resolution (and the shape-less primary)
    assert binding.tuned_config("scale")["block"] == 3          # hottest
    assert binding.tuned_config("scale", (jnp.zeros((8, 4)),))["block"] == 5
    assert binding.tuned_config("scale", shapes="8x4", dtype="float32")["block"] == 5


def test_dispatch_under_jit_distinct_geometries_no_retrace_blowup(tmp_path):
    """Distinct geometries of ONE bound op resolve distinct configs; the
    resolution happens at trace time, so N calls at one geometry cost one
    resolution (== one trace), not N."""
    reg, _, cache = _seeded_registry_and_cache(tmp_path)
    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("scale", (jnp.zeros((4, 4)),))
    prof.record("scale", (jnp.zeros((8, 4)),))
    ctx = TuningContext(cache, FAKE_SIM, profile=prof, search_on_miss=False)
    binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False,
                       tuning=ctx)

    fn = jax.jit(binding["scale"])
    a = jnp.ones((4, 4))
    b = jnp.ones((8, 4))
    for _ in range(4):
        assert float(fn(a)[0, 0]) == 3.0    # 4x4 bucket -> block=3
    assert float(fn(b)[0, 0]) == 5.0        # 8x4 bucket -> block=5

    dispatch = binding.impl("scale").fn
    assert isinstance(dispatch, TunedDispatch)
    # 2 compiled geometries -> exactly 2 resolutions despite 5 calls
    assert dispatch.stats == {"exact": 2, "nearest": 0, "near-dtype": 0,
                              "demoted": 0, "default": 0, "explicit": 0}
    assert dispatch.hit_rate == 1.0


def test_dispatch_nearest_and_default_branches_in_binding(tmp_path):
    reg, _, cache = _seeded_registry_and_cache(tmp_path)
    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("scale", (jnp.zeros((4, 4)),))
    prof.record("scale", (jnp.zeros((8, 4)),))
    ctx = TuningContext(cache, FAKE_SIM, profile=prof, search_on_miss=False)
    binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False,
                       tuning=ctx)
    dispatch = binding.impl("scale").fn
    # unseen same-structure bucket -> nearest tuned entry (8x4 -> block=5)
    assert float(binding["scale"](jnp.ones((16, 4)))[0, 0]) == 5.0
    assert dispatch.stats["nearest"] == 1
    # structurally foreign geometry -> platform default for 'scale'
    # (BlockConfig() is empty -> the fake fn would KeyError; assert the
    # default branch is taken via stats with a config-tolerant call)
    cfg, how = dispatch.table.resolve(shapes="4", dtype="float32")
    assert how == "default"


def test_explicit_config_kwarg_still_wins(tmp_path):
    reg, _, cache = _seeded_registry_and_cache(tmp_path)
    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("scale", (jnp.zeros((4, 4)),))
    ctx = TuningContext(cache, FAKE_SIM, profile=prof, search_on_miss=False)
    binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False,
                       tuning=ctx)
    out = binding["scale"](jnp.ones((4, 4)), config=BlockConfig.make(block=7))
    assert float(out[0, 0]) == 7.0
    assert binding.impl("scale").fn.stats["explicit"] == 1


# ----------------------------------------------------------- search budget --


def test_search_budget_exhausted_binds_default(tmp_path):
    """With budget=1 and two cold profiled buckets, exactly one search runs;
    the second bucket binds the platform default and says so."""
    reg = OpRegistry()
    abi = AbiString.make("scale", {"args": ["x"]})
    reg.register(OpImpl(abi=abi, kind=ImplKind.REFERENCE,
                        fn=lambda x: x, provider="ref"))
    tuner = OpTuner(
        op="scale", space={"block": (2, 4)},
        example_args=lambda platform: (jnp.zeros((4, 4)),),
        args_from_shapes=lambda platform, shapes, dtype: (
            jnp.zeros(tuple(int(d) for d in shapes.split(",")[0].split("x"))),),
        iters=1, warmup=0,
    )
    reg.register(OpImpl(
        abi=abi, kind=ImplKind.NATIVE,
        fn=lambda x, config=None: x * (config["block"] if "block" in config else 1),
        requires_feature="pallas_interpret", provider="fake-native",
        tuner=tuner,
    ))
    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("scale", (jnp.zeros((4, 4)),), weight=5)
    prof.record("scale", (jnp.zeros((8, 4)),), weight=1)
    cache = TuningCache(tmp_path / "t.json")
    ctx = TuningContext(cache, FAKE_SIM, profile=prof, search_budget=1)
    binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False,
                       tuning=ctx)
    assert ctx.searches_spent == 1
    statuses = {g.shapes: g.status for g in binding.reports[0].geometries}
    assert statuses["4x4"] == "cache-miss-searched"      # hottest searched
    assert statuses["8x4"] == "search-budget-exhausted"
    assert "mixed(" in binding.reports[0].tuning


# ------------------------------------------- profile-driven op selection --


def test_runtime_profile_driven_op_ordering_and_budget(tmp_path):
    """autotune_ops=None + a profile: ops bind hottest-first, the rank is
    in the SwapReport, and REPRO_SEARCH_BUDGET=0 suppresses every search."""
    from repro.core.bundle import Bundle

    profile_path = tmp_path / "workload.json"
    prof = WorkloadProfile(profile_path)
    prof.record("moe_gmm", (jnp.zeros((16, 32), jnp.float32),
                            jnp.zeros((4, 32, 32), jnp.float32),
                            jnp.zeros((4,), jnp.int32)), weight=9)
    prof.record("rmsnorm", (jnp.zeros((16, 32), jnp.float32),
                            jnp.zeros((32,), jnp.float32)), weight=2)
    prof.save()

    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(tmp_path / "tuning.json"),
        "REPRO_WORKLOAD_PROFILE": str(profile_path),
        "REPRO_SEARCH_BUDGET": "0",
    }
    ops = ("rmsnorm", "moe_gmm")
    bundle = Bundle(name="b", tag="t", model_config={}, recipe={},
                    required_ops={op: str(ABIS[op]) for op in ops}, env={})
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c = rt.deploy(bundle, native_ops=True, autotune=True)
    rt.cleanup()
    reports = list(c.binding.reports)
    # hottest op binds (and would search) first, rank recorded
    assert [r.op for r in reports] == ["moe_gmm", "rmsnorm"]
    assert reports[0].search_rank == 1 and reports[1].search_rank == 2
    assert "search#1" in c.binding.describe()
    # budget 0: nothing searched, every cold bucket binds the default
    for r in reports:
        assert all(g.status == "search-budget-exhausted" for g in r.geometries)


def test_search_budget_env_parsing():
    from repro.core.env import search_budget_default

    assert search_budget_default({}) is None
    assert search_budget_default({"REPRO_SEARCH_BUDGET": "3"}) == 3
    assert search_budget_default({"REPRO_SEARCH_BUDGET": "0"}) == 0
    assert search_budget_default({"REPRO_SEARCH_BUDGET": "junk"}) is None
    assert search_budget_default({"REPRO_SEARCH_BUDGET": "-2"}) is None


# ----------------------------------------------------------- profile decay --


def test_profile_decay_reranks_after_traffic_shift(tmp_path):
    prof = WorkloadProfile(tmp_path / "w.json")
    old_geom = (jnp.zeros((64, 32)),)
    new_geom = (jnp.zeros((8, 32)),)
    prof.record("rmsnorm", old_geom, weight=10)
    prof.save()

    aged = WorkloadProfile.load(tmp_path / "w.json")
    dropped = aged.decay(0.1)          # 10 -> 1.0, stays above floor
    assert dropped == 0
    aged.record("rmsnorm", new_geom, weight=3)
    aged.save()

    reloaded = WorkloadProfile.load(tmp_path / "w.json")
    top = reloaded.top(op="rmsnorm")
    assert top[0][0].shapes == "8x32" and top[0][1] == 3     # fresh wins
    assert top[1][1] == pytest.approx(1.0)                   # aged history

    # a second aggressive decay floors both buckets (1.0 and 3 -> 0.1, 0.3)
    again = WorkloadProfile.load(tmp_path / "w.json")
    assert again.decay(0.1) == 2
    again.save()
    assert len(WorkloadProfile.load(tmp_path / "w.json")) == 0


def test_profile_decay_rejects_bad_factor(tmp_path):
    prof = WorkloadProfile(tmp_path / "w.json")
    with pytest.raises(ValueError):
        prof.decay(1.5)
    with pytest.raises(ValueError):
        prof.decay(0.0)


def test_profile_op_totals(tmp_path):
    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("a", (jnp.zeros((4, 4)),), weight=2)
    prof.record("a", (jnp.zeros((8, 4)),), weight=3)
    prof.record("b", (jnp.zeros((4, 4)),), weight=1)
    assert prof.op_totals() == {"a": 5, "b": 1}


# ----------------------------------------------- cache sweep into binding --


def test_binding_sweeps_warmed_entries_beyond_profile_top_k(tmp_path):
    """A cache warmed deeper than the profile's current top-K still binds
    every entry: the sweep adds them as extra cache-hit geometries."""
    reg, abi, cache = _seeded_registry_and_cache(tmp_path)
    fp = platform_fingerprint(FAKE_SIM)
    cache.put(CacheKey(abi=str(abi), platform=fp, shapes="32x4",
                       dtype="float32"), BlockConfig.make(block=9))
    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("scale", (jnp.zeros((4, 4)),))
    ctx = TuningContext(cache, FAKE_SIM, profile=prof, search_on_miss=False,
                        top_k=1)
    binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False,
                       tuning=ctx)
    geoms = {g.shapes for g in binding.reports[0].geometries}
    assert geoms == {"4x4", "8x4", "32x4"}
    assert float(binding["scale"](jnp.ones((32, 4)))[0, 0]) == 9.0
