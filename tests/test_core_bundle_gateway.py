"""Bundle image format + Gateway pull/flatten/convert semantics."""

import pytest

from repro.core.bundle import Bundle, BundleError
from repro.core.gateway import Gateway, GatewayError


def _bundle(name="base", tag="latest", base=None, **over):
    return Bundle(
        name=name,
        tag=tag,
        base=base,
        model_config=over.get("model_config", {"d_model": 64}),
        recipe=over.get("recipe", {"lr": 1e-3}),
        required_ops=over.get("required_ops", {}),
        env=over.get("env", {"A": "1"}),
    )


def test_bundle_roundtrip(tmp_path):
    b = _bundle()
    p = b.save(tmp_path / "b.json")
    assert Bundle.load(p) == b
    assert Bundle.load(p).digest == b.digest


def test_digest_changes_with_content():
    assert _bundle().digest != _bundle(recipe={"lr": 2e-3}).digest


def test_flatten_layering():
    base = _bundle(name="base", env={"A": "1", "B": "base"})
    child = _bundle(
        name="child", base="base:latest",
        model_config={"n_layers": 2}, env={"B": "child"},
    )
    flat = child.flatten_onto(base)
    assert flat.base is None
    assert flat.model_config == {"d_model": 64, "n_layers": 2}
    assert flat.env == {"A": "1", "B": "child"}  # child layer wins


def test_flatten_wrong_parent():
    with pytest.raises(BundleError):
        _bundle(name="child", base="other:latest").flatten_onto(_bundle())


def test_gateway_pull_flatten_cache(tmp_path):
    gw = Gateway(tmp_path / "registry", tmp_path / "cache")
    gw.push(_bundle(name="base"))
    gw.push(_bundle(name="app", base="base:latest", env={"B": "2"}))

    flat = gw.pull("app:latest")
    assert flat.base is None
    assert flat.env == {"A": "1", "B": "2"}

    # lookup hits the cache only; images lists it
    assert gw.lookup("app:latest").digest == flat.digest
    assert any(i["name"] == "app" for i in gw.images())


def test_gateway_missing_image(tmp_path):
    gw = Gateway(tmp_path / "registry", tmp_path / "cache")
    with pytest.raises(GatewayError):
        gw.pull("ghost:latest")
    with pytest.raises(GatewayError):
        gw.lookup("ghost:latest")


def test_gateway_gc(tmp_path):
    gw = Gateway(tmp_path / "registry", tmp_path / "cache")
    gw.push(_bundle(name="a"))
    old = gw.pull("a:latest")
    gw.push(_bundle(name="a", recipe={"lr": 9.0}))   # retag with new content
    new = gw.pull("a:latest")
    assert old.digest != new.digest
    removed = gw.gc()
    assert removed == 1
    assert gw.lookup("a:latest").digest == new.digest


def test_pull_is_idempotent(tmp_path):
    gw = Gateway(tmp_path / "registry", tmp_path / "cache")
    gw.push(_bundle(name="a"))
    d1 = gw.pull("a:latest").digest
    d2 = gw.pull("a:latest").digest
    assert d1 == d2
