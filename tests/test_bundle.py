"""Portable tuning bundles: cross-site conformance + corruption injection.

The paper's portability thesis applied to tuning state: a laptop-warmed
artifact ships to a cluster and *adapts* — feasible entries replay
exactly with zero searches, infeasible ones demote to penalized
candidates instead of binding raw, and any damaged or ABI-incompatible
artifact is rejected atomically with the target cache left
byte-identical.  This suite drives:

  * the laptop->cluster round trip on pod-sim-style fake platforms
    (export under fingerprint A, import under mismatched fingerprint B);
  * corruption injection — truncated tarball, tampered member bytes,
    unknown manifest schema, ABI-major-mismatched bundle — each rejected
    wholesale, never a partial write;
  * import idempotency, demoted-entry dispatch/upgrade semantics, the
    Runtime auto-import path (REPRO_TUNING_BUNDLE / deploy kwarg /
    Bundle.tuning_bundle reference), the verify CLI, and the pinned
    consolidated-stats schema.
"""

import io
import json
import tarfile

import jax
import jax.numpy as jnp
import pytest

from repro.core.abi import AbiString
from repro.core.platform import POD_SIM, Platform
from repro.core.registry import ImplKind, OpImpl, OpRegistry
from repro.core.runtime import Runtime
from repro.kernels.ops import ABIS, register_all
from repro.tuning import (
    BlockConfig,
    BundleFormatError,
    CacheKey,
    ConfigTable,
    GeometryOutcome,
    TunedDispatch,
    TuningCache,
    TuningContext,
    WorkloadProfile,
    consolidated_stats,
    export_bundle,
    import_bundle,
    platform_fingerprint,
    verify_bundle,
)
from repro.tuning.bundle import main as bundle_main
from repro.tuning.dispatch import DISPATCH_PATHS, STATS_SCHEMA

# Two sites sharing hardware but not identity: the laptop the artifact
# was tuned on, and the cluster it ships to.  The fingerprint strings
# differ, so every import between them runs the revalidation path.
SITE_A = Platform(name="export-sim", hardware=POD_SIM.hardware,
                  mesh_shape=(1,), mesh_axes=("data",),
                  native_features=frozenset({"pallas_interpret"}))
SITE_B = Platform(name="cluster-sim", hardware=POD_SIM.hardware,
                  mesh_shape=(1,), mesh_axes=("data",),
                  native_features=frozenset({"pallas_interpret"}))

_ABI = AbiString.make("scale", {"args": ["x"]})

# Per-site block budget: SITE_A tolerates any block in the space, SITE_B
# only small ones — UNLESS the live workload itself is large (feasibility
# depends on the call's rows, so a config infeasible at its own bucket
# can re-qualify for a bigger borrowing geometry: the demotion story).
_BLOCK_BUDGET = {"export-sim": 64, "cluster-sim": 4}


def _feasible(cfg, platform, args):
    rows = args[0].shape[0]
    return cfg["block"] <= max(_BLOCK_BUDGET.get(platform.name, 64), rows)


def _synth(platform, shapes, dtype):
    parts = [p for p in shapes.split(",") if p]
    if len(parts) != 1:
        return None          # scale takes exactly one tensor
    try:
        dims = tuple(int(d) for d in parts[0].split("x"))
    except ValueError:
        return None
    return (jnp.zeros(dims, jnp.dtype(dtype)),)


def _registry(major=1):
    from repro.tuning import OpTuner

    abi = AbiString.make("scale", {"args": ["x"]}, major=major)
    reg = OpRegistry()
    reg.register(OpImpl(abi=abi, kind=ImplKind.REFERENCE,
                        fn=lambda x: x, provider="ref"))
    tuner = OpTuner(op="scale", space={"block": (2, 16)},
                    example_args=lambda platform: (jnp.zeros((4, 4)),),
                    feasible=_feasible, args_from_shapes=_synth,
                    iters=1, warmup=0)
    reg.register(OpImpl(
        abi=abi, kind=ImplKind.NATIVE,
        fn=lambda x, config=None: x * (config.get("block", 1)
                                       if config is not None else 1),
        requires_feature="pallas_interpret", provider="fake-native",
        tuner=tuner,
    ))
    return reg


def _key(shapes, *, platform, dtype="float32", abi=str(_ABI)):
    return CacheKey(abi=abi, platform=platform_fingerprint(platform),
                    shapes=shapes, dtype=dtype)


def _export_site_a(tmp_path, *, entries=(("8x8", 2), ("4x4", 16)),
                   profile_weights=None):
    """A warmed SITE_A: cache entries + profile + exported bundle."""
    cache = TuningCache(tmp_path / "a-tuning.json")
    for shapes, block in entries:
        cache.put(_key(shapes, platform=SITE_A), BlockConfig.make(block=block),
                  metrics={"best_us": 1.0})
    cache.save()
    profile = WorkloadProfile(tmp_path / "a-workload.json")
    for shapes, weight in (profile_weights
                           or [(s, i + 1) for i, (s, _) in enumerate(entries)]):
        dims = tuple(int(d) for d in shapes.split("x"))
        profile.record("scale", (jnp.zeros(dims),), weight=weight)
    profile.save()
    out, manifest = export_bundle(
        tmp_path / "site-a.tgz", cache_path=cache.path, platform=SITE_A,
        profile_path=profile.path)
    return out, manifest


def _repack(src, dst, mutate):
    """Rewrite a bundle tarball with `mutate(members: dict[str, bytes])`
    applied — the corruption-injection helper."""
    members = {}
    with tarfile.open(src, "r:gz") as tar:
        for m in tar.getmembers():
            members[m.name] = tar.extractfile(m).read()
    mutate(members)
    with tarfile.open(dst, "w:gz") as tar:
        for name, blob in members.items():
            info = tarfile.TarInfo(name)
            info.size = len(blob)
            tar.addfile(info, io.BytesIO(blob))
    return dst


# ------------------------------------------------------------- round trip --


def test_export_manifest_schema_and_size_accounting(tmp_path):
    out, manifest = _export_site_a(tmp_path)
    assert manifest["schema"] == 1
    assert manifest["kind"] == "repro-tuning-bundle"
    fp = manifest["fingerprint"]
    assert fp["platform"] == "export-sim" and fp["hardware"] == "cpu-host"
    assert fp["backend"] == jax.default_backend()
    assert fp["vmem_budget"] > 0 and "device_kind" in fp
    assert manifest["abis"] == {"scale": str(_ABI)}
    assert manifest["entries"]["count"] == 2
    assert manifest["entries"]["total_bytes"] > 0
    # the manifest's byte accounting agrees with the cache's own
    cache = TuningCache.load(tmp_path / "a-tuning.json")
    assert manifest["entries"]["total_bytes"] == cache.total_bytes()
    with tarfile.open(out, "r:gz") as tar:
        names = {m.name for m in tar.getmembers()}
    assert names == {"manifest.json", "tuning.json", "workload.json"}


def test_export_nothing_under_fingerprint_errors(tmp_path):
    cache = TuningCache(tmp_path / "t.json")
    cache.put(_key("8x8", platform=SITE_A), BlockConfig.make(block=2))
    cache.save()
    with pytest.raises(ValueError, match="nothing to export"):
        export_bundle(tmp_path / "b.tgz", cache_path=cache.path,
                      platform=SITE_B)


def test_cross_site_import_feasible_first_class_infeasible_demoted(tmp_path):
    """The acceptance loop: export A -> import B.  block=2 re-passes
    feasibility on B (imported first-class), block=16 fails at its own
    4x4 bucket (demoted — never bound raw) and the cache records both."""
    out, _ = _export_site_a(tmp_path)
    reg = _registry()
    report = import_bundle(out, cache_path=tmp_path / "b-tuning.json",
                           platform=SITE_B, registry=reg)
    assert report.cross_site
    assert report.counts() == {"imported": 1, "demoted": 1, "rejected": 0,
                               "already-present": 0, "skipped": 0}
    by_bucket = {r.shapes: r for r in report.results}
    assert by_bucket["8x8"].status == "imported"
    assert by_bucket["4x4"].status == "demoted"
    assert "infeasible" in by_bucket["4x4"].reason

    cache = TuningCache.load(tmp_path / "b-tuning.json")
    fp_b = platform_fingerprint(SITE_B)
    # imported entry is live under SITE_B's fingerprint...
    good = CacheKey(abi=str(_ABI), platform=fp_b, shapes="8x8",
                    dtype="float32")
    assert cache.get(good, touch=False) == BlockConfig.make(block=2)
    assert "bundle_origin" in cache.metrics(good)
    # ...the demoted one exists but never binds first-class
    bad = CacheKey(abi=str(_ABI), platform=fp_b, shapes="4x4",
                   dtype="float32")
    assert cache.get(bad, touch=False) is None
    assert cache.is_demoted(bad)
    assert cache.demoted_for(str(_ABI), fp_b) == {
        ("4x4", "float32"): BlockConfig.make(block=16)}


def test_cross_site_deploy_binds_imported_and_reports_demoted(tmp_path):
    """A bind on the target: imported buckets dispatch exactly with zero
    searches ("bundle-imported"), the demoted bucket appears in the
    SwapReport as "bundle-demoted" and resolves only through the
    validated penalized borrow — never exactly."""
    out, _ = _export_site_a(tmp_path)
    reg = _registry()
    report = import_bundle(out, cache_path=tmp_path / "b-tuning.json",
                           platform=SITE_B, registry=reg)
    profile = WorkloadProfile(tmp_path / "b-workload.json")
    profile.record("scale", (jnp.zeros((8, 8)),), weight=5)

    cache = TuningCache.load(tmp_path / "b-tuning.json")
    ctx = TuningContext(cache, SITE_B, profile=profile,
                        search_on_miss=False, bundle_report=report)
    binding = reg.bind(["scale"], SITE_B, native=True, freeze=False,
                       tuning=ctx)
    assert ctx.searches_spent == 0
    rep = binding.reports[0]
    statuses = {(g.shapes, g.status) for g in rep.geometries}
    assert ("8x8", "bundle-imported") in statuses
    assert ("4x4", "bundle-demoted") in statuses
    assert "bundle-imported" in rep.tuning          # mixed(...) summary

    table = binding.impl("scale").config
    # feasible import: exact dispatch with its shipped config
    cfg, how = table.resolve(shapes="8x8", dtype="float32")
    assert (cfg["block"], how) == (2, "exact")
    # demoted at its own bucket: block=16 fails validation for 4 rows on
    # SITE_B, and the first-class 8x8 neighbour wins instead
    cfg, how = table.resolve(shapes="4x4", dtype="float32")
    assert how == "nearest" and cfg["block"] == 2
    # live dispatch counts land on the tuned paths
    dispatch = binding.impl("scale").fn
    assert isinstance(dispatch, TunedDispatch)
    binding["scale"](jnp.ones((8, 8)))
    assert dispatch.stats["exact"] == 1


def test_demoted_candidate_lends_out_when_it_requalifies(tmp_path):
    """The near-config borrow: with no comparable first-class bucket, a
    big live geometry re-passes the demoted config's feasibility check
    and dispatches via the "demoted" path; a small one falls to default."""
    # only the infeasible-on-B entry is rank-2 (plus a structurally
    # incomparable rank-1 entry so the table is not empty of first-class)
    out, _ = _export_site_a(tmp_path, entries=(("1024", 2), ("4x4", 16)),
                            profile_weights=[("1024", 5)])
    reg = _registry()
    report = import_bundle(out, cache_path=tmp_path / "b-tuning.json",
                           platform=SITE_B, registry=reg)
    assert report.counts()["demoted"] == 1
    profile = WorkloadProfile(tmp_path / "b-workload.json")
    profile.record("scale", (jnp.zeros((1024,)),), weight=5)
    cache = TuningCache.load(tmp_path / "b-tuning.json")
    ctx = TuningContext(cache, SITE_B, profile=profile,
                        search_on_miss=False, bundle_report=report)
    binding = reg.bind(["scale"], SITE_B, native=True, freeze=False,
                       tuning=ctx)
    table = binding.impl("scale").config
    # 64 rows >= block 16: the demoted config re-qualifies and is lent out
    cfg, how = table.resolve(shapes="64x64", dtype="float32")
    assert (cfg["block"], how) == (16, "demoted")
    # 4 rows < block 16 and budget 4: validation fails, platform default
    cfg, how = table.resolve(shapes="4x4", dtype="float32")
    assert how == "default"
    dispatch = binding.impl("scale").fn
    binding["scale"](jnp.ones((64, 64)))
    assert dispatch.stats["demoted"] == 1


def test_local_search_upgrades_demoted_entry(tmp_path):
    """A search-enabled bind on the target re-measures the demoted bucket
    (it does NOT bind first-class) and the fresh put clears the flag."""
    out, _ = _export_site_a(tmp_path)
    reg = _registry()
    import_bundle(out, cache_path=tmp_path / "b-tuning.json",
                  platform=SITE_B, registry=reg)
    profile = WorkloadProfile(tmp_path / "b-workload.json")
    profile.record("scale", (jnp.zeros((4, 4)),), weight=5)
    cache = TuningCache.load(tmp_path / "b-tuning.json")
    ctx = TuningContext(cache, SITE_B, profile=profile)
    binding = reg.bind(["scale"], SITE_B, native=True, freeze=False,
                       tuning=ctx)
    rep = binding.reports[0]
    statuses = {g.shapes: g.status for g in rep.geometries
                if g.status != "bundle-imported"}
    assert statuses["4x4"] == "cache-miss-searched"     # re-measured here
    key = CacheKey(abi=str(_ABI), platform=platform_fingerprint(SITE_B),
                   shapes="4x4", dtype="float32")
    assert not cache.is_demoted(key)                    # flag cleared
    got = cache.get(key, touch=False)
    assert got is not None and _feasible(got, SITE_B, (jnp.zeros((4, 4)),))


def test_entries_for_undeclared_op_are_skipped_not_fatal(tmp_path):
    """A target that binds no tunable native for a bundled op skips its
    entries ('skipped') without failing the rest of the import."""
    out, _ = _export_site_a(tmp_path)
    bare = OpRegistry()
    other = AbiString.make("other", {"args": ["x"]})
    bare.register(OpImpl(abi=other, kind=ImplKind.REFERENCE,
                         fn=lambda x: x, provider="ref"))
    report = import_bundle(out, cache_path=tmp_path / "b.json",
                           platform=SITE_B, registry=bare)
    assert report.counts()["skipped"] == 2
    assert "skipped" in report.describe()
    assert not report.saved and not (tmp_path / "b.json").exists()


def test_import_is_idempotent_and_skips_existing_local_state(tmp_path):
    out, _ = _export_site_a(tmp_path)
    reg = _registry()
    cache_path = tmp_path / "b-tuning.json"
    # the target already measured its own 8x8 winner: imports never
    # clobber local measurements
    local = TuningCache(cache_path)
    local.put(_key("8x8", platform=SITE_B), BlockConfig.make(block=16))
    local.save()

    r1 = import_bundle(out, cache_path=cache_path, platform=SITE_B,
                       registry=reg)
    assert r1.counts()["already-present"] == 1 and r1.counts()["demoted"] == 1
    assert TuningCache.load(cache_path).get(
        _key("8x8", platform=SITE_B), touch=False) == BlockConfig.make(block=16)

    before = cache_path.read_bytes()
    r2 = import_bundle(out, cache_path=cache_path, platform=SITE_B,
                       registry=reg)
    assert not r2.saved
    assert all(r.status == "already-present" for r in r2.results)
    assert cache_path.read_bytes() == before            # byte-identical no-op


def test_structurally_foreign_bucket_rejected_per_entry(tmp_path):
    """A bucket that cannot match the op's signature is rejected (not
    imported, not fatal) and surfaces as "bundle-rejected" in the bind."""
    out, _ = _export_site_a(tmp_path, entries=(("8x8", 2), ("8x8,4x4", 16)),
                            profile_weights=[("8x8", 5)])
    reg = _registry()
    report = import_bundle(out, cache_path=tmp_path / "b.json",
                           platform=SITE_B, registry=reg)
    c = report.counts()
    assert c["imported"] == 1 and c["rejected"] == 1
    rejected = next(r for r in report.results if r.status == "rejected")
    assert rejected.shapes == "8x8,4x4"

    cache = TuningCache.load(tmp_path / "b.json")
    assert len(cache) == 1                               # nothing partial
    ctx = TuningContext(cache, SITE_B, search_on_miss=False,
                        bundle_report=report)
    binding = reg.bind(["scale"], SITE_B, native=True, freeze=False,
                       tuning=ctx)
    statuses = {(g.shapes, g.status) for g in binding.reports[0].geometries}
    assert ("8x8,4x4", "bundle-rejected") in statuses


# ------------------------------------------------------ corruption cases --


def _seeded_target(tmp_path):
    """A target cache with pre-existing state, for byte-identity checks."""
    cache_path = tmp_path / "target.json"
    cache = TuningCache(cache_path)
    cache.put(_key("32x32", platform=SITE_B), BlockConfig.make(block=2))
    cache.save()
    return cache_path, cache_path.read_bytes()


@pytest.mark.parametrize("corrupt", ["truncated", "tampered-checksum",
                                     "unknown-schema", "abi-major-mismatch",
                                     "missing-manifest"])
def test_corrupt_bundles_reject_atomically(tmp_path, corrupt):
    """Every corruption case rejects the WHOLE bundle with the target
    cache left byte-identical — never a partial write."""
    out, _ = _export_site_a(tmp_path)
    bad = tmp_path / "bad.tgz"
    reg = _registry()

    if corrupt == "truncated":
        data = out.read_bytes()
        bad.write_bytes(data[: len(data) // 2])
    elif corrupt == "tampered-checksum":
        def tamper(members):
            cachefile = json.loads(members["tuning.json"])
            for entry in cachefile["entries"].values():
                entry["config"]["block"] = 999999     # poison the config
            members["tuning.json"] = json.dumps(cachefile).encode()
        _repack(out, bad, tamper)
    elif corrupt == "unknown-schema":
        def future(members):
            manifest = json.loads(members["manifest.json"])
            manifest["schema"] = 99
            members["manifest.json"] = json.dumps(manifest).encode()
        _repack(out, bad, future)
    elif corrupt == "abi-major-mismatch":
        bad = out                       # well-formed artifact...
        reg = _registry(major=2)        # ...but the site moved to major 2
    elif corrupt == "missing-manifest":
        def strip(members):
            del members["manifest.json"]
        _repack(out, bad, strip)

    cache_path, before = _seeded_target(tmp_path)
    with pytest.raises(BundleFormatError):
        import_bundle(bad, cache_path=cache_path, platform=SITE_B,
                      registry=reg)
    assert cache_path.read_bytes() == before


def _rechecksum(members):
    """Recompute the manifest checksums over (possibly mutated) members —
    the attacker-grade tamper that internal-consistency checks must beat."""
    import hashlib

    manifest = json.loads(members["manifest.json"])
    for name in ("tuning.json", "workload.json"):
        if name in members:
            manifest["checksums"][name] = hashlib.sha256(
                members[name]).hexdigest()
    members["manifest.json"] = json.dumps(manifest).encode()


def _mutate_cachefile(members, fn):
    cachefile = json.loads(members["tuning.json"])
    fn(cachefile)
    members["tuning.json"] = json.dumps(cachefile).encode()
    _rechecksum(members)


@pytest.mark.parametrize("case", ["wrong-kind", "cache-schema", "bad-key",
                                  "foreign-fingerprint", "bad-config",
                                  "no-abi-table", "missing-cache-member",
                                  "profile-schema"])
def test_internally_inconsistent_bundles_reject_atomically(tmp_path, case):
    """Even a bundle whose checksums are VALID is rejected wholesale when
    its internals disagree — wrong artifact kind, wrong member schema,
    malformed/foreign entries, a stripped member or ABI table."""
    out, _ = _export_site_a(tmp_path)
    bad = tmp_path / "bad.tgz"

    def mutate(members):
        if case == "wrong-kind":
            manifest = json.loads(members["manifest.json"])
            manifest["kind"] = "not-a-tuning-bundle"
            members["manifest.json"] = json.dumps(manifest).encode()
        elif case == "cache-schema":
            _mutate_cachefile(members, lambda c: c.update(schema=99))
        elif case == "bad-key":
            def rekey(c):
                key, entry = next(iter(c["entries"].items()))
                c["entries"]["only|three|parts"] = entry
                del c["entries"][key]
            _mutate_cachefile(members, rekey)
        elif case == "foreign-fingerprint":
            def relocate(c):
                key, entry = next(iter(c["entries"].items()))
                parts = key.split("|")
                parts[1] = "somewhere-else/gpu-host/cuda"
                c["entries"]["|".join(parts)] = entry
                del c["entries"][key]
            _mutate_cachefile(members, relocate)
        elif case == "bad-config":
            def poison(c):
                for entry in c["entries"].values():
                    entry["config"] = {"block": "not-an-int"}
            _mutate_cachefile(members, poison)
        elif case == "no-abi-table":
            manifest = json.loads(members["manifest.json"])
            del manifest["abis"]
            members["manifest.json"] = json.dumps(manifest).encode()
        elif case == "missing-cache-member":
            del members["tuning.json"]
            manifest = json.loads(members["manifest.json"])
            del manifest["checksums"]["tuning.json"]
            members["manifest.json"] = json.dumps(manifest).encode()
        elif case == "profile-schema":
            members["workload.json"] = json.dumps(
                {"schema": 42, "counts": {}}).encode()
            _rechecksum(members)

    _repack(out, bad, mutate)
    cache_path, before = _seeded_target(tmp_path)
    with pytest.raises(BundleFormatError):
        import_bundle(bad, cache_path=cache_path, platform=SITE_B,
                      registry=_registry())
    assert cache_path.read_bytes() == before


def test_export_ops_filter_and_two_abi_cache_error(tmp_path):
    """--ops restricts the artifact to named ops (cache AND profile); a
    cache holding one op under two ABI strings refuses to export."""
    cache = TuningCache(tmp_path / "t.json")
    cache.put(_key("8x8", platform=SITE_A), BlockConfig.make(block=2))
    other = AbiString.make("other", {"args": ["x"]})
    cache.put(_key("4x4", platform=SITE_A, abi=str(other)),
              BlockConfig.make(block=4))
    cache.save()
    profile = WorkloadProfile(tmp_path / "w.json")
    profile.record("scale", (jnp.zeros((8, 8)),), weight=2)
    profile.record("other", (jnp.zeros((4, 4)),), weight=1)
    profile.save()
    out, manifest = export_bundle(tmp_path / "scoped.tgz",
                                  cache_path=cache.path, platform=SITE_A,
                                  profile_path=profile.path, ops=["scale"])
    assert manifest["abis"] == {"scale": str(_ABI)}
    assert manifest["entries"]["count"] == 1
    with tarfile.open(out, "r:gz") as tar:
        counts = json.loads(tar.extractfile("workload.json").read())["counts"]
    assert list(counts) == ["scale|8x8|float32"]     # other's traffic stayed

    # one op under two ABI strings is a malformed cache, not an artifact
    stale = _key("16x16", platform=SITE_A,
                 abi=str(_ABI).replace("1:0", "1:1"))
    cache.put(stale, BlockConfig.make(block=8))
    cache.save()
    with pytest.raises(BundleFormatError, match="two ABI strings"):
        export_bundle(tmp_path / "x.tgz", cache_path=cache.path,
                      platform=SITE_A)


def test_tampered_entry_with_recomputed_checksum_still_rejected(tmp_path):
    """An attacker-grade tamper (member AND checksum rewritten) cannot
    smuggle an entry under a different ABI than the manifest declares —
    internal consistency is checked member-against-manifest."""
    out, _ = _export_site_a(tmp_path)
    bad = tmp_path / "bad.tgz"

    def smuggle(members):
        import hashlib

        cachefile = json.loads(members["tuning.json"])
        key, entry = next(iter(cachefile["entries"].items()))
        foreign = key.replace("scale/1:0", "scale/3:0")
        cachefile["entries"][foreign] = entry
        del cachefile["entries"][key]
        blob = json.dumps(cachefile).encode()
        members["tuning.json"] = blob
        manifest = json.loads(members["manifest.json"])
        manifest["checksums"]["tuning.json"] = hashlib.sha256(blob).hexdigest()
        members["manifest.json"] = json.dumps(manifest).encode()

    _repack(out, bad, smuggle)
    cache_path, before = _seeded_target(tmp_path)
    with pytest.raises(BundleFormatError):
        import_bundle(bad, cache_path=cache_path, platform=SITE_B,
                      registry=_registry())
    assert cache_path.read_bytes() == before


# ------------------------------------------------------------------ verify --


def test_verify_round_trip_ok_with_demotions(tmp_path):
    out, _ = _export_site_a(tmp_path)
    code, lines = verify_bundle(out, platform=SITE_B, registry=_registry())
    text = "\n".join(lines)
    assert code == 0, text
    assert "zero searches" in text and "demoted" in text


def test_verify_flags_coverage_gap(tmp_path):
    """A profile bucket the bundle never warmed means the target WOULD
    cold-search: verify must fail, naming the bucket."""
    out, _ = _export_site_a(
        tmp_path, entries=(("8x8", 2),),
        profile_weights=[("8x8", 5), ("16x16", 3)])   # 16x16 never warmed
    code, lines = verify_bundle(out, platform=SITE_B, registry=_registry())
    text = "\n".join(lines)
    assert code == 1
    assert "16x16" in text and "cold search" in text


def test_verify_same_site_round_trip(tmp_path):
    out, _ = _export_site_a(tmp_path)
    code, lines = verify_bundle(out, platform=SITE_A, registry=_registry())
    assert code == 0, "\n".join(lines)


def test_verify_handles_partially_supported_bundle(tmp_path):
    """Regression: a bundle carrying an op the target binds no tunable
    native for must verify the rest and report, not crash on the skipped
    op's missing binding."""
    cache = TuningCache(tmp_path / "t.json")
    cache.put(_key("8x8", platform=SITE_A), BlockConfig.make(block=2))
    other = AbiString.make("other", {"args": ["x"]})
    cache.put(_key("4x4", platform=SITE_A, abi=str(other)),
              BlockConfig.make(block=2))
    cache.save()
    out, _ = export_bundle(tmp_path / "mixed.tgz", cache_path=cache.path,
                           platform=SITE_A)
    code, lines = verify_bundle(out, platform=SITE_B, registry=_registry())
    text = "\n".join(lines)
    assert code == 0, text                       # scale verified; other skipped
    assert "skipped" in text


def test_malformed_manifest_abi_rejects_not_crashes(tmp_path):
    """Regression: a hand-edited abis table with an unparseable ABI string
    must reject as BundleFormatError (so Runtime degrades to a cold
    deploy), never escape as a raw AbiError."""
    out, _ = _export_site_a(tmp_path)
    bad = tmp_path / "bad.tgz"

    def poison(members):
        manifest = json.loads(members["manifest.json"])
        manifest["abis"]["bogus_op"] = "not-an-abi"
        members["manifest.json"] = json.dumps(manifest).encode()

    _repack(out, bad, poison)
    cache_path, before = _seeded_target(tmp_path)
    with pytest.raises(BundleFormatError, match="malformed"):
        import_bundle(bad, cache_path=cache_path, platform=SITE_B,
                      registry=_registry())
    assert cache_path.read_bytes() == before


def test_dtype_agnostic_demoted_resolve_still_validates(tmp_path):
    """Regression: the explicit-bucket (dtype=None) lookup must not hand
    out a demoted config the feasibility check rejects — same promise as
    the dtype'd path."""
    rejected = []

    def validate(config, shapes, dtype):
        rejected.append((str(config), shapes, dtype))
        return False

    table = ConfigTable(
        "op", [],
        default=BlockConfig.make(block=1),
        validate=validate,
        demoted=[GeometryOutcome(shapes="4x4", dtype="float32",
                                 status="bundle-demoted",
                                 config=BlockConfig.make(block=16))],
    )
    cfg, how = table.resolve(shapes="8x8")       # no dtype given
    assert how == "default" and cfg["block"] == 1
    assert rejected == [("block=16", "8x8", "float32")]   # checked, refused


def test_verify_fails_when_site_binds_no_bundled_op(tmp_path):
    out, _ = _export_site_a(tmp_path)
    bare = OpRegistry()
    other = AbiString.make("other", {"args": ["x"]})
    bare.register(OpImpl(abi=other, kind=ImplKind.REFERENCE,
                         fn=lambda x: x, provider="ref"))
    code, lines = verify_bundle(out, platform=SITE_B, registry=bare)
    assert code == 1
    assert "no tunable native" in "\n".join(lines)


# ------------------------------------------------------------- runtime ----


def _pod_sim_bundle(tmp_path):
    """A real pod-sim artifact: warmed rmsnorm traffic, exported."""
    from repro.tuning.warm import warm_cache

    reg = register_all(OpRegistry())
    profile = WorkloadProfile(tmp_path / "lap-workload.json")
    w = jnp.zeros((64,))
    profile.record("rmsnorm", (jnp.zeros((8, 64)), w), weight=4)
    profile.record("rmsnorm", (jnp.zeros((48, 64)), w), weight=2)
    profile.save()
    cache = TuningCache(tmp_path / "lap-tuning.json")
    warm_cache(profile, cache, POD_SIM, registry=reg)
    cache.save()
    out, _ = export_bundle(tmp_path / "laptop.tgz", cache_path=cache.path,
                           platform=POD_SIM, profile_path=profile.path)
    return out


def test_runtime_env_auto_import_binds_bundle_entries(tmp_path):
    """REPRO_TUNING_BUNDLE auto-imports before binding: the shipped
    buckets bind as "bundle-imported" with zero searches paid for them,
    and the import stats ride on the container."""
    from repro.core.bundle import Bundle

    out = _pod_sim_bundle(tmp_path)
    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(tmp_path / "site-tuning.json"),
        "REPRO_WORKLOAD_PROFILE": str(tmp_path / "site-workload.json"),
        "REPRO_TUNING_BUNDLE": str(out),
        "REPRO_SEARCH_BUDGET": "0",
    }
    bundle = Bundle(name="app", tag="t", model_config={}, recipe={},
                    required_ops={"rmsnorm": str(ABIS["rmsnorm"])}, env={})
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c = rt.deploy(bundle, native_ops=True, autotune=True)
    assert c.tuning_imports is not None
    assert c.tuning_imports.counts()["imported"] == 2
    rep = next(r for r in c.binding.reports if r.op == "rmsnorm")
    imported = {g.shapes for g in rep.geometries
                if g.status == "bundle-imported"}
    assert {"8x64,64", "64x64,64"} <= imported
    # size accounting shows up in the human-facing describe()
    assert "state ~" in c.binding.describe()
    # the allowlist forwards the bundle reference into the container env
    assert c.env["REPRO_TUNING_BUNDLE"] == str(out)
    # live traffic at a shipped bucket dispatches exactly
    x = jnp.ones((8, 64)), jnp.ones((64,))
    jax.block_until_ready(c.binding["rmsnorm"](*x))
    assert c.binding.impl("rmsnorm").fn.stats["exact"] == 1
    rt.cleanup()


def test_runtime_rejected_bundle_degrades_to_cold_deploy(tmp_path):
    """A corrupt artifact must not kill the deployment: the site cache
    stays untouched and the deploy proceeds cold (env-triggered features
    degrade, they do not error)."""
    from repro.core.bundle import Bundle

    out = _pod_sim_bundle(tmp_path)
    data = out.read_bytes()
    bad = tmp_path / "bad.tgz"
    bad.write_bytes(data[: len(data) // 2])
    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(tmp_path / "site-tuning.json"),
        "REPRO_WORKLOAD_PROFILE": str(tmp_path / "site-workload.json"),
        "REPRO_TUNING_BUNDLE": str(bad),
        "REPRO_SEARCH_BUDGET": "0",
    }
    bundle = Bundle(name="app", tag="t", model_config={}, recipe={},
                    required_ops={"rmsnorm": str(ABIS["rmsnorm"])}, env={})
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c = rt.deploy(bundle, native_ops=True, autotune=True)
    assert c.tuning_imports is None
    rep = next(r for r in c.binding.reports if r.op == "rmsnorm")
    assert "bundle" not in rep.tuning
    rt.cleanup()


def test_run_bundle_carries_tuning_bundle_reference(tmp_path):
    """core.Bundle.tuning_bundle travels with the run bundle (save/load,
    layering) and the Runtime auto-imports it when env/kwarg are silent."""
    from repro.core.bundle import Bundle

    out = _pod_sim_bundle(tmp_path)
    b = Bundle(name="app", tag="t", model_config={}, recipe={},
               required_ops={"rmsnorm": str(ABIS["rmsnorm"])}, env={},
               tuning_bundle=str(out))
    p = b.save(tmp_path / "bundle.json")
    loaded = Bundle.load(p)
    assert loaded.tuning_bundle == str(out)
    assert loaded.digest == b.digest
    # layering: the child's reference wins; absent child inherits parent
    base = Bundle(name="base", tag="v1", model_config={"a": 1}, recipe={},
                  required_ops={}, env={}, tuning_bundle="base.tgz")
    child = Bundle(name="app2", tag="t", model_config={}, recipe={},
                   required_ops={}, env={}, base="base:v1")
    assert child.flatten_onto(base).tuning_bundle == "base.tgz"

    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(tmp_path / "site-tuning.json"),
        "REPRO_WORKLOAD_PROFILE": str(tmp_path / "site-workload.json"),
        "REPRO_SEARCH_BUDGET": "0",
    }
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c = rt.deploy(loaded, native_ops=True, autotune=True)
    assert c.tuning_imports is not None and c.tuning_imports.counts()["imported"] == 2
    rt.cleanup()


# ----------------------------------------------------------------- CLI ----


def test_cli_export_import_verify_loop(tmp_path, capsys, monkeypatch):
    monkeypatch.setenv("REPRO_PLATFORM", "pod-sim")
    _ = _pod_sim_bundle(tmp_path)   # warms lap-tuning.json on pod-sim
    out = tmp_path / "cli.tgz"
    rc = bundle_main(["export", "--out", str(out),
                      "--cache", str(tmp_path / "lap-tuning.json"),
                      "--profile", str(tmp_path / "lap-workload.json"),
                      "--platform", "pod-sim"])
    assert rc == 0
    assert "exported" in capsys.readouterr().out and out.is_file()

    rc = bundle_main(["import", str(out),
                      "--cache", str(tmp_path / "site.json"),
                      "--platform", "pod-sim"])
    assert rc == 0
    text = capsys.readouterr().out
    assert "2 imported" in text and "updated" in text
    # second import: explicit no-op
    rc = bundle_main(["import", str(out),
                      "--cache", str(tmp_path / "site.json"),
                      "--platform", "pod-sim"])
    assert rc == 0
    assert "no-op import" in capsys.readouterr().out

    rc = bundle_main(["verify", str(out), "--platform", "pod-sim"])
    assert rc == 0
    assert "zero searches" in capsys.readouterr().out


def test_cli_rejects_corrupt_bundle_nonzero(tmp_path, capsys):
    out = _pod_sim_bundle(tmp_path)
    data = out.read_bytes()
    bad = tmp_path / "bad.tgz"
    bad.write_bytes(data[: len(data) // 2])
    target = tmp_path / "site.json"
    rc = bundle_main(["import", str(bad), "--cache", str(target),
                      "--platform", "pod-sim"])
    assert rc == 1
    assert "not modified" in capsys.readouterr().out
    assert not target.exists()

    rc = bundle_main(["verify", str(bad), "--platform", "pod-sim"])
    assert rc == 1
    assert "rejected the bundle outright" in capsys.readouterr().out


def test_cli_export_empty_cache_fails_cleanly(tmp_path, capsys):
    rc = bundle_main(["export", "--out", str(tmp_path / "x.tgz"),
                      "--cache", str(tmp_path / "missing.json"),
                      "--platform", "pod-sim"])
    assert rc == 1
    assert "export failed" in capsys.readouterr().out


# ------------------------------------------- consolidated stats schema ----


def test_consolidated_stats_schema_is_pinned():
    """Regression pin: the one stats dict serve/train print from always
    carries exactly the schema keys — near-dtype, demotion, eviction and
    bundle counters included — so no counter can silently drop out."""
    table = ConfigTable(
        "op",
        [GeometryOutcome(shapes="8x8", dtype="float32", status="cache-hit",
                         config=BlockConfig.make(block=2), bytes=100)],
        default=BlockConfig.make(block=1),
        demoted=[GeometryOutcome(shapes="4x4", dtype="float32",
                                 status="bundle-demoted",
                                 config=BlockConfig.make(block=16), bytes=50)],
        max_entries=3,
    )
    dispatch = TunedDispatch(lambda x, config=None: x, table)
    assert set(dispatch.stats) == set(DISPATCH_PATHS)

    geometries = [
        GeometryOutcome(shapes="8x8", dtype="float32",
                        status="bundle-imported",
                        config=BlockConfig.make(block=2)),
        GeometryOutcome(shapes="4x4", dtype="float32",
                        status="bundle-demoted",
                        config=BlockConfig.make(block=16)),
        GeometryOutcome(shapes="2x2", dtype="float32",
                        status="bundle-rejected",
                        config=BlockConfig.make(block=1)),
        GeometryOutcome(shapes="64x64", dtype="float32",
                        status="cache-evicted-lru",
                        config=BlockConfig.make(block=4)),
    ]
    stats = consolidated_stats(dispatch, geometries)
    assert set(stats) == STATS_SCHEMA               # the pin
    assert stats["table-entries"] == 1 and stats["table-demoted"] == 1
    assert stats["table-cap"] == 3 and stats["table-bytes"] == 150
    assert stats["bundle-imported"] == 1 and stats["bundle-demoted"] == 1
    assert stats["bundle-rejected"] == 1 and stats["evicted-lru"] == 1
    # counting a resolution updates the consolidated view coherently
    dispatch(jnp.ones((8, 8)))
    assert consolidated_stats(dispatch, geometries)["exact"] == 1


def test_serve_dispatch_printout_iterates_the_schema(tmp_path, capsys):
    """The launcher printout is generated FROM the pinned schema: every
    resolution path appears by name, plus table shape/bytes and any
    nonzero lifecycle counters (bundle import stats included)."""
    from repro.core.bundle import Bundle
    from repro.launch.serve import print_dispatch_stats

    out = _pod_sim_bundle(tmp_path)
    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(tmp_path / "site-tuning.json"),
        "REPRO_WORKLOAD_PROFILE": str(tmp_path / "site-workload.json"),
        "REPRO_TUNING_BUNDLE": str(out),
        "REPRO_SEARCH_BUDGET": "0",
    }
    bundle = Bundle(name="app", tag="t", model_config={}, recipe={},
                    required_ops={"rmsnorm": str(ABIS["rmsnorm"])}, env={})
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c = rt.deploy(bundle, native_ops=True, autotune=True, profile=True)
    jax.block_until_ready(c.binding["rmsnorm"](jnp.ones((8, 64)),
                                               jnp.ones((64,))))
    print_dispatch_stats(c)
    text = capsys.readouterr().out
    assert "tuning bundle [pod-sim/cpu-host/cpu]: " in text
    assert "imported=2" in text
    line = next(ln for ln in text.splitlines()
                if ln.startswith("dispatch rmsnorm"))
    for path in DISPATCH_PATHS:
        assert f"{path}=" in line                 # schema-driven printout
    assert "table 3" in line and "~" in line      # fullness (2 imported
    # buckets + the canonical placeholder) and approximate bytes
    assert "bundle-imported=2" in line            # lifecycle counter
    rt.cleanup()


def test_dispatch_paths_cover_every_resolution_outcome():
    """Every `how` resolve() can return is a schema path (a new fallback
    path must register itself or this trips)."""
    table = ConfigTable(
        "op",
        [GeometryOutcome(shapes="8x8", dtype="float32", status="cache-hit",
                         config=BlockConfig.make(block=2))],
        default=BlockConfig.make(block=1),
        demoted=[GeometryOutcome(shapes="4x4x4", dtype="float32",
                                 status="bundle-demoted",
                                 config=BlockConfig.make(block=16))],
    )
    hows = {
        table.resolve(shapes="8x8", dtype="float32")[1],       # exact
        table.resolve(shapes="16x16", dtype="float32")[1],     # nearest
        table.resolve(shapes="16x16", dtype="bfloat16")[1],    # near-dtype
        table.resolve(shapes="8x8x8", dtype="float32")[1],     # demoted
        table.resolve(shapes="scalar", dtype="float32")[1],    # default
    }
    assert hows == {"exact", "nearest", "near-dtype", "demoted", "default"}
    assert hows <= set(DISPATCH_PATHS)
    # the dtype-agnostic (explicit bucket string) lookup reaches demoted
    # candidates too, still behind every first-class one
    assert table.resolve(shapes="8x8x8")[1] == "demoted"
    assert table.resolve(shapes="16x16")[1] == "nearest"
