"""Bounded tuning-state lifecycle: eviction pressure, compaction, soak.

The paper's claim is that a site-adapted runtime keeps containers fast
*indefinitely*; before this layer the tuning state only ever grew.  This
suite covers the managed lifecycle end to end:

  * LRU mechanics — ``last_used`` stamped on hits, persisted in the JSON,
    compaction evicts coldest-first with protect/prefer knobs;
  * a traffic soak — N "days" of shifting traffic (profile decay + warm +
    capped deploy in a loop) with the invariants a long-lived deployment
    needs: dispatch-table size stays <= cap, live-traffic hit rate stays
    high, and eviction never sheds the currently hottest bucket;
  * the acceptance loop — REPRO_TUNING_MAX_ENTRIES=K through a real
    Runtime.deploy binds exactly the K hottest warmed buckets and routes
    bf16 traffic over fp32-only state via the near-dtype borrow;
  * concurrency — two processes warming one cache under file_lock lose
    nothing and corrupt nothing; tombstones merge cleanly across writers;
  * the ``warm --compact`` GC CLI.
"""

import json
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import pytest

from repro.core.abi import AbiString
from repro.core.platform import POD_SIM, Platform
from repro.core.registry import ImplKind, OpImpl, OpRegistry
from repro.core.runtime import Runtime
from repro.kernels.ops import ABIS, register_all
from repro.tuning import (
    BlockConfig,
    CacheKey,
    OpTuner,
    TuningCache,
    TuningContext,
    WorkloadProfile,
    compact_lru,
    platform_fingerprint,
)
from repro.tuning.warm import warm_cache

SRC = str(Path(__file__).resolve().parent.parent / "src")

FAKE_SIM = Platform(
    name="fake-sim",
    hardware=POD_SIM.hardware,
    mesh_shape=(1,),
    mesh_axes=("data",),
    native_features=frozenset({"pallas_interpret"}),
)

_ABI = AbiString.make("scale", {"args": ["x"]})


def _synth(platform, shapes, dtype):
    parts = [p for p in shapes.split(",") if p]
    try:
        dims = tuple(int(d) for d in parts[0].split("x"))
    except ValueError:
        return None
    return (jnp.zeros(dims, jnp.dtype(dtype)),)


def _scale_registry():
    """A tunable 'scale' op whose searches are instant — the deterministic
    stand-in for a warmed site (same idiom as test_dispatch)."""
    reg = OpRegistry()
    reg.register(OpImpl(abi=_ABI, kind=ImplKind.REFERENCE,
                        fn=lambda x: x, provider="ref"))
    tuner = OpTuner(op="scale", space={"block": (2, 3)},
                    example_args=lambda platform: (jnp.zeros((4, 4)),),
                    args_from_shapes=_synth, iters=1, warmup=0)
    reg.register(OpImpl(
        abi=_ABI, kind=ImplKind.NATIVE,
        fn=lambda x, config=None: x * (config.get("block", 1)
                                       if config is not None else 1),
        requires_feature="pallas_interpret", provider="fake-native",
        tuner=tuner,
    ))
    return reg


def _key(shapes, dtype="float32", abi=str(_ABI),
         platform=None):
    return CacheKey(abi=abi,
                    platform=platform or platform_fingerprint(FAKE_SIM),
                    shapes=shapes, dtype=dtype)


# ------------------------------------------------------------ LRU mechanics --


def test_last_used_stamped_on_hits_and_persisted(tmp_path):
    cache = TuningCache(tmp_path / "t.json")
    k = _key("4x4")
    cache.put(k, BlockConfig.make(block=3))
    t0 = cache.last_used(k)
    assert t0 > 0
    assert cache.get(k) is not None
    assert cache.last_used(k) > t0            # the hit refreshed it
    t1 = cache.last_used(k)
    assert cache.get(k, touch=False) is not None
    assert cache.last_used(k) == t1           # a peek did not
    cache.save()
    reloaded = TuningCache.load(tmp_path / "t.json")
    assert reloaded.last_used(k) == t1        # recency survives redeploys
    assert reloaded.last_used(_key("8x8")) == 0.0


def test_compact_evicts_coldest_first(tmp_path):
    cache = TuningCache(tmp_path / "t.json")
    keys = [_key(f"{2 ** i}x4") for i in range(4)]
    for i, k in enumerate(keys):
        cache.put(k, BlockConfig.make(block=i + 1))
    cache.get(keys[0])                        # oldest entry becomes newest
    evicted = cache.compact(2)
    assert set(evicted) == {keys[1].encode(), keys[2].encode()}
    assert cache.get(keys[0], touch=False) is not None
    assert cache.get(keys[3], touch=False) is not None
    assert cache.compact(2) == []             # already within the cap
    # evictions are tombstoned: a save cannot resurrect them from disk
    cache.save()
    assert len(TuningCache.load(tmp_path / "t.json")) == 2


def test_compact_protect_and_prefer(tmp_path):
    cache = TuningCache(tmp_path / "t.json")
    keys = [_key(f"{2 ** i}x4") for i in range(4)]
    for i, k in enumerate(keys):
        cache.put(k, BlockConfig.make(block=i + 1))
    # protect the coldest entry: everything else goes before it
    evicted = cache.compact(1, protect={keys[0].encode()})
    assert keys[0].encode() not in evicted and len(cache) == 1
    # prefer beats recency: the newest entry is marked stale and goes first
    cache2 = TuningCache(tmp_path / "t2.json")
    for i, k in enumerate(keys):
        cache2.put(k, BlockConfig.make(block=i + 1))
    evicted = cache2.compact(3, prefer={keys[3].encode()})
    assert evicted == [keys[3].encode()]


def test_save_enforces_cache_cap_through_merges(tmp_path):
    path = tmp_path / "t.json"
    a = TuningCache(path)
    for i in range(3):
        a.put(_key(f"{2 ** i}x4"), BlockConfig.make(block=1))
    a.save()
    b = TuningCache.load(path)
    b.max_entries = 4
    for i in range(3, 6):
        b.put(_key(f"{2 ** i}x4"), BlockConfig.make(block=1))
    b.save()                                  # merge would hold 6; cap is 4
    final = TuningCache.load(path)
    assert len(final) == 4
    # the survivors are the most recently used (b's fresh puts + newest of a)
    for i in range(3, 6):
        assert final.get(_key(f"{2 ** i}x4"), touch=False) is not None


def test_compact_lru_prefers_stale_profile_buckets(tmp_path):
    cache = TuningCache(tmp_path / "t.json")
    hot, lukewarm, stale1, stale2 = (_key("4x4"), _key("8x4"),
                                     _key("16x4"), _key("32x4"))
    for k in (stale1, stale2, hot, lukewarm):   # stale ones are OLDEST too
        cache.put(k, BlockConfig.make(block=2))
    profile = WorkloadProfile(tmp_path / "w.json")
    profile.record("scale", (jnp.zeros((4, 4)),), weight=5)
    profile.record("scale", (jnp.zeros((8, 4)),), weight=1)
    report = compact_lru(cache, 2, profile=profile)
    assert {op for op, _ in report.evicted} == {"scale"}
    assert {k for _, k in report.evicted} == {stale1.encode(), stale2.encode()}
    assert report.kept == 2 and report.cap == 2
    assert "evicted 2" in report.describe()
    # within cap: clean report
    assert len(compact_lru(cache, 2, profile=profile)) == 0
    with pytest.raises(ValueError):
        compact_lru(cache, -1)


# ------------------------------------------------------- eviction pressure --


def test_capped_bind_keeps_k_hottest_and_sheds_the_rest(tmp_path):
    """A warmed redeploy over more buckets than the cap binds exactly the
    K hottest; shed buckets surface as cache-evicted-lru and leave the
    cache (tombstoned, so the persisted file shrinks too)."""
    reg = _scale_registry()
    cache = TuningCache(tmp_path / "t.json")
    for rows in (4, 8, 16, 32):
        cache.put(_key(f"{rows}x4"), BlockConfig.make(block=3))
    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("scale", (jnp.zeros((4, 4)),), weight=9)
    prof.record("scale", (jnp.zeros((8, 4)),), weight=5)
    prof.record("scale", (jnp.zeros((16, 4)),), weight=1)

    ctx = TuningContext(cache, FAKE_SIM, profile=prof, search_on_miss=False,
                        max_entries=2)
    binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False,
                       tuning=ctx)
    table = binding.impl("scale").config
    assert len(table) == 2
    by_status = {}
    for g in binding.reports[0].geometries:
        by_status.setdefault(g.status, set()).add(g.shapes)
    assert by_status["cache-hit"] == {"4x4", "8x4"}            # the 2 hottest
    assert by_status["cache-evicted-lru"] == {"16x4", "32x4"}  # the shed tail
    assert "cache-evicted-lru" in binding.reports[0].tuning    # mixed(...)
    assert len(cache) == 2
    cache.save()
    assert len(TuningCache.load(tmp_path / "t.json")) == 2


def test_soak_shifting_traffic_keeps_state_bounded(tmp_path):
    """N days of drifting traffic: each day a new geometry dominates, the
    profile decays, the cache warms, and a capped deploy rebinds.  The
    lifecycle invariants must hold every single day."""
    CAP = 3
    reg = _scale_registry()
    cache_path = tmp_path / "tuning.json"
    prof = WorkloadProfile(tmp_path / "workload.json")
    day_rows = [4, 8, 16, 32, 64, 128, 256, 512]

    for day, rows in enumerate(day_rows):
        if day:
            prof.decay(0.4)                          # history ages...
            prof.record("scale", (jnp.zeros((day_rows[day - 1], 4)),),
                        weight=2)                    # ...with a long tail
        prof.record("scale", (jnp.zeros((rows, 4)),), weight=10)
        prof.save()

        cache = TuningCache.load(cache_path)
        warm_cache(prof, cache, FAKE_SIM, registry=reg, top_k=CAP)
        cache.save()

        cache = TuningCache.load(cache_path)
        ctx = TuningContext(cache, FAKE_SIM, profile=prof,
                            search_on_miss=False, top_k=CAP, max_entries=CAP)
        binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False,
                           tuning=ctx)
        ctx.flush()

        # 1. the dispatch table never outgrows its cap
        table = binding.impl("scale").config
        assert len(table) <= CAP, f"day {day}: table {len(table)} > cap {CAP}"

        # 2. eviction pressure never sheds the current hottest bucket
        hottest, _ = prof.top(op="scale", k=1)[0]
        shed = {g.shapes for g in binding.reports[0].geometries
                if g.status == "cache-evicted-lru"}
        assert hottest.shapes not in shed, f"day {day}: evicted the hottest"
        assert cache.get(_key(hottest.shapes), touch=False) is not None

        # 3. live traffic keeps hitting its own tuned entries
        dispatch = binding.impl("scale").fn
        for geo, _ in prof.top(op="scale", k=CAP):
            dims = tuple(int(d) for d in geo.shapes.split(",")[0].split("x"))
            binding["scale"](jnp.ones(dims))
        assert dispatch.hit_rate >= 0.75, \
            f"day {day}: hit rate {dispatch.hit_rate:.2f} ({dispatch.stats})"

    # the persisted site state is bounded after a week of drift, not a
    # transcript of every geometry ever seen
    final = TuningCache.load(cache_path)
    assert len(final) <= CAP


def test_env_capped_redeploy_binds_k_hottest_with_near_dtype(tmp_path):
    """The acceptance loop through a real Runtime: REPRO_TUNING_MAX_ENTRIES=2
    over 4 warmed rmsnorm buckets binds the 2 hottest, and bf16 traffic
    (only fp32 warmed) dispatches via near-dtype instead of default."""
    from repro.core.bundle import Bundle

    fp = platform_fingerprint(POD_SIM)
    abi = str(ABIS["rmsnorm"])
    cache = TuningCache(tmp_path / "tuning.json")
    for rows in (8, 16, 32, 64):
        cache.put(CacheKey(abi=abi, platform=fp, shapes=f"{rows}x64,64",
                           dtype="float32"),
                  BlockConfig.make(block_rows=rows))
    cache.save()
    prof = WorkloadProfile(tmp_path / "workload.json")
    w = jnp.zeros((64,))
    prof.record("rmsnorm", (jnp.zeros((64, 64)), w), weight=9)
    prof.record("rmsnorm", (jnp.zeros((8, 64)), w), weight=5)
    prof.record("rmsnorm", (jnp.zeros((16, 64)), w), weight=1)
    prof.save()

    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(tmp_path / "tuning.json"),
        "REPRO_WORKLOAD_PROFILE": str(tmp_path / "workload.json"),
        "REPRO_SEARCH_BUDGET": "0",
        "REPRO_TUNING_MAX_ENTRIES": "2",
    }
    bundle = Bundle(name="cap", tag="t", model_config={}, recipe={},
                    required_ops={"rmsnorm": abi}, env={})
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c = rt.deploy(bundle, native_ops=True, autotune=True)

    rep = next(r for r in c.binding.reports if r.op == "rmsnorm")
    table = c.binding.impl("rmsnorm").config
    assert len(table) == 2
    hits = {g.shapes for g in rep.geometries if g.status == "cache-hit"}
    shed = {g.shapes for g in rep.geometries
            if g.status == "cache-evicted-lru"}
    assert hits == {"64x64,64", "8x64,64"}        # exactly the 2 hottest
    assert shed == {"16x64,64", "32x64,64"}
    # the allowlist forwards the cap into the container env
    assert c.env["REPRO_TUNING_MAX_ENTRIES"] == "2"

    # bf16 call over fp32-only tuned state: near-dtype borrow, not default
    x16 = jnp.ones((64, 64), jnp.bfloat16)
    w16 = jnp.ones((64,), jnp.bfloat16)
    out = c.binding["rmsnorm"](x16, w16)
    dispatch = c.binding.impl("rmsnorm").fn
    assert out.dtype == jnp.bfloat16
    assert dispatch.stats["near-dtype"] == 1
    assert dispatch.stats["default"] == 0
    rt.cleanup()

    # pressure persisted: the cache file kept only the bound buckets
    final = TuningCache.load(tmp_path / "tuning.json")
    assert len(final) == 2


def test_calibrated_penalty_borrow_quantized_traffic(tmp_path):
    """Quantized<->full-precision borrows price distance with the
    MEASURED dtype penalty: a cache holding best_us for the same shape
    bucket at "float32" and "float32+int8" calibrates |log2(ratio)|
    doublings (here 4x -> 2.0, not the fixed DTYPE_PENALTY=4), and a
    quantized call whose own bucket was never warmed borrows the fp32
    entry via near-dtype instead of falling to the shipped default."""
    from repro.core.bundle import Bundle
    from repro.tuning.dispatch import DTYPE_PENALTY

    fp = platform_fingerprint(POD_SIM)
    abi = str(ABIS["quant_matmul"])
    cache = TuningCache(tmp_path / "tuning.json")
    # the calibration pair: one (large) shape bucket measured at both
    # dtypes — far from the traffic below, so the SAME-shape fp32 entry
    # (cross-dtype, distance == penalty) outranks it for the borrow
    cache.put(CacheKey(abi=abi, platform=fp, shapes="256x256,256x256,256",
                       dtype="float32"),
              BlockConfig.make(block_m=64, block_n=64),
              metrics={"best_us": 40.0})
    cache.put(CacheKey(abi=abi, platform=fp, shapes="256x256,256x256,256",
                       dtype="float32+int8"),
              BlockConfig.make(block_m=64, block_n=64),
              metrics={"best_us": 10.0})
    # an fp32-only bucket the quantized traffic below must borrow
    cache.put(CacheKey(abi=abi, platform=fp, shapes="32x64,64x64,64",
                       dtype="float32"),
              BlockConfig.make(block_m=32, block_n=64),
              metrics={"best_us": 20.0})
    cache.save()
    prof = WorkloadProfile(tmp_path / "workload.json")
    x256 = jnp.zeros((256, 256), jnp.float32)
    qw256 = jnp.zeros((256, 256), jnp.int8)
    sc256 = jnp.zeros((256,), jnp.float32)
    prof.record("quant_matmul", (x256, qw256, sc256), weight=3)
    prof.record("quant_matmul", (x256, x256, sc256), weight=2)
    prof.record("quant_matmul", (jnp.zeros((32, 64)), jnp.zeros((64, 64)),
                                 jnp.zeros((64,))), weight=1)
    prof.save()

    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(tmp_path / "tuning.json"),
        "REPRO_WORKLOAD_PROFILE": str(tmp_path / "workload.json"),
        "REPRO_SEARCH_BUDGET": "0",
    }
    bundle = Bundle(name="qpen", tag="t", model_config={}, recipe={},
                    required_ops={"quant_matmul": abi}, env={})
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c = rt.deploy(bundle, native_ops=True, autotune=True)

    table = c.binding.impl("quant_matmul").config
    assert table.dtype_penalty == pytest.approx(2.0)      # |log2(40/10)|
    assert table.dtype_penalty != DTYPE_PENALTY           # not the guess

    # live int8 traffic at the fp32-only bucket: near-dtype borrow
    out = c.binding["quant_matmul"](jnp.ones((32, 64), jnp.float32),
                                    jnp.ones((64, 64), jnp.int8),
                                    jnp.full((64,), 0.01, jnp.float32))
    dispatch = c.binding.impl("quant_matmul").fn
    assert out.shape == (32, 64)
    assert dispatch.stats["near-dtype"] == 1
    assert dispatch.stats["default"] == 0
    # ...and the warmed quantized bucket still dispatches exactly
    out2 = c.binding["quant_matmul"](
        jnp.ones((256, 256), jnp.float32), jnp.ones((256, 256), jnp.int8),
        jnp.full((256,), 0.01, jnp.float32))
    assert out2.shape == (256, 256)
    assert dispatch.stats["exact"] >= 1
    rt.cleanup()


# ----------------------------------------------------------- concurrency --


_WORKER = """
import sys
sys.path.insert(0, sys.argv[4])
from repro.tuning.cache import CacheKey, TuningCache
from repro.tuning.config import BlockConfig

path, tag, n = sys.argv[1], sys.argv[2], int(sys.argv[3])
cache = TuningCache.load(path)
for i in range(n):
    key = CacheKey(abi="scale/1:0/x", platform="fake",
                   shapes=f"{tag}{i}x4", dtype="float32")
    cache.put(key, BlockConfig.make(block=i + 1))
cache.save()
"""


def test_two_processes_warm_one_cache_without_losing_entries(tmp_path):
    """Two concurrent writers, disjoint keys: the file_lock'd load-merge-
    replace keeps both sets — no lost update, no torn JSON."""
    path = tmp_path / "tuning.json"
    n = 20
    procs = [
        subprocess.Popen([sys.executable, "-c", _WORKER, str(path), tag,
                          str(n), SRC])
        for tag in ("a", "b")
    ]
    for p in procs:
        assert p.wait(timeout=120) == 0
    raw = json.loads(path.read_text())          # parseable, not torn
    assert len(raw["entries"]) == 2 * n
    cache = TuningCache.load(path)
    for tag in ("a", "b"):
        for i in range(n):
            key = CacheKey(abi="scale/1:0/x", platform="fake",
                           shapes=f"{tag}{i}x4", dtype="float32")
            assert cache.get(key, touch=False) is not None


def test_tombstones_merge_cleanly_across_writers(tmp_path):
    """A writer that loaded an entry before another process evicted it must
    not resurrect it on save; a fresh put legitimately may."""
    path = tmp_path / "t.json"
    k1, k2, k3 = _key("4x4"), _key("8x4"), _key("16x4")
    seed = TuningCache(path)
    seed.put(k1, BlockConfig.make(block=3))
    seed.put(k2, BlockConfig.make(block=5))
    seed.save()

    b = TuningCache.load(path)                  # holds k1 from load
    a = TuningCache.load(path)
    a.evict(k1)
    a.save()                                    # k1 gone from disk
    b.put(k3, BlockConfig.make(block=7))
    b.save()                                    # must NOT resurrect k1
    final = TuningCache.load(path)
    assert final.get(k1, touch=False) is None
    assert final.get(k2, touch=False) is not None
    assert final.get(k3, touch=False) is not None
    assert len(final) == 2

    c = TuningCache.load(path)                  # a fresh measurement DOES
    c.put(k1, BlockConfig.make(block=9))        # bring the key back
    c.save()
    assert TuningCache.load(path).get(k1, touch=False)["block"] == 9


def test_save_keeps_concurrent_writers_fresher_state(tmp_path):
    """Regression: a process that merely LOADED an entry must not clobber
    a concurrent writer's fresher copy on save — disk wins for untouched
    keys, with last_used merged at the max."""
    path = tmp_path / "t.json"
    k1, k2 = _key("4x4"), _key("8x4")
    seed = TuningCache(path)
    seed.put(k1, BlockConfig.make(block=3))
    seed.put(k2, BlockConfig.make(block=5))
    seed.save()

    a = TuningCache.load(path)                  # loads k1 but never uses it
    b = TuningCache.load(path)
    b.put(k1, BlockConfig.make(block=9))        # concurrent re-measure
    b.save()
    stamp_b = TuningCache.load(path).last_used(k1)
    a.put(_key("16x4"), BlockConfig.make(block=7))
    a.save()                                    # must not rewind k1
    final = TuningCache.load(path)
    assert final.get(k1, touch=False)["block"] == 9
    assert final.last_used(k1) == stamp_b
    # ...but a hit HERE is a real recency signal and must survive the merge
    c = TuningCache.load(path)
    assert c.get(k1) is not None                # stamps locally
    stamp_c = c.last_used(k1)
    c.save()
    assert TuningCache.load(path).last_used(k1) == stamp_c


def test_save_onto_wiped_file_keeps_loaded_state(tmp_path):
    """Regression: an empty/missing/corrupt on-disk file at save time is
    not a universal tombstone — the process rewrites its loaded state
    instead of silently dropping the whole warmed cache."""
    path = tmp_path / "t.json"
    seed = TuningCache(path)
    seed.put(_key("4x4"), BlockConfig.make(block=3))
    seed.put(_key("8x4"), BlockConfig.make(block=5))
    seed.save()

    cache = TuningCache.load(path)
    path.write_text("{ truncated garbage")      # transient corruption
    cache.put(_key("16x4"), BlockConfig.make(block=7))
    cache.save()
    final = TuningCache.load(path)
    assert len(final) == 3                      # nothing was lost


def test_tombstones_do_not_outlive_their_save(tmp_path):
    """Regression: once an eviction is persisted, the tombstone is spent —
    a later save by the same long-lived object must not keep deleting a
    key another process re-measured in between."""
    path = tmp_path / "t.json"
    k1, k2 = _key("4x4"), _key("8x4")
    longlived = TuningCache(path)
    longlived.put(k1, BlockConfig.make(block=3))
    longlived.put(k2, BlockConfig.make(block=5))
    longlived.evict(k1)
    longlived.save()                            # k1 gone from disk

    warmer = TuningCache.load(path)             # offline warm re-measures k1
    warmer.put(k1, BlockConfig.make(block=9))
    warmer.save()

    longlived.put(_key("16x4"), BlockConfig.make(block=7))
    longlived.save()                            # must NOT re-kill k1
    assert TuningCache.load(path).get(k1, touch=False)["block"] == 9


def test_capped_unsynthesizable_profile_still_binds_canonical(tmp_path):
    """Regression: when every profiled bucket is foreign to the op, the
    canonical-geometry fallback must survive a table cap — the cap trims
    the unsynthesizable placeholders, never the one real config."""
    reg = OpRegistry()
    abi = AbiString.make("scale2", {"args": ["x"]})
    reg.register(OpImpl(abi=abi, kind=ImplKind.REFERENCE,
                        fn=lambda x: x, provider="ref"))
    # args_from_shapes=None: every profiled bucket is unsynthesizable
    tuner = OpTuner(op="scale2", space={"block": (3,)},
                    example_args=lambda platform: (jnp.zeros((4, 4)),),
                    iters=1, warmup=0)
    reg.register(OpImpl(
        abi=abi, kind=ImplKind.NATIVE,
        fn=lambda x, config=None: x * (config.get("block", 1)
                                       if config is not None else 1),
        requires_feature="pallas_interpret", provider="fake-native",
        tuner=tuner,
    ))
    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("scale2", (jnp.zeros((8, 8)),), weight=5)
    prof.record("scale2", (jnp.zeros((16, 8)),), weight=2)

    cache = TuningCache(tmp_path / "t.json")
    ctx = TuningContext(cache, FAKE_SIM, profile=prof, max_entries=2)
    binding = reg.bind(["scale2"], FAKE_SIM, native=True, freeze=False,
                       tuning=ctx)
    table = binding.impl("scale2").config
    assert len(table) <= 2
    # the canonical geometry's searched config is in the table and primary
    assert table.primary["block"] == 3
    cfg, how = table.resolve((jnp.zeros((4, 4)),))
    assert (cfg["block"], how) == (3, "exact")
    statuses = {g.shapes: g.status for g in binding.reports[0].geometries}
    assert statuses["4x4"] == "cache-miss-searched"


def test_budget_starved_capped_bind_keeps_warmed_state(tmp_path):
    """Regression: placeholder outcomes (search budget spent) hold no
    cache entry, so they must not consume cap slots — a budget-starved
    capped redeploy binds the warmed entries instead of evicting them
    and dispatching nothing but defaults."""
    reg = _scale_registry()
    cache = TuningCache(tmp_path / "t.json")
    cache.put(_key("4x4"), BlockConfig.make(block=3))
    cache.put(_key("8x4"), BlockConfig.make(block=5))
    prof = WorkloadProfile(tmp_path / "w.json")
    prof.record("scale", (jnp.zeros((64, 4)),), weight=9)    # cold buckets
    prof.record("scale", (jnp.zeros((128, 4)),), weight=5)

    ctx = TuningContext(cache, FAKE_SIM, profile=prof, search_budget=0,
                        max_entries=2)
    binding = reg.bind(["scale"], FAKE_SIM, native=True, freeze=False,
                       tuning=ctx)
    rep = binding.reports[0]
    assert not any(g.status == "cache-evicted-lru" for g in rep.geometries)
    assert len(cache) == 2                      # nothing was shed
    table = binding.impl("scale").config
    assert len(table) == 2                      # ...and the warmed state binds
    cfg, how = table.resolve((jnp.zeros((4, 4)),))
    assert (cfg["block"], how) == (3, "exact")
    statuses = {g.shapes: g.status for g in rep.geometries}
    assert statuses["64x4"] == "search-budget-exhausted"
    assert statuses["4x4"] == statuses["8x4"] == "cache-hit"


def test_capped_sweep_touch_preserves_lru_order(tmp_path):
    """Regression: binding swept entries MRU-first must not hand out
    stamps in that same order (which would invert their relative recency
    for the next eviction pass)."""
    reg = _scale_registry()
    cache = TuningCache(tmp_path / "t.json")
    cache.put(_key("4x4"), BlockConfig.make(block=3))      # older
    cache.put(_key("8x4"), BlockConfig.make(block=5))      # newer
    ctx = TuningContext(cache, FAKE_SIM, search_on_miss=False,
                        max_entries=3)
    reg.bind(["scale"], FAKE_SIM, native=True, freeze=False, tuning=ctx)
    assert cache.last_used(_key("8x4")) > cache.last_used(_key("4x4"))


def test_compact_merges_with_concurrent_warm(tmp_path):
    """A compaction racing a warm run: the compactor's tombstones hold,
    the warmer's fresh entries survive, the file stays valid."""
    path = tmp_path / "t.json"
    seed = TuningCache(path)
    keys = [_key(f"{2 ** i}x4") for i in range(6)]
    for k in keys:
        seed.put(k, BlockConfig.make(block=2))
    seed.save()

    warmer = TuningCache.load(path)             # loaded before the GC ran
    compactor = TuningCache.load(path)
    report = compact_lru(compactor, 3)
    assert len(report) == 3
    compactor.save()
    fresh = [_key("1024x4"), _key("2048x4")]
    for k in fresh:
        warmer.put(k, BlockConfig.make(block=7))
    warmer.save()

    final = TuningCache.load(path)
    assert len(final) == 5                      # 3 survivors + 2 fresh
    for _, evicted_key in report.evicted:
        assert evicted_key not in final.raw_keys()
    for k in fresh:
        assert final.get(k, touch=False) is not None
    json.loads(path.read_text())


# ------------------------------------------------------------- the GC CLI --


def test_warm_compact_cli(tmp_path, capsys):
    from repro.tuning import warm

    cache_path = tmp_path / "tuning.json"
    prof_path = tmp_path / "workload.json"
    cache = TuningCache(cache_path)
    for rows in (4, 8, 16, 32, 64):
        cache.put(_key(f"{rows}x4"), BlockConfig.make(block=2))
    cache.save()
    prof = WorkloadProfile(prof_path)
    prof.record("scale", (jnp.zeros((32, 4)),), weight=4)
    prof.record("scale", (jnp.zeros((64, 4)),), weight=2)
    prof.save()

    rc = warm.main(["--compact", "--max-entries", "3",
                    "--cache", str(cache_path), "--profile", str(prof_path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "evicted 2" in out and "cap 3" in out
    final = TuningCache.load(cache_path)
    assert len(final) == 3
    # the profiled (still-live-traffic) buckets survived the GC
    assert final.get(_key("32x4"), touch=False) is not None
    assert final.get(_key("64x4"), touch=False) is not None

    # idempotent: a second pass is a no-op
    assert warm.main(["--compact", "--max-entries", "3",
                      "--cache", str(cache_path),
                      "--profile", str(prof_path)]) == 0
    assert len(TuningCache.load(cache_path)) == 3


def test_warm_compact_cli_requires_a_bound(tmp_path, capsys, monkeypatch):
    from repro.tuning import warm

    monkeypatch.delenv("REPRO_TUNING_MAX_ENTRIES", raising=False)
    rc = warm.main(["--compact", "--cache", str(tmp_path / "t.json"),
                    "--profile", str(tmp_path / "w.json")])
    assert rc == 2
    assert "REPRO_TUNING_MAX_ENTRIES" in capsys.readouterr().out

    # the env default supplies the bound (and an empty cache is a no-op)
    monkeypatch.setenv("REPRO_TUNING_MAX_ENTRIES", "3")
    rc = warm.main(["--compact", "--cache", str(tmp_path / "t.json"),
                    "--profile", str(tmp_path / "w.json")])
    assert rc == 0
    assert "nothing to compact" in capsys.readouterr().out


def test_warm_cli_end_to_end_with_decay(tmp_path, capsys):
    """The plain warm CLI over a real (pod-sim) op, with --decay: covers
    main()'s warm path the docs job otherwise exercises only in CI."""
    from repro.tuning import warm

    prof_path = tmp_path / "workload.json"
    cache_path = tmp_path / "tuning.json"
    prof = WorkloadProfile(prof_path)
    w = jnp.zeros((64,))
    prof.record("rmsnorm", (jnp.zeros((8, 64)), w), weight=4)
    prof.save()

    rc = warm.main(["--profile", str(prof_path), "--cache", str(cache_path),
                    "--platform", "pod-sim", "--top", "1",
                    "--decay", "0.5", "--ops", "rmsnorm"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "decayed profile by 0.5" in out and "warmed 1 entry" in out
    cache = TuningCache.load(cache_path)
    assert len(cache) == 1
    key = CacheKey(abi=str(ABIS["rmsnorm"]),
                   platform=platform_fingerprint(POD_SIM),
                   shapes="8x64,64", dtype="float32")
    assert cache.get(key, touch=False) is not None


def test_warm_cli_empty_profile_reports(tmp_path, capsys):
    from repro.tuning import warm

    rc = warm.main(["--profile", str(tmp_path / "none.json"),
                    "--cache", str(tmp_path / "t.json"),
                    "--platform", "pod-sim"])
    assert rc == 1
    assert "nothing to warm" in capsys.readouterr().out


# ------------------------------------------------------------ env parsing --


def test_tuning_max_entries_env_parsing():
    from repro.core.env import tuning_max_entries_default

    assert tuning_max_entries_default({}) is None
    assert tuning_max_entries_default({"REPRO_TUNING_MAX_ENTRIES": "4"}) == 4
    assert tuning_max_entries_default({"REPRO_TUNING_MAX_ENTRIES": " 7 "}) == 7
    # zero and junk deactivate the cap instead of erroring (or evicting
    # every warmed bucket, which no deployment can want)
    assert tuning_max_entries_default({"REPRO_TUNING_MAX_ENTRIES": "0"}) is None
    assert tuning_max_entries_default({"REPRO_TUNING_MAX_ENTRIES": "-3"}) is None
    assert tuning_max_entries_default({"REPRO_TUNING_MAX_ENTRIES": "junk"}) is None


def test_tuning_max_bytes_env_parsing():
    from repro.core.env import tuning_max_bytes_default

    assert tuning_max_bytes_default({}) is None
    assert tuning_max_bytes_default({"REPRO_TUNING_MAX_BYTES": "4096"}) == 4096
    assert tuning_max_bytes_default({"REPRO_TUNING_MAX_BYTES": " 512 "}) == 512
    assert tuning_max_bytes_default({"REPRO_TUNING_MAX_BYTES": "0"}) is None
    assert tuning_max_bytes_default({"REPRO_TUNING_MAX_BYTES": "-1"}) is None
    assert tuning_max_bytes_default({"REPRO_TUNING_MAX_BYTES": "1.5MB"}) is None


# --------------------------------------------------------------- byte cap --


def test_compact_byte_cap_evicts_coldest_until_under(tmp_path):
    cache = TuningCache(tmp_path / "t.json")
    keys = [_key(f"{2 ** i}x4") for i in range(4)]
    for i, k in enumerate(keys):
        cache.put(k, BlockConfig.make(block=i + 1))
    cache.get(keys[0])                        # oldest entry becomes hottest
    sizes = {k: cache.entry_bytes(k) for k in keys}
    # cap to roughly two entries' worth: the two coldest (1, 2) must go
    cap = cache.total_bytes() - sizes[keys[1]] - sizes[keys[2]]
    evicted = cache.compact(max_bytes=cap)
    assert set(evicted) == {keys[1].encode(), keys[2].encode()}
    assert cache.total_bytes() <= cap
    assert cache.compact(max_bytes=cap) == []  # already under


def test_compact_entry_and_byte_caps_compose(tmp_path):
    cache = TuningCache(tmp_path / "t.json")
    keys = [_key(f"{2 ** i}x4") for i in range(4)]
    for i, k in enumerate(keys):
        cache.put(k, BlockConfig.make(block=i + 1))
    # entry cap alone would keep 3; the byte cap bites harder
    cap = cache.entry_bytes(keys[3]) + 1
    cache.compact(3, max_bytes=cap)
    assert len(cache) == 1
    assert cache.get(keys[3], touch=False) is not None


def test_save_enforces_byte_cap(tmp_path):
    path = tmp_path / "t.json"
    cache = TuningCache(path)
    keys = [_key(f"{2 ** i}x4") for i in range(4)]
    for i, k in enumerate(keys):
        cache.put(k, BlockConfig.make(block=i + 1))
    cache.max_bytes = cache.total_bytes() - cache.entry_bytes(keys[0])
    cache.save()
    final = TuningCache.load(path)
    assert len(final) == 3
    assert final.get(keys[0], touch=False) is None  # coldest shed at save


def test_compact_lru_byte_cap_reports_sizes(tmp_path):
    cache = TuningCache(tmp_path / "t.json")
    keys = [_key(f"{2 ** i}x4") for i in range(3)]
    for i, k in enumerate(keys):
        cache.put(k, BlockConfig.make(block=i + 1))
    cap = cache.total_bytes() - cache.entry_bytes(keys[0])
    report = compact_lru(cache, None, max_bytes=cap)
    assert len(report) == 1 and report.kept == 2
    assert report.cap is None and report.cap_bytes == cap
    assert report.kept_bytes == cache.total_bytes() <= cap
    assert f"cap {cap}B" in report.describe()
    with pytest.raises(ValueError):
        compact_lru(cache, None, max_bytes=-1)


def test_warm_compact_cli_max_bytes(tmp_path, capsys, monkeypatch):
    from repro.tuning import warm

    monkeypatch.delenv("REPRO_TUNING_MAX_ENTRIES", raising=False)
    monkeypatch.delenv("REPRO_TUNING_MAX_BYTES", raising=False)
    cache_path = tmp_path / "tuning.json"
    cache = TuningCache(cache_path)
    for rows in (4, 8, 16, 32):
        cache.put(_key(f"{rows}x4"), BlockConfig.make(block=2))
    cap = cache.total_bytes() - cache.entry_bytes(_key("4x4"))
    cache.save()

    prof_path = str(tmp_path / "workload.json")   # absent: no prefer set
    rc = warm.main(["--compact", "--max-bytes", str(cap),
                    "--cache", str(cache_path), "--profile", prof_path])
    assert rc == 0
    out = capsys.readouterr().out
    assert "evicted 1" in out
    assert len(TuningCache.load(cache_path)) == 3

    # the env default supplies the byte bound too
    monkeypatch.setenv("REPRO_TUNING_MAX_BYTES", str(cap))
    assert warm.main(["--compact", "--cache", str(cache_path),
                      "--profile", prof_path]) == 0
    assert len(TuningCache.load(cache_path)) == 3
