"""Table V analogue — n-body GFLOP/s: native kernels vs reference.

The paper reports the same containerized CUDA n-body hitting each
system's native GFLOP/s.  Here the compute hot spots are the swap ops;
we report:

  * measured CPU GFLOP/s of the *reference* implementations (what this
    host natively delivers — the 'Laptop' row of Table V), and
  * the Pallas kernels' structural TPU numbers: FLOPs per call, VMEM
    working set from the BlockSpecs, and the v5e roofline bound (the
    'Piz Daint' row — this container has no TPU, so the bound is derived,
    not measured).

Correctness parity of the two implementations (the actual Table V claim)
is enforced in tests/test_kernels.py; the derived column repeats the
max-abs-err observed here.

The windowed-decode rows quantify the sliding-window kernel at a
long-KV decode geometry: the reference pays the full cache (a masked
softmax cannot skip unattended pages) while the kernel's skip-step
index maps execute only the KV blocks intersecting the window, so its
roofline bound shrinks with W/Smax instead of staying flat.  ``--smoke``
(CLI) runs only those rows, pins the interpret-mode kernel against the
windowed ref, and exits non-zero unless the windowed bound beats the
full-attention bound and the measured reference by >= 1.5x each — the
CI guard for the long-KV win.

The quant_matmul rows do the same for the int8 serving path: an
interpret-mode pin against the quantized reference, a measured
weight-stream read (fp32 weight vs int8 codes — the directly observable
part of the bandwidth win on a CPU host), and the derived HBM
bytes-moved ratio and v5e memory-roofline bound.  ``--smoke`` asserts
the pin, a >=3.5x bytes ratio, a >=3x roofline speedup, and a measured
stream speedup > 1x.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core.platform import TPU_V5E
from repro.kernels.flash_attention_ref import attention_ref, decode_attention_ref
from repro.kernels.moe_gmm_ref import moe_gmm_ref
from repro.kernels.rmsnorm_ref import rmsnorm_ref
from repro.kernels.ssd_scan_ref import ssd_scan_ref


def _attention_case():
    b, s, h, kv, dh = 1, 1024, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    fn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    flops = 4 * b * s * s * h * dh / 2          # causal halves the work
    vmem = (128 * dh * 3 + 128 * 128) * 4        # q,k,v tiles + scores fp32
    return "flash_attention", fn, (q, k, v), flops, vmem


def _rmsnorm_case():
    x = jax.random.normal(jax.random.PRNGKey(1), (8192, 1024))
    w = jax.random.normal(jax.random.PRNGKey(2), (1024,))
    fn = jax.jit(lambda x, w: rmsnorm_ref(x, w))
    flops = 3 * x.size
    vmem = (256 * 1024 * 2) * 4
    return "rmsnorm", fn, (x, w), flops, vmem


def _gmm_case():
    t, d, e, f = 4096, 512, 8, 512
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (t, d))
    w = jax.random.normal(ks[1], (e, d, f))
    gs = jnp.full((e,), t // e, jnp.int32)
    fn = jax.jit(lambda x, w, gs: moe_gmm_ref(x, w, gs, capacity_factor=1.0))
    flops = 2 * t * d * f
    vmem = (128 * d + d * 128 + 128 * 128) * 4
    return "moe_gmm", fn, (x, w, gs), flops, vmem


def _ssd_case():
    b, s, h, p, g, n, chunk = 1, 2048, 8, 64, 1, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    fn = jax.jit(lambda *a: ssd_scan_ref(*a, chunk=chunk)[0])
    # intra-chunk QxQ dual + state terms per chunk
    nc = s // chunk
    flops = b * h * nc * (2 * chunk * chunk * n + 2 * chunk * chunk * p
                          + 4 * chunk * n * p)
    vmem = (chunk * p + 2 * chunk * n + chunk * chunk + n * p) * 4
    return "ssd_scan", fn, (x, dt, A, Bm, Cm), flops, vmem


def run() -> list[tuple[str, float, str]]:
    rows = []
    for name, fn, args, flops, vmem in (
        _attention_case(), _rmsnorm_case(), _gmm_case(), _ssd_case()
    ):
        t = timeit(lambda: jax.block_until_ready(fn(*args)), warmup=1, iters=3)
        gflops_cpu = flops / t / 1e9
        # v5e structural bound for the Pallas kernel: compute-limited time
        t_tpu_bound = flops / TPU_V5E.peak_flops_bf16
        rows.append(row(
            f"table5/{name}/cpu_reference",
            t * 1e6,
            f"gflops={gflops_cpu:.2f}",
        ))
        rows.append(row(
            f"table5/{name}/tpu_kernel_bound",
            t_tpu_bound * 1e6,
            f"flops_per_call={flops:.3e};vmem_working_set_B={vmem}",
        ))
    rows.extend(windowed_decode_rows())
    rows.extend(quant_matmul_rows())
    return rows


def quant_matmul_rows() -> list[tuple[str, float, str]]:
    """Int8 quantized matmul at a weight-dominated decode geometry.

    Measured: the fp32 matmul and the dequantize-then-matmul reference
    (the latter is *slower* on CPU — it materializes the fp32 weight —
    which is exactly why the fused kernel exists), plus a weight-stream
    read of the fp32 weight vs the int8 codes: the only part of the win
    a CPU host can observe directly.  Derived: HBM bytes moved per call
    for the fp32 and int8 paths and the v5e memory-roofline bound each
    implies — decode matmuls are bandwidth-bound, so the bound speedup
    is the bytes ratio.  An interpret-mode run pins the fused kernel
    against the quantized reference first.
    """
    t, d, f = 16, 2048, 2048

    # interpret-mode correctness pin at a small geometry
    from repro.kernels.ops import _NATIVES_INTERPRET
    from repro.kernels.quant import quantize_per_channel
    from repro.kernels.quant_matmul_ref import quant_matmul_ref

    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    xs = jax.random.normal(ks[0], (16, 64))
    ws = jax.random.normal(ks[1], (64, 64)) / np.sqrt(64)
    qws, sws = quantize_per_channel(ws, axis=-2, fmt="int8")
    got = _NATIVES_INTERPRET["quant_matmul"](xs, qws, sws)
    want = quant_matmul_ref(xs, qws, sws)
    maxerr = float(jnp.abs(got - want).max())
    dq_err = float(jnp.abs(want - xs @ ws).max())

    # measured matmuls at the weight-dominated geometry
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    x = jax.random.normal(ks[0], (t, d))
    w = jax.random.normal(ks[1], (d, f)) / np.sqrt(d)
    qw, sw = quantize_per_channel(w, axis=-2, fmt="int8")
    mm = jax.jit(lambda x, w: x @ w)
    qmm = jax.jit(quant_matmul_ref)
    t_fp32 = timeit(lambda: jax.block_until_ready(mm(x, w)),
                    warmup=1, iters=3)
    t_qref = timeit(lambda: jax.block_until_ready(qmm(x, qw, sw)),
                    warmup=1, iters=3)

    # measured weight-stream read: fp32 weight vs int8 codes (best-of-3
    # each side — the stream is short enough for scheduler noise)
    red32 = jax.jit(lambda w: jnp.abs(w).sum())
    red8 = jax.jit(lambda q: jnp.abs(q.astype(jnp.float32)).sum())
    t_s32 = min(timeit(lambda: jax.block_until_ready(red32(w)),
                       warmup=1, iters=3) for _ in range(3))
    t_s8 = min(timeit(lambda: jax.block_until_ready(red8(qw)),
                      warmup=1, iters=3) for _ in range(3))
    stream_speedup = t_s32 / t_s8

    # derived: HBM bytes per call and the v5e memory-roofline bound
    bytes_fp32 = (t * d + d * f + t * f) * 4
    bytes_int8 = t * d * 4 + d * f * 1 + f * 4 + t * f * 4
    bytes_ratio = bytes_fp32 / bytes_int8
    t_fp32_bound = bytes_fp32 / TPU_V5E.hbm_bandwidth
    t_int8_bound = bytes_int8 / TPU_V5E.hbm_bandwidth
    return [
        row("table5/quant_matmul/cpu_reference", t_fp32 * 1e6,
            f"geometry=t{t}xd{d}xf{f};maxerr={maxerr:.2e};"
            f"dequant_err={dq_err:.2e};quant_ref_us={t_qref * 1e6:.1f}"),
        row("table5/quant_matmul/weight_stream", t_s8 * 1e6,
            f"fp32_stream_us={t_s32 * 1e6:.1f};"
            f"stream_speedup={stream_speedup:.2f}x"),
        row("table5/quant_matmul/tpu_kernel_bound", t_int8_bound * 1e6,
            f"bytes_fp32={bytes_fp32};bytes_int8={bytes_int8};"
            f"bytes_ratio={bytes_ratio:.2f}x;"
            f"hbm_bound_speedup={t_fp32_bound / t_int8_bound:.2f}x"),
    ]


def windowed_decode_rows() -> list[tuple[str, float, str]]:
    """Sliding-window decode at a long-KV geometry (Smax >> W).

    Measured: the jnp reference with the window mask — it still
    materializes scores for the whole cache, so its cost is flat in W.
    Derived: the v5e roofline bound of the windowed Pallas kernel over
    the KV blocks its skip predicate actually executes (closed form of
    the kernel's grid gate: a block runs iff it reaches past the window
    start and starts before kv_len), next to the full-attention kernel's
    bound over every block.  A small interpret-mode run pins the kernel
    against the windowed ref first, so the derived rows describe a
    kernel that is numerically correct on this host.
    """
    b, h, kvh, dh = 4, 8, 4, 64
    smax, window, block_k = 4096, 256, 128

    # interpret-mode correctness pin at a scaled-down geometry (the full
    # one takes minutes under the Pallas interpreter)
    from repro.kernels.ops import _NATIVES_INTERPRET

    vs, vw = 256, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 1, 2, 16))
    k = jax.random.normal(ks[1], (1, vs, 1, 16))
    v = jax.random.normal(ks[2], (1, vs, 1, 16))
    pos = jnp.asarray(vs - 5, jnp.int32)
    wv = jnp.asarray(vw, jnp.int32)
    t_pin = timeit(lambda: jax.block_until_ready(
        _NATIVES_INTERPRET["decode_attention"](q, k, v, pos, None, wv)),
        warmup=1, iters=3)
    got = _NATIVES_INTERPRET["decode_attention"](q, k, v, pos, None, wv)
    want = decode_attention_ref(q, k, v, pos, None, wv)
    maxerr = float(jnp.abs(got - want).max())

    # measured reference at the long-KV geometry
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh))
    k = jax.random.normal(ks[1], (b, smax, kvh, dh))
    v = jax.random.normal(ks[2], (b, smax, kvh, dh))
    posv = jnp.full((b,), smax - 1, jnp.int32)
    win = jnp.asarray(window, jnp.int32)
    ref = jax.jit(lambda *a: decode_attention_ref(*a))
    t_ref = timeit(lambda: jax.block_until_ready(ref(q, k, v, posv, None, win)),
                   warmup=1, iters=3)

    # executed KV blocks, closed form of the kernel's skip predicate:
    # run iff ik*bk < kv_len  and  ik*bk + bk - 1 >= window_start
    kv_len = smax                      # pos + 1
    w_start = kv_len - window          # decode: ws = kv_len - 1 - W + 1
    nblk = -(-smax // block_k)
    blk_full = -(-kv_len // block_k)
    blk_win = blk_full - w_start // block_k
    flops_blk = 4 * b * h * block_k * dh       # qk + pv per executed block
    t_full_bound = blk_full * flops_blk / TPU_V5E.peak_flops_bf16
    t_win_bound = blk_win * flops_blk / TPU_V5E.peak_flops_bf16
    return [
        row("table5/windowed_decode/cpu_reference", t_ref * 1e6,
            f"geometry=b{b}xS{smax}xW{window};maxerr={maxerr:.2e};"
            f"pin_us={t_pin * 1e6:.1f}"),
        row("table5/windowed_decode/tpu_kernel_bound", t_win_bound * 1e6,
            f"kv_blocks={blk_win}/{nblk};"
            f"win_vs_full_bound={t_full_bound / t_win_bound:.2f}x;"
            f"ref_vs_pallas={t_ref / t_win_bound:.2f}x"),
        row("table5/decode_attention/tpu_kernel_bound", t_full_bound * 1e6,
            f"kv_blocks={blk_full}/{nblk};flat_in_window=1"),
    ]


def main(argv=None) -> int:
    """CLI wrapper; ``--smoke`` runs only the windowed-decode rows and
    asserts the long-KV win CI depends on."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="windowed-decode + quant rows only, with "
                         "assertions (the CI guard)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    if not args.smoke:
        for name, us, derived in run():
            print(f"{name},{us:.1f},{derived}")
        return 0
    rows = windowed_decode_rows()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    by_name = {n: (us, d) for n, us, d in rows}
    us_ref, note_ref = by_name["table5/windowed_decode/cpu_reference"]
    us_win, note_win = by_name["table5/windowed_decode/tpu_kernel_bound"]
    us_full, _ = by_name["table5/decode_attention/tpu_kernel_bound"]
    maxerr = float(note_ref.split("maxerr=")[1].split(";")[0])
    if maxerr > 1e-4:
        print(f"FAIL: interpret-mode windowed decode drifted from the "
              f"windowed ref (maxerr={maxerr:.2e})")
        return 1
    if us_full < 1.5 * us_win:
        print(f"FAIL: windowed bound {us_win:.3f}us should beat the full-"
              f"attention bound {us_full:.3f}us by >=1.5x at long KV")
        return 1
    if us_ref < 1.5 * us_win:
        print(f"FAIL: windowed kernel bound {us_win:.3f}us should beat the "
              f"measured reference {us_ref:.1f}us by >=1.5x")
        return 1
    print(f"OK: windowed decode executes {note_win.split(';')[0]} KV blocks; "
          f"bound beats full attention {us_full / us_win:.1f}x and the "
          f"measured reference {us_ref / us_win:.0f}x at S=4096, W=256")

    qrows = quant_matmul_rows()
    for name, us, derived in qrows:
        print(f"{name},{us:.1f},{derived}")
    by_name = {n: (us, d) for n, us, d in qrows}
    _, note_ref = by_name["table5/quant_matmul/cpu_reference"]
    _, note_stream = by_name["table5/quant_matmul/weight_stream"]
    _, note_bound = by_name["table5/quant_matmul/tpu_kernel_bound"]
    maxerr = float(note_ref.split("maxerr=")[1].split(";")[0])
    if maxerr > 1e-4:
        print(f"FAIL: interpret-mode quant_matmul drifted from the "
              f"quantized ref (maxerr={maxerr:.2e})")
        return 1
    bytes_ratio = float(note_bound.split("bytes_ratio=")[1].split("x")[0])
    if bytes_ratio < 3.5:
        print(f"FAIL: int8 path should move >=3.5x fewer HBM bytes than "
              f"fp32 at a weight-dominated geometry (got {bytes_ratio:.2f}x)")
        return 1
    bound_speedup = float(
        note_bound.split("hbm_bound_speedup=")[1].split("x")[0])
    if bound_speedup < 3.0:
        print(f"FAIL: v5e memory-roofline speedup of the int8 path should "
              f"be >=3x (got {bound_speedup:.2f}x)")
        return 1
    stream_speedup = float(
        note_stream.split("stream_speedup=")[1].split("x")[0])
    if stream_speedup <= 1.0:
        print(f"FAIL: reading the int8 weight codes should measurably beat "
              f"reading the fp32 weight (got {stream_speedup:.2f}x)")
        return 1
    print(f"OK: quant_matmul moves {bytes_ratio:.1f}x fewer bytes "
          f"(roofline speedup {bound_speedup:.1f}x), measured weight-stream "
          f"speedup {stream_speedup:.2f}x, kernel pinned to the quantized "
          f"ref at maxerr={maxerr:.1e}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
