"""Table V analogue — n-body GFLOP/s: native kernels vs reference.

The paper reports the same containerized CUDA n-body hitting each
system's native GFLOP/s.  Here the compute hot spots are the swap ops;
we report:

  * measured CPU GFLOP/s of the *reference* implementations (what this
    host natively delivers — the 'Laptop' row of Table V), and
  * the Pallas kernels' structural TPU numbers: FLOPs per call, VMEM
    working set from the BlockSpecs, and the v5e roofline bound (the
    'Piz Daint' row — this container has no TPU, so the bound is derived,
    not measured).

Correctness parity of the two implementations (the actual Table V claim)
is enforced in tests/test_kernels.py; the derived column repeats the
max-abs-err observed here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core.platform import TPU_V5E
from repro.kernels.flash_attention_ref import attention_ref
from repro.kernels.moe_gmm_ref import moe_gmm_ref
from repro.kernels.rmsnorm_ref import rmsnorm_ref
from repro.kernels.ssd_scan_ref import ssd_scan_ref


def _attention_case():
    b, s, h, kv, dh = 1, 1024, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    fn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    flops = 4 * b * s * s * h * dh / 2          # causal halves the work
    vmem = (128 * dh * 3 + 128 * 128) * 4        # q,k,v tiles + scores fp32
    return "flash_attention", fn, (q, k, v), flops, vmem


def _rmsnorm_case():
    x = jax.random.normal(jax.random.PRNGKey(1), (8192, 1024))
    w = jax.random.normal(jax.random.PRNGKey(2), (1024,))
    fn = jax.jit(lambda x, w: rmsnorm_ref(x, w))
    flops = 3 * x.size
    vmem = (256 * 1024 * 2) * 4
    return "rmsnorm", fn, (x, w), flops, vmem


def _gmm_case():
    t, d, e, f = 4096, 512, 8, 512
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (t, d))
    w = jax.random.normal(ks[1], (e, d, f))
    gs = jnp.full((e,), t // e, jnp.int32)
    fn = jax.jit(lambda x, w, gs: moe_gmm_ref(x, w, gs, capacity_factor=1.0))
    flops = 2 * t * d * f
    vmem = (128 * d + d * 128 + 128 * 128) * 4
    return "moe_gmm", fn, (x, w, gs), flops, vmem


def _ssd_case():
    b, s, h, p, g, n, chunk = 1, 2048, 8, 64, 1, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    fn = jax.jit(lambda *a: ssd_scan_ref(*a, chunk=chunk)[0])
    # intra-chunk QxQ dual + state terms per chunk
    nc = s // chunk
    flops = b * h * nc * (2 * chunk * chunk * n + 2 * chunk * chunk * p
                          + 4 * chunk * n * p)
    vmem = (chunk * p + 2 * chunk * n + chunk * chunk + n * p) * 4
    return "ssd_scan", fn, (x, dt, A, Bm, Cm), flops, vmem


def run() -> list[tuple[str, float, str]]:
    rows = []
    for name, fn, args, flops, vmem in (
        _attention_case(), _rmsnorm_case(), _gmm_case(), _ssd_case()
    ):
        t = timeit(lambda: jax.block_until_ready(fn(*args)), warmup=1, iters=3)
        gflops_cpu = flops / t / 1e9
        # v5e structural bound for the Pallas kernel: compute-limited time
        t_tpu_bound = flops / TPU_V5E.peak_flops_bf16
        rows.append(row(
            f"table5/{name}/cpu_reference",
            t * 1e6,
            f"gflops={gflops_cpu:.2f}",
        ))
        rows.append(row(
            f"table5/{name}/tpu_kernel_bound",
            t_tpu_bound * 1e6,
            f"flops_per_call={flops:.3e};vmem_working_set_B={vmem}",
        ))
    return rows
