"""Table V analogue — n-body GFLOP/s: native kernels vs reference.

The paper reports the same containerized CUDA n-body hitting each
system's native GFLOP/s.  Here the compute hot spots are the swap ops;
we report:

  * measured CPU GFLOP/s of the *reference* implementations (what this
    host natively delivers — the 'Laptop' row of Table V), and
  * the Pallas kernels' structural TPU numbers: FLOPs per call, VMEM
    working set from the BlockSpecs, and the v5e roofline bound (the
    'Piz Daint' row — this container has no TPU, so the bound is derived,
    not measured).

Correctness parity of the two implementations (the actual Table V claim)
is enforced in tests/test_kernels.py; the derived column repeats the
max-abs-err observed here.

The windowed-decode rows quantify the sliding-window kernel at a
long-KV decode geometry: the reference pays the full cache (a masked
softmax cannot skip unattended pages) while the kernel's skip-step
index maps execute only the KV blocks intersecting the window, so its
roofline bound shrinks with W/Smax instead of staying flat.  ``--smoke``
(CLI) runs only those rows, pins the interpret-mode kernel against the
windowed ref, and exits non-zero unless the windowed bound beats the
full-attention bound and the measured reference by >= 1.5x each — the
CI guard for the long-KV win.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core.platform import TPU_V5E
from repro.kernels.flash_attention_ref import attention_ref, decode_attention_ref
from repro.kernels.moe_gmm_ref import moe_gmm_ref
from repro.kernels.rmsnorm_ref import rmsnorm_ref
from repro.kernels.ssd_scan_ref import ssd_scan_ref


def _attention_case():
    b, s, h, kv, dh = 1, 1024, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    fn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    flops = 4 * b * s * s * h * dh / 2          # causal halves the work
    vmem = (128 * dh * 3 + 128 * 128) * 4        # q,k,v tiles + scores fp32
    return "flash_attention", fn, (q, k, v), flops, vmem


def _rmsnorm_case():
    x = jax.random.normal(jax.random.PRNGKey(1), (8192, 1024))
    w = jax.random.normal(jax.random.PRNGKey(2), (1024,))
    fn = jax.jit(lambda x, w: rmsnorm_ref(x, w))
    flops = 3 * x.size
    vmem = (256 * 1024 * 2) * 4
    return "rmsnorm", fn, (x, w), flops, vmem


def _gmm_case():
    t, d, e, f = 4096, 512, 8, 512
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (t, d))
    w = jax.random.normal(ks[1], (e, d, f))
    gs = jnp.full((e,), t // e, jnp.int32)
    fn = jax.jit(lambda x, w, gs: moe_gmm_ref(x, w, gs, capacity_factor=1.0))
    flops = 2 * t * d * f
    vmem = (128 * d + d * 128 + 128 * 128) * 4
    return "moe_gmm", fn, (x, w, gs), flops, vmem


def _ssd_case():
    b, s, h, p, g, n, chunk = 1, 2048, 8, 64, 1, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    fn = jax.jit(lambda *a: ssd_scan_ref(*a, chunk=chunk)[0])
    # intra-chunk QxQ dual + state terms per chunk
    nc = s // chunk
    flops = b * h * nc * (2 * chunk * chunk * n + 2 * chunk * chunk * p
                          + 4 * chunk * n * p)
    vmem = (chunk * p + 2 * chunk * n + chunk * chunk + n * p) * 4
    return "ssd_scan", fn, (x, dt, A, Bm, Cm), flops, vmem


def run() -> list[tuple[str, float, str]]:
    rows = []
    for name, fn, args, flops, vmem in (
        _attention_case(), _rmsnorm_case(), _gmm_case(), _ssd_case()
    ):
        t = timeit(lambda: jax.block_until_ready(fn(*args)), warmup=1, iters=3)
        gflops_cpu = flops / t / 1e9
        # v5e structural bound for the Pallas kernel: compute-limited time
        t_tpu_bound = flops / TPU_V5E.peak_flops_bf16
        rows.append(row(
            f"table5/{name}/cpu_reference",
            t * 1e6,
            f"gflops={gflops_cpu:.2f}",
        ))
        rows.append(row(
            f"table5/{name}/tpu_kernel_bound",
            t_tpu_bound * 1e6,
            f"flops_per_call={flops:.3e};vmem_working_set_B={vmem}",
        ))
    rows.extend(windowed_decode_rows())
    return rows


def windowed_decode_rows() -> list[tuple[str, float, str]]:
    """Sliding-window decode at a long-KV geometry (Smax >> W).

    Measured: the jnp reference with the window mask — it still
    materializes scores for the whole cache, so its cost is flat in W.
    Derived: the v5e roofline bound of the windowed Pallas kernel over
    the KV blocks its skip predicate actually executes (closed form of
    the kernel's grid gate: a block runs iff it reaches past the window
    start and starts before kv_len), next to the full-attention kernel's
    bound over every block.  A small interpret-mode run pins the kernel
    against the windowed ref first, so the derived rows describe a
    kernel that is numerically correct on this host.
    """
    b, h, kvh, dh = 4, 8, 4, 64
    smax, window, block_k = 4096, 256, 128

    # interpret-mode correctness pin at a scaled-down geometry (the full
    # one takes minutes under the Pallas interpreter)
    from repro.kernels.ops import _NATIVES_INTERPRET

    vs, vw = 256, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 1, 2, 16))
    k = jax.random.normal(ks[1], (1, vs, 1, 16))
    v = jax.random.normal(ks[2], (1, vs, 1, 16))
    pos = jnp.asarray(vs - 5, jnp.int32)
    wv = jnp.asarray(vw, jnp.int32)
    t_pin = timeit(lambda: jax.block_until_ready(
        _NATIVES_INTERPRET["decode_attention"](q, k, v, pos, None, wv)),
        warmup=1, iters=3)
    got = _NATIVES_INTERPRET["decode_attention"](q, k, v, pos, None, wv)
    want = decode_attention_ref(q, k, v, pos, None, wv)
    maxerr = float(jnp.abs(got - want).max())

    # measured reference at the long-KV geometry
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh))
    k = jax.random.normal(ks[1], (b, smax, kvh, dh))
    v = jax.random.normal(ks[2], (b, smax, kvh, dh))
    posv = jnp.full((b,), smax - 1, jnp.int32)
    win = jnp.asarray(window, jnp.int32)
    ref = jax.jit(lambda *a: decode_attention_ref(*a))
    t_ref = timeit(lambda: jax.block_until_ready(ref(q, k, v, posv, None, win)),
                   warmup=1, iters=3)

    # executed KV blocks, closed form of the kernel's skip predicate:
    # run iff ik*bk < kv_len  and  ik*bk + bk - 1 >= window_start
    kv_len = smax                      # pos + 1
    w_start = kv_len - window          # decode: ws = kv_len - 1 - W + 1
    nblk = -(-smax // block_k)
    blk_full = -(-kv_len // block_k)
    blk_win = blk_full - w_start // block_k
    flops_blk = 4 * b * h * block_k * dh       # qk + pv per executed block
    t_full_bound = blk_full * flops_blk / TPU_V5E.peak_flops_bf16
    t_win_bound = blk_win * flops_blk / TPU_V5E.peak_flops_bf16
    return [
        row("table5/windowed_decode/cpu_reference", t_ref * 1e6,
            f"geometry=b{b}xS{smax}xW{window};maxerr={maxerr:.2e};"
            f"pin_us={t_pin * 1e6:.1f}"),
        row("table5/windowed_decode/tpu_kernel_bound", t_win_bound * 1e6,
            f"kv_blocks={blk_win}/{nblk};"
            f"win_vs_full_bound={t_full_bound / t_win_bound:.2f}x;"
            f"ref_vs_pallas={t_ref / t_win_bound:.2f}x"),
        row("table5/decode_attention/tpu_kernel_bound", t_full_bound * 1e6,
            f"kv_blocks={blk_full}/{nblk};flat_in_window=1"),
    ]


def main(argv=None) -> int:
    """CLI wrapper; ``--smoke`` runs only the windowed-decode rows and
    asserts the long-KV win CI depends on."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="windowed-decode rows only, with assertions "
                         "(the CI guard)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    if not args.smoke:
        for name, us, derived in run():
            print(f"{name},{us:.1f},{derived}")
        return 0
    rows = windowed_decode_rows()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    by_name = {n: (us, d) for n, us, d in rows}
    us_ref, note_ref = by_name["table5/windowed_decode/cpu_reference"]
    us_win, note_win = by_name["table5/windowed_decode/tpu_kernel_bound"]
    us_full, _ = by_name["table5/decode_attention/tpu_kernel_bound"]
    maxerr = float(note_ref.split("maxerr=")[1].split(";")[0])
    if maxerr > 1e-4:
        print(f"FAIL: interpret-mode windowed decode drifted from the "
              f"windowed ref (maxerr={maxerr:.2e})")
        return 1
    if us_full < 1.5 * us_win:
        print(f"FAIL: windowed bound {us_win:.3f}us should beat the full-"
              f"attention bound {us_full:.3f}us by >=1.5x at long KV")
        return 1
    if us_ref < 1.5 * us_win:
        print(f"FAIL: windowed kernel bound {us_win:.3f}us should beat the "
              f"measured reference {us_ref:.1f}us by >=1.5x")
        return 1
    print(f"OK: windowed decode executes {note_win.split(';')[0]} KV blocks; "
          f"bound beats full attention {us_full / us_win:.1f}x and the "
          f"measured reference {us_ref / us_win:.0f}x at S=4096, W=256")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
