"""Table II analogue — PyFR multi-GPU scaling with GPU+MPI support.

The paper scales the SAME container from 1 to 8 GPUs.  Here the same
Bundle trains at data-parallel degree 1/2/4/8 (forced host devices); we
report per-step wall-clock and the work-per-device scaling.  All degrees
share one physical CPU core, so wall-clock stays ~flat while per-device
batch shrinks 8x — the derived column reports parallel efficiency
normalized to total work, the property Table II demonstrates.
"""

from __future__ import annotations

import json

from benchmarks.common import row, run_subprocess

_CODE = """
import time, json
import jax
from repro.configs.base import ShapeConfig, ModelConfig
from repro.core import Runtime
from repro.data import DataConfig, SyntheticStream
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import DeployOptions, make_deployment
from repro.launch.train import make_bundle
from repro.optim import adamw_init

bundle = make_bundle("granite-3-8b", reduced=True)
rt = Runtime(host_env={})
container = rt.deploy(bundle, mesh=make_host_mesh())
cfg = ModelConfig.from_dict(container.bundle.model_config)
shape = ShapeConfig("b", 64, 8, "train")     # fixed GLOBAL batch
dep = make_deployment(cfg, shape, container.mesh,
                      options=DeployOptions(donate=False),
                      binding=container.binding)
params = jax.device_put(dep.model.init(jax.random.PRNGKey(0)), dep.param_sharding)
opt = jax.device_put(adamw_init(params), dep.opt_sharding)
stream = SyntheticStream(cfg, shape, DataConfig())
batch = jax.device_put(stream.global_batch_at(0), dep.batch_sharding)
params, opt, m = dep.train_step(params, opt, batch)
steps = 5
t0 = time.perf_counter()
for s in range(steps):
    batch = jax.device_put(stream.global_batch_at(s + 1), dep.batch_sharding)
    params, opt, m = dep.train_step(params, opt, batch)
float(m["loss"])
dt = (time.perf_counter() - t0) / steps
print(json.dumps({"per_step_s": dt, "devices": len(container.devices)}))
"""


def run() -> list[tuple[str, float, str]]:
    rows = []
    base = None
    for devices in (1, 2, 4, 8):
        out = run_subprocess(_CODE, devices=devices)
        r = json.loads(out.strip().splitlines()[-1])
        if base is None:
            base = r["per_step_s"]
        # on 1 physical core, ideal virtual scaling keeps wall-clock flat
        eff = base / r["per_step_s"]
        rows.append(row(
            f"table2/train_step/{devices}dev",
            r["per_step_s"] * 1e6,
            f"rel_throughput={eff:.2f}",
        ))
    return rows
