"""Table I analogue — containerized TensorFlow run times across systems.

The paper's claim: the SAME unmodified container runs on every system,
with run time set by the system's hardware.  Here: the same Bundle
(reduced LM, identical digest) is deployed on the 'laptop' platform
(1 device) and the 'cluster' platform (8 forced host devices, flat DP) —
wall-clock per train step is reported for each.  On this single-core CPU
container the 8-"device" run shows SPMD overhead rather than speedup; the
portability property (one artifact, two systems, numerics equal) is what
the table demonstrates, exactly like Table I's unmodified-image rows.
"""

from __future__ import annotations

from benchmarks.common import row, run_subprocess

_STEPS = 6

_CODE = """
import time, json
import jax
from repro.configs.base import ShapeConfig
from repro.core import Runtime
from repro.data import DataConfig, SyntheticStream
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import DeployOptions, make_deployment
from repro.launch.train import make_bundle
from repro.configs.base import ModelConfig
from repro.optim import adamw_init

bundle = make_bundle("qwen2.5-14b", reduced=True)
rt = Runtime(host_env={})
container = rt.deploy(bundle, mesh=make_host_mesh())
cfg = ModelConfig.from_dict(container.bundle.model_config)
shape = ShapeConfig("b", 64, 8, "train")
dep = make_deployment(cfg, shape, container.mesh,
                      options=DeployOptions(donate=False),
                      binding=container.binding)
params = jax.device_put(dep.model.init(jax.random.PRNGKey(0)), dep.param_sharding)
opt = jax.device_put(adamw_init(params), dep.opt_sharding)
stream = SyntheticStream(cfg, shape, DataConfig())
batch = jax.device_put(stream.global_batch_at(0), dep.batch_sharding)
params, opt, m = dep.train_step(params, opt, batch)   # compile + warmup
t0 = time.perf_counter()
for s in range(%d):
    batch = jax.device_put(stream.global_batch_at(s + 1), dep.batch_sharding)
    params, opt, m = dep.train_step(params, opt, batch)
float(m["loss"])
dt = (time.perf_counter() - t0) / %d
print(json.dumps({"per_step_s": dt, "loss": float(m["loss"]),
                  "digest": container.bundle.digest,
                  "devices": len(container.devices)}))
"""


def run() -> list[tuple[str, float, str]]:
    import json

    rows = []
    results = {}
    for system, devices in (("laptop", 1), ("cluster", 8)):
        out = run_subprocess(_CODE % (_STEPS, _STEPS), devices=devices)
        r = json.loads(out.strip().splitlines()[-1])
        results[system] = r
        rows.append(row(
            f"table1/train_step/{system}",
            r["per_step_s"] * 1e6,
            f"devices={r['devices']};loss={r['loss']:.3f}",
        ))
    same = results["laptop"]["digest"] == results["cluster"]["digest"]
    rows.append(row("table1/same_artifact", 0.0, f"unmodified_bundle={same}"))
    return rows
