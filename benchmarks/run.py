"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows, as required.

  table1_e2e          Table I   (containerized app across systems)
  table2_scaling      Table II  (same container, 1..8 devices)
  table34_collectives Tables III/IV (native vs container collectives)
  table5_kernels      Table V   (kernel GFLOP/s, reference vs native bound)
  table6_autotune     Table VI  (default vs site-tuned kernel block configs)
  fig3_startup        Fig. 3    (startup metadata storm vs single manifest)

Usage: PYTHONPATH=src python -m benchmarks.run [--only table5_kernels,fig3_startup]
"""

from __future__ import annotations

import argparse
import sys
import traceback

_MODULES = [
    "table1_e2e",
    "table2_scaling",
    "table34_collectives",
    "table5_kernels",
    "table6_autotune",
    "fig3_startup",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module list (default: all)")
    args = ap.parse_args(argv)
    wanted = args.only.split(",") if args.only else _MODULES

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in wanted:
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:
            failures += 1
            print(f"{mod_name},nan,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
