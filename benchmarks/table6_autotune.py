"""Table VI (new) — deferred specialization: default vs site-tuned configs.

The paper's portability claim is that the same container reaches native
performance once the site binds its optimized resources.  This table
quantifies the last piece of that gap for the swap kernels: the kernel
with its shipped default BlockConfig vs the config the autotuner picked
for *this* host, both bound through the real registry path.

On this CPU container the kernels run in interpret mode (pod-sim), so
absolute numbers are simulation-host numbers; the mechanism — search,
persist, rebind — is identical on a TPU site.  Rows:

  table6/<op>/default_config    us/call with the shipped defaults
  table6/<op>/tuned_config      us/call with the searched winner
  table6/<op>/profile_warmed    us/call at a *recorded live geometry*
                                (different from the canonical example),
                                tuned offline by repro.tuning.warm
  table6/<op>/top1_binding      us/call at the SECOND-hottest live
                                geometry under the pre-dispatch binding
                                (one baked config: the hottest bucket's,
                                foreign to this call)
  table6/<op>/geometry_dispatch us/call at the same geometry under the
                                geometry-dispatched binding (its own
                                warmed entry, resolved at trace time);
                                the note carries both bindings'
                                multi-bucket exact-hit rates
  table6/<op>/near_dtype_borrow us/call for bf16 traffic on a site whose
                                cache was only ever warmed at fp32: the
                                dispatch borrows the same-structure fp32
                                bucket's config ("near-dtype", VMEM
                                re-validated for bf16) instead of
                                falling to the shipped default
  table6/<op>/bundle_import     time-to-first-dispatch at a FRESH site:
                                a cold deploy (searches at bind) vs the
                                same deploy after importing the origin
                                site's exported tuning bundle (zero
                                searches, exact dispatch) — the paper's
                                ship-the-artifact story quantified

``--smoke`` (CLI) runs only the geometry-dispatch + near-dtype + bundle
rows with tiny workloads and exits non-zero unless the dispatched
binding resolves every live bucket exactly while the top-1 binding
cannot, the bf16 call dispatches via near-dtype, and the bundle-imported
deploy pays zero searches where the cold one paid at least one — the CI
guard that keeps the new rows runnable.
"""

from __future__ import annotations

import tempfile
from pathlib import Path

import jax

from benchmarks.common import row, timeit
from repro.core.platform import POD_SIM
from repro.core.registry import OpRegistry
from repro.kernels.ops import OP_NAMES, register_all, tuners
from repro.tuning import TuningCache, TuningContext, WorkloadProfile, default_config
from repro.tuning.warm import warm_cache

_OPS = ("rmsnorm", "moe_gmm", "ssd_scan")


def run() -> list[tuple[str, float, str]]:
    reg = register_all(OpRegistry())
    cache = TuningCache(Path(tempfile.mkdtemp(prefix="repro-t6-")) / "tuning.json")
    ctx = TuningContext(cache, POD_SIM, ops=set(_OPS))
    tuned = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False, tuning=ctx)
    default = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False)

    rows = []
    per_op_tuner = tuners()
    for op in _OPS:
        args = per_op_tuner[op].example_args(POD_SIM)
        def_cfg = default_config(op, POD_SIM)   # untuned per-platform fallback
        t_def = timeit(
            lambda: jax.block_until_ready(default[op](*args, config=def_cfg)),
            warmup=1, iters=3,
        )
        t_tun = timeit(
            lambda: jax.block_until_ready(tuned[op](*args)), warmup=1, iters=3
        )
        report = next(r for r in tuned.reports if r.op == op)
        rows.append(row(
            f"table6/{op}/default_config", t_def * 1e6,
            f"config={def_cfg}",
        ))
        rows.append(row(
            f"table6/{op}/tuned_config", t_tun * 1e6,
            f"config={report.config};{report.tuning};"
            f"speedup_vs_default={t_def / t_tun:.2f}x",
        ))

    # -- tune-on-real-traffic: warm the cache from a recorded geometry ------
    # A live serve-loop geometry (moe at half the canonical width) is
    # recorded into a workload profile, warmed offline, then bound with
    # the profile present: the op must hit the warmed entry, not the
    # canonical-example one.
    tmp = Path(tempfile.mkdtemp(prefix="repro-t6-warm-"))
    profile = WorkloadProfile(tmp / "workload.json")
    import jax.numpy as jnp

    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    live = (jax.random.normal(ks[0], (64, 32), jnp.float32),
            jax.random.normal(ks[1], (4, 32, 32), jnp.float32),
            jnp.full((4,), 16, jnp.int32))
    profile.record("moe_gmm", live)
    warm_bench = TuningCache(tmp / "tuning.json")
    warm_cache(profile, warm_bench, POD_SIM, registry=reg)
    ctx_w = TuningContext(warm_bench, POD_SIM, profile=profile,
                          search_on_miss=False)   # read-only: must hit
    warmed = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False, tuning=ctx_w)
    report_w = next(r for r in warmed.reports if r.op == "moe_gmm")
    t_warm = timeit(
        lambda: jax.block_until_ready(warmed["moe_gmm"](*live)),
        warmup=1, iters=3,
    )
    rows.append(row(
        "table6/moe_gmm/profile_warmed", t_warm * 1e6,
        f"config={report_w.config};{report_w.tuning};"
        f"geometry=live-64x32-traffic",
    ))
    rows.extend(geometry_dispatch_rows(reg))
    rows.extend(near_dtype_rows(reg))
    rows.extend(bundle_import_rows(reg))
    return rows


def bundle_import_rows(reg) -> list[tuple[str, float, str]]:
    """Cold-search deploy vs bundle-imported deploy at a fresh site: the
    origin warms rmsnorm from recorded traffic and exports; the target
    either searches at bind (cold) or imports the artifact first.  Both
    rows time bind + first live dispatch (time-to-first-dispatch); the
    note carries the search counts the artifact eliminated."""
    import time

    import jax.numpy as jnp

    from repro.tuning import WorkloadProfile, import_bundle
    from repro.tuning.bundle import export_bundle

    tmp = Path(tempfile.mkdtemp(prefix="repro-t6-bundle-"))
    ks = jax.random.split(jax.random.PRNGKey(17), 2)
    live = (jax.random.normal(ks[0], (128, 64), jnp.float32),
            jax.random.normal(ks[1], (64,), jnp.float32))
    profile = WorkloadProfile(tmp / "workload.json")
    profile.record("rmsnorm", live, weight=4)
    profile.save()

    # origin site: warm from the recorded traffic, export the artifact
    origin = TuningCache(tmp / "origin.json")
    warm_cache(profile, origin, POD_SIM, registry=reg, top_k=1)
    origin.save()
    bundle_path, _ = export_bundle(tmp / "origin.tgz",
                                   cache_path=origin.path, platform=POD_SIM,
                                   profile_path=profile.path)

    def deploy_and_first_dispatch(cache_path):
        """Bind (searching on miss) + first live call; returns
        (seconds, searches paid, dispatch stats)."""
        cache = TuningCache.load(cache_path)
        ctx = TuningContext(cache, POD_SIM, ops={"rmsnorm"}, profile=profile)
        t0 = time.perf_counter()
        binding = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False,
                           tuning=ctx)
        jax.block_until_ready(binding["rmsnorm"](*live))
        dt = time.perf_counter() - t0
        return dt, ctx.searches_spent, dict(binding.impl("rmsnorm").fn.stats)

    t_cold, searches_cold, _ = deploy_and_first_dispatch(tmp / "cold.json")
    import_bundle(bundle_path, cache_path=tmp / "shipped.json",
                  platform=POD_SIM, registry=reg)
    t_bundle, searches_bundle, stats = \
        deploy_and_first_dispatch(tmp / "shipped.json")
    return [row(
        "table6/rmsnorm/bundle_import", t_bundle * 1e6,
        f"searches_cold={searches_cold};searches_bundle={searches_bundle};"
        f"exact={stats['exact']};cold_us={t_cold * 1e6:.1f};"
        f"ttfd_speedup_vs_cold={t_cold / t_bundle:.2f}x",
    )]


def near_dtype_rows(reg) -> list[tuple[str, float, str]]:
    """bf16 traffic against an fp32-only warmed site: the dtype-crossing
    fallback borrows the fp32 bucket's tuned config at a distance
    penalty (after re-validating VMEM for bf16) rather than running the
    shipped default — the lifecycle layer's answer to mixed-precision
    drift on a long-lived deployment."""
    import jax.numpy as jnp

    tmp = Path(tempfile.mkdtemp(prefix="repro-t6-neardtype-"))
    ks = jax.random.split(jax.random.PRNGKey(13), 2)
    live32 = (jax.random.normal(ks[0], (256, 128), jnp.float32),
              jax.random.normal(ks[1], (128,), jnp.float32))
    live16 = tuple(a.astype(jnp.bfloat16) for a in live32)

    profile = WorkloadProfile(tmp / "workload.json")
    profile.record("rmsnorm", live32, weight=3)       # fp32-only history
    cache = TuningCache(tmp / "tuning.json")
    warm_cache(profile, cache, POD_SIM, registry=reg, top_k=1)
    ctx = TuningContext(cache, POD_SIM, profile=profile, search_on_miss=False)
    binding = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False,
                       tuning=ctx)

    dispatch = binding.impl("rmsnorm").fn
    t_borrow = timeit(
        lambda: jax.block_until_ready(binding["rmsnorm"](*live16)),
        warmup=1, iters=3,
    )
    stats = dispatch.stats
    return [row(
        "table6/rmsnorm/near_dtype_borrow", t_borrow * 1e6,
        f"near-dtype={stats['near-dtype']};default={stats['default']};"
        f"config={binding.tuned_config('rmsnorm', live16)};"
        f"geometry=bf16-on-fp32-warmed-site",
    )]


def geometry_dispatch_rows(reg) -> list[tuple[str, float, str]]:
    """One op, two live geometries: the old top-1 binding bakes the hottest
    bucket's config into every call; the geometry-dispatched binding
    resolves each call's own warmed entry at trace time.  Reported: the
    second geometry's us/call under both bindings, plus each binding's
    multi-bucket exact-hit rate."""
    import jax.numpy as jnp

    tmp = Path(tempfile.mkdtemp(prefix="repro-t6-dispatch-"))
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    live_hot = (jax.random.normal(ks[0], (256, 32), jnp.float32),
                jax.random.normal(ks[1], (4, 32, 32), jnp.float32),
                jnp.full((4,), 64, jnp.int32))
    live_cold = (jax.random.normal(ks[2], (16, 64), jnp.float32),
                 jax.random.normal(ks[3], (4, 64, 64), jnp.float32),
                 jnp.full((4,), 4, jnp.int32))
    profile = WorkloadProfile(tmp / "workload.json")
    profile.record("moe_gmm", live_hot, weight=3)
    profile.record("moe_gmm", live_cold, weight=1)

    # the pre-dispatch deployment: only the hottest bucket is warmed and
    # its config is the single entry every call resolves to
    cache_top1 = TuningCache(tmp / "tuning-top1.json")
    warm_cache(profile, cache_top1, POD_SIM, registry=reg, top_k=1)
    ctx_top1 = TuningContext(cache_top1, POD_SIM, profile=profile,
                             top_k=1, search_on_miss=False)
    top1 = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False,
                    tuning=ctx_top1)

    # the geometry-dispatched deployment: every warmed bucket binds
    cache_full = TuningCache(tmp / "tuning-full.json")
    warm_cache(profile, cache_full, POD_SIM, registry=reg, top_k=3)
    ctx_full = TuningContext(cache_full, POD_SIM, profile=profile,
                             search_on_miss=False)
    dispatched = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False,
                          tuning=ctx_full)

    def hit_rate(binding):
        stats = dict(binding.impl("moe_gmm").fn.stats)
        for args in (live_hot, live_cold):
            jax.block_until_ready(binding["moe_gmm"](*args))
        new = binding.impl("moe_gmm").fn.stats
        return {k: new[k] - stats.get(k, 0) for k in new}

    stats_top1 = hit_rate(top1)
    stats_full = hit_rate(dispatched)
    t_top1 = timeit(
        lambda: jax.block_until_ready(top1["moe_gmm"](*live_cold)),
        warmup=1, iters=3,
    )
    t_disp = timeit(
        lambda: jax.block_until_ready(dispatched["moe_gmm"](*live_cold)),
        warmup=1, iters=3,
    )
    rep = next(r for r in dispatched.reports if r.op == "moe_gmm")
    return [
        row("table6/moe_gmm/top1_binding", t_top1 * 1e6,
            f"exact={stats_top1['exact']}/2;nearest={stats_top1['nearest']};"
            f"geometry=cold-16x64"),
        row("table6/moe_gmm/geometry_dispatch", t_disp * 1e6,
            f"exact={stats_full['exact']}/2;geometries={len(rep.geometries)};"
            f"speedup_vs_top1={t_top1 / t_disp:.2f}x"),
    ]


def main(argv=None) -> int:
    """CLI wrapper; ``--smoke`` runs only the geometry-dispatch rows and
    asserts the dispatch behaviour CI depends on."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="geometry-dispatch rows only, with assertions "
                         "(the CI guard)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    if not args.smoke:
        for name, us, derived in run():
            print(f"{name},{us:.1f},{derived}")
        return 0
    reg = register_all(OpRegistry())
    rows = geometry_dispatch_rows(reg) + near_dtype_rows(reg) \
        + bundle_import_rows(reg)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    top1_note = next(d for n, _, d in rows if n.endswith("top1_binding"))
    disp_note = next(d for n, _, d in rows if n.endswith("geometry_dispatch"))
    borrow_note = next(d for n, _, d in rows if n.endswith("near_dtype_borrow"))
    bundle_note = next(d for n, _, d in rows if n.endswith("bundle_import"))
    if "exact=1/2" not in top1_note:
        print(f"FAIL: top-1 binding should hit exactly its one bucket, "
              f"got {top1_note}")
        return 1
    if "exact=2/2" not in disp_note:
        print(f"FAIL: dispatched binding should hit both buckets, "
              f"got {disp_note}")
        return 1
    # eager calls resolve per invocation, so assert the PATH (every bf16
    # call borrowed, none defaulted), not a specific count
    if "near-dtype=0;" in borrow_note or "default=0" not in borrow_note:
        print(f"FAIL: bf16 call on an fp32-warmed site should dispatch via "
              f"near-dtype, got {borrow_note}")
        return 1
    if "searches_bundle=0" not in bundle_note \
            or "searches_cold=0" in bundle_note:
        print(f"FAIL: the bundle-imported deploy should pay zero searches "
              f"where the cold one pays >=1, got {bundle_note}")
        return 1
    print("OK: geometry dispatch resolved 2/2 live buckets; top-1 binding "
          "resolved 1/2; bf16 traffic borrowed the fp32 bucket (near-dtype); "
          "bundle import turned the cold-search deploy into a zero-search one")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
