"""Table VI (new) — deferred specialization: default vs site-tuned configs.

The paper's portability claim is that the same container reaches native
performance once the site binds its optimized resources.  This table
quantifies the last piece of that gap for the swap kernels: the kernel
with its shipped default BlockConfig vs the config the autotuner picked
for *this* host, both bound through the real registry path.

On this CPU container the kernels run in interpret mode (pod-sim), so
absolute numbers are simulation-host numbers; the mechanism — search,
persist, rebind — is identical on a TPU site.  Rows:

  table6/<op>/default_config   us/call with the shipped defaults
  table6/<op>/tuned_config     us/call with the searched winner
"""

from __future__ import annotations

import tempfile
from pathlib import Path

import jax

from benchmarks.common import row, timeit
from repro.core.platform import POD_SIM
from repro.core.registry import OpRegistry
from repro.kernels.ops import OP_NAMES, register_all, tuners
from repro.tuning import TuningCache, TuningContext, default_config

_OPS = ("rmsnorm", "moe_gmm", "ssd_scan")


def run() -> list[tuple[str, float, str]]:
    reg = register_all(OpRegistry())
    cache = TuningCache(Path(tempfile.mkdtemp(prefix="repro-t6-")) / "tuning.json")
    ctx = TuningContext(cache, POD_SIM, ops=set(_OPS))
    tuned = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False, tuning=ctx)
    default = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False)

    rows = []
    per_op_tuner = tuners()
    for op in _OPS:
        args = per_op_tuner[op].example_args(POD_SIM)
        def_cfg = default_config(op, POD_SIM)   # untuned per-platform fallback
        t_def = timeit(
            lambda: jax.block_until_ready(default[op](*args, config=def_cfg)),
            warmup=1, iters=3,
        )
        t_tun = timeit(
            lambda: jax.block_until_ready(tuned[op](*args)), warmup=1, iters=3
        )
        report = next(r for r in tuned.reports if r.op == op)
        rows.append(row(
            f"table6/{op}/default_config", t_def * 1e6,
            f"config={def_cfg}",
        ))
        rows.append(row(
            f"table6/{op}/tuned_config", t_tun * 1e6,
            f"config={report.config};{report.tuning};"
            f"speedup_vs_default={t_def / t_tun:.2f}x",
        ))
    return rows
