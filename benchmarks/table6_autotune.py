"""Table VI (new) — deferred specialization: default vs site-tuned configs.

The paper's portability claim is that the same container reaches native
performance once the site binds its optimized resources.  This table
quantifies the last piece of that gap for the swap kernels: the kernel
with its shipped default BlockConfig vs the config the autotuner picked
for *this* host, both bound through the real registry path.

On this CPU container the kernels run in interpret mode (pod-sim), so
absolute numbers are simulation-host numbers; the mechanism — search,
persist, rebind — is identical on a TPU site.  Rows:

  table6/<op>/default_config    us/call with the shipped defaults
  table6/<op>/tuned_config      us/call with the searched winner
  table6/<op>/profile_warmed    us/call at a *recorded live geometry*
                                (different from the canonical example),
                                tuned offline by repro.tuning.warm
"""

from __future__ import annotations

import tempfile
from pathlib import Path

import jax

from benchmarks.common import row, timeit
from repro.core.platform import POD_SIM
from repro.core.registry import OpRegistry
from repro.kernels.ops import OP_NAMES, register_all, tuners
from repro.tuning import TuningCache, TuningContext, WorkloadProfile, default_config
from repro.tuning.warm import warm_cache

_OPS = ("rmsnorm", "moe_gmm", "ssd_scan")


def run() -> list[tuple[str, float, str]]:
    reg = register_all(OpRegistry())
    cache = TuningCache(Path(tempfile.mkdtemp(prefix="repro-t6-")) / "tuning.json")
    ctx = TuningContext(cache, POD_SIM, ops=set(_OPS))
    tuned = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False, tuning=ctx)
    default = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False)

    rows = []
    per_op_tuner = tuners()
    for op in _OPS:
        args = per_op_tuner[op].example_args(POD_SIM)
        def_cfg = default_config(op, POD_SIM)   # untuned per-platform fallback
        t_def = timeit(
            lambda: jax.block_until_ready(default[op](*args, config=def_cfg)),
            warmup=1, iters=3,
        )
        t_tun = timeit(
            lambda: jax.block_until_ready(tuned[op](*args)), warmup=1, iters=3
        )
        report = next(r for r in tuned.reports if r.op == op)
        rows.append(row(
            f"table6/{op}/default_config", t_def * 1e6,
            f"config={def_cfg}",
        ))
        rows.append(row(
            f"table6/{op}/tuned_config", t_tun * 1e6,
            f"config={report.config};{report.tuning};"
            f"speedup_vs_default={t_def / t_tun:.2f}x",
        ))

    # -- tune-on-real-traffic: warm the cache from a recorded geometry ------
    # A live serve-loop geometry (moe at half the canonical width) is
    # recorded into a workload profile, warmed offline, then bound with
    # the profile present: the op must hit the warmed entry, not the
    # canonical-example one.
    tmp = Path(tempfile.mkdtemp(prefix="repro-t6-warm-"))
    profile = WorkloadProfile(tmp / "workload.json")
    import jax.numpy as jnp

    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    live = (jax.random.normal(ks[0], (64, 32), jnp.float32),
            jax.random.normal(ks[1], (4, 32, 32), jnp.float32),
            jnp.full((4,), 16, jnp.int32))
    profile.record("moe_gmm", live)
    warm_bench = TuningCache(tmp / "tuning.json")
    warm_cache(profile, warm_bench, POD_SIM, registry=reg)
    ctx_w = TuningContext(warm_bench, POD_SIM, profile=profile,
                          search_on_miss=False)   # read-only: must hit
    warmed = reg.bind(OP_NAMES, POD_SIM, native=True, freeze=False, tuning=ctx_w)
    report_w = next(r for r in warmed.reports if r.op == "moe_gmm")
    t_warm = timeit(
        lambda: jax.block_until_ready(warmed["moe_gmm"](*live)),
        warmup=1, iters=3,
    )
    rows.append(row(
        "table6/moe_gmm/profile_warmed", t_warm * 1e6,
        f"config={report_w.config};{report_w.tuning};"
        f"geometry=live-64x32-traffic",
    ))
    return rows
