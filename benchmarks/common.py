"""Shared helpers for the paper-table benchmarks."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def run_subprocess(code: str, *, devices: int = 1, timeout: int = 900) -> str:
    """Run a benchmark snippet on `devices` forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"benchmark subprocess failed:\n{proc.stderr[-2000:]}")
    return proc.stdout


def timeit(fn, *, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-clock seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str) -> tuple[str, float, str]:
    return (name, us_per_call, derived)
