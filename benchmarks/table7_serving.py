"""Table VII (new) — serving scoreboard: chunked prefill vs prefill-by-decode.

The serving engine's claim is that prompt ingestion should cost
ceil(prompt_len / C) compiled steps, not the O(prompt_len) whole-batch
decode ticks the old server burned.  This table prices that claim with a
load generator driving the real `repro.launch.serve.Server` twice over
the SAME seeded request set — once per prefill mode — and scoring each
run like a serving deployment would be scored:

  table7/<mode>/ttft_p50        median time-to-first-token (ms)
  table7/<mode>/ttft_p99        tail TTFT (ms)
  table7/<mode>/per_token_ms    mean inter-token latency while decoding
  table7/<mode>/tok_s           end-to-end generated tokens per second
  table7/<mode>/goodput_tok_s   tokens/sec counting ONLY requests whose
                                TTFT met the SLO (default SLO: the
                                baseline run's own p50 TTFT, so the
                                chunked row reads as "goodput at the
                                latency the old server could promise")
  table7/<mode>/prefill_steps   mean compiled prefill work units per
                                request — the honesty metric: chunked
                                must report ceil(prompt_len / C),
                                baseline reports prompt_len

On this CPU container the kernels run in interpret mode (pod-sim), so
absolute latencies are simulation-host numbers; the *ratios* — steps per
prompt, chunked vs baseline TTFT — are the portable result.

``--paged`` adds a third run over the same request set: the paged KV
cache (page size = C, per-slot block tables) serving MORE slots from the
SAME cache-memory budget the contiguous chunked run reserved — the pool
holds slots * max_len tokens total, but admission budgets in pages
actually needed, so short requests are no longer starved by whole-window
reservations.  Its scoreboard adds:

  table7/paged/peak_active      max concurrently admitted requests — the
                                admission-under-memory-pressure metric;
                                --smoke asserts it strictly exceeds the
                                contiguous chunked run's
  table7/paged/fragmentation    1 - used/allocated pages (mean over
                                ticks): pages reserved for generation
                                headroom but not yet written

``--quantize {int8,fp8}`` adds a quantized-deploy run (1-byte weight
storage subtrees + quantized KV cache, docs/quantization.md) over the
same request set.  Its scoreboard adds:

  table7/quantized/kv_bytes            quantized KV-cache footprint, with
                                       the fp32 KV and weight ratios
  table7/quantized/quality_logit_delta max |prefill logit - fp32 logit|
                                       on a fixed probe prompt, plus the
                                       served-token match fraction (info
                                       only: greedy argmax on a reduced
                                       random-init model flips easily)
  table7/quantized/admitted_under_budget  the deployment-admission demo:
                                       a byte budget between the two
                                       footprints rejects the fp32 deploy
                                       (DeploymentRejected) and admits
                                       the quantized one

With ``--smoke`` it additionally asserts the KV cache shrinks >=3x and
weights >=2.5x, the budget gate rejects fp32 while admitting quantized,
and the probe-prompt logit delta stays inside QUANT_LOGIT_ENVELOPE.

``--smoke`` (CLI) runs a tiny workload through both modes and exits
non-zero unless every accepted request completes, the chunked path's
per-request compiled-step counts match the pinned invariants
(prefill_steps == ceil(prompt_len/C), decode_steps == max_new - 1), and
chunked p50 TTFT beats the prefill-by-decode baseline — the CI guard.
With ``--paged`` it additionally asserts the paged run emits the SAME
tokens per request as contiguous chunked, admits strictly more
concurrent requests, and stays within 10% of chunked's p50 TTFT.
``--json PATH`` writes the full scoreboard for the CI artifact.

``--fleet`` replaces the single-host comparison with the disaggregated
serving-fleet storm (repro.serving, docs/fleet.md), priced end to end
through the paper's portability loop:

  1. *capture* — one single-host paged chunked run with autotune +
     profile on (REPRO_PLATFORM=pod-sim, fresh site cache); its tokens
     are the reference every fleet run must reproduce, and its cache +
     workload profile are warmed (repro.tuning.warm) and exported as a
     portable tuning bundle (repro.tuning.bundle).
  2. *static*  — a 1-prefill + N-decode fleet on a fake tick clock,
     every replica deployed into a FRESH site cache that warm-starts
     from the bundle.  Mid-run the busiest decode replica is killed;
     the supervisor detects the silence and the fleet re-prefills the
     lost requests, but with ``rescale=False`` the capacity is never
     replaced.
  3. *elastic* — the same storm with rescaling on: the controller
     provisions replacement decode replicas whose deploys bind
     "bundle-imported" with zero searches (the §III claim: portable
     site artifacts make elastic capacity cheap).

Scoreboard rows (latencies in deterministic fleet ticks, not wall ms):

  table7/fleet-<run>/e2e_p50_ticks   median submit->finish latency
  table7/fleet-<run>/goodput_tok_tick tokens/tick counting ONLY requests
                                     whose e2e latency met the SLO
                                     (default: the static run's own p50)
  table7/fleet-<run>/drain_ticks     ticks until the fleet drained
  table7/fleet-elastic/provisioned   replicas added during the storm

``--fleet --smoke`` exits non-zero unless both fleet runs emit tokens
identical to the capture run, the kill was recovered in both, elastic
goodput-under-SLO strictly exceeds static, and every provisioned
replica bound bundle-imported entries with zero cold searches.  The
elastic run's event log (rescale decisions, warm-start dispatch lines)
is printed for the CI fleet-smoke job to grep.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs import get_config
from repro.core import Runtime
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Request, Server
from repro.launch.train import make_bundle

_MODES = ("decode", "chunked")      # baseline first: its p50 seeds the SLO


def _percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile of a non-empty list (q in [0, 100])."""
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(round(q / 100 * (len(ys) - 1))))]


def make_requests(n: int, *, vocab: int, chunk: int, max_new: int,
                  seed: int = 7) -> list[Request]:
    """Seeded request set sized to exercise partial prefill chunks.

    Prompt lengths are drawn around the chunk width so the set always
    contains exact-multiple, sub-chunk, and chunk+partial prompts —
    the three cases the ceil(L/C) invariant has to cover.
    """
    rng = np.random.default_rng(seed)
    lens = [chunk, max(2, chunk // 2), chunk + max(1, chunk // 2)]
    lens += list(rng.integers(2, 2 * chunk, size=max(0, n - len(lens))))
    reqs = []
    for rid, plen in enumerate(lens[:n]):
        prompt = rng.integers(0, vocab, size=int(plen)).astype(np.int32)
        reqs.append(Request(rid=rid, prompt=prompt, max_new=max_new))
    return reqs


def serve_once(cfg, container, reqs: list[Request], *, mode: str,
               slots: int, max_len: int, chunk: int,
               interleave: int, quantize: str | None = None) -> dict:
    """One full serving run; returns the per-mode scoreboard dict.

    Throwaway requests are served first so jit compilation is paid
    before the clock starts — TTFT then measures steady-state
    scheduling, which is what a serving SLO is written against.  The
    warmup pair is sized so one request is still prefilling after the
    other starts decoding: prefill-on-a-decode-produced-cache is a
    distinct compilation (the decode step's output shardings), and a
    warmup that never interleaves would leave it to the measured run.

    mode "paged" serves from the SAME cache-memory budget the contiguous
    chunked run reserved (slots * max_len cache tokens, counting the
    park page) spread over twice the slots — whether more of those slots
    actually run concurrently is then purely the admission policy's
    doing, which is the comparison the paged scoreboard prices.

    mode "quantized" mirrors the contiguous chunked run but deploys with
    1-byte weights and a quantized KV cache; its board carries the
    deployment footprint and a fixed-prompt prefill-logit probe so the
    quantized scoreboard can price KV bytes and the quality delta
    against the fp32 chunked run.
    """
    paged = mode == "paged"
    n_slots = 2 * slots if paged else slots
    num_pages = slots * max_len // chunk if paged else None
    prefill_mode = "chunked" if mode in ("paged", "quantized") else mode
    server = Server(cfg, container, slots=n_slots, max_len=max_len,
                    chunk=chunk, prefill_mode=prefill_mode,
                    interleave=interleave, paged=paged, num_pages=num_pages,
                    quantize=quantize)
    warm_rng = np.random.default_rng(0)
    for plen in (chunk, min(3 * chunk + 1, max_len - 4)):
        prompt = warm_rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        server.submit(Request(rid=-1, prompt=prompt, max_new=2))
    server.run()
    probe = None
    if mode in ("chunked", "quantized"):
        # fixed-prompt prefill-logit probe: same tokens under every
        # deployment, so max|logit delta| is purely the quantization
        probe_toks = (np.random.default_rng(11)
                      .integers(0, cfg.vocab_size, size=chunk)
                      .astype(np.int32))
        probe = np.asarray(server.engine.prefill_step(0, probe_toks, 0),
                           np.float32)
    server.requests.clear()
    server.engine.prefill_calls = 0
    server.engine.decode_calls = 0
    server.scheduler.peak_active = 0
    server.scheduler.page_samples.clear()

    t0 = time.monotonic()
    for r in reqs:
        server.submit(r)
    server.run()
    wall = time.monotonic() - t0

    done = [r for r in server.requests if r.done]
    ttfts = [r.ttft for r in done]
    per_tok = [
        (r.finish_t - r.first_token_t) / (len(r.tokens) - 1)
        for r in done if len(r.tokens) > 1
    ]
    tokens = sum(len(r.tokens) for r in done)
    board = {
        "mode": mode,
        "quantize": quantize or "none",
        "footprint": server.engine.footprint,
        "_probe": probe,
        "chunk": 1 if mode == "decode" else chunk,
        "slots": n_slots,
        "submitted": len(reqs),
        "completed": len(done),
        "tokens": tokens,
        "wall_s": wall,
        "peak_active": server.scheduler.peak_active,
        "ttft_p50_ms": _percentile(ttfts, 50) * 1e3,
        "ttft_p99_ms": _percentile(ttfts, 99) * 1e3,
        "per_token_ms": (sum(per_tok) / len(per_tok)) * 1e3 if per_tok else 0.0,
        "tok_s": tokens / max(wall, 1e-9),
        "prefill_steps_mean": sum(r.prefill_steps for r in done) / len(done),
        "engine_prefill_calls": server.engine.prefill_calls,
        "engine_decode_calls": server.engine.decode_calls,
        "per_request": [
            {"rid": r.rid, "prompt_len": r.prompt_len, "max_new": r.max_new,
             "prefill_steps": r.prefill_steps, "decode_steps": r.decode_steps,
             "ttft_ms": r.ttft * 1e3, "tokens": list(r.tokens)}
            for r in done
        ],
    }
    if paged:
        samples = server.scheduler.page_samples or [(0, 0)]
        alloc_mean = sum(a for a, _ in samples) / len(samples)
        used_mean = sum(u for _, u in samples) / len(samples)
        board["num_pages"] = server.engine.pool.num_pages
        board["pages_allocated_mean"] = alloc_mean
        board["pages_used_mean"] = used_mean
        board["fragmentation"] = (1.0 - used_mean / alloc_mean
                                  if alloc_mean else 0.0)
    return board


def goodput(board: dict, slo_s: float) -> float:
    """Tokens/sec counting only requests whose TTFT met the SLO."""
    good = sum(
        len_tokens for len_tokens, ttft_ms in (
            (pr["max_new"], pr["ttft_ms"]) for pr in board["per_request"]
        ) if ttft_ms / 1e3 <= slo_s
    )
    return good / max(board["wall_s"], 1e-9)


def check_invariants(boards: dict, chunk: int, max_new: int) -> list[str]:
    """The compiled-step honesty checks --smoke enforces."""
    fails = []
    for mode, board in boards.items():
        if board["completed"] != board["submitted"]:
            fails.append(f"{mode}: {board['completed']}/{board['submitted']} "
                         f"requests completed")
        for pr in board["per_request"]:
            ln = pr["prompt_len"]
            if mode in ("chunked", "paged", "quantized"):
                want_p, want_d = -(-ln // chunk), pr["max_new"] - 1
            else:
                want_p, want_d = ln, pr["max_new"]
            if pr["prefill_steps"] != want_p:
                fails.append(f"{mode} rid={pr['rid']}: prefill_steps="
                             f"{pr['prefill_steps']} want {want_p} (L={ln})")
            if pr["decode_steps"] != want_d:
                fails.append(f"{mode} rid={pr['rid']}: decode_steps="
                             f"{pr['decode_steps']} want {want_d}")
    ch = boards["chunked"]
    if ch["engine_prefill_calls"] != sum(
            pr["prefill_steps"] for pr in ch["per_request"]):
        fails.append("chunked: engine prefill_calls disagrees with the "
                     "per-request ledger")
    if boards["decode"]["engine_prefill_calls"] != 0:
        fails.append("baseline should never hit the chunked-prefill "
                     "executable")
    if ch["ttft_p50_ms"] >= boards["decode"]["ttft_p50_ms"]:
        fails.append(f"chunked p50 TTFT {ch['ttft_p50_ms']:.1f}ms not below "
                     f"baseline {boards['decode']['ttft_p50_ms']:.1f}ms")
    if "paged" in boards:
        pg = boards["paged"]
        by_rid = {pr["rid"]: pr["tokens"] for pr in ch["per_request"]}
        for pr in pg["per_request"]:
            if pr["tokens"] != by_rid.get(pr["rid"]):
                fails.append(f"paged rid={pr['rid']}: tokens diverge from "
                             f"contiguous chunked")
        if pg["peak_active"] <= ch["peak_active"]:
            fails.append(f"paged peak_active {pg['peak_active']} not above "
                         f"contiguous {ch['peak_active']} under the same "
                         f"cache-memory budget")
        # 10% relative + 5ms absolute: pod-sim TTFTs are single-digit ms,
        # where scheduler wall-clock jitter swamps a pure relative bound;
        # on real hardware (tens-to-hundreds of ms) the 10% term binds
        if pg["ttft_p50_ms"] > 1.1 * ch["ttft_p50_ms"] + 5.0:
            fails.append(f"paged p50 TTFT {pg['ttft_p50_ms']:.1f}ms regresses "
                         f">10%+5ms over chunked {ch['ttft_p50_ms']:.1f}ms")
    return fails


# measured fixed-prompt prefill deltas on the reduced random-init model
# (weights AND KV quantized, noise compounding through every layer) are
# ~0.23x (int8) / ~0.20x (fp8) of the fp32 logit magnitude; the gate
# sits ~3x above, relative to that magnitude, so it trips on a broken
# scale path (rel >= 1: scales ignored or misapplied), not on
# quantization noise
QUANT_LOGIT_ENVELOPE = {"int8": 0.6, "fp8": 0.6}


def check_quantized_invariants(boards: dict, fmt: str,
                               budget_demo: dict) -> list[str]:
    """The --quantize --smoke assertions: footprint shrink, budget-gated
    admission, and a bounded prefill-logit delta vs the fp32 run."""
    fails = []
    fp = boards["chunked"]["footprint"]
    qf = boards["quantized"]["footprint"]
    if qf["kv_bytes"] * 3.0 > fp["kv_bytes"]:
        fails.append(f"quantized KV cache {qf['kv_bytes']:,}B not >=3x "
                     f"below fp32 {fp['kv_bytes']:,}B")
    if qf["weight_bytes"] * 2.5 > fp["weight_bytes"]:
        fails.append(f"quantized weights {qf['weight_bytes']:,}B not >=2.5x "
                     f"below fp32 {fp['weight_bytes']:,}B")
    if not budget_demo["fp32_rejected"]:
        fails.append(f"fp32 deploy was admitted under the "
                     f"{budget_demo['budget']:,}B budget it cannot fit")
    if not budget_demo["quantized_admitted"]:
        fails.append(f"quantized deploy was rejected under the "
                     f"{budget_demo['budget']:,}B budget it fits")
    rel = boards["quantized"]["quality_rel_delta"]
    if rel > QUANT_LOGIT_ENVELOPE[fmt]:
        fails.append(f"quantized prefill logits drifted {rel:.3f}x of the "
                     f"fp32 logit magnitude (envelope "
                     f"{QUANT_LOGIT_ENVELOPE[fmt]}x)")
    return fails


# ------------------------------------------------------------------ fleet --
class TickClock:
    """Deterministic fleet clock: one unit per scheduler tick."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float = 1.0) -> None:
        self.t += dt


def warm_start_stats(container) -> dict[str, int]:
    """How this deploy's dispatch tables were populated: geometries that
    arrived via the tuning bundle vs searches paid at bind time.  The
    ElasticController logs this dict verbatim when it provisions a
    replica — "searched=0" is the bundle warm-start claim."""
    imported = searched = 0
    for report in container.binding.reports:
        for g in report.geometries:
            if g.status == "bundle-imported":
                imported += 1
            elif g.status in ("cache-miss-searched", "cache-expired-searched"):
                searched += 1
    return {"bundle-imported": imported, "searched": searched}


def fleet_capture(args, cfg, arch_bundle, workdir,
                  reqs: list[Request]) -> tuple[dict, str, str]:
    """The portability loop's producer half: serve once on a single host
    with autotune + profile capture, warm the cache against the recorded
    traffic, and export the site's tuned state as a portable bundle.

    The serving geometry (slots, max_len, chunk, paged) matches the
    fleet replicas exactly, so the captured buckets are the ones every
    replica deploy will dispatch — and the run's tokens are the
    reference the fleet must reproduce."""
    from repro.tuning import warm
    from repro.tuning.bundle import export_bundle

    cache0 = str(workdir / "capture-cache.json")
    profile = str(workdir / "workload-profile.json")
    bundle_path = str(workdir / "site-bundle.tgz")
    runtime = Runtime(host_env={"REPRO_PLATFORM": "pod-sim",
                                "REPRO_TUNING_CACHE": cache0,
                                "REPRO_WORKLOAD_PROFILE": profile})
    container = runtime.deploy(arch_bundle, mesh=make_host_mesh(data=1),
                               native_ops=True, autotune=True, profile=True)
    platform = container.platform
    server = Server(cfg, container, slots=args.slots, max_len=args.max_len,
                    chunk=args.chunk, prefill_mode="chunked",
                    interleave=args.interleave, paged=True)
    t0 = time.monotonic()
    for r in reqs:
        if not server.submit(Request(rid=r.rid, prompt=r.prompt.copy(),
                                     max_new=r.max_new)):
            raise RuntimeError(f"capture run rejected rid={r.rid}")
    server.run()
    wall = time.monotonic() - t0
    done = [r for r in server.requests if r.done]
    board = {
        "completed": len(done),
        "submitted": len(reqs),
        "tokens": sum(len(r.tokens) for r in done),
        "wall_s": wall,
        "bind": warm_start_stats(container),
        "per_request": [{"rid": r.rid, "tokens": list(r.tokens)}
                        for r in done],
    }
    runtime.cleanup()        # persists the captured workload profile

    rc = warm.main(["--cache", cache0, "--profile", profile,
                    "--platform", "pod-sim"])
    if rc != 0:
        raise RuntimeError(f"tuning.warm exited {rc}")
    export_bundle(bundle_path, cache_path=cache0, platform=platform,
                  profile_path=profile)
    return board, bundle_path, profile


def fleet_once(args, cfg, arch_bundle, workdir, reqs: list[Request], *,
               label: str, elastic: bool, bundle_path: str,
               profile_path: str) -> dict:
    """One kill-and-rescale storm over the seeded request set.

    Every replica deploys into its OWN fresh site cache and warm-starts
    from the exported bundle — the disaggregated analogue of shipping
    one site artifact to a whole pool.  At --fleet-kill-tick the busiest
    decode replica is killed; with ``elastic`` the controller replaces
    the capacity, otherwise the survivors absorb the storm."""
    from repro.ft import Supervisor, SupervisorConfig
    from repro.launch.serve import JaxEngine
    from repro.serving import ACTIVE, ElasticController, FleetScheduler, Replica

    clock = TickClock()
    runtimes: list[Runtime] = []
    made: list[Replica] = []
    initial = 1 + args.fleet_decode       # prefill + initial decode pool

    def factory(role: str, host_id: int) -> Replica:
        cache = str(workdir / f"{label}-site-{host_id}.json")
        rt = Runtime(host_env={"REPRO_PLATFORM": "pod-sim",
                               "REPRO_TUNING_CACHE": cache,
                               "REPRO_WORKLOAD_PROFILE": profile_path})
        runtimes.append(rt)
        container = rt.deploy(arch_bundle, mesh=make_host_mesh(data=1),
                              native_ops=True, autotune=True,
                              tuning_bundle=bundle_path)
        engine = JaxEngine(cfg, container, slots=args.slots,
                           max_len=args.max_len, chunk=args.chunk,
                           prefill_mode="chunked", paged=True)
        rep = Replica(host_id, role, engine, clock=clock,
                      interleave=args.interleave)
        rep.warm_start = warm_start_stats(container)
        made.append(rep)
        return rep

    try:
        controller = ElasticController(
            Supervisor(0, SupervisorConfig(heartbeat_timeout=2.5)),
            min_decode=1, max_decode=args.fleet_max_decode,
            rescale=elastic, provision_delay=1.0)
        fleet = FleetScheduler(factory, prefill=1, decode=args.fleet_decode,
                               clock=clock, controller=controller)
        t0 = time.monotonic()
        for r in reqs:
            if not fleet.submit(Request(rid=r.rid, prompt=r.prompt.copy(),
                                        max_new=r.max_new)):
                raise RuntimeError(f"{label} fleet rejected rid={r.rid}")
        killed = None
        ticks = 0
        while not fleet.idle:
            if killed is None and ticks >= args.fleet_kill_tick:
                victim = max(
                    (rep for rep in fleet.decode_pool
                     if rep.alive and rep.state == ACTIVE),
                    key=lambda rep: len(rep.active_requests()), default=None)
                if victim is not None:
                    victim.kill()
                    killed = victim.name
            fleet.tick()
            clock.advance(1.0)
            ticks += 1
            if ticks > 10_000:
                raise RuntimeError(f"{label} fleet failed to drain")
        wall = time.monotonic() - t0
        recs = sorted(fleet.records.values(), key=lambda r: r.rid)
        return {
            "label": label,
            "elastic": elastic,
            "submitted": fleet.submitted,
            "completed": fleet.completed,
            "drain_ticks": ticks,
            "wall_s": wall,
            "killed": killed,
            "recovered": fleet.recovered,
            "handoffs": fleet.handoffs,
            "adoptions": fleet.adoptions,
            "handoff_bytes": fleet.handoff_bytes,
            "provisioned": controller.provisioned,
            "warm_starts": [
                {"replica": rep.name, "provisioned": rep.id >= initial,
                 **(rep.warm_start or {})}
                for rep in made
            ],
            "events": list(fleet.events),
            "per_request": [
                {"rid": r.rid, "tokens": list(r.tokens), "max_new": r.max_new,
                 "e2e_ticks": r.finish_t - r.submit_t}
                for r in recs
            ],
        }
    finally:
        for rt in runtimes:
            rt.cleanup()


def fleet_goodput(board: dict, slo_ticks: float) -> float:
    """Tokens per tick counting only requests whose submit->finish
    latency met the SLO — the fleet analogue of goodput()."""
    good = sum(len(pr["tokens"]) for pr in board["per_request"]
               if pr["e2e_ticks"] <= slo_ticks)
    return good / max(board["drain_ticks"], 1)


def check_fleet_invariants(capture: dict, boards: dict) -> list[str]:
    """The --fleet --smoke assertions: token identity with the capture
    run, recovered kills, strict goodput separation, and zero-search
    bundle warm-starts on every provisioned replica."""
    fails = []
    reference = {pr["rid"]: pr["tokens"] for pr in capture["per_request"]}
    for label, b in boards.items():
        if b["completed"] != b["submitted"]:
            fails.append(f"{label}: {b['completed']}/{b['submitted']} "
                         f"requests completed")
        for pr in b["per_request"]:
            if pr["tokens"] != reference.get(pr["rid"]):
                fails.append(f"{label} rid={pr['rid']}: tokens diverge from "
                             f"the single-host capture run")
        if b["killed"] is None:
            fails.append(f"{label}: no decode replica was killed")
        if b["recovered"] < 1:
            fails.append(f"{label}: kill was never recovered")
    static, dyn = boards["fleet-static"], boards["fleet-elastic"]
    if static["provisioned"] != 0:
        fails.append("static fleet provisioned capacity with rescale off")
    if dyn["provisioned"] < 1:
        fails.append("elastic fleet never provisioned a replacement")
    if not any("rescale: decode pool" in e for e in dyn["events"]):
        fails.append("elastic fleet logged no rescale decision")
    if dyn["goodput_tok_tick"] <= static["goodput_tok_tick"]:
        fails.append(
            f"elastic goodput {dyn['goodput_tok_tick']:.2f} tok/tick not "
            f"above static {static['goodput_tok_tick']:.2f} during the storm")
    provisioned = [w for w in dyn["warm_starts"] if w["provisioned"]]
    if not provisioned:
        fails.append("no provisioned replica recorded warm-start stats")
    for w in provisioned:
        if w.get("bundle-imported", 0) < 1:
            fails.append(f"{w['replica']}: provisioned without bundle-"
                         f"imported geometries (cold deploy)")
        if w.get("searched", 0) != 0:
            fails.append(f"{w['replica']}: paid {w['searched']} cold "
                         f"search(es) despite the bundle warm-start")
    return fails


def fleet_main(args) -> int:
    import tempfile
    from pathlib import Path

    arch_bundle = make_bundle(args.arch, reduced=True)
    cfg = get_config(args.arch).reduced()
    reqs = make_requests(args.requests, vocab=cfg.vocab_size,
                         chunk=args.chunk, max_new=args.max_new)

    with tempfile.TemporaryDirectory(prefix="table7-fleet-") as tmp:
        workdir = Path(tmp)
        capture, bundle_path, profile_path = fleet_capture(
            args, cfg, arch_bundle, workdir, reqs)
        boards = {}
        for label, elastic in (("fleet-static", False),
                               ("fleet-elastic", True)):
            boards[label] = fleet_once(
                args, cfg, arch_bundle, workdir, reqs, label=label,
                elastic=elastic, bundle_path=bundle_path,
                profile_path=profile_path)

    slo_ticks = (args.fleet_slo_ticks
                 if args.fleet_slo_ticks is not None
                 else _percentile([pr["e2e_ticks"] for pr in
                                   boards["fleet-static"]["per_request"]], 50))
    print("name,value,derived")
    print(f"table7/fleet-capture/tokens,{capture['tokens']},"
          f"completed={capture['completed']}/{capture['submitted']};"
          f"single_host_reference")
    for label, b in boards.items():
        lat = [pr["e2e_ticks"] for pr in b["per_request"]]
        b["slo_ticks"] = slo_ticks
        b["e2e_p50_ticks"] = _percentile(lat, 50)
        b["e2e_p99_ticks"] = _percentile(lat, 99)
        b["goodput_tok_tick"] = fleet_goodput(b, slo_ticks)
        note = (f"killed={b['killed']};recovered={b['recovered']};"
                f"completed={b['completed']}/{b['submitted']}")
        print(f"table7/{label}/e2e_p50_ticks,{b['e2e_p50_ticks']:.0f},{note}")
        print(f"table7/{label}/e2e_p99_ticks,{b['e2e_p99_ticks']:.0f},{note}")
        print(f"table7/{label}/goodput_tok_tick,{b['goodput_tok_tick']:.2f},"
              f"slo_ticks={slo_ticks:.0f}")
        print(f"table7/{label}/drain_ticks,{b['drain_ticks']},"
              f"handoffs={b['handoffs']};adoptions={b['adoptions']};"
              f"handoff_bytes={b['handoff_bytes']}")
    dyn = boards["fleet-elastic"]
    print(f"table7/fleet-elastic/provisioned,{dyn['provisioned']},"
          f"max_decode={args.fleet_max_decode};"
          f"warm_started={sum(1 for w in dyn['warm_starts'] if w['provisioned'])}")
    gain = (dyn["goodput_tok_tick"]
            / max(boards["fleet-static"]["goodput_tok_tick"], 1e-9))
    print(f"table7/summary/fleet_goodput_gain,{gain:.2f},"
          f"elastic_vs_static_under_kill_storm")
    print(f"fleet-capture bind: " + " ".join(
        f"{k}={v}" for k, v in sorted(capture["bind"].items())))
    for e in dyn["events"]:
        print(f"fleet-event[elastic]: {e}")

    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"chunk": args.chunk, "max_new": args.max_new,
                       "slo_ticks": slo_ticks, "capture": capture,
                       "fleet": boards}, fh, indent=2)
        print(f"wrote {args.json}")

    if not args.smoke:
        return 0
    fails = check_fleet_invariants(capture, boards)
    for f in fails:
        print(f"FAIL: {f}")
    if fails:
        return 1
    print("OK: both fleet runs reproduced the single-host capture tokens "
          "through a mid-run replica kill; the elastic fleet replaced the "
          "capacity with bundle-warm-started replicas (zero cold searches) "
          "and beat the static fleet's goodput under the SLO")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--interleave", type=int, default=2)
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="TTFT SLO for the goodput rows (default: the "
                         "baseline run's own p50 TTFT)")
    ap.add_argument("--paged", action="store_true",
                    help="add a paged-KV-cache run (2x slots from the same "
                         "cache-memory budget) to the scoreboard")
    ap.add_argument("--quantize", choices=("none", "int8", "fp8"),
                    default="none",
                    help="add a quantized-deploy run (1-byte weights + "
                         "quantized KV) with footprint, budget-admission, "
                         "and quality-delta rows (docs/quantization.md)")
    ap.add_argument("--fleet", action="store_true",
                    help="run the disaggregated-fleet storm instead: capture "
                         "-> warm -> bundle export, then a static vs elastic "
                         "kill-and-rescale comparison with bundle-warm-"
                         "started replicas (repro.serving)")
    ap.add_argument("--fleet-decode", type=int, default=2,
                    help="initial decode-pool size for the fleet runs")
    ap.add_argument("--fleet-max-decode", type=int, default=2,
                    help="elastic controller's decode-pool ceiling (default "
                         "matches --fleet-decode: the elastic fleet replaces "
                         "lost capacity but never outgrows the static "
                         "baseline, so the goodput gap is purely the storm "
                         "response)")
    ap.add_argument("--fleet-kill-tick", type=int, default=4,
                    help="tick at which the busiest decode replica is killed")
    ap.add_argument("--fleet-slo-ticks", type=float, default=None,
                    help="e2e-latency SLO in fleet ticks for the goodput "
                         "rows (default: the static run's own p50)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload + compiled-step/TTFT assertions "
                         "(the CI guard)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full scoreboard JSON (the CI artifact)")
    args = ap.parse_args(argv)
    if args.fleet:
        # the storm needs enough in-flight work that losing a replica
        # matters; the single-host smoke clamp would starve it
        return fleet_main(args)
    if args.smoke:
        args.requests = min(args.requests, 4)
        args.max_new = min(args.max_new, 4)

    bundle = make_bundle(args.arch, reduced=True)
    runtime = Runtime()
    container = runtime.deploy(bundle, mesh=make_host_mesh(data=1))
    cfg = get_config(args.arch).reduced()
    reqs = make_requests(args.requests, vocab=cfg.vocab_size,
                         chunk=args.chunk, max_new=args.max_new)

    fmt = None if args.quantize == "none" else args.quantize
    modes = _MODES + (("paged",) if args.paged else ())
    modes += ("quantized",) if fmt else ()
    boards = {}
    for mode in modes:
        boards[mode] = serve_once(
            cfg, container,
            [Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new)
             for r in reqs],
            mode=mode, slots=args.slots, max_len=args.max_len,
            chunk=args.chunk, interleave=args.interleave,
            quantize=fmt if mode == "quantized" else None)

    budget_demo = None
    if fmt:
        # the admission demo: a budget only the quantized footprint fits
        from repro.launch.serve import DeploymentRejected, JaxEngine

        fp_total = boards["chunked"]["footprint"]["total_bytes"]
        q_total = boards["quantized"]["footprint"]["total_bytes"]
        budget = (fp_total + q_total) // 2
        try:
            JaxEngine(cfg, container, slots=args.slots, max_len=args.max_len,
                      chunk=args.chunk, memory_budget=budget)
            fp32_rejected = False
        except DeploymentRejected:
            fp32_rejected = True
        try:
            JaxEngine(cfg, container, slots=args.slots, max_len=args.max_len,
                      chunk=args.chunk, quantize=fmt, memory_budget=budget)
            quantized_admitted = True
        except DeploymentRejected:
            quantized_admitted = False
        budget_demo = {"budget": budget, "fp32_rejected": fp32_rejected,
                       "quantized_admitted": quantized_admitted}

        # quality delta: fixed-prompt prefill logits + served-token match
        probe_fp = boards["chunked"].pop("_probe")
        probe_q = boards["quantized"].pop("_probe")
        qb = boards["quantized"]
        qb["quality_logit_delta"] = float(np.abs(probe_q - probe_fp).max())
        qb["quality_rel_delta"] = (qb["quality_logit_delta"]
                                   / max(float(np.abs(probe_fp).max()), 1e-9))
        by_rid = {pr["rid"]: pr["tokens"]
                  for pr in boards["chunked"]["per_request"]}
        matched = sum(1 for pr in qb["per_request"]
                      if pr["tokens"] == by_rid.get(pr["rid"]))
        qb["token_match_frac"] = matched / max(len(qb["per_request"]), 1)
    for b in boards.values():
        b.pop("_probe", None)
    runtime.cleanup()

    slo_s = (args.slo_ms / 1e3 if args.slo_ms is not None
             else boards["decode"]["ttft_p50_ms"] / 1e3)
    print("name,value,derived")
    for mode in modes:
        b = boards[mode]
        b["slo_ms"] = slo_s * 1e3
        b["goodput_tok_s"] = goodput(b, slo_s)
        note = (f"chunk={b['chunk']};completed={b['completed']}"
                f"/{b['submitted']}")
        print(f"table7/{mode}/ttft_p50,{b['ttft_p50_ms']:.1f},{note}")
        print(f"table7/{mode}/ttft_p99,{b['ttft_p99_ms']:.1f},{note}")
        print(f"table7/{mode}/per_token_ms,{b['per_token_ms']:.1f},{note}")
        print(f"table7/{mode}/tok_s,{b['tok_s']:.1f},{note}")
        print(f"table7/{mode}/goodput_tok_s,{b['goodput_tok_s']:.1f},"
              f"slo_ms={slo_s * 1e3:.1f}")
        print(f"table7/{mode}/prefill_steps,{b['prefill_steps_mean']:.2f},"
              f"compiled_prefill={b['engine_prefill_calls']};"
              f"compiled_decode={b['engine_decode_calls']}")
        if mode == "paged":
            print(f"table7/paged/peak_active,{b['peak_active']},"
                  f"slots={b['slots']};pool={b['num_pages']}x{b['chunk']}tok;"
                  f"contiguous_peak={boards['chunked']['peak_active']}")
            print(f"table7/paged/fragmentation,{b['fragmentation']:.2f},"
                  f"pages_alloc_mean={b['pages_allocated_mean']:.1f};"
                  f"pages_used_mean={b['pages_used_mean']:.1f}")
        if mode == "quantized":
            fpb = boards["chunked"]["footprint"]
            qfb = b["footprint"]
            print(f"table7/quantized/kv_bytes,{qfb['kv_bytes']},"
                  f"fp32_kv={fpb['kv_bytes']};"
                  f"kv_ratio={fpb['kv_bytes'] / qfb['kv_bytes']:.2f}x;"
                  f"weight_ratio="
                  f"{fpb['weight_bytes'] / qfb['weight_bytes']:.2f}x;"
                  f"fmt={b['quantize']}")
            print(f"table7/quantized/quality_logit_delta,"
                  f"{b['quality_logit_delta']:.3f},"
                  f"rel={b['quality_rel_delta']:.3f}x;"
                  f"envelope={QUANT_LOGIT_ENVELOPE[b['quantize']]}x;"
                  f"token_match={b['token_match_frac']:.2f};"
                  f"greedy_argmax_flips_are_info_only")
            print(f"table7/quantized/admitted_under_budget,"
                  f"{int(budget_demo['quantized_admitted'])},"
                  f"budget={budget_demo['budget']};"
                  f"fp32_rejected={int(budget_demo['fp32_rejected'])};"
                  f"fp32_total={fpb['total_bytes']};"
                  f"quant_total={qfb['total_bytes']}")
    speedup = (boards["decode"]["ttft_p50_ms"]
               / max(boards["chunked"]["ttft_p50_ms"], 1e-9))
    print(f"table7/summary/ttft_p50_speedup,{speedup:.2f},"
          f"chunked_vs_prefill_by_decode")
    if args.paged:
        ratio = (boards["paged"]["peak_active"]
                 / max(boards["chunked"]["peak_active"], 1))
        print(f"table7/summary/paged_admission_gain,{ratio:.2f},"
              f"peak_active_paged_vs_contiguous_same_memory")

    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"chunk": args.chunk, "max_new": args.max_new,
                       "slo_ms": slo_s * 1e3, "modes": boards}, fh, indent=2)
        print(f"wrote {args.json}")

    if not args.smoke:
        return 0
    fails = check_invariants(boards, args.chunk, args.max_new)
    if fmt:
        fails += check_quantized_invariants(boards, fmt, budget_demo)
    for f in fails:
        print(f"FAIL: {f}")
    if fails:
        return 1
    msg = ("OK: all requests completed in both modes; chunked prefill paid "
           "ceil(L/C) compiled steps per request and beat the "
           "prefill-by-decode baseline's p50 TTFT")
    if args.paged:
        msg += ("; paged admission served strictly more concurrent requests "
                "from the same cache-memory budget with identical tokens")
    if fmt:
        qb = boards["quantized"]
        msg += (f"; the {fmt} deploy fit a budget that rejected fp32, shrank "
                f"the KV cache "
                f"{boards['chunked']['footprint']['kv_bytes'] / qb['footprint']['kv_bytes']:.1f}x, "
                f"and held the prefill-logit delta to "
                f"{qb['quality_rel_delta']:.2f}x of the fp32 magnitude")
    print(msg)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
