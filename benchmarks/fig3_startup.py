"""Fig. 3 analogue — Pynamic: startup time vs rank count.

The paper's result: native Python startup drowns the Lustre MDS in one
metadata round-trip per shared object per rank, while the squashfs image
needs one lookup per rank.  The weight-loading analogue: a per-tensor
checkpoint costs 2 metadata ops per tensor per rank; the single-manifest
blob costs 3 per rank.  We measure real load wall-clock for both layouts
on this host and scale the metadata-op model to the paper's rank counts
(48..3072); derived reports ops_naive/ops_manifest — the Fig. 3 gap.
"""

from __future__ import annotations

import tempfile
from pathlib import Path

import jax

from benchmarks.common import row, timeit
from repro.checkpoint import (
    file_op_counts,
    load_naive,
    restore_checkpoint,
    save_checkpoint,
    save_naive,
)
from repro.configs import ARCHS
from repro.models import build_model

_RANKS = [48, 96, 192, 384, 768, 1536, 3072]


def _explode_layers(params):
    """Split stacked per-block leaves into per-layer tensors — the
    conventional (torch-style) checkpoint layout Pynamic-style loads see:
    one file per tensor per layer."""
    out = {}

    def walk(tree, prefix, depth):
        for k, v in tree.items():
            path = f"{prefix}__{k}" if prefix else k
            if isinstance(v, dict):
                walk(v, path, depth)
            elif prefix.startswith("decoder") and v.ndim > 1:
                for i in range(v.shape[0]):
                    out[f"{path}__L{i}"] = v[i]
            else:
                out[path] = v

    walk(params, "", 0)
    return out


def run() -> list[tuple[str, float, str]]:
    # a reduced model, exploded to per-layer tensors for a realistic
    # (hundreds-of-files) conventional layout
    cfg = ARCHS["jamba-1.5-large-398b"].reduced()
    model = build_model(cfg)
    params = _explode_layers(model.init(jax.random.PRNGKey(0)))
    n_leaves = len(jax.tree.leaves(params))

    rows = []
    with tempfile.TemporaryDirectory() as d:
        naive_dir = Path(d) / "naive"
        mani_dir = Path(d) / "manifest"
        n_files = save_naive(naive_dir, params)
        save_checkpoint(mani_dir, 0, params)

        t_naive = timeit(lambda: load_naive(naive_dir, params), warmup=1, iters=3)
        t_mani = timeit(
            lambda: restore_checkpoint(mani_dir, params)[0], warmup=1, iters=3
        )
        rows.append(row("fig3/load_naive", t_naive * 1e6,
                        f"files={n_files};leaves={n_leaves}"))
        rows.append(row("fig3/load_manifest", t_mani * 1e6,
                        f"files=2;speedup={t_naive / t_mani:.2f}x"))

        counts = file_op_counts(params)
        for ranks in _RANKS:
            ops_naive = counts["naive_metadata_ops"] * ranks
            ops_mani = counts["manifest_metadata_ops"] * ranks
            rows.append(row(
                f"fig3/metadata_ops/{ranks}ranks",
                0.0,
                f"naive={ops_naive};manifest={ops_mani};"
                f"ratio={ops_naive / ops_mani:.0f}x",
            ))
    return rows
