"""Tables III/IV analogue — OSU latency: native vs container collectives.

The paper pits the host's vendor MPI (Aries / InfiniBand) against the
container's generic MPI across message sizes.  Here the logical
`grad_allreduce` collective has a reference schedule (flat all-reduce over
all DP axes — the bundle's portable implementation) and a native schedule
(hierarchical: ICI reduce-scatter -> DCN all-reduce on 1/N shards -> ICI
all-gather), plus the int8-compressed DCN variant.  For every message
size we report measured wall-clock on the 8-virtual-device host AND the
structural DCN bytes per device (the quantity the real fabric feels);
derived shows numerics parity (max |err|) — the paper's "ratio = 1.0"
claim — and the DCN byte reduction.
"""

from __future__ import annotations

import json

from benchmarks.common import row, run_subprocess

_SIZES = [32, 128, 512, 2048, 8192, 32768, 131072, 524288, 2097152]

_CODE = f"""
import json, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed import flat_grad_allreduce, hierarchical_grad_allreduce
from repro.distributed.collectives import compat_shard_map
from repro.launch.mesh import make_compat_mesh

mesh = make_compat_mesh((2, 4), ("pod", "data"))
results = []
for size_bytes in {_SIZES!r}:
    n = max(size_bytes // 4, 1)
    x = {{"g": jnp.arange(n, dtype=jnp.float32) / n}}

    def run_fn(fn):
        return jax.jit(compat_shard_map(fn, mesh=mesh, in_specs=(P(),),
                                     out_specs=P(), check_vma=False))

    flat = run_fn(lambda t: flat_grad_allreduce(t, data_axis="data", pod_axis="pod"))
    hier = run_fn(lambda t: hierarchical_grad_allreduce(t, data_axis="data", pod_axis="pod"))
    comp = run_fn(lambda t: hierarchical_grad_allreduce(
        t, data_axis="data", pod_axis="pod", compress_dcn=True))

    out_f = flat(x)["g"]; out_h = hier(x)["g"]; out_c = comp(x)["g"]
    err_h = float(jnp.abs(out_f - out_h).max())
    err_c = float(jnp.abs(out_f - out_c).max())

    def med(f):
        f(x)["g"].block_until_ready()
        ts = []
        for _ in range(5):
            t0 = time.perf_counter(); f(x)["g"].block_until_ready()
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[2]

    # structural DCN bytes per device (the thin-pipe cost the schedule moves)
    dcn_flat = size_bytes                # whole tensor crosses pods
    dcn_hier = size_bytes // 4           # 1/data_size shard crosses pods
    dcn_comp = dcn_hier // 4             # int8 + scale vs f32

    results.append(dict(size=size_bytes,
                        t_flat=med(flat), t_hier=med(hier), t_comp=med(comp),
                        err_h=err_h, err_c=err_c,
                        dcn_flat=dcn_flat, dcn_hier=dcn_hier, dcn_comp=dcn_comp))
print(json.dumps(results))
"""


def run() -> list[tuple[str, float, str]]:
    out = run_subprocess(_CODE, devices=8)
    results = json.loads(out.strip().splitlines()[-1])
    rows = []
    for r in results:
        rows.append(row(
            f"table34/allreduce_flat/{r['size']}B",
            r["t_flat"] * 1e6,
            f"dcn_bytes={r['dcn_flat']}",
        ))
        rows.append(row(
            f"table34/allreduce_hier/{r['size']}B",
            r["t_hier"] * 1e6,
            f"dcn_bytes={r['dcn_hier']};err_vs_flat={r['err_h']:.1e}",
        ))
        rows.append(row(
            f"table34/allreduce_int8dcn/{r['size']}B",
            r["t_comp"] * 1e6,
            f"dcn_bytes={r['dcn_comp']};err_vs_flat={r['err_c']:.1e}",
        ))
    return rows
