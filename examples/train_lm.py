"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps,
with checkpoint/restart fault tolerance demonstrated mid-run.

The model is the granite-3-8b *family* scaled to ~100M parameters (the
assignment's end-to-end driver size; pass --tiny for a CI-speed run).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]
"""

import argparse
import dataclasses
import tempfile
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import Runtime
from repro.data import DataConfig, SyntheticStream
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import DeployOptions, make_deployment
from repro.launch.train import make_bundle, train_loop
from repro.optim import adamw_init


def lm_100m():
    """granite-family config at ~100M params (12L, d=512, ff=2048, v=8192)."""
    return dataclasses.replace(
        get_config("granite-3-8b"),
        name="granite-100m",
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=8192, tie_embeddings=True,
        dtype="float32", remat="none",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true", help="reduced config (CI)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config("granite-3-8b").reduced() if args.tiny else lm_100m()
    if args.tiny:
        args.steps = min(args.steps, 30)
        args.seq = 64

    bundle = make_bundle("granite-3-8b", reduced=True)   # registry metadata
    rt = Runtime(host_env={})
    container = rt.deploy(bundle, mesh=make_host_mesh())
    n_params = None

    shape = ShapeConfig("train", args.seq, args.batch, "train")
    dep = make_deployment(cfg, shape, container.mesh,
                          options=DeployOptions(donate=True),
                          binding=container.binding)
    params = dep.model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"training {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    stream = SyntheticStream(cfg, shape, DataConfig(seed=0))
    ckpt_dir = Path(args.ckpt_dir) if args.ckpt_dir else Path(
        tempfile.mkdtemp(prefix="repro_ckpt_")
    )

    # phase 1: train halfway, checkpointing
    half = args.steps // 2
    params = jax.device_put(params, dep.param_sharding)
    opt = jax.device_put(adamw_init(params), dep.opt_sharding)
    _, _, losses1 = train_loop(
        dep, stream, steps=half, ckpt_dir=ckpt_dir, ckpt_every=max(half // 2, 1),
        params=params, opt_state=opt, log_every=25,
    )

    # simulate a failure + restart: restore from LATEST and continue
    step = latest_step(ckpt_dir)
    print(f"--- simulated node failure; restarting from checkpoint step {step} ---")
    skeleton = {
        "params": jax.tree.map(np.asarray, dep.model.init(jax.random.PRNGKey(0))),
        "opt": jax.tree.map(
            np.asarray, adamw_init(dep.model.init(jax.random.PRNGKey(0)))
        ),
    }
    restored, step = restore_checkpoint(ckpt_dir, skeleton)
    _, _, losses2 = train_loop(
        dep, stream, steps=args.steps, start_step=step,
        ckpt_dir=ckpt_dir, ckpt_every=max(half // 2, 1),
        params=jax.device_put(restored["params"], dep.param_sharding),
        opt_state=jax.device_put(restored["opt"], dep.opt_sharding),
        log_every=25,
    )

    print(f"final loss {losses2[-1]:.4f} (initial {losses1[0]:.4f}); "
          f"checkpoints in {ckpt_dir}")
    rt.cleanup()


if __name__ == "__main__":
    main()
