"""Serving example: batched decode with continuous slot refill.

Deploys a reduced model through the Runtime and serves a stream of
requests with the slot-based Server (static shapes; finished slots are
refilled from the queue without recompiling).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "qwen2.5-14b", "--requests", "6", "--slots", "2",
          "--max-len", "48", "--max-new", "6"])
