"""Quickstart: the paper's Fig. 2 workflow in ~60 lines.

  1. build a Bundle (the container image) on the "laptop";
  2. test it locally;
  3. push it to a registry;
  4. pull it through the Gateway (flatten + convert + cache);
  5. run it through the Runtime (platform detection, op binding, mesh).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core import Gateway, Runtime
from repro.launch.train import make_bundle
from repro.models import build_model


def main() -> None:
    # 1) build the image: hardware-agnostic program spec + required op ABIs
    bundle = make_bundle("qwen2.5-14b", reduced=True)
    print(f"[1] built bundle {bundle.reference} (digest {bundle.digest})")
    print(f"    required ops: {sorted(bundle.required_ops)}")

    # 2) test locally (the laptop step): pure reference ops, no mesh
    cfg = ModelConfig.from_dict(bundle.model_config)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    loss, _ = jax.jit(model.loss_fn)(params, {"tokens": toks, "labels": toks})
    print(f"[2] local smoke test: loss = {float(loss):.4f}")

    with tempfile.TemporaryDirectory() as d:
        # 3) push to the registry
        gw = Gateway(f"{d}/registry", f"{d}/cache")
        gw.push(bundle)
        print(f"[3] pushed to registry")

        # 4) pull: fetch + flatten + convert into the site cache
        flat = gw.pull(bundle.reference)
        print(f"[4] pulled; cached images: {gw.images()}")

        # 5) deploy: the Runtime detects the platform, binds ops (swapping
        #    in natives where the site provides them), builds the mesh
        rt = Runtime(host_env={})
        container = rt.deploy(flat)
        print("[5] deployed container:")
        print(container.describe())

        # run one forward step *through the container's binding*
        model2 = build_model(cfg, binding=container.binding)
        loss2, _ = jax.jit(model2.loss_fn)(params, {"tokens": toks, "labels": toks})
        print(f"    containerized loss = {float(loss2):.4f} "
              f"(matches local: {abs(float(loss) - float(loss2)) < 1e-5})")
        rt.cleanup()


if __name__ == "__main__":
    main()
