"""Portability demo — the paper's central claim, §V-B/V-C, end to end.

One bundle, three "systems" (platform descriptors), zero modification:

  laptop   : reference ops only (no native features)      — build & test
  cluster  : native collectives available                  — deploy
  pod-v5e  : Pallas kernels + native collectives declared  — deploy

For each deployment we print the op-binding report (which ops were
swapped, which refused and why) and verify the model output is IDENTICAL
across deployments — the ratio==1.0 result of Tables III-V.  An
ABI-violating "vendor kernel" is then registered to show the runtime
refusing the swap (libtool-string check) instead of mis-deploying.

Run:  PYTHONPATH=src python examples/portability_demo.py
"""

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import PLATFORMS, Runtime
from repro.core.abi import AbiString
from repro.core.registry import ImplKind, OpImpl, OpRegistry
from repro.kernels.ops import ABIS, OP_NAMES, _REFS  # noqa: F401
from repro.launch.mesh import make_host_mesh
from repro.launch.train import make_bundle
from repro.models import build_model


def deploy_and_run(bundle, platform_name, params, batch):
    rt = Runtime(host_env={})
    container = rt.deploy(
        bundle,
        native_ops=True,
        platform=PLATFORMS[platform_name],
        mesh=make_host_mesh(data=1),
    )
    cfg = ModelConfig.from_dict(container.bundle.model_config)
    model = build_model(cfg, binding=container.binding)
    loss, _ = jax.jit(model.loss_fn)(params, batch)
    swapped = [r.op for r in container.binding.reports if r.swapped]
    refused = [
        (r.op, r.reason) for r in container.binding.reports if not r.swapped
    ]
    rt.cleanup()
    return float(loss), swapped, refused


def main() -> None:
    bundle = make_bundle("qwen2.5-14b", reduced=True)
    cfg = ModelConfig.from_dict(bundle.model_config)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    print(f"bundle: {bundle.reference} (digest {bundle.digest})\n")
    losses = {}
    swapped_by_system = {}
    # pod-v5e declares pallas_kernels but requires an actual TPU ("driver
    # loaded") — on this CPU host the swap is refused with a report.
    # pod-sim runs the SAME Pallas kernels through the interpreter, so the
    # swap genuinely happens and the numerics can be compared.
    for system in ("laptop", "cluster", "pod-v5e", "pod-sim"):
        loss, swapped, refused = deploy_and_run(bundle, system, params, batch)
        losses[system] = loss
        swapped_by_system[system] = swapped
        print(f"=== {system} ===")
        print(f"  loss = {loss:.6f}")
        print(f"  swapped ops: {swapped or 'none'}")
        for op, reason in refused[:3]:
            print(f"  kept ref {op}: {reason}")
        print()

    assert swapped_by_system["pod-sim"], "pod-sim must swap in the kernels"
    assert not swapped_by_system["pod-v5e"], "no TPU present -> swap refused"
    spread = max(losses.values()) - min(losses.values())
    print(f"cross-system loss spread: {spread:.2e} "
          f"(ref vs swapped-kernel numerics agree: {spread < 1e-3})\n")

    # --- ABI refusal demo: a 'vendor kernel' with the wrong signature ----
    reg = OpRegistry()
    for name in OP_NAMES:
        reg.declare(ABIS[name])
        reg.register(OpImpl(abi=ABIS[name], kind=ImplKind.REFERENCE,
                            fn=_REFS[name], provider="jnp-ref"))
    bad_abi = AbiString.make("rmsnorm", {"args": ["x"], "note": "wrong"}, major=1)
    reg.register(
        OpImpl(abi=bad_abi, kind=ImplKind.NATIVE,
               fn=lambda x, w, eps=0: x * 0, requires_feature=None,
               provider="bad-vendor"),
        strict=False,
    )
    binding = reg.bind(["rmsnorm"], PLATFORMS["pod-v5e"], native=True, freeze=False)
    report = binding.reports[0]
    print("ABI refusal demo (mismatched vendor rmsnorm):")
    print("  registration refused (libtool-string mismatch logged above);")
    print(f"  swapped={report.swapped}  binding: {report.reason}")
    assert not report.swapped, "runtime must refuse an ABI-incompatible swap"


if __name__ == "__main__":
    main()
