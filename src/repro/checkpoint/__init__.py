from repro.checkpoint.manifest import (
    AsyncCheckpointer,
    file_op_counts,
    latest_step,
    load_naive,
    quantize_tree,
    restore_checkpoint,
    save_checkpoint,
    save_naive,
)

__all__ = [
    "AsyncCheckpointer", "file_op_counts", "latest_step", "load_naive",
    "quantize_tree", "restore_checkpoint", "save_checkpoint", "save_naive",
]
