"""Single-manifest checkpoints — the squashfs lesson applied to weights.

Fig. 3 of the paper: Python startup at 3000 ranks dies on Lustre *metadata*
(one MDS round-trip per shared object), while Shifter's loop-mounted
squashfs needs ONE metadata lookup and then pure block reads.  A
per-tensor checkpoint directory has exactly the same failure mode (one
stat+open per tensor per rank).  So `repro` checkpoints are:

  manifest.json   one metadata object: tree structure, per-leaf shape/
                  dtype/offset/size/sha256, step, config digest
  data.blob       one contiguous blob, leaves at recorded offsets

Restore is one metadata read + offset reads (mmap) — and because the
manifest records *logical* layout only, restore may apply ANY sharding:
elastic rescaling = restore with a different mesh (see ft/elastic.py).

`save_naive` / `load_naive` implement the per-tensor-files layout purely
for the Fig. 3 benchmark comparison.

Durability: blob + manifest are written to a temp name and atomically
renamed; a `LATEST` pointer is updated last, so a crash mid-save never
corrupts the restore path (the supervisor restarts from the previous
checkpoint).
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "AsyncCheckpointer",
    "quantize_tree",
    "save_naive",
    "load_naive",
    "file_op_counts",
]


def _flatten(tree: Any, prefix: str = "") -> list[tuple[str, Any]]:
    if isinstance(tree, dict):
        out: list[tuple[str, Any]] = []
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}/{k}" if prefix else str(k)))
        return out
    if isinstance(tree, (tuple, list)):
        out = []
        for i, v in enumerate(tree):
            out.extend(_flatten(v, f"{prefix}/{i}" if prefix else str(i)))
        return out
    return [(prefix, tree)]


def _unflatten_into(skeleton: Any, values: dict[str, np.ndarray], prefix: str = "") -> Any:
    if isinstance(skeleton, dict):
        return {
            k: _unflatten_into(skeleton[k], values, f"{prefix}/{k}" if prefix else str(k))
            for k in skeleton
        }
    if isinstance(skeleton, (tuple, list)):
        seq = [
            _unflatten_into(v, values, f"{prefix}/{i}" if prefix else str(i))
            for i, v in enumerate(skeleton)
        ]
        return type(skeleton)(seq) if not hasattr(skeleton, "_fields") else type(skeleton)(*seq)
    return values[prefix]


# --------------------------------------------------------------------------- #
# single-manifest format
# --------------------------------------------------------------------------- #
# subtrees whose apply functions consume raw arrays (no dequant hook), so
# their weights must stay full-precision even in a quantized save
_QUANT_EXCLUDED_SUBTREES = ("moe", "ssm")


def _quantizable(path: str, leaf: Any) -> bool:
    """Leaves the checkpoint quantizer touches: matmul-style float weights
    (name ``w*`` or the ``tok`` embedding, >= 2-d) outside the moe/ssm
    subtrees.  Norm gains, biases, and integer leaves stay full-precision —
    they are a rounding error of the footprint, and once layer-stacked a
    norm gain is 2-d too, so the filter is by name, not just rank."""
    dtype = getattr(leaf, "dtype", None)
    shape = getattr(leaf, "shape", None)
    if dtype is None or shape is None or len(shape) < 2:
        return False
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return False
    parts = path.split("/")
    if any(seg in _QUANT_EXCLUDED_SUBTREES for seg in parts):
        return False
    return parts[-1].startswith("w") or parts[-1] == "tok"


def quantize_tree(tree: Any, fmt: str) -> Any:
    """In-memory analogue of a quantized save + ``dequantize=False``
    restore: every quantizable leaf becomes a ``{"q", "scale"}`` storage
    subtree (codes + axis -2 per-channel fp32 scales), everything else
    passes through.  The serving engine uses this to deploy a freshly
    initialized model in storage form without touching disk."""
    from repro.kernels.quant import FORMATS, quantize_per_channel

    if fmt not in FORMATS:
        raise ValueError(f"quantize format must be one of {FORMATS}, got {fmt!r}")
    values: dict[str, Any] = {}
    for path, leaf in _flatten(tree):
        if _quantizable(path, leaf):
            q, s = quantize_per_channel(jnp.asarray(leaf), axis=-2, fmt=fmt)
            values[path] = {"q": q, "scale": s}
        else:
            values[path] = leaf
    return _unflatten_into(tree, values)


def save_checkpoint(
    directory: Path | str,
    step: int,
    tree: Any,
    *,
    extra_meta: dict | None = None,
    quantize: str | None = None,
) -> Path:
    """Write one manifest + blob checkpoint.

    ``quantize`` ("int8"/"fp8") stores every quantizable leaf (see
    _quantizable) as 1-byte code points with per-channel fp32 scales:
    the leaf's entry gains a ``"quant": {format, axis, orig_dtype}``
    block and a companion ``<path>.scale`` entry holds the scales.
    Axis -2 is reduced away: for a plain (d, f) weight that is the
    contraction dim — one scale per output channel, the layout
    quant_matmul consumes directly — and for layer-stacked leaves
    ((layers, ...) from the scanned decoder) it keeps the leading stack
    axis intact, so scales scan alongside their codes.
    restore_checkpoint dequantizes transparently by default.
    """
    from repro.kernels.quant import FORMATS, quantize_per_channel

    if quantize is not None and quantize not in FORMATS:
        raise ValueError(f"quantize must be one of {FORMATS}, got {quantize!r}")
    directory = Path(directory)
    ckpt_dir = directory / f"step_{step:010d}"
    tmp_dir = directory / f".tmp_step_{step:010d}"
    tmp_dir.mkdir(parents=True, exist_ok=True)

    leaves = _flatten(tree)
    entries = {}
    offset = 0
    blob_path = tmp_dir / "data.blob"
    with open(blob_path, "wb") as blob:
        def write_leaf(path: str, arr: np.ndarray, extra: dict | None = None):
            nonlocal offset
            raw = arr.tobytes()
            digest = hashlib.sha256(raw).hexdigest()[:16]
            entries[path] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "offset": offset,
                "nbytes": len(raw),
                "sha256_16": digest,
            }
            if extra:
                entries[path].update(extra)
            blob.write(raw)
            offset += len(raw)

        for path, leaf in leaves:
            if quantize is not None and _quantizable(path, leaf):
                x = jnp.asarray(leaf)
                q, s = quantize_per_channel(x, axis=-2, fmt=quantize)
                write_leaf(path, np.asarray(jax.device_get(q)), {
                    "quant": {"format": quantize, "axis": -2,
                              "orig_dtype": str(x.dtype)},
                })
                write_leaf(path + ".scale", np.asarray(jax.device_get(s)))
            else:
                write_leaf(path, np.asarray(jax.device_get(leaf)))
    manifest = {
        "format": "repro-manifest-v1",
        "step": step,
        "total_bytes": offset,
        "entries": entries,
        "meta": extra_meta or {},
    }
    (tmp_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    os.replace(tmp_dir, ckpt_dir)                       # atomic publish
    (directory / "LATEST.tmp").write_text(str(step))
    os.replace(directory / "LATEST.tmp", directory / "LATEST")
    return ckpt_dir


def latest_step(directory: Path | str) -> int | None:
    p = Path(directory) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore_checkpoint(
    directory: Path | str,
    skeleton: Any,
    *,
    step: int | None = None,
    sharding_fn: Callable[[str, np.ndarray], Any] | None = None,
    verify: bool = False,
    dequantize: bool = True,
) -> tuple[Any, int]:
    """Restore into `skeleton`'s structure.  `sharding_fn(path, arr)` may
    return a jax.sharding.Sharding to place each leaf — reshard-on-restore
    is what makes restarts mesh-shape-agnostic (elastic rescaling).

    Entries a quantized save wrote (``"quant"`` block + ``<path>.scale``
    companion) are dequantized back to their original dtype by default.
    ``dequantize=False`` keeps the storage form: the leaf restores as a
    ``{"q": codes, "scale": scales}`` dict — the quantized-weight subtree
    layout the serving model binds against quant_matmul directly, so a
    quantized deploy never materializes the full-precision weights.
    """
    from repro.kernels.quant import dequantize as dequant

    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no LATEST pointer in {directory}")
    ckpt_dir = directory / f"step_{step:010d}"
    manifest = json.loads((ckpt_dir / "manifest.json").read_text())
    blob = np.memmap(ckpt_dir / "data.blob", dtype=np.uint8, mode="r")

    arrays: dict[str, np.ndarray] = {}
    for path, ent in manifest["entries"].items():
        raw = blob[ent["offset"] : ent["offset"] + ent["nbytes"]]
        if verify:
            digest = hashlib.sha256(raw.tobytes()).hexdigest()[:16]
            if digest != ent["sha256_16"]:
                raise IOError(f"checksum mismatch for {path} in step {step}")
        arrays[path] = np.frombuffer(
            raw.tobytes(), dtype=np.dtype(ent["dtype"])
        ).reshape(ent["shape"])

    def place(path: str, arr: Any) -> Any:
        if sharding_fn is not None:
            sh = sharding_fn(path, np.asarray(arr))
            if sh is not None:
                return jax.device_put(arr, sh)
        return jnp.asarray(arr)

    values: dict[str, Any] = {}
    for path, ent in manifest["entries"].items():
        if path.endswith(".scale") and path[: -len(".scale")] in manifest["entries"]:
            continue                      # companion of a quantized leaf
        qmeta = ent.get("quant")
        arr = arrays[path]
        if qmeta is not None:
            scale = arrays[path + ".scale"]
            if dequantize:
                values[path] = place(path, dequant(
                    jnp.asarray(arr), jnp.asarray(scale),
                    axis=int(qmeta["axis"]),
                    dtype=jnp.dtype(qmeta["orig_dtype"])))
            else:
                values[path] = {"q": place(path, arr),
                                "scale": place(path + ".scale", scale)}
        else:
            values[path] = place(path, arr)
    return _unflatten_into(skeleton, values), step


class AsyncCheckpointer:
    """Double-buffered async save: snapshot to host, write on a thread.

    `wait()` joins the in-flight write (call before the next save or exit).
    The snapshot (device_get) happens on the caller's thread so the arrays
    handed to the writer are immutable host copies.
    """

    def __init__(self, directory: Path | str):
        self.directory = Path(directory)
        self._thread: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save_checkpoint(self.directory, step, host_tree)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


# --------------------------------------------------------------------------- #
# naive per-tensor layout (Fig. 3 comparison only)
# --------------------------------------------------------------------------- #
def save_naive(directory: Path | str, tree: Any) -> int:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    n = 0
    for path, leaf in _flatten(tree):
        fname = directory / (path.replace("/", "__") + ".npy")
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V":   # .npy cannot express bf16 — widen.
            arr = np.asarray(jax.device_get(jnp.asarray(leaf, jnp.float32)))
        np.save(fname, arr)
        n += 1
    return n


def load_naive(directory: Path | str, skeleton: Any) -> Any:
    directory = Path(directory)
    values = {}
    for path, _ in _flatten(skeleton):
        fname = directory / (path.replace("/", "__") + ".npy")
        values[path] = jnp.asarray(np.load(fname))
    return _unflatten_into(skeleton, values)


def file_op_counts(tree: Any) -> dict[str, int]:
    """Metadata-operation counts per rank for both layouts (Fig. 3 model)."""
    n_leaves = len(_flatten(tree))
    return {
        "naive_metadata_ops": 2 * n_leaves,   # stat + open per tensor
        "manifest_metadata_ops": 3,           # LATEST + manifest + blob
    }
