"""Single-manifest checkpoints — the squashfs lesson applied to weights.

Fig. 3 of the paper: Python startup at 3000 ranks dies on Lustre *metadata*
(one MDS round-trip per shared object), while Shifter's loop-mounted
squashfs needs ONE metadata lookup and then pure block reads.  A
per-tensor checkpoint directory has exactly the same failure mode (one
stat+open per tensor per rank).  So `repro` checkpoints are:

  manifest.json   one metadata object: tree structure, per-leaf shape/
                  dtype/offset/size/sha256, step, config digest
  data.blob       one contiguous blob, leaves at recorded offsets

Restore is one metadata read + offset reads (mmap) — and because the
manifest records *logical* layout only, restore may apply ANY sharding:
elastic rescaling = restore with a different mesh (see ft/elastic.py).

`save_naive` / `load_naive` implement the per-tensor-files layout purely
for the Fig. 3 benchmark comparison.

Durability: blob + manifest are written to a temp name and atomically
renamed; a `LATEST` pointer is updated last, so a crash mid-save never
corrupts the restore path (the supervisor restarts from the previous
checkpoint).
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "AsyncCheckpointer",
    "save_naive",
    "load_naive",
    "file_op_counts",
]


def _flatten(tree: Any, prefix: str = "") -> list[tuple[str, Any]]:
    if isinstance(tree, dict):
        out: list[tuple[str, Any]] = []
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}/{k}" if prefix else str(k)))
        return out
    if isinstance(tree, (tuple, list)):
        out = []
        for i, v in enumerate(tree):
            out.extend(_flatten(v, f"{prefix}/{i}" if prefix else str(i)))
        return out
    return [(prefix, tree)]


def _unflatten_into(skeleton: Any, values: dict[str, np.ndarray], prefix: str = "") -> Any:
    if isinstance(skeleton, dict):
        return {
            k: _unflatten_into(skeleton[k], values, f"{prefix}/{k}" if prefix else str(k))
            for k in skeleton
        }
    if isinstance(skeleton, (tuple, list)):
        seq = [
            _unflatten_into(v, values, f"{prefix}/{i}" if prefix else str(i))
            for i, v in enumerate(skeleton)
        ]
        return type(skeleton)(seq) if not hasattr(skeleton, "_fields") else type(skeleton)(*seq)
    return values[prefix]


# --------------------------------------------------------------------------- #
# single-manifest format
# --------------------------------------------------------------------------- #
def save_checkpoint(
    directory: Path | str,
    step: int,
    tree: Any,
    *,
    extra_meta: dict | None = None,
) -> Path:
    directory = Path(directory)
    ckpt_dir = directory / f"step_{step:010d}"
    tmp_dir = directory / f".tmp_step_{step:010d}"
    tmp_dir.mkdir(parents=True, exist_ok=True)

    leaves = _flatten(tree)
    entries = {}
    offset = 0
    blob_path = tmp_dir / "data.blob"
    with open(blob_path, "wb") as blob:
        for path, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            raw = arr.tobytes()
            digest = hashlib.sha256(raw).hexdigest()[:16]
            entries[path] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "offset": offset,
                "nbytes": len(raw),
                "sha256_16": digest,
            }
            blob.write(raw)
            offset += len(raw)
    manifest = {
        "format": "repro-manifest-v1",
        "step": step,
        "total_bytes": offset,
        "entries": entries,
        "meta": extra_meta or {},
    }
    (tmp_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    os.replace(tmp_dir, ckpt_dir)                       # atomic publish
    (directory / "LATEST.tmp").write_text(str(step))
    os.replace(directory / "LATEST.tmp", directory / "LATEST")
    return ckpt_dir


def latest_step(directory: Path | str) -> int | None:
    p = Path(directory) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore_checkpoint(
    directory: Path | str,
    skeleton: Any,
    *,
    step: int | None = None,
    sharding_fn: Callable[[str, np.ndarray], Any] | None = None,
    verify: bool = False,
) -> tuple[Any, int]:
    """Restore into `skeleton`'s structure.  `sharding_fn(path, arr)` may
    return a jax.sharding.Sharding to place each leaf — reshard-on-restore
    is what makes restarts mesh-shape-agnostic (elastic rescaling)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no LATEST pointer in {directory}")
    ckpt_dir = directory / f"step_{step:010d}"
    manifest = json.loads((ckpt_dir / "manifest.json").read_text())
    blob = np.memmap(ckpt_dir / "data.blob", dtype=np.uint8, mode="r")

    values: dict[str, Any] = {}
    for path, ent in manifest["entries"].items():
        raw = blob[ent["offset"] : ent["offset"] + ent["nbytes"]]
        if verify:
            digest = hashlib.sha256(raw.tobytes()).hexdigest()[:16]
            if digest != ent["sha256_16"]:
                raise IOError(f"checksum mismatch for {path} in step {step}")
        arr = np.frombuffer(raw.tobytes(), dtype=np.dtype(ent["dtype"])).reshape(
            ent["shape"]
        )
        if sharding_fn is not None:
            sh = sharding_fn(path, arr)
            values[path] = jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)
        else:
            values[path] = jnp.asarray(arr)
    return _unflatten_into(skeleton, values), step


class AsyncCheckpointer:
    """Double-buffered async save: snapshot to host, write on a thread.

    `wait()` joins the in-flight write (call before the next save or exit).
    The snapshot (device_get) happens on the caller's thread so the arrays
    handed to the writer are immutable host copies.
    """

    def __init__(self, directory: Path | str):
        self.directory = Path(directory)
        self._thread: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save_checkpoint(self.directory, step, host_tree)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


# --------------------------------------------------------------------------- #
# naive per-tensor layout (Fig. 3 comparison only)
# --------------------------------------------------------------------------- #
def save_naive(directory: Path | str, tree: Any) -> int:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    n = 0
    for path, leaf in _flatten(tree):
        fname = directory / (path.replace("/", "__") + ".npy")
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V":   # .npy cannot express bf16 — widen.
            arr = np.asarray(jax.device_get(jnp.asarray(leaf, jnp.float32)))
        np.save(fname, arr)
        n += 1
    return n


def load_naive(directory: Path | str, skeleton: Any) -> Any:
    directory = Path(directory)
    values = {}
    for path, _ in _flatten(skeleton):
        fname = directory / (path.replace("/", "__") + ".npy")
        values[path] = jnp.asarray(np.load(fname))
    return _unflatten_into(skeleton, values)


def file_op_counts(tree: Any) -> dict[str, int]:
    """Metadata-operation counts per rank for both layouts (Fig. 3 model)."""
    n_leaves = len(_flatten(tree))
    return {
        "naive_metadata_ops": 2 * n_leaves,   # stat + open per tensor
        "manifest_metadata_ops": 3,           # LATEST + manifest + blob
    }
