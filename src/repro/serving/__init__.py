"""Disaggregated prefill/decode serving fleet (docs/fleet.md).

`FleetScheduler` routes requests across prefill and decode replica
pools, migrating finished prefill slots as checksummed KV handoff
artifacts; `ElasticController` supervises liveness, stragglers, and
decode-pool rescaling with bundle warm-started replicas; `Replica` /
`FakeReplica` wrap one engine + local scheduler (the fake is the
fault-injection harness).
"""

from repro.serving.elastic import ElasticController
from repro.serving.fleet import FleetScheduler
from repro.serving.replica import (
    ACTIVE,
    DEAD,
    DRAINED,
    JOINING,
    FakeFleetEngine,
    FakeReplica,
    Replica,
)

__all__ = [
    "FleetScheduler", "ElasticController",
    "Replica", "FakeReplica", "FakeFleetEngine",
    "JOINING", "ACTIVE", "DRAINED", "DEAD",
]
