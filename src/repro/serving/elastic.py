"""ElasticController: the fleet's control plane, built from ft/.

One `step()` per fleet tick, entirely clock-injected:

  1. **Liveness** — every alive replica heartbeats into the
     `ft.Supervisor`; a killed replica goes silent, misses its
     heartbeat window, and shows up in `dead_hosts()`.  Newly-dead
     replicas trigger `FleetScheduler.on_replica_dead` (crash
     recovery by re-prefill).
  2. **Stragglers** — per-tick decode latencies feed the
     `ft.StragglerDetector`; a replica flagged `evict_after`
     consecutive ticks is *gracefully drained* (its slots leave as KV
     handoffs — unlike a crash, nothing is recomputed) and evicted
     from the pool.
  3. **Rescale** — `ft.pool_rescale_plan` sizes the decode pool
     against open demand.  Growth is immediate (a storm must not wait);
     shrink needs `shrink_patience` consecutive under-demand plans so a
     momentary dip cannot thrash the pool.  Every provisioned replica
     comes from the fleet's factory, which warm-starts it from a
     tuning bundle — the controller logs the replica's bind stats
     ("warm-start decode-N: bundle-imported=K ...") so the paper's
     claim (portable site artifacts make elastic capacity cheap, §III)
     is visible in the event stream the CI smoke greps.

A controller with ``rescale=False`` is the *static* fleet baseline the
--fleet benchmark compares against: deaths are still detected and
recovered, but lost capacity is never replaced.
"""

from __future__ import annotations

from repro.ft import (
    StragglerDetector,
    Supervisor,
    pool_rescale_plan,
)
from repro.serving.replica import ACTIVE, JOINING, Replica

__all__ = ["ElasticController"]


class ElasticController:
    def __init__(self, supervisor: Supervisor, *,
                 detector: StragglerDetector | None = None,
                 min_decode: int = 1, max_decode: int = 8,
                 rescale: bool = True, shrink_patience: int = 3,
                 provision_delay: float = 0.0):
        if min_decode < 1:
            raise ValueError("min_decode must be >= 1 (a fleet with no "
                             "decode pool cannot drain)")
        self.supervisor = supervisor
        self.detector = detector
        self.min_decode = min_decode
        self.max_decode = max_decode
        self.rescale = rescale
        self.shrink_patience = shrink_patience
        self.provision_delay = provision_delay
        self.provisioned = 0
        self.drained = 0
        self._known_dead: set[int] = set()
        self._shrink_votes = 0
        self._slots_per_replica = 1

    def attach(self, fleet) -> None:
        """Adopt a fleet's existing replicas into supervision (called by
        FleetScheduler when constructed with this controller)."""
        now = fleet.clock()
        for rep in fleet.replicas():
            self.supervisor.register(rep.id, now)
        if fleet.decode_pool:
            self._slots_per_replica = fleet.decode_pool[0].engine.slots

    # -- the control step --------------------------------------------------
    def step(self, fleet, now: float) -> None:
        self._liveness(fleet, now)
        self._stragglers(fleet, now)
        if self.rescale:
            self._rescale(fleet, now)

    def _liveness(self, fleet, now: float) -> None:
        for rep in fleet.replicas():
            if rep.alive:
                self.supervisor.heartbeat(rep.id, now)
        self.supervisor.poll(now)
        newly = set(self.supervisor.dead_hosts()) - self._known_dead
        if not newly:
            return
        self._known_dead |= newly
        for rep in [r for r in fleet.replicas() if r.id in newly]:
            fleet.on_replica_dead(rep, now)
            if self.detector is not None:
                self.detector.forget(rep.id)

    def _stragglers(self, fleet, now: float) -> None:
        if self.detector is None:
            return
        durations = {rep.id: rep.last_tick_s for rep in fleet.decode_pool
                     if rep.alive and rep.state == ACTIVE and rep.ticks > 0}
        if not durations:
            return
        plan = self.detector.observe(durations)
        for host in sorted(plan.evict_hosts):
            rep = next((r for r in fleet.decode_pool if r.id == host), None)
            if rep is None:
                continue
            fleet.drain_replica(rep, now, reason="straggler")
            self.supervisor.evict(host, now, reason="straggler")
            self._known_dead.add(host)
            self.detector.forget(host)
            self.drained += 1

    def _rescale(self, fleet, now: float) -> None:
        current = sum(1 for r in fleet.decode_pool
                      if r.alive and r.state in (ACTIVE, JOINING))
        plan = pool_rescale_plan(
            current, demand=fleet.decode_demand(),
            slots_per_replica=self._slots_per_replica,
            min_replicas=self.min_decode, max_replicas=self.max_decode,
        )
        if plan.delta > 0:
            self._shrink_votes = 0
            fleet.events.append(f"t={now:.1f} {plan.describe()}")
            for _ in range(plan.delta):
                self.provision(fleet, now)
        elif plan.delta < 0:
            self._shrink_votes += 1
            if self._shrink_votes >= self.shrink_patience:
                self._shrink_votes = 0
                fleet.events.append(f"t={now:.1f} {plan.describe()}")
                self._shrink_one(fleet, now)
        else:
            self._shrink_votes = 0

    # -- pool mutations ----------------------------------------------------
    def provision(self, fleet, now: float) -> Replica:
        """Grow the decode pool by one warm-started replica."""
        rep = fleet.add_replica("decode", join_at=now + self.provision_delay)
        self.supervisor.register(rep.id, now)
        self.provisioned += 1
        fleet.events.append(
            f"t={now:.1f} provision {rep.name} "
            f"(active at t={rep.join_at:.1f})")
        if rep.warm_start:
            binds = ", ".join(f"{k}={v}"
                              for k, v in sorted(rep.warm_start.items()))
            fleet.events.append(f"t={now:.1f} warm-start {rep.name}: {binds}")
        return rep

    def _shrink_one(self, fleet, now: float) -> None:
        candidates = [r for r in fleet.decode_pool
                      if r.alive and r.state == ACTIVE]
        if len(candidates) <= self.min_decode:
            return
        rep = min(candidates, key=lambda r: len(r.active_requests()))
        fleet.drain_replica(rep, now, reason="scale-in")
        self.supervisor.evict(rep.id, now, reason="scale-in")
        self._known_dead.add(rep.id)
        if self.detector is not None:
            self.detector.forget(rep.id)
        self.drained += 1
