"""Replica: one engine + its local scheduling policy, plus the
fault-injection harness the fleet tests drive.

A replica is the fleet's unit of capacity: a `JaxEngine` (or the fake
below) wrapped with the `launch.serve.Scheduler` running as that
replica's *local* policy, a lifecycle state, and the fault hooks
(`kill`, latency injection) the `ElasticController` reacts to.

Lifecycle (docs/fleet.md state machine):

    joining -> active -> (drained | dead)

  * ``joining`` — provisioned but not yet serving (the elastic
    controller's provision delay); heartbeats, takes no work.
  * ``active``  — ticking; prefill replicas hand finished slots to the
    fleet, decode replicas adopt them.
  * ``drained`` — gracefully retired (straggler eviction, scale-in):
    its in-flight slots were exported as KV handoffs, it leaves the
    pool with nothing owed.
  * ``dead``    — killed/silent: its engine state is *lost*; the fleet
    recovers in-flight requests by re-prefilling prompt + emitted
    tokens (greedy decoding makes the continuation token-identical).

The factory contract the fleet/controller provision through is
``factory(role, host_id) -> Replica`` with ``role`` in
``("prefill", "decode")``.
"""

from __future__ import annotations

import time
from typing import Callable

import numpy as np

from repro.launch.serve import DECODING, PagedPool, Request, Scheduler

__all__ = ["JOINING", "ACTIVE", "DRAINED", "DEAD",
           "Replica", "FakeFleetEngine", "FakeReplica"]

# replica lifecycle states
JOINING = "joining"
ACTIVE = "active"
DRAINED = "drained"
DEAD = "dead"


class Replica:
    """One serving replica: engine + local Scheduler + lifecycle state.

    ``role`` picks which half of the disaggregated pipeline this replica
    serves: a ``"prefill"`` replica ingests prompts and exits every
    request through the fleet's handoff hook (installed by the fleet via
    `set_handoff_hook`); a ``"decode"`` replica never sees the queue —
    it only `Scheduler.adopt`s handed-off requests and ticks them to
    completion.

    ``last_tick_s`` is the per-tick duration the `StragglerDetector`
    observes — measured wall time by default, overridable for
    deterministic tests and fault injection (`set_latency`).
    """

    def __init__(self, host_id: int, role: str, engine, *,
                 clock: Callable[[], float] = time.monotonic,
                 interleave: int = 2, queue_depth: int | None = None,
                 max_new_cap: int = 1 << 30):
        if role not in ("prefill", "decode"):
            raise ValueError(f"unknown replica role {role!r}")
        self.id = host_id
        self.role = role
        self.name = f"{role}-{host_id}"
        self.engine = engine
        self.scheduler = Scheduler(
            engine,
            queue_depth=engine.slots if queue_depth is None else queue_depth,
            max_new_cap=max_new_cap, interleave=interleave, clock=clock,
        )
        self.state = ACTIVE
        self.alive = True
        self.join_at = 0.0
        self.last_tick_s = 0.0
        self.latency_override: float | None = None
        self.warm_start: dict | None = None   # bind stats the factory records
        self.ticks = 0

    # -- fleet wiring ------------------------------------------------------
    def set_handoff_hook(self, hook: Callable[[Request], None]) -> None:
        """Install the fleet's handoff exporter (prefill replicas only).
        The hook runs with the finishing request's slot still held, so
        it can export the pages before the scheduler releases them."""
        if self.role != "prefill":
            raise ValueError(f"{self.name}: only prefill replicas hand off")
        if self.engine.prefill_mode != "chunked":
            raise ValueError("handoff requires chunked prefill")
        self.scheduler.on_handoff = hook

    def free_slots(self) -> int:
        return sum(r is None for r in self.scheduler.active)

    def active_requests(self) -> list[Request]:
        return [r for r in self.scheduler.active if r is not None]

    # -- fault injection ---------------------------------------------------
    def kill(self) -> None:
        """Simulate process death: no more ticks, no more heartbeats,
        engine state unrecoverable.  The supervisor notices via missed
        heartbeats; the fleet recovers the in-flight requests."""
        self.alive = False

    def set_latency(self, seconds: float | None) -> None:
        """Pin the per-tick duration the straggler detector sees (None
        restores wall-time measurement)."""
        self.latency_override = seconds

    # -- serving -----------------------------------------------------------
    def tick(self) -> list[tuple[int, int]]:
        """One local scheduling quantum; returns emitted (rid, token)
        pairs.  Dead or non-active replicas do nothing — a killed
        process cannot make progress, and the fleet must not count on
        it."""
        if not self.alive or self.state != ACTIVE:
            return []
        t0 = time.perf_counter()
        out = self.scheduler.tick()
        measured = time.perf_counter() - t0
        self.last_tick_s = (measured if self.latency_override is None
                            else self.latency_override)
        self.ticks += 1
        return out


class FakeFleetEngine:
    """Deterministic paged fake engine for fleet tests — no jax.

    The "model" is next-token = (previous + 1) % vocab, so any replica
    continues any token stream identically — exactly the property real
    greedy decoding has with shared params — and the expected chain for
    prompt [.., t] is t+1, t+2, ... (mod vocab).

    Unlike test_serving's shape-only fake, this one keeps a real paged
    store: every fed token's value lands in its page via the slot's
    block table, and SSM-style per-slot state (a running token sum plus
    a last-token "conv" tap) rides along.  `export_slot`/`import_slot`
    move those bytes exactly like the JaxEngine does for KV pools, so a
    handoff that loses pages, scatters to the wrong page, or drops the
    recurrent row is caught by decode-side integrity checks and by
    direct pool inspection in tests.
    """

    def __init__(self, *, slots: int = 2, max_len: int = 32, chunk: int = 4,
                 num_pages: int | None = None, vocab: int = 16):
        self.slots = slots
        self.max_len = max_len
        self.chunk = chunk
        self.vocab = vocab
        self.prefill_mode = "chunked"
        self.paged = True
        self.pool = PagedPool(slots, max_len, chunk, num_pages)
        # page store: token value per (page, offset); -1 == never written
        self.kv = np.full((self.pool.num_pages, chunk), -1, np.int64)
        # per-slot recurrent state: running sum + last token fed
        self.state = np.zeros(slots, np.int64)
        self.conv = np.full(slots, -1, np.int64)
        self.prefill_calls = 0
        self.decode_calls = 0

    @property
    def prefill_unit(self) -> int:
        return self.chunk

    def _logits(self, token: int) -> np.ndarray:
        out = np.zeros(self.vocab, np.float32)
        out[(int(token) + 1) % self.vocab] = 1.0
        return out

    def _write(self, slot: int, pos: int, token: int) -> None:
        page = self.pool.block_tables[slot][pos // self.chunk]
        self.kv[page, pos % self.chunk] = int(token)
        self.state[slot] += int(token)
        self.conv[slot] = int(token)

    def prefill_step(self, slot: int, tokens: np.ndarray, pos: int):
        for i, t in enumerate(tokens):
            self._write(slot, pos + i, int(t))
        self.prefill_calls += 1
        return self._logits(int(tokens[-1]))

    def decode_step(self, tokens: np.ndarray, pos: np.ndarray,
                    active: np.ndarray) -> np.ndarray:
        logits = np.zeros((self.slots, self.vocab), np.float32)
        for s in range(self.slots):
            if not active[s]:
                continue
            p = int(pos[s])
            # integrity: every earlier position of this slot must hold a
            # written token — a botched handoff (lost page, wrong slot
            # row) surfaces here instead of as silent wrong tokens
            for q in range(p):
                page = self.pool.block_tables[s][q // self.chunk]
                if self.kv[page, q % self.chunk] < 0:
                    raise AssertionError(
                        f"slot {s}: position {q} unwritten at decode "
                        f"pos {p} — KV lost in handoff?")
            self._write(s, p, int(tokens[s, 0]))
            logits[s] = self._logits(int(tokens[s, 0]))
        self.decode_calls += 1
        return logits

    # -- KV handoff (mirrors JaxEngine.export_slot/import_slot) -----------
    def export_slot(self, slot: int, n_tokens: int) -> tuple[dict, int]:
        if n_tokens < 1:
            raise ValueError(f"export of {n_tokens} tokens")
        pages_used = -(-n_tokens // self.chunk)
        pages = self.pool.block_tables[slot][:pages_used]
        arrays = {
            "kv": self.kv[pages].copy(),
            "state": self.state[slot:slot + 1].copy(),
            "conv": self.conv[slot:slot + 1].copy(),
        }
        return arrays, pages_used

    def import_slot(self, slot: int, arrays: dict, pages_used: int) -> None:
        pages = self.pool.block_tables[slot][:pages_used]
        kv = np.asarray(arrays["kv"])
        if kv.shape != (pages_used, self.chunk):
            raise ValueError(f"handoff kv is {kv.shape}, want "
                             f"{(pages_used, self.chunk)}")
        self.kv[pages] = kv
        self.state[slot] = int(np.asarray(arrays["state"])[0])
        self.conv[slot] = int(np.asarray(arrays["conv"])[0])


class FakeReplica(Replica):
    """Replica over a FakeFleetEngine — the fault-injection harness.

    Everything the fleet does to a real replica works here (kill,
    latency injection, handoff export/import, page accounting) with
    deterministic tokens and no jax, so tests/test_fleet.py can drive
    replica death mid-decode, straggler eviction, and pool exhaustion
    with a fake clock and still assert token-identical drains.
    """

    def __init__(self, host_id: int, role: str, *, slots: int = 2,
                 max_len: int = 32, chunk: int = 4,
                 num_pages: int | None = None, vocab: int = 16,
                 clock: Callable[[], float] = time.monotonic,
                 interleave: int = 2):
        engine = FakeFleetEngine(slots=slots, max_len=max_len, chunk=chunk,
                                 num_pages=num_pages, vocab=vocab)
        super().__init__(host_id, role, engine, clock=clock,
                         interleave=interleave)

    def decoding_requests(self) -> list[Request]:
        return [r for r in self.active_requests() if r.state == DECODING]
