"""FleetScheduler: disaggregated prefill/decode serving.

The fleet splits serving across two replica pools.  **Prefill**
replicas ingest prompts with chunked prefill; the moment a prompt's
final chunk emits the first token, the slot's cache state leaves the
replica as a **KV handoff** — the per-slot page contents plus recurrent
(SSM) rows, serialized through the same checksummed-manifest artifact
path as tuning bundles (`repro.tuning.bundle.KVHandoff`).  **Decode**
replicas adopt pending handoffs into free slots (leasing pages from
their *own* allocator — page numbers never cross replicas) and tick
them to completion.

Because decoding is greedy and every replica runs the same params,
migration is token-exact: the fleet's output for a request set is
identical to a single-host chunked server's (pinned by
tests/test_fleet.py and the --fleet benchmark).  The same property
powers crash recovery — when a replica dies, its in-flight requests
are re-submitted as *prompt + tokens-emitted-so-far* with the
remaining budget, and the re-prefilled continuation picks up exactly
where the lost replica stopped.

Bookkeeping is split between user-facing **records** (the Request the
caller submitted: accumulates tokens, timestamps, step counts across
any number of migrations) and internal **work items** (the Request
clone a replica actually holds; replaced wholesale on crash recovery).
The KVHandoff bytes carry the engine state across the pool boundary;
the work item carries the scheduling metadata.  All timing flows from
one injected clock, so the whole fleet — elastic controller included —
is deterministic under a fake clock.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Callable

import numpy as np

from repro.launch.serve import (
    DECODING,
    DONE,
    HANDOFF,
    QUEUED,
    REJECT_QUEUE_FULL,
    REJECT_TOO_LONG,
    Request,
)
from repro.serving.replica import ACTIVE, DEAD, DRAINED, JOINING, Replica
from repro.tuning.bundle import KVHandoff

__all__ = ["FleetScheduler"]


class FleetScheduler:
    """Routes requests across prefill/decode replica pools.

    ``factory(role, host_id) -> Replica`` provisions capacity — the
    constructor uses it for the initial pools and the elastic
    controller uses it to grow the decode pool at runtime (each new
    decode replica warm-starts from a tuning bundle; see
    serving/elastic.py).

    Per tick: controller step (deaths, stragglers, rescale) -> activate
    joiners -> route queue into prefill slots -> tick every replica ->
    adopt pending handoffs FCFS -> merge emissions into records.
    """

    def __init__(self, factory: Callable[[str, int], Replica], *,
                 prefill: int = 1, decode: int = 1,
                 clock: Callable[[], float] = time.monotonic,
                 queue_depth: int = 64, max_new_cap: int = 1 << 30,
                 controller=None):
        self.factory = factory
        self.clock = clock
        self.queue_depth = queue_depth
        self.max_new_cap = max_new_cap
        self.prefill_pool: list[Replica] = []
        self.decode_pool: list[Replica] = []
        self.records: dict[int, Request] = {}
        self.items: dict[int, Request] = {}
        # rid -> replica id currently holding the item (None while the
        # item is queued or its state travels as a handoff artifact)
        self.owner: dict[int, int | None] = {}
        self.queue: deque[int] = deque()
        self.pending_handoffs: deque[bytes] = deque()
        self.events: list[str] = []
        self.rejected: dict[str, int] = {}
        self.submitted = 0
        self.completed = 0
        self.handoffs = 0
        self.adoptions = 0
        self.recovered = 0
        self.handoff_bytes = 0
        self.ticks = 0
        self._next_host = 0
        self._order = 0
        self._now = clock()
        self._blocked_rid: int | None = None
        for _ in range(max(1, prefill)):
            self.add_replica("prefill")
        for _ in range(max(1, decode)):
            self.add_replica("decode")
        self.controller = controller
        if controller is not None:
            controller.attach(self)

    # -- pool management ---------------------------------------------------
    def replicas(self) -> list[Replica]:
        return self.prefill_pool + self.decode_pool

    def add_replica(self, role: str, *, join_at: float | None = None) -> Replica:
        """Provision one replica through the factory.  With a future
        ``join_at`` the replica starts JOINING (the controller's
        provision delay) and activates once the clock reaches it."""
        rep = self.factory(role, self._next_host)
        self._next_host += 1
        if role == "prefill":
            rep.set_handoff_hook(
                lambda req, _rep=rep: self._on_handoff(_rep, req))
            self.prefill_pool.append(rep)
        else:
            self.decode_pool.append(rep)
        if join_at is not None and join_at > self._now:
            rep.state = JOINING
            rep.join_at = join_at
        return rep

    def _remove(self, rep: Replica) -> None:
        for pool in (self.prefill_pool, self.decode_pool):
            if rep in pool:
                pool.remove(rep)

    def decode_demand(self) -> int:
        """Open work items — what pool_rescale_plan sizes the decode
        pool against (everything accepted and not yet done will need a
        decode slot)."""
        return sum(1 for r in self.records.values() if r.state != DONE)

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admission-checked intake.  The geometry check runs against a
        template replica from EACH pool: a request a prefill replica
        could serve but no decode replica could adopt must be rejected
        up front, not discovered as a stuck handoff."""
        self.submitted += 1
        req.max_new = min(req.max_new, self.max_new_cap)
        templates = [p[0] for p in (self.prefill_pool, self.decode_pool) if p]
        if not all(t.scheduler.servable(req.prompt_len, req.max_new)
                   for t in templates):
            self.rejected[REJECT_TOO_LONG] = \
                self.rejected.get(REJECT_TOO_LONG, 0) + 1
            return False
        if len(self.queue) >= self.queue_depth:
            self.rejected[REJECT_QUEUE_FULL] = \
                self.rejected.get(REJECT_QUEUE_FULL, 0) + 1
            return False
        req.state = QUEUED
        req.submit_t = self.clock()
        self.records[req.rid] = req
        item = Request(rid=req.rid, prompt=np.asarray(req.prompt, np.int32),
                       max_new=req.max_new)
        self._order += 1
        item.order = self._order    # fleet-global FCFS / allocator-owner id
        self.items[req.rid] = item
        self.owner[req.rid] = None
        self.queue.append(req.rid)
        return True

    # -- handoff path ------------------------------------------------------
    def _export(self, rep: Replica, req: Request) -> None:
        """Serialize a slot's cache state into a pending KVHandoff
        artifact.  Must run while the slot (and its pages) are still
        held by ``rep``."""
        arrays, pages_used = rep.engine.export_slot(req.slot, req.next_pos)
        blob = KVHandoff(
            rid=req.rid, source=rep.name, next_pos=req.next_pos,
            pages_used=pages_used, page_size=rep.engine.pool.page_size,
            arrays=arrays,
        ).to_bytes()
        self.pending_handoffs.append(blob)
        self.owner[req.rid] = None
        self.handoffs += 1
        self.handoff_bytes += len(blob)
        self.events.append(
            f"t={self._now:.1f} handoff rid={req.rid} from {rep.name} "
            f"({len(blob)} bytes, {pages_used} page(s))")

    def _on_handoff(self, rep: Replica, req: Request) -> None:
        # Scheduler._handoff hook: slot still held, pages still leased
        self._export(rep, req)

    def _adopt_pending(self) -> None:
        """Place pending handoffs onto decode replicas, strictly FCFS:
        a blocked head-of-line artifact waits for capacity rather than
        being overtaken (the same no-starvation rule as paged
        admission)."""
        while self.pending_handoffs:
            h = KVHandoff.from_bytes(self.pending_handoffs[0])
            item = self.items.get(h.rid)
            if item is None or self.records[h.rid].state == DONE:
                # stale artifact (request finished via crash recovery)
                self.pending_handoffs.popleft()
                continue
            if not self._try_adopt(h, item):
                if self._blocked_rid != h.rid:
                    self._blocked_rid = h.rid
                    self.events.append(
                        f"t={self._now:.1f} adoption of rid={h.rid} waiting "
                        f"for decode capacity")
                break
            self.pending_handoffs.popleft()
            self._blocked_rid = None

    def _try_adopt(self, h: KVHandoff, item: Request) -> bool:
        for rep in self.decode_pool:
            if not (rep.alive and rep.state == ACTIVE):
                continue
            if rep.engine.pool.page_size != h.page_size:
                raise ValueError(
                    f"handoff rid={h.rid} page_size {h.page_size} != "
                    f"{rep.name} page_size {rep.engine.pool.page_size}")
            if rep.scheduler.adopt(item):
                rep.engine.import_slot(item.slot, dict(h.arrays), h.pages_used)
                self.owner[h.rid] = rep.id
                self.adoptions += 1
                self.events.append(
                    f"t={self._now:.1f} adopt rid={h.rid} on {rep.name} "
                    f"(pos {h.next_pos})")
                return True
        return False

    # -- fault handling ----------------------------------------------------
    def on_replica_dead(self, rep: Replica, now: float) -> int:
        """Crash recovery: the replica's engine state is gone, so every
        item it held is re-submitted as prompt + emitted tokens with
        the remaining budget — greedy decoding makes the re-prefilled
        continuation token-identical to the lost one.  Returns the
        number of requests recovered."""
        rep.state = DEAD
        rep.alive = False
        self._remove(rep)
        lost = [rid for rid, oid in self.owner.items() if oid == rep.id]
        self.events.append(
            f"t={now:.1f} {rep.name} dead; recovering {len(lost)} request(s)")
        for rid in lost:
            self.owner[rid] = None
            rec = self.records[rid]
            item = self.items.get(rid)
            if item is None or rec.state == DONE:
                continue
            rec.prefill_steps += item.prefill_steps
            rec.decode_steps += item.decode_steps
            replacement = Request(
                rid=rid,
                prompt=np.concatenate([np.asarray(rec.prompt, np.int32),
                                       np.asarray(rec.tokens, np.int32)]),
                max_new=rec.max_new - len(rec.tokens),
            )
            replacement.order = item.order   # keeps FCFS seniority
            self.items[rid] = replacement
            self.queue.appendleft(rid)       # head of line: it was here first
            self.recovered += 1
            self.events.append(
                f"t={now:.1f} requeue rid={rid}: {len(rec.tokens)} emitted, "
                f"{replacement.max_new} remaining")
        return len(lost)

    def drain_replica(self, rep: Replica, now: float,
                      reason: str = "drain") -> int:
        """Graceful retirement (straggler eviction, scale-in): decoding
        slots leave as KV handoffs — no tokens are lost and no work is
        redone — while not-yet-prefilled slots and the local queue go
        back to the global queue.  Returns exported-slot count."""
        exported = 0
        for req in list(rep.active_requests()):
            if req.state == DECODING:
                self._export(rep, req)
                req.state = HANDOFF
                exported += 1
            else:       # PREFILLING: partial chunks can't migrate; redo
                self.queue.appendleft(req.rid)
                self.owner[req.rid] = None
                req.state = QUEUED
            if rep.scheduler.paged:
                rep.engine.pool.free(req.order)
                rep.engine.pool.release(req.slot)
            rep.scheduler.active[req.slot] = None
            req.slot = None
        while rep.scheduler.queue:
            q = rep.scheduler.queue.pop()
            self.queue.appendleft(q.rid)
            self.owner[q.rid] = None
        rep.state = DRAINED
        self._remove(rep)
        self.events.append(
            f"t={now:.1f} drain {rep.name} ({reason}): {exported} slot(s) "
            f"exported")
        return exported

    # -- the fleet quantum -------------------------------------------------
    def _route(self) -> None:
        for rep in self.prefill_pool:
            if not (rep.alive and rep.state == ACTIVE):
                continue
            avail = rep.free_slots() - len(rep.scheduler.queue)
            while avail > 0 and self.queue:
                rid = self.queue.popleft()
                if not rep.scheduler.submit(self.items[rid]):
                    self.queue.appendleft(rid)
                    return
                self.owner[rid] = rep.id
                avail -= 1

    def _merge(self, emissions: list[tuple[int, int]], now: float) -> None:
        for rid, tok in emissions:
            rec = self.records[rid]
            if rec.state == DONE:
                continue
            if rec.first_token_t is None:
                rec.first_token_t = now
            rec.tokens.append(int(tok))
        for rid in [r for r, item in self.items.items() if item.done]:
            rec = self.records[rid]
            item = self.items.pop(rid)
            if rec.state == DONE:
                continue
            rec.state = DONE
            rec.finish_t = now
            rec.prefill_steps += item.prefill_steps
            rec.decode_steps += item.decode_steps
            self.completed += 1
            self.owner.pop(rid, None)

    def tick(self) -> list[tuple[int, int]]:
        """One fleet quantum; returns every (rid, token) emitted."""
        now = self.clock()
        self._now = now
        self.ticks += 1
        if self.controller is not None:
            self.controller.step(self, now)
        for rep in self.replicas():
            if rep.state == JOINING and rep.alive and now >= rep.join_at:
                rep.state = ACTIVE
                self.events.append(f"t={now:.1f} {rep.name} active")
        self._route()
        emissions: list[tuple[int, int]] = []
        for rep in self.replicas():
            emissions.extend(rep.tick())
        self._adopt_pending()
        self._merge(emissions, now)
        return emissions

    @property
    def idle(self) -> bool:
        return (not self.queue and not self.pending_handoffs
                and all(r.state == DONE for r in self.records.values()))

    def run(self, max_ticks: int = 1 << 20) -> None:
        """Tick until every accepted request completes."""
        ticks = 0
        while not self.idle:
            self.tick()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("fleet failed to drain (livelock?)")

    def stats(self) -> dict[str, float]:
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected-queue-full": self.rejected.get(REJECT_QUEUE_FULL, 0),
            "rejected-too-long": self.rejected.get(REJECT_TOO_LONG, 0),
            "handoffs": self.handoffs,
            "adoptions": self.adoptions,
            "recovered": self.recovered,
            "handoff-bytes": self.handoff_bytes,
            "pending-handoffs": len(self.pending_handoffs),
            "prefill-replicas": len(self.prefill_pool),
            "decode-replicas": len(self.decode_pool),
            "ticks": self.ticks,
        }
