"""Composable model zoo (pure JAX; ops injected via the container binding)."""

from repro.models.layers import ParallelCtx
from repro.models.model import Model, build_model

__all__ = ["Model", "build_model", "ParallelCtx"]
