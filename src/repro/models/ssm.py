"""Mamba-2 (SSD) layer — used by `mamba2-780m` and the Jamba hybrid.

Layer structure (arXiv:2405.21060):
  in_proj -> [z | x | B | C | dt]; causal depthwise conv on x; SSD scan
  (via binding["ssd_scan"]: chunked jnp reference or the Pallas kernel);
  D skip; RMSNorm(gated by silu(z)); out_proj.

Decode keeps two pieces of state per layer: the (conv_k-1) trailing inputs
for the depthwise conv and the (H, N, P) SSM state — both O(1) in sequence
length, which is what makes the `long_500k` cell runnable.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ssd_scan_ref import ssd_decode_step_ref
from repro.models.schema import LeafSpec

__all__ = [
    "ssm_schema",
    "ssm_apply",
    "ssm_decode",
    "ssm_prefill_chunk",
    "ssm_init_cache_shapes",
]

_NGROUPS = 1  # B/C shared across heads (mamba2 default ngroups=1)


def ssm_schema(cfg: ModelConfig) -> dict[str, LeafSpec]:
    d = cfg.d_model
    din = cfg.ssm_d_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state
    return {
        "w_z": LeafSpec((d, din), ("embed", "ssm_inner"), init="scaled"),
        "w_x": LeafSpec((d, din), ("embed", "ssm_inner"), init="scaled"),
        "w_b": LeafSpec((d, _NGROUPS * n), ("embed", None), init="scaled"),
        "w_c": LeafSpec((d, _NGROUPS * n), ("embed", None), init="scaled"),
        "w_dt": LeafSpec((d, h), ("embed", "ssm_heads"), init="scaled"),
        "dt_bias": LeafSpec((h,), ("ssm_heads",), init="zeros"),
        "a_log": LeafSpec((h,), ("ssm_heads",), init="normal", scale=0.5),
        "d_skip": LeafSpec((h,), ("ssm_heads",), init="ones"),
        "conv_w": LeafSpec((cfg.ssm_conv, din), (None, "ssm_inner"), init="scaled"),
        "conv_b": LeafSpec((din,), ("ssm_inner",), init="zeros"),
        "norm_scale": LeafSpec((din,), ("ssm_inner",), init="ones"),
        "w_out": LeafSpec((din, d), ("ssm_inner", "embed"), init="scaled"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv as shifted adds.  x: (B, S, Din), w: (K, Din)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    s = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        y = y + pad[:, i : i + s, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def _projections(params, x):
    z = jnp.einsum("bsd,de->bse", x, params["w_z"])
    xs = jnp.einsum("bsd,de->bse", x, params["w_x"])
    bm = jnp.einsum("bsd,dn->bsn", x, params["w_b"])
    cm = jnp.einsum("bsd,dn->bsn", x, params["w_c"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["w_dt"])
    return z, xs, bm, cm, dt


def ssm_apply(
    params,
    x: jnp.ndarray,        # (B, S, D)
    cfg: ModelConfig,
    binding,
    *,
    return_state: bool = False,
):
    b, s, _ = x.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z, xs, bm, cm, dt = _projections(params, x)
    xs = _causal_conv(xs, params["conv_w"], params["conv_b"])
    xs = jax.nn.silu(xs)

    xh = xs.reshape(b, s, h, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    bmg = bm.reshape(b, s, _NGROUPS, n)
    cmg = cm.reshape(b, s, _NGROUPS, n)

    # The chunk is a pure implementation tile: a site-tuned binding knows a
    # better value than the model config's static ssm_chunk, so defer to it
    # — resolved for THIS call's geometry (prefill and decode sequences tune
    # to different chunks) — falling back to the largest divisor when it
    # doesn't divide this seq.
    tuned = getattr(binding, "tuned_config", lambda name, shapes=None: None)(
        "ssd_scan", (xh, dt, a, bmg, cmg))
    chunk = tuned["chunk"] if tuned is not None and "chunk" in tuned else cfg.ssm_chunk
    chunk = min(chunk, s)
    if s % chunk:
        chunk = math.gcd(chunk, s)
    y, state = binding["ssd_scan"](xh, dt, a, bmg, cmg, chunk=chunk)
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, s, h * p)
    y = _rms(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    if return_state:
        conv_tail = _conv_tail(x, params, cfg)
        return out, {"state": state, "conv": conv_tail}
    return out


def _conv_tail(x, params, cfg: ModelConfig):
    """Last (conv_k - 1) *pre-conv* inputs, for decode continuation."""
    xs = jnp.einsum("bsd,de->bse", x, params["w_x"])
    k = cfg.ssm_conv
    return xs[:, -(k - 1):, :].astype(xs.dtype)


def ssm_init_cache_shapes(cfg: ModelConfig, batch: int):
    h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    return {
        "state": ((batch, h, n, p), "float32"),
        "conv": ((batch, cfg.ssm_conv - 1, cfg.ssm_d_inner), cfg.dtype),
    }


def ssm_prefill_chunk(
    params,
    x: jnp.ndarray,        # (B, C, D) — chunk of prompt at positions pos..
    cache: dict[str, jnp.ndarray],
    pos: jnp.ndarray,      # () int32 — chunk's global start position
    n_valid: jnp.ndarray,  # () int32 — real tokens in this chunk (<= C)
    cfg: ModelConfig,
    binding,
):
    """C-token state advance for chunked prefill.

    The SSD recurrence is *linear* in the state, so continuing from the
    cached state needs no special kernel: run the chunk's scan from a
    zero state via the bound op, then add the initial state's closed-form
    contribution —

        y_t      += C_t . (exp(cumsum(dt*A)_t) * state0)
        state_out = scan_final + exp(cumsum(dt*A)_C) * state0

    Padding (n_valid < C, the prompt's final partial chunk) is absorbed
    by clamping dt to 0 at padded steps: decay exp(0*A) = 1 and input
    contribution dt*B*x = 0, so the state is bit-exactly unchanged there.
    The conv window is reconstructed from [cached tail | chunk inputs]
    and the new tail sliced at n_valid, so partial chunks hand the next
    chunk the same window a contiguous prefill would have.  At pos == 0
    the cached state/tail are slot leftovers from the previous request
    and are zeroed instead of consumed.
    """
    b, c, _ = x.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    k = cfg.ssm_conv
    z, xs, bm, cm, dt = _projections(params, x)

    fresh = pos > 0
    tail = jnp.where(fresh, cache["conv"].astype(xs.dtype), 0)
    state0 = jnp.where(fresh, cache["state"].astype(jnp.float32), 0)

    # causal conv over [tail | chunk]: position t of the chunk sees ext
    # window [t, t+k) — identical to a whole-sequence conv at pos+t
    ext = jnp.concatenate([tail, xs], axis=1)          # (B, k-1+C, Din)
    y = jnp.zeros_like(xs, dtype=jnp.float32)
    for i in range(k):
        y = y + ext[:, i : i + c, :].astype(jnp.float32) * params["conv_w"][i].astype(jnp.float32)
    xc = jax.nn.silu(y + params["conv_b"].astype(jnp.float32)).astype(x.dtype)

    xh = xc.reshape(b, c, h, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    dt = dt * (jnp.arange(c)[None, :, None] < n_valid)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    bmg = bm.reshape(b, c, _NGROUPS, n)
    cmg = cm.reshape(b, c, _NGROUPS, n)

    tuned = getattr(binding, "tuned_config", lambda name, shapes=None: None)(
        "ssd_scan", (xh, dt, a, bmg, cmg))
    chunk = tuned["chunk"] if tuned is not None and "chunk" in tuned else cfg.ssm_chunk
    chunk = min(chunk, c)
    if c % chunk:
        chunk = math.gcd(chunk, c)
    y, state = binding["ssd_scan"](xh, dt, a, bmg, cmg, chunk=chunk)

    decay = jnp.exp(jnp.cumsum(dt * a[None, None, :], axis=1))   # (B, C, H)
    y = y + jnp.einsum("btn,bth,bhnp->bthp", cmg[:, :, 0], decay, state0).astype(y.dtype)
    state = state + decay[:, -1][..., None, None] * state0

    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, c, h * p)
    y = _rms(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    new_tail = jax.lax.dynamic_slice_in_dim(ext, n_valid, k - 1, axis=1)
    return out, {"state": state, "conv": new_tail.astype(cache["conv"].dtype)}


def ssm_decode(
    params,
    x: jnp.ndarray,        # (B, 1, D)
    cache: dict[str, jnp.ndarray],
    cfg: ModelConfig,
):
    """One-token state update (pure jnp: trivially memory-bound, no swap)."""
    b = x.shape[0]
    h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    x1 = x[:, 0, :]
    z = x1 @ params["w_z"]
    xs_new = x1 @ params["w_x"]                       # (B, Din) pre-conv
    bm = (x1 @ params["w_b"]).reshape(b, _NGROUPS, n)
    cm = (x1 @ params["w_c"]).reshape(b, _NGROUPS, n)
    dt = jax.nn.softplus(
        (x1 @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )

    # conv over [tail, new]
    window = jnp.concatenate([cache["conv"], xs_new[:, None, :]], axis=1)  # (B, K, Din)
    w = params["conv_w"]
    xc = (window.astype(jnp.float32) * w[None].astype(jnp.float32)).sum(axis=1)
    xc = jax.nn.silu(xc + params["conv_b"].astype(jnp.float32)).astype(x.dtype)

    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, new_state = ssd_decode_step_ref(
        xc.reshape(b, h, p), dt, a, bm, cm, cache["state"].astype(jnp.float32)
    )
    y = y + params["d_skip"].astype(y.dtype)[None, :, None] * xc.reshape(b, h, p)
    y = y.reshape(b, h * p)
    y = _rms(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm_scale"])
    out = (y @ params["w_out"])[:, None, :]
    new_cache = {"state": new_state, "conv": window[:, 1:, :]}
    return out, new_cache
