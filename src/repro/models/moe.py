"""Mixture-of-Experts layer: dropless top-k routing.

Two execution paths, selected by deployment (the op-substitution story):

  * **oracle** (`dense`): every expert computes every token, combined by
    gate weights — exact, O(E/topk) wasteful, used for tiny smoke configs
    and as the numerical oracle.
  * **gmm** (default): tokens are sorted by expert and run through the
    grouped matmul op (`binding["moe_gmm"]`: ragged_dot reference or the
    Pallas kernel).  Under a mesh this runs inside shard_map with
    *expert tensor parallelism*: the expert hidden dim F is sharded over
    the model axis (every routed pair computed exactly once, split over
    the axis; balanced regardless of routing skew), expert stacks are
    stored FSDP-sharded over the data axis and gathered per layer.  The
    only collective is one psum over the model axis — the same pattern as
    the dense TP MLP, so MoE and dense layers share a collective schedule.

Routing happens once, outside shard_map (cheap; lets the load-balancing
aux loss reuse it).  Shared experts (moonshot) are a dense MLP added to
the routed output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ParallelCtx, mlp_apply, mlp_schema
from repro.models.schema import LeafSpec

__all__ = ["moe_schema", "moe_apply"]


def moe_schema(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    leaves = {
        "router": LeafSpec((d, e), ("embed", None), init="scaled", dtype="float32"),
        "w_in": LeafSpec((e, d, f), ("experts", "embed", "ff"), init="scaled"),
        "w_gate": LeafSpec((e, d, f), ("experts", "embed", "ff"), init="scaled"),
        "w_out": LeafSpec((e, f, d), ("experts", "ff", "embed"), init="scaled"),
    }
    if cfg.n_shared_experts:
        leaves["shared"] = mlp_schema(cfg, d_ff=cfg.n_shared_experts * cfg.expert_d_ff)
    return leaves


def _route(x_flat: jnp.ndarray, router_w: jnp.ndarray, top_k: int):
    """(probs (T,E) f32, top_p (T,k) f32 renormalized, top_i (T,k) i32)."""
    logits = x_flat.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return probs, top_p, top_i.astype(jnp.int32)


def _load_balance_aux(probs, top_i, num_experts: int) -> jnp.ndarray:
    """Switch-style aux loss: E * sum_e(frac_routed_e * mean_prob_e)."""
    t = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    mean_p = probs.mean(axis=0)
    return num_experts * jnp.sum(frac * mean_p)


def _gmm_pairs(x_flat, top_p, top_i, w_in, w_gate, w_out, cfg: ModelConfig, binding):
    """Sorted grouped-matmul MoE with given routing.  Weights may be the
    ff-sharded local slice (inside shard_map) or the full stack."""
    t, d = x_flat.shape
    e, k = cfg.num_experts, cfg.top_k

    pair_expert = top_i.reshape(-1)                       # (T*k,)
    order = jnp.argsort(pair_expert)
    inv_order = jnp.argsort(order)
    token_of_pair = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    x_sorted = x_flat[token_of_pair[order]]
    group_sizes = jnp.bincount(pair_expert, length=e).astype(jnp.int32)

    # fused in+gate: one grouped matmul over [w_in | w_gate] halves the
    # pack/scatter rounds through HBM (the reference path's dominant
    # traffic; the Pallas kernel fuses these on-chip anyway)
    f = w_in.shape[-1]
    h2 = binding["moe_gmm"](
        x_sorted, jnp.concatenate([w_in, w_gate], axis=-1), group_sizes
    )
    h = jax.nn.silu(h2[:, f:]) * h2[:, :f]
    y_pairs = binding["moe_gmm"](h, w_out, group_sizes)   # (T*k, D), partial over ff shards

    y_pairs = y_pairs[inv_order] * top_p.reshape(-1, 1).astype(y_pairs.dtype)
    return jnp.zeros((t, d), y_pairs.dtype).at[token_of_pair].add(y_pairs)


def _dense_oracle(x_flat, top_p, top_i, params, cfg: ModelConfig):
    combine = jnp.zeros((x_flat.shape[0], cfg.num_experts), jnp.float32)
    combine = combine.at[jnp.arange(x_flat.shape[0])[:, None], top_i].add(top_p)
    h_in = jnp.einsum("td,edf->tef", x_flat, params["w_in"])
    h_gate = jnp.einsum("td,edf->tef", x_flat, params["w_gate"])
    h = jax.nn.silu(h_gate) * h_in
    y_e = jnp.einsum("tef,efd->ted", h, params["w_out"])
    return jnp.einsum("ted,te->td", y_e, combine.astype(y_e.dtype))


def _gmm_chunked(x_flat, top_p, top_i, w_in, w_gate, w_out, cfg, binding,
                 chunks: int, unroll: bool = False):
    """Token-chunked expert execution: lax.scan over token chunks keeps the
    peak gather/pack buffers at 1/chunks of the layer's tokens (routing is
    per-token, so chunking is exact up to per-chunk capacity).  `unroll`
    is the dry-run cost-measurement mode (while bodies count once)."""
    t, d = x_flat.shape
    if chunks <= 1 or t % chunks:
        return _gmm_pairs(x_flat, top_p, top_i, w_in, w_gate, w_out, cfg, binding)
    k = cfg.top_k
    xs = x_flat.reshape(chunks, t // chunks, d)
    tps = top_p.reshape(chunks, t // chunks, k)
    tis = top_i.reshape(chunks, t // chunks, k)

    def body(_, inp):
        xi, tpi, tii = inp
        return None, _gmm_pairs(xi, tpi, tii, w_in, w_gate, w_out, cfg, binding)

    _, ys = jax.lax.scan(body, None, (xs, tps, tis),
                         unroll=chunks if unroll else 1)
    return ys.reshape(t, -1)


def moe_apply(
    params,
    x: jnp.ndarray,                 # (B, S, D)
    cfg: ModelConfig,
    pctx: ParallelCtx,
    binding,
    *,
    oracle: bool = False,
    with_aux: bool = False,
    token_chunks: int = 1,
    unroll: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    probs, top_p, top_i = _route(x_flat, params["router"], cfg.top_k)
    aux = (
        _load_balance_aux(probs, top_i, cfg.num_experts)
        if with_aux
        else jnp.zeros((), jnp.float32)
    )

    if oracle:
        y = _dense_oracle(x_flat, top_p, top_i, params, cfg)
    elif not (pctx.active and pctx.model_axis):
        y = _gmm_chunked(
            x_flat, top_p, top_i,
            params["w_in"], params["w_gate"], params["w_out"], cfg, binding,
            token_chunks, unroll,
        )
    else:
        mesh = pctx.mesh
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        batch_axes = tuple(a for a in pctx.batch_axes if a in axis_sizes)
        dp = 1
        for a in batch_axes:
            dp *= axis_sizes[a]
        shard_tokens = dp > 1 and (b * s) % dp == 0
        token_spec = P(batch_axes if shard_tokens else None, None)
        tk_spec = P(batch_axes if shard_tokens else None, None)
        m = pctx.model_axis
        w3 = P(None, None, m)          # (E, D, F): ff sharded over model
        w_out_spec = P(None, m, None)  # (E, F, D)

        def local(xl, tp, ti, w_in, w_gate, w_out):
            y = _gmm_chunked(xl, tp, ti, w_in, w_gate, w_out, cfg, binding,
                             token_chunks, unroll)
            return jax.lax.psum(y, m)

        from repro.distributed.collectives import compat_shard_map

        y = compat_shard_map(
            local,
            mesh=mesh,
            in_specs=(token_spec, tk_spec, tk_spec, w3, w3, w_out_spec),
            out_specs=token_spec,
            check_vma=False,
        )(x_flat, top_p, top_i, params["w_in"], params["w_gate"], params["w_out"])

    if cfg.n_shared_experts:
        y = y + mlp_apply(params["shared"], x, cfg).reshape(b * s, d).astype(y.dtype)
    return y.reshape(b, s, d).astype(x.dtype), aux
