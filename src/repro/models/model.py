"""Model assembly: one composable decoder covers all ten architectures.

The decoder stack is a `lax.scan` over *pattern blocks*: the layer pattern
repeats with period p = lcm(attn_every, moe_every) (p=1 for homogeneous
stacks, p=8 for Jamba's [attn, mamba x7] interleave with MoE every 2nd
layer).  Each position j in the pattern has its own parameter group,
stacked over num_layers/p — so a qwen2-72b traces ONE layer body, not 80.

Modes:
  train   — full-sequence forward, cross-entropy loss (labels shifted by
            the data pipeline), remat per cfg.remat.
  prefill — full-sequence forward, emits the KV/SSM caches + last logits.
  decode  — one token against the caches (the decode_32k / long_500k cells).

Modality stubs per assignment: vlm consumes precomputed patch embeddings
(prepended to token embeddings), audio consumes precomputed frame
embeddings through a bidirectional encoder (whisper enc-dec).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models.moe import moe_apply, moe_schema
from repro.models.schema import LeafSpec, abstract_params, init_params, map_leaves
from repro.models.ssm import (
    ssm_apply,
    ssm_decode,
    ssm_init_cache_shapes,
    ssm_prefill_chunk,
    ssm_schema,
)

__all__ = ["Model", "build_model"]

Tree = dict[str, Any]


def _stack(tree: Tree, n: int) -> Tree:
    return map_leaves(
        lambda _, s: dataclasses.replace(s, shape=(n,) + s.shape, axes=("layers",) + s.axes),
        tree,
    )


# Static KV-cache calibration: attention k/v projections from scaled-init
# weights land well inside |x| < 8 after rotary, so the quantized cache
# uses one conservative amax for every slot (scale = amax / format-top).
# A static scale is what lets the (B,) scale vector live as a cache leaf
# and ride the kernels' SMEM scale-meta rows unchanged across steps.
KV_CALIBRATION_AMAX = 8.0


class Model:
    def __init__(
        self,
        cfg: ModelConfig,
        binding=None,
        pctx: L.ParallelCtx | None = None,
        *,
        moe_oracle: bool = False,
        scan_unroll: bool = False,
        head_pad_multiple: int | None = None,
        moe_token_chunks: int = 1,
        loss_seq_chunks: int = 1,
        kv_quantize: str | None = None,
    ):
        if binding is None:
            from repro.kernels.ops import default_binding

            binding = default_binding()
        self.cfg = cfg
        self.binding = binding
        self.pctx = pctx or L.ParallelCtx()
        self.moe_oracle = moe_oracle
        self.kv_quantize = kv_quantize
        self.kv_storage_dtype = None
        self.kv_scale_init = None
        if kv_quantize is not None:
            from repro.kernels.quant import (
                FORMATS, FP8_MAX, INT8_MAX, storage_dtype)

            if kv_quantize not in FORMATS:
                raise ValueError(
                    f"kv_quantize must be one of {FORMATS}, got {kv_quantize!r}")
            if cfg.is_enc_dec or cfg.modality == "vision":
                raise NotImplementedError(
                    "quantized KV cache supports text decoders only")
            self.kv_storage_dtype = str(jnp.dtype(storage_dtype(kv_quantize)))
            top = INT8_MAX if kv_quantize == "int8" else FP8_MAX
            self.kv_scale_init = KV_CALIBRATION_AMAX / top
        # dry-run sets scan_unroll: XLA cost_analysis does not multiply
        # while-loop bodies by trip count, so the roofline pass unrolls.
        self.scan_unroll = scan_unroll
        # Megatron-style vocab padding: embedding/head tables are padded to
        # a multiple of 128 so the vocab dim shards evenly on any assigned
        # mesh axis; padded logit columns are masked to -inf.  The model's
        # *interface* vocab (token ids, labels) is the published size.
        self.padded_vocab = -(-cfg.vocab_size // 128) * 128
        # Group-aligned head padding: when num_heads doesn't divide the TP
        # degree, XLA falls back to head_dim sharding and every score
        # einsum contracts the sharded dim -> multi-GB all-reduces per
        # attention (measured: 10.7 GB fp32 ARs on qwen2.5's 40 heads @
        # TP16).  We pad the GQA *group* width g -> g' (smallest g' >= g
        # with KV*g' % tp == 0), keeping the q-head -> kv-head mapping
        # h // g' exact; padded slots are zero-init and output-masked, so
        # the padded model is numerically identical to the unpadded one.
        tp = head_pad_multiple
        if tp is None and self.pctx.active and self.pctx.model_axis:
            tp = dict(zip(self.pctx.mesh.axis_names,
                          self.pctx.mesh.devices.shape))[self.pctx.model_axis]
        tp = tp or 1
        self.q_group = (cfg.num_heads // cfg.num_kv_heads) if cfg.num_kv_heads else 0
        gp = self.q_group
        if cfg.num_heads and cfg.num_heads % tp:
            while gp * cfg.num_kv_heads % tp:
                gp += 1
        self.q_group_padded = gp
        self.padded_heads = gp * cfg.num_kv_heads if cfg.num_kv_heads else 0
        self.moe_token_chunks = moe_token_chunks
        self.loss_seq_chunks = loss_seq_chunks
        self.use_rope = cfg.family != "audio"
        p = 1
        if cfg.family == "hybrid":
            p = cfg.attn_every
        if cfg.num_experts and cfg.moe_every > 1:
            import math

            p = math.lcm(p, cfg.moe_every)
        assert cfg.num_layers % p == 0, (cfg.num_layers, p)
        self.period = p
        self.num_blocks = cfg.num_layers // p

    # ------------------------------------------------------------------ #
    # schema
    # ------------------------------------------------------------------ #
    def _layer_schema(self, j: int) -> Tree:
        cfg = self.cfg
        sch: Tree = {"pre_norm": L.norm_schema(cfg)}
        if cfg.is_attn_layer(j):
            sch["attn"] = L.attention_schema(cfg, n_heads=self.padded_heads)
        else:
            sch["ssm"] = ssm_schema(cfg)
        if cfg.is_enc_dec:
            sch["cross_norm"] = L.norm_schema(cfg)
            sch["cross_attn"] = L.attention_schema(cfg, n_heads=self.padded_heads)
        if cfg.d_ff or cfg.num_experts:
            sch["post_norm"] = L.norm_schema(cfg)
            if cfg.is_moe_layer(j):
                sch["moe"] = moe_schema(cfg)
            elif cfg.d_ff:
                sch["mlp"] = L.mlp_schema(cfg)
        return sch

    def schema(self) -> Tree:
        cfg = self.cfg
        sch: Tree = {
            "embed": {
                "tok": LeafSpec(
                    (self.padded_vocab, cfg.d_model), ("vocab", "embed"), scale=0.01
                )
            },
            "decoder": {
                f"p{j}": _stack(self._layer_schema(j), self.num_blocks)
                for j in range(self.period)
            },
            "final_norm": L.norm_schema(cfg),
        }
        if not cfg.tie_embeddings:
            sch["lm_head"] = {
                "w": LeafSpec((cfg.d_model, self.padded_vocab), ("embed", "vocab"),
                              init="scaled")
            }
        if cfg.is_enc_dec:
            enc_layer = {
                "pre_norm": L.norm_schema(cfg),
                "attn": L.attention_schema(cfg, n_heads=self.padded_heads),
                "post_norm": L.norm_schema(cfg),
                "mlp": L.mlp_schema(cfg),
            }
            sch["encoder"] = {
                "layers": _stack(enc_layer, cfg.encoder_layers),
                "final_norm": L.norm_schema(cfg),
            }
        return sch

    def init(self, key: jax.Array) -> Tree:
        return init_params(self.schema(), key, self.cfg.dtype)

    def abstract_params(self) -> Tree:
        return abstract_params(self.schema(), self.cfg.dtype)

    # ------------------------------------------------------------------ #
    # caches
    # ------------------------------------------------------------------ #
    def cache_shapes(self, batch: int, max_len: int) -> Tree:
        """Per-pattern-position cache entry shapes, stacked over blocks."""
        cfg = self.cfg
        nb = self.num_blocks
        out: Tree = {}
        for j in range(self.period):
            entry: Tree = {}
            if cfg.is_attn_layer(j):
                kv_shape = (nb, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
                kv_dt = self.kv_storage_dtype or cfg.dtype
                entry["k"] = (kv_shape, kv_dt)
                entry["v"] = (kv_shape, kv_dt)
                if self.kv_quantize:
                    entry["k_scale"] = ((nb, batch), "float32")
                    entry["v_scale"] = ((nb, batch), "float32")
            else:
                ss = ssm_init_cache_shapes(cfg, batch)
                entry["state"] = ((nb,) + ss["state"][0], ss["state"][1])
                entry["conv"] = ((nb,) + ss["conv"][0], ss["conv"][1])
            if cfg.is_enc_dec:
                ckv = (nb, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
                entry["ck"] = (ckv, cfg.dtype)
                entry["cv"] = (ckv, cfg.dtype)
            out[f"p{j}"] = entry
        return out

    @staticmethod
    def _to_abstract(t):
        if isinstance(t, tuple) and len(t) == 2 and isinstance(t[0], tuple):
            return jax.ShapeDtypeStruct(t[0], jnp.dtype(t[1]))
        return {k: Model._to_abstract(v) for k, v in t.items()}

    def abstract_cache(self, batch: int, max_len: int) -> Tree:
        return self._to_abstract(self.cache_shapes(batch, max_len))

    def _init_cache_tree(self, abstract: Tree) -> Tree:
        """Zeros everywhere except the quantized cache's scale leaves,
        which start at the static calibration (a zero scale would blow up
        the first quantized write)."""
        return {
            pj: {
                name: (jnp.full(s.shape, self.kv_scale_init, s.dtype)
                       if name in ("k_scale", "v_scale")
                       else jnp.zeros(s.shape, s.dtype))
                for name, s in entry.items()
            }
            for pj, entry in abstract.items()
        }

    def init_cache(self, batch: int, max_len: int) -> Tree:
        return self._init_cache_tree(self.abstract_cache(batch, max_len))

    def paged_cache_shapes(self, num_pages: int, page_size: int, slots: int) -> Tree:
        """Paged-cache entry shapes: attention k/v become page *pools*
        (nb, num_pages, page_size, KV, Dh) shared by all slots and
        addressed through per-slot block tables; recurrent (SSM) state is
        O(1) per slot and stays slot-indexed exactly as in `cache_shapes`.
        """
        cfg = self.cfg
        if cfg.is_enc_dec or cfg.modality == "vision":
            raise NotImplementedError("paged cache supports text decoders only")
        nb = self.num_blocks
        out: Tree = {}
        for j in range(self.period):
            entry: Tree = {}
            if cfg.is_attn_layer(j):
                kv_shape = (nb, num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
                kv_dt = self.kv_storage_dtype or cfg.dtype
                entry["k"] = (kv_shape, kv_dt)
                entry["v"] = (kv_shape, kv_dt)
                if self.kv_quantize:
                    entry["k_scale"] = ((nb, slots), "float32")
                    entry["v_scale"] = ((nb, slots), "float32")
            else:
                ss = ssm_init_cache_shapes(cfg, slots)
                entry["state"] = ((nb,) + ss["state"][0], ss["state"][1])
                entry["conv"] = ((nb,) + ss["conv"][0], ss["conv"][1])
            out[f"p{j}"] = entry
        return out

    def abstract_paged_cache(self, num_pages: int, page_size: int, slots: int) -> Tree:
        return self._to_abstract(self.paged_cache_shapes(num_pages, page_size, slots))

    def init_paged_cache(self, num_pages: int, page_size: int, slots: int) -> Tree:
        return self._init_cache_tree(
            self.abstract_paged_cache(num_pages, page_size, slots))

    def export_paged_slot(self, cache: Tree, pages, slot: int) -> dict:
        """One slot's state out of a paged cache, as host numpy arrays.

        ``pages`` is the slot's leased page ids in block-table order
        (only the written prefix — the KV-handoff sender passes
        ``block_tables[slot][:pages_used]``).  Attention k/v pools yield
        ``(nb, len(pages), page_size, KV, Dh)`` page stacks; SSM leaves
        yield the slot's row.  Keys are ``"p{j}/{leaf}"`` — the flat
        naming the `KVHandoff` artifact serializes.
        """
        import numpy as np

        pages = np.asarray(pages, dtype=np.int32)
        out: dict = {}
        for pj, entry in cache.items():
            for name, buf in entry.items():
                arr = np.asarray(buf)
                out[f"{pj}/{name}"] = (arr[:, pages] if name in ("k", "v")
                                       else arr[:, slot])
        return out

    def import_paged_slot(self, cache: Tree, arrays: Mapping[str, Any],
                          pages, slot: int) -> Tree:
        """Scatter an exported slot into this cache's own pages.

        The receiver leased ``pages`` (same count, any ids) from its own
        allocator; page numbering does not survive the trip.  Returns the
        updated cache tree; shapes are validated leaf-by-leaf so a
        mismatched artifact fails before any buffer is written.
        """
        import numpy as np

        pages_ix = jnp.asarray(np.asarray(pages, dtype=np.int32))
        new: Tree = {}
        for pj, entry in cache.items():
            upd_entry: Tree = {}
            for name, buf in entry.items():
                key = f"{pj}/{name}"
                if key not in arrays:
                    raise ValueError(f"paged-slot import: missing leaf {key}")
                src = jnp.asarray(arrays[key], dtype=buf.dtype)
                if name in ("k", "v"):
                    want = (buf.shape[0], len(pages)) + buf.shape[2:]
                    if src.shape != want:
                        raise ValueError(
                            f"paged-slot import: {key} is {src.shape}, "
                            f"target pages need {want}")
                    upd_entry[name] = buf.at[:, pages_ix].set(src)
                else:
                    want = (buf.shape[0],) + buf.shape[2:]
                    if src.shape != want:
                        raise ValueError(
                            f"paged-slot import: {key} is {src.shape}, "
                            f"slot row needs {want}")
                    upd_entry[name] = buf.at[:, slot].set(src)
            new[pj] = upd_entry
        return new

    # ------------------------------------------------------------------ #
    # layer application
    # ------------------------------------------------------------------ #
    def _layer(self, j, lp, x, mode, lc, pos, enc_out, positions, aux,
               n_valid=None, active=None, block_tables=None, window=None):
        cfg, binding = self.cfg, self.binding
        new_cache: Tree = {}
        h = L.norm_apply(lp["pre_norm"], x, cfg, binding)
        rg = (self.q_group, self.q_group_padded)
        if cfg.is_attn_layer(j):
            if mode in ("decode", "chunk"):
                attn_cache = {"k": lc["k"], "v": lc["v"]}
                if "k_scale" in lc:
                    attn_cache["k_scale"] = lc["k_scale"]
                    attn_cache["v_scale"] = lc["v_scale"]
                apply = (L.attention_decode if mode == "decode"
                         else L.attention_chunk)
                y, kv = apply(
                    lp["attn"], h, attn_cache, pos, cfg, binding,
                    use_rope=self.use_rope, pctx=self.pctx, real_group=rg,
                    block_tables=block_tables, window=window,
                )
                new_cache.update(kv)
            else:
                y, kv = L.attention_apply(
                    lp["attn"], h, cfg, binding, positions=positions,
                    causal=True, use_rope=self.use_rope, pctx=self.pctx,
                    real_group=rg,
                )
                if mode == "prefill":
                    if self.kv_quantize:
                        sc = jnp.full((h.shape[0],), self.kv_scale_init,
                                      jnp.float32)
                        sd = jnp.dtype(self.kv_storage_dtype)
                        new_cache["k"] = L._quant_update(kv["k"], sc, sd)
                        new_cache["v"] = L._quant_update(kv["v"], sc, sd)
                        new_cache["k_scale"] = sc
                        new_cache["v_scale"] = sc
                    else:
                        new_cache["k"] = kv["k"].astype(jnp.dtype(cfg.dtype))
                        new_cache["v"] = kv["v"].astype(jnp.dtype(cfg.dtype))
        else:
            if mode == "decode":
                y, sc = ssm_decode(lp["ssm"], h, {"state": lc["state"], "conv": lc["conv"]}, cfg)
                if active is not None:
                    # inactive slots must not advance: unlike KV (whose
                    # parked write is harmless), the SSM recurrence would
                    # fold the dummy token into the state irreversibly
                    sc = {
                        "state": jnp.where(active[:, None, None, None],
                                           sc["state"], lc["state"]),
                        "conv": jnp.where(active[:, None, None],
                                          sc["conv"], lc["conv"]),
                    }
                new_cache.update(sc)
            elif mode == "chunk":
                y, sc = ssm_prefill_chunk(
                    lp["ssm"], h, {"state": lc["state"], "conv": lc["conv"]},
                    pos, n_valid, cfg, binding,
                )
                new_cache.update(sc)
            elif mode == "prefill":
                y, sc = ssm_apply(lp["ssm"], h, cfg, binding, return_state=True)
                new_cache["state"] = sc["state"]
                new_cache["conv"] = sc["conv"]
            else:
                y = ssm_apply(lp["ssm"], h, cfg, binding)
        x = x + y

        if cfg.is_enc_dec:
            h = L.norm_apply(lp["cross_norm"], x, cfg, binding)
            if mode == "decode":
                y, _ = L.attention_decode(
                    lp["cross_attn"], h, {"k": lc["ck"], "v": lc["cv"]}, pos, cfg,
                    binding, use_rope=False, cross=True, pctx=self.pctx,
                    real_group=rg,
                )
                new_cache["ck"] = lc["ck"]
                new_cache["cv"] = lc["cv"]
            else:
                y, ckv = L.attention_apply(
                    lp["cross_attn"], h, cfg, binding, causal=False,
                    kv_source=enc_out, use_rope=False, pctx=self.pctx,
                    real_group=rg,
                )
                if mode == "prefill":
                    new_cache["ck"] = ckv["k"].astype(jnp.dtype(cfg.dtype))
                    new_cache["cv"] = ckv["v"].astype(jnp.dtype(cfg.dtype))
            x = x + y

        if cfg.d_ff or cfg.num_experts:
            if "moe" in lp or "mlp" in lp:
                h = L.norm_apply(lp["post_norm"], x, cfg, binding)
                if "moe" in lp:
                    y, layer_aux = moe_apply(
                        lp["moe"], h, cfg, self.pctx, binding,
                        oracle=self.moe_oracle, with_aux=(mode == "train"),
                        token_chunks=self.moe_token_chunks,
                        unroll=self.scan_unroll,
                    )
                    aux = aux + layer_aux
                else:
                    y = L.mlp_apply(lp["mlp"], h, cfg, binding)
                x = x + y
        x = self.pctx.constrain_residual(x)
        return x, (new_cache if mode in ("prefill", "decode", "chunk") else None), aux

    # ------------------------------------------------------------------ #
    # decoder stack
    # ------------------------------------------------------------------ #
    def _decoder(self, params, x, mode, cache=None, pos=None, enc_out=None,
                 positions=None, n_valid=None, active=None, block_tables=None,
                 window=None):
        cfg = self.cfg
        p = self.period
        unroll = self.num_blocks if self.scan_unroll else 1
        aux0 = jnp.zeros((), jnp.float32)

        if mode == "decode" and self.scan_unroll:
            # measurement mode: the xs->ys formulation — XLA cost analysis
            # charges dynamic_update_slice ~2x the FULL buffer (measured),
            # which would inflate the carry path's memory term ~30x; the
            # slab-wise ys traffic is the honest per-step cost.
            def dec_ys(carry, xs):
                x, aux = carry
                bp, bc = xs
                ncs: Tree = {}
                for j in range(p):
                    x, nc, aux = self._layer(
                        j, bp[f"p{j}"], x, mode, bc[f"p{j}"], pos, enc_out,
                        positions, aux
                    )
                    ncs[f"p{j}"] = nc
                return (x, aux), ncs

            (x, aux), new_cache = jax.lax.scan(
                dec_ys, (x, aux0), (params["decoder"], cache), unroll=unroll,
            )
            return x, new_cache, aux

        if mode in ("decode", "chunk"):
            # deployment mode: cache rides in the CARRY and is updated in
            # place with dynamic_update_slice — XLA keeps while-loop
            # carries aliased, so decode never materializes a second full
            # KV cache (the xs->ys formulation cannot alias across the
            # loop boundary; measured +5.4 GB temp on qwen2-72b decode_32k).
            # Chunked prefill reuses the same formulation: C tokens instead
            # of 1, same in-place cache discipline.
            def dec_block(carry, bp):
                x, aux, cache_st, i = carry
                new_cache = cache_st
                for j in range(p):
                    lc = jax.tree.map(
                        lambda c: jax.lax.dynamic_index_in_dim(
                            c, i, axis=0, keepdims=False
                        ),
                        new_cache[f"p{j}"],
                    )
                    x, nc, aux = self._layer(
                        j, bp[f"p{j}"], x, mode, lc, pos, enc_out, positions, aux,
                        n_valid=n_valid, active=active, block_tables=block_tables,
                        window=window,
                    )
                    new_cache = dict(new_cache)
                    new_cache[f"p{j}"] = jax.tree.map(
                        lambda buf, upd: jax.lax.dynamic_update_slice_in_dim(
                            buf, upd[None].astype(buf.dtype), i, axis=0
                        ),
                        new_cache[f"p{j}"],
                        nc,
                    )
                return (x, aux, new_cache, i + 1), None

            (x, aux, new_cache, _), _ = jax.lax.scan(
                dec_block, (x, aux0, cache, jnp.int32(0)), params["decoder"],
                unroll=unroll,
            )
            return x, new_cache, aux

        def block_fn(carry, xs):
            x, aux = carry
            bp = xs
            ncs: Tree = {}
            for j in range(p):
                x, nc, aux = self._layer(
                    j, bp[f"p{j}"], x, mode, None, pos, enc_out, positions, aux
                )
                if nc is not None:
                    ncs[f"p{j}"] = nc
            return (x, aux), (ncs if ncs else None)

        if mode == "train" and cfg.remat != "none":
            policy = (
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                if cfg.remat == "dots"
                else jax.checkpoint_policies.nothing_saveable
            )
            block_fn = jax.checkpoint(block_fn, policy=policy)

        (x, aux), new_cache = jax.lax.scan(
            block_fn, (x, aux0), params["decoder"], unroll=unroll,
        )
        return x, new_cache, aux

    def _encoder(self, params, frames):
        cfg, binding = self.cfg, self.binding
        x = frames + L.sinusoidal_positions(frames.shape[1], cfg.d_model).astype(frames.dtype)

        def enc_fn(x, lp):
            h = L.norm_apply(lp["pre_norm"], x, cfg, binding)
            y, _ = L.attention_apply(
                lp["attn"], h, cfg, binding, causal=False, use_rope=False,
                pctx=self.pctx, real_group=(self.q_group, self.q_group_padded),
            )
            x = x + y
            h = L.norm_apply(lp["post_norm"], x, cfg, binding)
            x = x + L.mlp_apply(lp["mlp"], h, cfg, binding)
            return x, None

        if cfg.remat != "none":
            enc_fn = jax.checkpoint(enc_fn)
        x, _ = jax.lax.scan(
            enc_fn, x, params["encoder"]["layers"],
            unroll=cfg.encoder_layers if self.scan_unroll else 1,
        )
        return L.norm_apply(params["encoder"]["final_norm"], x, cfg, binding)

    # ------------------------------------------------------------------ #
    # embeddings + logits
    # ------------------------------------------------------------------ #
    def _embed(self, params, tokens, offset: jnp.ndarray | int = 0):
        tok = L.dequant_param(params["embed"]["tok"], jnp.dtype(self.cfg.dtype))
        x = jnp.take(tok, tokens, axis=0)
        if self.cfg.family == "audio":
            x = x + L.sinusoidal_positions(
                tokens.shape[1], self.cfg.d_model, offset
            ).astype(x.dtype)
        return x

    def _logits(self, params, x):
        if self.cfg.tie_embeddings:
            # tied head reuses the (vocab, d) embedding; its axis-0 scales
            # do not match quant_matmul's per-output-channel layout after
            # the transpose, so the tied path always densifies.
            w = L.dequant_param(params["embed"]["tok"], x.dtype).T
        else:
            w = params["lm_head"]["w"]
        if isinstance(w, dict) and "quant_matmul" in self.binding:
            b, s, d = x.shape
            logits = self.binding["quant_matmul"](
                x.reshape(b * s, d), w["q"], w["scale"]
            ).reshape(b, s, -1).astype(jnp.float32)
        else:
            logits = jnp.einsum(
                "bsd,dv->bsv", x, L.dequant_param(w, x.dtype)
            ).astype(jnp.float32)
        if self.padded_vocab != self.cfg.vocab_size:
            mask = jnp.arange(self.padded_vocab) < self.cfg.vocab_size
            logits = jnp.where(mask, logits, -1e9)
        if self.pctx.active and self.pctx.model_axis:
            from jax.sharding import PartitionSpec as P

            logits = self.pctx.constrain(
                logits, P(self.pctx.batch_axes or None, None, self.pctx.model_axis)
            )
        return logits

    def _assemble_inputs(self, params, batch):
        """Token/modality fusion -> (x, enc_out, text_offset)."""
        cfg = self.cfg
        enc_out = None
        if cfg.is_enc_dec:
            enc_out = self._encoder(params, batch["frames"])
            x = self._embed(params, batch["tokens"])
            offset = 0
        elif cfg.modality == "vision":
            tok = self._embed(params, batch["tokens"])
            x = jnp.concatenate([batch["patch_embeds"].astype(tok.dtype), tok], axis=1)
            offset = batch["patch_embeds"].shape[1]
        else:
            x = self._embed(params, batch["tokens"])
            offset = 0
        return x, enc_out, offset

    # ------------------------------------------------------------------ #
    # public entry points
    # ------------------------------------------------------------------ #
    def loss_fn(self, params, batch):
        cfg = self.cfg
        x, enc_out, offset = self._assemble_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        x, _, aux = self._decoder(params, x, "train", enc_out=enc_out,
                                  positions=positions)
        x = L.norm_apply(params["final_norm"], x, cfg, self.binding)
        if offset:
            x = x[:, offset:, :]
        labels = batch["labels"]
        nll_sum = self._chunked_nll(params, x, labels)
        loss = nll_sum / (labels.shape[0] * labels.shape[1])
        if cfg.num_experts:
            loss = loss + 0.01 * aux / max(cfg.num_layers, 1)
        return loss, {"loss": loss, "aux": aux}

    def _chunked_nll(self, params, x, labels):
        """Cross-entropy with sequence-chunked logits.

        Full fp32 logits are (B, S, V) — for moonshot's 163k vocab that is
        ~8 GB of live softmax buffers per device.  Chunking the sequence
        recomputes each chunk's logits in the backward (jax.checkpoint),
        holding only (B, S/c, V) alive: the standard large-vocab loss."""
        b, s, _ = x.shape
        chunks = self.loss_seq_chunks
        if chunks <= 1 or s % chunks:
            logits = self._logits(params, x)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
            return nll.sum()

        xs = x.reshape(b, chunks, s // chunks, -1).swapaxes(0, 1)
        ls = labels.reshape(b, chunks, s // chunks).swapaxes(0, 1)

        @jax.checkpoint
        def chunk_nll(acc, inp):
            xc, lc = inp
            logits = self._logits(params, xc)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0]
            return acc + nll.sum(), None

        total, _ = jax.lax.scan(
            chunk_nll, jnp.zeros((), jnp.float32), (xs, ls),
            unroll=chunks if self.scan_unroll else 1,
        )
        return total

    def prefill(self, params, batch):
        cfg = self.cfg
        x, enc_out, _ = self._assemble_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        x, cache, _ = self._decoder(params, x, "prefill", enc_out=enc_out,
                                    positions=positions)
        x = L.norm_apply(params["final_norm"], x, cfg, self.binding)
        logits = self._logits(params, x[:, -1:, :])[:, 0]
        return logits, cache

    def prefill_into(self, params, tokens, cache, slot, pos, n_valid=None,
                     block_row=None, window=None):
        """Chunked prefill: advance ONE slot of a batched cache by C tokens.

        The compiled unit of prompt ingestion — a fixed-shape step the
        scheduler calls ceil(prompt_len / C) times per request, instead of
        O(prompt_len) whole-batch decode ticks.  Compiles once per chunk
        width C; slot / pos / n_valid are traced, so every request reuses
        the same executable.

        Args:
          tokens: (1, C) int32 — the chunk, right-padded to C.
          cache: batched cache from `init_cache(batch, max_len)`; only the
            `slot` row is read or written.
          slot: () int32 — batch row to fill.
          pos: () int32 — global position of tokens[:, 0] (0 for the first
            chunk; the caller must guarantee pos + C <= max_len, or the
            in-bounds-clamped cache write would corrupt neighbor slots).
          n_valid: () int32 — real tokens in this chunk (defaults to C);
            < C only for the prompt's final partial chunk.  At pos == 0
            stale slot state (KV garbage, SSM state, conv tail) is
            neutralized inside the step — slot reuse needs no reset pass.

        Returns (logits (1, vocab) for token n_valid-1, updated cache).
        The logits seed the request's first generated token: sampling from
        them replaces the decode tick the old prefill-by-decode loop burned
        re-feeding the last prompt token.

        With `block_row` (this slot's (nblocks,) int32 block-table row)
        the cache is paged (`init_paged_cache`): the k/v pools are shared
        by all slots, so they are passed to the decoder whole and written
        back whole — only the per-slot recurrent (SSM) leaves are sliced
        and scattered at `slot` as in the contiguous path.

        With `window` (() int32, traced) each chunk query attends only its
        trailing `window` keys (sliding-window attention) — pages wholly
        behind the window may already have been released by the scheduler.
        """
        cfg = self.cfg
        if cfg.is_enc_dec or cfg.modality == "vision":
            raise NotImplementedError("chunked prefill supports text decoders only")
        if n_valid is None:
            n_valid = tokens.shape[1]
        n_valid = jnp.asarray(n_valid, jnp.int32)
        slot = jnp.asarray(slot, jnp.int32)
        pos = jnp.asarray(pos, jnp.int32)
        paged = block_row is not None
        if paged:
            row = {
                pj: {
                    name: (buf if name in ("k", "v")
                           else jax.lax.dynamic_slice_in_dim(buf, slot, 1, axis=1))
                    for name, buf in entry.items()
                }
                for pj, entry in cache.items()
            }
        else:
            row = jax.tree.map(
                lambda buf: jax.lax.dynamic_slice_in_dim(buf, slot, 1, axis=1),
                cache,
            )
        x = self._embed(params, tokens)
        x, new_row, _ = self._decoder(params, x, "chunk", cache=row, pos=pos,
                                      n_valid=n_valid, block_tables=block_row,
                                      window=window)
        x = L.norm_apply(params["final_norm"], x, cfg, self.binding)
        last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
        logits = self._logits(params, last)[:, 0]
        if paged:
            cache = {
                pj: {
                    name: (upd.astype(cache[pj][name].dtype)
                           if name in ("k", "v")
                           else jax.lax.dynamic_update_slice_in_dim(
                               cache[pj][name], upd.astype(cache[pj][name].dtype),
                               slot, axis=1))
                    for name, upd in entry.items()
                }
                for pj, entry in new_row.items()
            }
        else:
            cache = jax.tree.map(
                lambda buf, upd: jax.lax.dynamic_update_slice_in_dim(
                    buf, upd.astype(buf.dtype), slot, axis=1
                ),
                cache, new_row,
            )
        return logits, cache

    def decode(self, params, token, cache, pos, active=None, block_tables=None,
               window=None):
        """token: (B, 1) int32; pos: () or (B,) int32 — per-slot positions
        under continuous batching; active: optional (B,) bool — rows whose
        recurrent (SSM) state may advance.  Inactive rows keep their state;
        their KV write lands wherever the scheduler parks pos (by
        convention max_len-1, a slot admission never lets live data reach;
        paged: table row all zeros, the write lands in the park page).
        block_tables: optional (B, nblocks) int32 — the cache is paged.
        window: optional () or (B,) int32 — sliding-window decode: only
        the trailing `window` cache slots are attended, so out-of-window
        pages may already have been released to other slots.
        """
        cfg = self.cfg
        x = self._embed(params, token, offset=pos)
        x, new_cache, _ = self._decoder(params, x, "decode", cache=cache, pos=pos,
                                        active=active, block_tables=block_tables,
                                        window=window)
        x = L.norm_apply(params["final_norm"], x, cfg, self.binding)
        logits = self._logits(params, x)[:, 0]
        return logits, new_cache

    # ------------------------------------------------------------------ #
    # input specs (ShapeDtypeStruct stand-ins for the dry-run)
    # ------------------------------------------------------------------ #
    def input_specs(self, shape: ShapeConfig) -> Tree:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sd = jax.ShapeDtypeStruct
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "train":
            if cfg.is_enc_dec:
                return {"frames": sd((b, s, cfg.d_model), dt),
                        "tokens": sd((b, s), i32), "labels": sd((b, s), i32)}
            if cfg.modality == "vision":
                p = cfg.n_patches
                return {"patch_embeds": sd((b, p, cfg.d_model), dt),
                        "tokens": sd((b, s - p), i32), "labels": sd((b, s - p), i32)}
            return {"tokens": sd((b, s), i32), "labels": sd((b, s), i32)}
        if shape.kind == "prefill":
            if cfg.is_enc_dec:
                return {"frames": sd((b, s, cfg.d_model), dt), "tokens": sd((b, s), i32)}
            if cfg.modality == "vision":
                p = cfg.n_patches
                return {"patch_embeds": sd((b, p, cfg.d_model), dt),
                        "tokens": sd((b, s - p), i32)}
            return {"tokens": sd((b, s), i32)}
        # decode: one new token against a cache of seq_len
        return {
            "token": sd((b, 1), i32),
            "cache": self.abstract_cache(b, s),
            "pos": sd((), i32),
        }


def build_model(cfg: ModelConfig, binding=None, pctx=None, **kw) -> Model:
    return Model(cfg, binding=binding, pctx=pctx, **kw)
