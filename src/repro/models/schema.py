"""Parameter schema: one declarative source for init / abstract / sharding.

Every parameter leaf is declared once as a `LeafSpec` (shape + logical axis
names + initializer).  From the same schema tree we derive:

  * `init_params`      — materialized arrays (smoke tests, examples);
  * `abstract_params`  — ShapeDtypeStructs (dry-run: no allocation, the
                         qwen2-72b table never touches host RAM);
  * sharding specs     — via distributed.sharding rules mapping logical
                         axes ("heads", "ff", "vocab", ...) to mesh axes.

This mirrors how the Bundle stays hardware-agnostic: the schema is part of
the portable program; the logical->mesh mapping is injected at deployment.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["LeafSpec", "init_params", "abstract_params", "map_leaves", "leaf_items"]

Tree = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis name per dim
    init: str = "normal"                  # normal | zeros | ones | scaled
    scale: float = 0.02
    dtype: str | None = None              # None -> model default

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")

    def materialize(self, key: jax.Array, default_dtype: jnp.dtype) -> jax.Array:
        dtype = jnp.dtype(self.dtype) if self.dtype else default_dtype
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "normal":
            return (jax.random.normal(key, self.shape) * self.scale).astype(dtype)
        if self.init == "scaled":  # fan-in scaled
            fan_in = self.shape[0] if self.shape else 1
            s = 1.0 / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, self.shape) * s).astype(dtype)
        raise ValueError(f"unknown init {self.init!r}")

    def abstract(self, default_dtype: jnp.dtype) -> jax.ShapeDtypeStruct:
        dtype = jnp.dtype(self.dtype) if self.dtype else default_dtype
        return jax.ShapeDtypeStruct(self.shape, dtype)


def leaf_items(tree: Tree, prefix: str = "") -> list[tuple[str, LeafSpec]]:
    out: list[tuple[str, LeafSpec]] = []
    for k, v in tree.items():
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, LeafSpec):
            out.append((path, v))
        else:
            out.extend(leaf_items(v, path))
    return out


def map_leaves(fn: Callable[[str, LeafSpec], Any], tree: Tree, prefix: str = "") -> Tree:
    out: Tree = {}
    for k, v in tree.items():
        path = f"{prefix}/{k}" if prefix else k
        out[k] = fn(path, v) if isinstance(v, LeafSpec) else map_leaves(fn, v, path)
    return out


def init_params(schema: Tree, key: jax.Array, default_dtype: str) -> Tree:
    dd = jnp.dtype(default_dtype)
    leaves = leaf_items(schema)
    keys = jax.random.split(key, max(len(leaves), 1))
    key_of = {path: keys[i] for i, (path, _) in enumerate(leaves)}
    return map_leaves(lambda p, s: s.materialize(key_of[p], dd), schema)


def abstract_params(schema: Tree, default_dtype: str) -> Tree:
    dd = jnp.dtype(default_dtype)
    return map_leaves(lambda _, s: s.abstract(dd), schema)
