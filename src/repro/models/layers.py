"""Composable transformer layers (pure JAX; swappable ops via OpBinding).

Every hardware-sensitive op goes through the container's op binding
(`binding["attention"]`, `binding["rmsnorm"]`, ...) — the model never
imports a kernel directly, which is the whole point of the paper's
portability discipline.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.schema import LeafSpec

__all__ = [
    "ParallelCtx",
    "rotary",
    "norm_apply",
    "norm_schema",
    "attention_schema",
    "attention_apply",
    "attention_decode",
    "attention_chunk",
    "dequant_param",
    "mlp_schema",
    "mlp_apply",
    "sinusoidal_positions",
]


def dequant_param(p, dtype=jnp.float32):
    """Materialize a quantized weight subtree ``{"q", "scale"}`` (the
    ``restore_checkpoint(dequantize=False)`` layout — codes with axis -2
    reduced to per-channel scales) back to a dense array; full-precision
    leaves pass through untouched."""
    if isinstance(p, dict) and "q" in p and "scale" in p:
        from repro.kernels.quant import dequantize

        return dequantize(p["q"], p["scale"], axis=-2, dtype=dtype)
    return p


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Deployment-injected parallel context (None mesh = laptop)."""

    mesh: jax.sharding.Mesh | None = None
    batch_axes: tuple[str, ...] = ()       # e.g. ("pod", "data")
    model_axis: str | None = None          # e.g. "model"
    seq_shard: bool = False                # SP: shard activations' seq dim

    @property
    def active(self) -> bool:
        return self.mesh is not None

    def constrain(self, x: jnp.ndarray, spec) -> jnp.ndarray:
        if not self.active:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec)
        )

    def residual_spec(self, seq_len: int | None = None):
        P = jax.sharding.PartitionSpec
        seq = None
        if self.seq_shard and self.model_axis and seq_len:
            size = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[
                self.model_axis
            ]
            if seq_len % size == 0:
                seq = self.model_axis
        return P(self.batch_axes or None, seq, None)

    def constrain_residual(self, x: jnp.ndarray) -> jnp.ndarray:
        """SP anchor on the (B, S, D) residual stream (no-op off-mesh or
        when S doesn't divide, e.g. decode's S=1)."""
        if not self.active:
            return x
        return self.constrain(x, self.residual_spec(x.shape[1]))

    def heads_spec(self, n_heads: int, head_dim: int):
        """Spec for (B, S, H, Dh) activations: heads on the model axis when
        divisible, else head_dim — anchors XLA's propagation through the
        GQA reshapes (without this the partitioner falls back to
        'involuntary full rematerialization' copies)."""
        P = jax.sharding.PartitionSpec
        if not self.active or self.model_axis is None:
            return None
        size = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[self.model_axis]
        if n_heads % size == 0:
            return P(self.batch_axes or None, None, self.model_axis, None)
        if head_dim % size == 0:
            return P(self.batch_axes or None, None, None, self.model_axis)
        return P(self.batch_axes or None, None, None, None)

    def constrain_heads(self, x: jnp.ndarray) -> jnp.ndarray:
        spec = self.heads_spec(x.shape[2], x.shape[3])
        return self.constrain(x, spec) if spec is not None else x


# --------------------------------------------------------------------------- #
# rotary / positional
# --------------------------------------------------------------------------- #
def rotary(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]                    # (1, S, 1, half)
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]                    # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, offset: jnp.ndarray | int = 0) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings, computed (no parameters)."""
    half = d // 2
    pos = jnp.arange(seq, dtype=jnp.float32) + jnp.asarray(offset, jnp.float32)
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = pos[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #
def norm_schema(cfg: ModelConfig, d: int | None = None) -> dict[str, LeafSpec]:
    d = d or cfg.d_model
    leaves = {"scale": LeafSpec((d,), ("norm",), init="ones")}
    if cfg.norm == "layernorm":
        leaves["bias"] = LeafSpec((d,), ("norm",), init="zeros")
    return leaves


def norm_apply(params, x, cfg: ModelConfig, binding, eps: float = 1e-6):
    if cfg.norm == "rmsnorm":
        return binding["rmsnorm"](x, params["scale"], eps=eps)
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #
def attention_schema(cfg: ModelConfig, n_heads: int | None = None) -> dict[str, LeafSpec]:
    d, h, kv, dh = cfg.d_model, n_heads or cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    leaves = {
        "wq": LeafSpec((d, h, dh), ("embed", "heads", "head_dim"), init="scaled"),
        "wk": LeafSpec((d, kv, dh), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wv": LeafSpec((d, kv, dh), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wo": LeafSpec((h, dh, d), ("heads", "head_dim", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        leaves["bq"] = LeafSpec((h, dh), ("heads", "head_dim"), init="zeros")
        leaves["bk"] = LeafSpec((kv, dh), ("kv_heads", "head_dim"), init="zeros")
        leaves["bv"] = LeafSpec((kv, dh), ("kv_heads", "head_dim"), init="zeros")
    return leaves


def attention_apply(
    params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    binding,
    *,
    positions: jnp.ndarray | None = None,
    causal: bool = True,
    kv_source: jnp.ndarray | None = None,       # cross-attention input
    use_rope: bool = True,
    pctx: "ParallelCtx | None" = None,
    real_group: tuple[int, int] | None = None,   # (g, g') head padding
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """Full-sequence attention (train / prefill).  Returns (out, kv)."""
    src = x if kv_source is None else kv_source
    q = jnp.einsum("bsd,dhk->bshk", x, dequant_param(params["wq"], x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, dequant_param(params["wk"], x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, dequant_param(params["wv"], x.dtype))
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if use_rope and positions is not None:
        q = rotary(q, positions, cfg.rope_theta)
        k = rotary(k, positions, cfg.rope_theta)
    if pctx is not None and pctx.active:
        q = pctx.constrain_heads(q)
        k = pctx.constrain_heads(k)
        v = pctx.constrain_heads(v)
    out = binding["attention"](q, k, v, causal=causal)
    out = _mask_padded_heads(out, real_group)
    if pctx is not None and pctx.active:
        out = pctx.constrain_heads(out)
    y = jnp.einsum("bshk,hkd->bsd", out, dequant_param(params["wo"], x.dtype))
    return y, {"k": k, "v": v}


def _mask_padded_heads(out: jnp.ndarray, real_group: tuple[int, int] | None):
    """Zero the outputs of TP-alignment padding heads (slots g..g'-1 of
    each GQA group), making the padded model numerically identical to the
    unpadded one (padded slots get zero forward contribution AND zero
    gradients through this mask)."""
    if real_group is None:
        return out
    g, gp = real_group
    if g == gp:
        return out
    h = out.shape[-2]
    mask = (jnp.arange(h) % gp) < g
    return out * mask[:, None].astype(out.dtype)


def _quant_update(upd: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    """Quantize a fresh k/v projection onto a quantized cache's grid using
    the slot's static calibrated scale (() or (B,) fp32).  A plain astype
    would truncate int8 codes; this divides by the scale and rounds/clips
    per format — the exact inverse of the kernels' in-VMEM dequant."""
    from repro.kernels.quant import FP8_MAX, INT8_MAX

    s = jnp.asarray(scale, jnp.float32)
    s = s.reshape(s.shape + (1,) * (upd.ndim - s.ndim))
    y = upd.astype(jnp.float32) / s
    if jnp.dtype(dtype) == jnp.int8:
        return jnp.clip(jnp.round(y), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return jnp.clip(y, -FP8_MAX, FP8_MAX).astype(dtype)


def _cache_write(buf: jnp.ndarray, upd: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Write `upd` (B, S, KV, Dh) into `buf` at seq offset `pos` — per batch
    row when pos is (B,) (continuous batching: every slot at its own
    position), one shared offset when pos is scalar."""
    upd = upd.astype(buf.dtype)
    if pos.ndim:
        return jax.vmap(
            lambda b, u, p: jax.lax.dynamic_update_slice_in_dim(b, u, p, axis=0)
        )(buf, upd, pos)
    return jax.lax.dynamic_update_slice_in_dim(buf, upd, pos, axis=1)


def _paged_decode_write(
    pool: jnp.ndarray,           # (P, page, KV, Dh)
    upd: jnp.ndarray,            # (B, 1, KV, Dh)
    pos: jnp.ndarray,            # () or (B,) int32
    block_tables: jnp.ndarray,   # (B, nblocks) int32
) -> jnp.ndarray:
    """Scatter each row's new token into its page: logical position p of
    row b lands in page block_tables[b, p // page] at offset p % page.
    Parked rows (table row all zeros) write into the reserved park page —
    harmless garbage, their logits are discarded by the active mask."""
    b = upd.shape[0]
    page = pool.shape[1]
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    phys = block_tables[jnp.arange(b), posv // page]
    return pool.at[phys, posv % page].set(upd[:, 0].astype(pool.dtype))


def attention_decode(
    params,
    x: jnp.ndarray,                      # (B, 1, D)
    cache: dict[str, jnp.ndarray],       # k/v: (B, Smax, KV, Dh)
    pos: jnp.ndarray,                    # () or (B,) int32 — new token index
    cfg: ModelConfig,
    binding,
    *,
    use_rope: bool = True,
    cross: bool = False,
    pctx: "ParallelCtx | None" = None,
    real_group: tuple[int, int] | None = None,
    block_tables: jnp.ndarray | None = None,   # (B, nblocks) — paged cache
    window: jnp.ndarray | None = None,         # () or (B,) i32 sliding window
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """One-token attention against the cache; writes the new k/v (self only).

    With `block_tables` the cache k/v are page pools (P, page, KV, Dh)
    shared by all slots; the write scatters through the table and the op
    gathers through it (paged decode_attention ABI).  With `window` only
    the trailing `window` cache slots are attended (sliding-window decode
    ABI) — out-of-window pages may already have been released.

    A quantized cache carries ``"k_scale"``/``"v_scale"`` leaves (static
    per-slot calibration, () or (B,) fp32): fresh k/v are quantized onto
    the cache grid before the write and the scales ride as trailing
    binding args — the op dequantizes in-kernel (scale meta ABI)."""
    k_scale, v_scale = cache.get("k_scale"), cache.get("v_scale")
    rope_pos = pos[None] if pos.ndim == 0 else pos[:, None]
    q = jnp.einsum("bsd,dhk->bshk", x, dequant_param(params["wq"], x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"]
    if use_rope:
        q = rotary(q, rope_pos, cfg.rope_theta)
    if pctx is not None and pctx.active:
        q = pctx.constrain_heads(q)
    if cross:
        k_cache, v_cache = cache["k"], cache["v"]
        cache_len = jnp.asarray(k_cache.shape[1] - 1, jnp.int32)
        out = binding["decode_attention"](q, k_cache, v_cache, cache_len)
        new_cache = cache
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, dequant_param(params["wk"], x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, dequant_param(params["wv"], x.dtype))
        if cfg.qkv_bias:
            k, v = k + params["bk"], v + params["bv"]
        if use_rope:
            k = rotary(k, rope_pos, cfg.rope_theta)
        if k_scale is not None:
            k = _quant_update(k, k_scale, cache["k"].dtype)
            v = _quant_update(v, v_scale, cache["v"].dtype)
        if block_tables is not None:
            k_cache = _paged_decode_write(cache["k"], k, pos, block_tables)
            v_cache = _paged_decode_write(cache["v"], v, pos, block_tables)
        else:
            k_cache = _cache_write(cache["k"], k, pos)
            v_cache = _cache_write(cache["v"], v, pos)
        if k_scale is not None:
            out = binding["decode_attention"](q, k_cache, v_cache, pos,
                                              block_tables, window,
                                              k_scale, v_scale)
        elif window is not None:
            out = binding["decode_attention"](q, k_cache, v_cache, pos,
                                              block_tables, window)
        elif block_tables is not None:
            out = binding["decode_attention"](q, k_cache, v_cache, pos,
                                              block_tables)
        else:
            out = binding["decode_attention"](q, k_cache, v_cache, pos)
        new_cache = {"k": k_cache, "v": v_cache}
        if k_scale is not None:
            new_cache["k_scale"], new_cache["v_scale"] = k_scale, v_scale
    out = _mask_padded_heads(out, real_group)
    if pctx is not None and pctx.active:
        out = pctx.constrain_heads(out)
    y = jnp.einsum("bshk,hkd->bsd", out, dequant_param(params["wo"], x.dtype))
    return y, new_cache


def attention_chunk(
    params,
    x: jnp.ndarray,                      # (B, C, D) — chunk of prompt
    cache: dict[str, jnp.ndarray],       # k/v: (B, Smax, KV, Dh)
    pos: jnp.ndarray,                    # () int32 — chunk's global start
    cfg: ModelConfig,
    binding,
    *,
    use_rope: bool = True,
    pctx: "ParallelCtx | None" = None,
    real_group: tuple[int, int] | None = None,
    block_tables: jnp.ndarray | None = None,   # (nblocks,) — this slot's row
    window: jnp.ndarray | None = None,         # () i32 sliding window
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """Chunked-prefill attention: C prompt tokens at global positions
    pos..pos+C-1 against the partially filled cache.

    Writes the chunk's k/v into the cache window [pos, pos+C) and attends
    via binding["chunk_attention"] (query i sees cache keys <= pos+i).
    Positions past the prompt's true end carry garbage k/v, but every
    later query — in-chunk (causal mask) or decode (its own write lands
    first) — sees those slots only after they are overwritten, so no
    masking is needed here; the SSM path is where padding needs care.

    With `block_tables` (the prefilling slot's (nblocks,) table row,
    B == 1) the cache k/v are page pools and the serving invariant
    page == C makes the chunk's write exactly one page: the chunk at
    global position pos fills page block_tables[pos // page] whole.
    """
    k_scale, v_scale = cache.get("k_scale"), cache.get("v_scale")
    c = x.shape[1]
    chunk_pos = pos + jnp.arange(c)
    q = jnp.einsum("bsd,dhk->bshk", x, dequant_param(params["wq"], x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, dequant_param(params["wk"], x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, dequant_param(params["wv"], x.dtype))
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if use_rope:
        q = rotary(q, chunk_pos, cfg.rope_theta)
        k = rotary(k, chunk_pos, cfg.rope_theta)
    if pctx is not None and pctx.active:
        q = pctx.constrain_heads(q)
    if k_scale is not None:
        k = _quant_update(k, k_scale, cache["k"].dtype)
        v = _quant_update(v, v_scale, cache["v"].dtype)
    if block_tables is not None:
        page = cache["k"].shape[1]
        assert c == page, f"paged prefill requires chunk == page, {c} != {page}"
        blk = jax.lax.dynamic_index_in_dim(
            jnp.asarray(block_tables, jnp.int32), pos // page, keepdims=False
        )
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (blk, 0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (blk, 0, 0, 0))
        if k_scale is not None:
            out = binding["chunk_attention"](q, k_cache, v_cache, pos,
                                             block_tables[None], window,
                                             k_scale, v_scale)
        elif window is not None:
            out = binding["chunk_attention"](q, k_cache, v_cache, pos,
                                             block_tables[None], window)
        else:
            out = binding["chunk_attention"](q, k_cache, v_cache, pos,
                                             block_tables[None])
    else:
        k_cache = _cache_write(cache["k"], k, pos)
        v_cache = _cache_write(cache["v"], v, pos)
        if k_scale is not None:
            out = binding["chunk_attention"](q, k_cache, v_cache, pos,
                                             None, window, k_scale, v_scale)
        elif window is not None:
            out = binding["chunk_attention"](q, k_cache, v_cache, pos,
                                             None, window)
        else:
            out = binding["chunk_attention"](q, k_cache, v_cache, pos)
    out = _mask_padded_heads(out, real_group)
    if pctx is not None and pctx.active:
        out = pctx.constrain_heads(out)
    y = jnp.einsum("bshk,hkd->bsd", out, dequant_param(params["wo"], x.dtype))
    kv = {"k": k_cache, "v": v_cache}
    if k_scale is not None:
        kv["k_scale"], kv["v_scale"] = k_scale, v_scale
    return y, kv


# --------------------------------------------------------------------------- #
# dense MLP
# --------------------------------------------------------------------------- #
def mlp_schema(cfg: ModelConfig, d_ff: int | None = None) -> dict[str, LeafSpec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    leaves = {
        "w_in": LeafSpec((d, f), ("embed", "ff"), init="scaled"),
        "w_out": LeafSpec((f, d), ("ff", "embed"), init="scaled"),
    }
    if cfg.activation == "silu_glu":
        leaves["w_gate"] = LeafSpec((d, f), ("embed", "ff"), init="scaled")
    return leaves


def mlp_apply(params, x, cfg: ModelConfig, binding=None):
    """Dense MLP.  Quantized weight subtrees (``{"q", "scale"}``) route
    through ``binding["quant_matmul"]`` when a binding is supplied — the
    per-output-channel dequant happens inside the kernel, so the dense
    weight matrix is never materialized; without a binding (or for
    full-precision leaves) the plain einsum path runs."""

    def matmul(y, w):
        if isinstance(w, dict) and "q" in w and "scale" in w:
            if binding is not None and "quant_matmul" in binding:
                b, s, d = y.shape
                out = binding["quant_matmul"](
                    y.reshape(b * s, d), w["q"], w["scale"])
                return out.reshape(b, s, -1)
            w = dequant_param(w, y.dtype)
        return jnp.einsum("bsd,df->bsf", y, w)

    h = matmul(x, params["w_in"])
    if cfg.activation == "silu_glu":
        g = matmul(x, params["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return matmul(h, params["w_out"])
