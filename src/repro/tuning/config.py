"""BlockConfig — the tunable tile geometry of a Pallas kernel.

The kernels used to hard-code their block sizes; that made "native"
performance native only on the geometry the author tuned for.  A
`BlockConfig` lifts those constants into a hashable value object the
autotuner can search over and the tuning cache can persist — the knob
the deployment site turns, not the bundle author.

Resolution order inside a kernel wrapper is always:

  explicit kwarg (caller knows best)  >  config=BlockConfig  >  default

so the pre-tuning call sites keep working unchanged and the registry can
inject a tuned config without touching the model code.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

__all__ = ["BlockConfig", "default_config"]


@dataclasses.dataclass(frozen=True, order=True)
class BlockConfig:
    """Immutable, hashable name->int parameter set (jit-static friendly)."""

    items: tuple[tuple[str, int], ...] = ()

    def __post_init__(self) -> None:
        for name, value in self.items:
            if not isinstance(name, str) or not name:
                raise ValueError(f"bad parameter name {name!r}")
            if not isinstance(value, int) or isinstance(value, bool) or value <= 0:
                raise ValueError(f"parameter {name!r} must be a positive int, got {value!r}")

    # -- construction -----------------------------------------------------
    @classmethod
    def make(cls, **params: int) -> "BlockConfig":
        return cls(items=tuple(sorted(params.items())))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "BlockConfig":
        return cls(items=tuple(sorted((str(k), int(v)) for k, v in d.items())))

    # -- access -----------------------------------------------------------
    def get(self, name: str, default: int | None = None) -> int | None:
        for k, v in self.items:
            if k == name:
                return v
        return default

    def __getitem__(self, name: str) -> int:
        v = self.get(name)
        if v is None:
            raise KeyError(name)
        return v

    def __contains__(self, name: str) -> bool:
        return self.get(name) is not None

    def override(self, **params: int) -> "BlockConfig":
        merged = dict(self.items)
        merged.update(params)
        return BlockConfig.make(**merged)

    def to_dict(self) -> dict[str, int]:
        return dict(self.items)

    def __str__(self) -> str:
        if not self.items:
            return "<empty>"
        return ",".join(f"{k}={v}" for k, v in self.items)


# The pre-autotuner hard-coded constants, preserved as the untuned
# fallback: a site that never runs the tuner behaves exactly like the
# seed repo did.  (moe_gmm's block_k is the one post-seed addition: at
# D <= 2048 it degrades to a single k step — bit-identical to the old
# no-k-loop kernel — and only chunks the contraction for wider experts,
# which the seed kernel could not run at all without overflowing VMEM.)
_OP_DEFAULTS: dict[str, BlockConfig] = {
    "rmsnorm": BlockConfig.make(block_rows=256),
    "attention": BlockConfig.make(block_q=128, block_k=128),
    "decode_attention": BlockConfig.make(block_q=128, block_k=128),
    "chunk_attention": BlockConfig.make(block_q=128, block_k=128),
    "ssd_scan": BlockConfig.make(chunk=128),
    "moe_gmm": BlockConfig.make(block_m=128, block_n=128, block_k=2048),
    "quant_matmul": BlockConfig.make(block_m=128, block_n=128),
}

# Per-platform refinements of the fallback (still not *tuned* — just a
# better guess than the TPU constants where the hardware is known to be
# different).  Keyed by (platform name, op name).
_PLATFORM_DEFAULTS: dict[tuple[str, str], BlockConfig] = {
    # interpret-mode simulation host: small tiles keep per-call latency sane
    ("pod-sim", "rmsnorm"): BlockConfig.make(block_rows=64),
    ("pod-sim", "attention"): BlockConfig.make(block_q=32, block_k=32),
    ("pod-sim", "decode_attention"): BlockConfig.make(block_q=32, block_k=32),
    ("pod-sim", "chunk_attention"): BlockConfig.make(block_q=32, block_k=32),
    ("pod-sim", "ssd_scan"): BlockConfig.make(chunk=32),
    ("pod-sim", "moe_gmm"): BlockConfig.make(block_m=32, block_n=32, block_k=64),
    ("pod-sim", "quant_matmul"): BlockConfig.make(block_m=32, block_n=32),
}


def default_config(op: str, platform: Any | None = None) -> BlockConfig:
    """Fallback config for `op` — platform-specific if one is registered.

    `platform` may be a Platform object or its name; None means the
    generic (TPU-tuned) constants the kernels shipped with.
    """
    if platform is not None:
        name = platform if isinstance(platform, str) else platform.name
        hit = _PLATFORM_DEFAULTS.get((name, op))
        if hit is not None:
            return hit
    return _OP_DEFAULTS.get(op, BlockConfig())
