"""Geometry-dispatched tuned configs — one bound op, many tuned configs.

PR 1's binding baked exactly one `BlockConfig` into each op at bind
time: whatever geometry the `TuningContext` keyed on (canonical example,
or the profile's single hottest bucket) won, and every other geometry
the deployment later traced ran under that foreign config.  The warm
subsystem already tunes the top-K recorded buckets — this module makes
the *binding* use all of them.

Three pieces:

  * `GeometryOutcome` — one (shape bucket, dtype) with its bind-time
    tuning status and resolved config; the per-geometry breakdown a
    `SwapReport` carries.
  * `ConfigTable` — the per-op map geometry -> config plus a fallback
    chain: exact bucket match, else the *nearest* tuned bucket of the
    same structure (same-dtype candidates at raw log2 distance,
    dtype-crossing candidates at distance + `DTYPE_PENALTY`, validated
    against the borrowing dtype first — the ``near-dtype`` path), else
    the platform default.  This is what `OpImpl.config` holds after an
    autotuned bind (it used to hold a single BlockConfig;
    `ConfigTable.primary` preserves that view).  ``max_entries`` bounds
    the table — the lifecycle layer's per-op cap: hottest-first callers
    keep exactly their K hottest buckets.
  * `TunedDispatch` — the callable the binding exposes.  At trace time
    it buckets the call's operand shapes (the same `bucket_shapes`
    encoding `WorkloadProfile` records and `CacheKey` persists) and
    injects the resolved config; an explicit ``config=`` kwarg from the
    call site always wins, so kernel signatures are unchanged.

Under ``jit`` the dispatch runs while tracing, i.e. once per compiled
geometry — the resolved config is a Python-level static, so distinct
geometries compile distinct specializations and repeated calls at one
geometry reuse the compiled function with zero dispatch overhead.
`TunedDispatch.stats` counts resolutions per path (exact / nearest /
default / explicit), which is exactly the multi-bucket hit rate the
`geometry_dispatch` benchmark row reports.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Callable, Mapping, Sequence

from repro.tuning.cache import bucket_shapes
from repro.tuning.config import BlockConfig

__all__ = ["GeometryOutcome", "ConfigTable", "TunedDispatch", "bucket_distance",
           "DTYPE_PENALTY", "DEMOTED_PENALTY", "DISPATCH_PATHS", "STATS_SCHEMA",
           "consolidated_stats", "calibrate_dtype_penalty"]

# What crossing a dtype costs, in doublings: a bf16 call prefers any
# same-dtype bucket within 4 doublings of it over an exact-shape fp32
# bucket, but borrows the fp32 entry rather than fall to the shipped
# default when its own dtype was never warmed.  This is the *fixed
# fallback*: a table built from a cache with measured timings for more
# than one dtype carries a calibrated penalty instead (see
# calibrate_dtype_penalty) — quantized buckets made dtype-crossing
# borrows routine enough that a guessed constant over- or under-lends.
DTYPE_PENALTY = 4.0


def calibrate_dtype_penalty(
    measured: Mapping[tuple[str, str], float],
) -> float | None:
    """Dtype-crossing borrow penalty from measured bucket timings.

    ``measured`` maps (shape bucket, dtype) -> best_us from the tuning
    cache.  Every same-shape pair that differs only in dtype is one
    observation of what crossing dtypes actually costs on this platform:
    |log2(time ratio)| doublings.  The penalty is the median observation
    clamped to [1, 8] — never cheaper than one doubling (an exact
    same-dtype neighbour should still win) and never so dear that a
    validated borrow loses to the shipped default.  Returns None when no
    cross-dtype pair was measured (callers keep DTYPE_PENALTY).
    """
    by_shape: dict[str, list[tuple[str, float]]] = {}
    for (shapes, dtype), us in measured.items():
        if us and us > 0:
            by_shape.setdefault(shapes, []).append((dtype, float(us)))
    ratios = []
    for group in by_shape.values():
        for (da, ua), (db, ub) in itertools.combinations(group, 2):
            if da != db:
                ratios.append(abs(math.log2(ua / ub)))
    if not ratios:
        return None
    ratios.sort()
    mid = len(ratios) // 2
    med = (ratios[mid] if len(ratios) % 2
           else (ratios[mid - 1] + ratios[mid]) / 2)
    return min(max(med, 1.0), 8.0)

# What a *demoted* candidate costs on top of its distance: a config a
# tuning-bundle import could not validate at its own bucket (foreign
# fingerprint, or tuned on a drifted kernel revision) competes only after
# every first-class candidate within this radius, and must re-pass the
# borrowing call's feasibility check before it is lent out.
DEMOTED_PENALTY = 6.0

# The fixed vocabulary of per-call resolution paths.  TunedDispatch.stats
# carries exactly these keys from construction — new paths are added HERE,
# never accreted ad hoc at count time, so downstream consumers (serve's
# dispatch printout, the consolidated stats dict) cannot silently miss one.
DISPATCH_PATHS = ("exact", "nearest", "near-dtype", "demoted", "default",
                  "explicit")

# Schema of consolidated_stats(): resolution-path counters + table shape +
# the bind-time lifecycle counters.  Regression-pinned by the test suite so
# `serve`/`train` output cannot silently drop a counter.
STATS_SCHEMA = frozenset(DISPATCH_PATHS) | {
    "table-entries", "table-demoted", "table-cap", "table-bytes",
    "evicted-lru", "bundle-imported", "bundle-demoted", "bundle-rejected",
}

# GeometryOutcome statuses that consolidated_stats() counts (everything
# else — hits, searches, defaults — is already visible through the
# resolution paths and the table size).
_COUNTED_STATUSES = {
    "cache-evicted-lru": "evicted-lru",
    "bundle-imported": "bundle-imported",
    "bundle-demoted": "bundle-demoted",
    "bundle-rejected": "bundle-rejected",
}


@dataclasses.dataclass(frozen=True)
class GeometryOutcome:
    """One geometry's bind-time tuning outcome (the SwapReport breakdown)."""

    shapes: str          # bucket_shapes encoding, e.g. "64x32,32"
    dtype: str
    status: str          # cache-hit / cache-miss-searched / cache-miss-default /
    #                      search-failed-default / cache-expired-searched /
    #                      search-budget-exhausted / unsynthesizable-default /
    #                      cache-evicted-lru (bucket lost its entry to the
    #                      per-op cap's pressure — reported, not bound) /
    #                      bundle-imported (entry arrived via a tuning
    #                      bundle and revalidated feasible here) /
    #                      bundle-demoted (bundle entry that failed the
    #                      local feasibility re-check: a penalized
    #                      candidate, never bound first-class) /
    #                      bundle-rejected (bundle entry structurally
    #                      foreign to this op — reported, not bound)
    config: BlockConfig
    count: float = 0.0   # profile observations (0 = canonical/unprofiled)
    bytes: int = 0       # approximate serialized size of the backing cache
    #                      entry (0 = placeholder outcome with no entry)

    def describe(self) -> str:
        hot = f" x{self.count:g}" if self.count else ""
        size = f" ~{self.bytes}B" if self.bytes else ""
        return (f"{self.shapes or '<scalar>'}/{self.dtype}{hot} "
                f"{self.status} ({self.config}){size}")


def _parse_bucket(shapes: str) -> list[tuple[int, ...]] | None:
    try:
        return [
            () if part == "scalar" else tuple(int(n) for n in part.split("x"))
            for part in shapes.split(",") if part
        ]
    except ValueError:
        return None


def bucket_distance(a: str, b: str) -> float | None:
    """Log-space distance between two shape buckets, or None if they are
    structurally incomparable (different arg count or ranks).

    Buckets are powers of two, so sum(|log2 d - log2 d'|) counts how many
    doublings separate the workloads — the natural metric for "which tuned
    geometry is this call closest to".
    """
    pa, pb = _parse_bucket(a), _parse_bucket(b)
    if pa is None or pb is None or len(pa) != len(pb):
        return None
    dist = 0.0
    for da, db in zip(pa, pb):
        if len(da) != len(db):
            return None
        for x, y in zip(da, db):
            dist += abs(math.log2(max(x, 1)) - math.log2(max(y, 1)))
    return dist


class ConfigTable:
    """Per-geometry tuned configs for one bound op, with fallback chain.

    ``outcomes`` orders geometries hottest-first; ``default`` is the
    platform fallback used when no tuned geometry is comparable to the
    call's.  Hashable content lives in plain dicts so resolution is a
    lookup, not a scan, on the exact path.

    ``max_entries`` is the bounded mode: only the first K *distinct*
    geometries enter the table (callers order hottest-first, so the cap
    keeps exactly the K hottest buckets); overflow outcomes are dropped
    here — the TuningContext surfaces them as ``cache-evicted-lru``
    before construction.  ``validate`` guards dtype-crossing borrows:
    ``(config, shapes, dtype) -> bool`` re-checks the candidate config's
    feasibility (VMEM working set etc.) against the *borrowing* call's
    dtype; None (tables built outside a TuningContext) admits any
    structurally comparable borrow.

    ``demoted`` is the second-class candidate pool a tuning-bundle
    import leaves behind (configs that failed the target platform's
    feasibility re-check at their own bucket, or were tuned on a drifted
    kernel revision): never matched exactly, never counted against the
    cap, but competing in the fallback ranking at ``DEMOTED_PENALTY``
    distance — and always re-``validate``d for the borrowing call first,
    since demotion means "suspect until proven feasible for YOU".
    """

    def __init__(self, op: str, outcomes: Sequence[GeometryOutcome],
                 default: BlockConfig, *,
                 validate: Callable[[BlockConfig, str, str], bool] | None = None,
                 max_entries: int | None = None,
                 demoted: Sequence[GeometryOutcome] = (),
                 dtype_penalty: float | None = None) -> None:
        self.op = op
        self.default = default
        self.validate = validate
        self.max_entries = max_entries
        # dtype-crossing borrow cost: measured (calibrate_dtype_penalty)
        # when the bind had cross-dtype timings, else the fixed fallback
        self.dtype_penalty = (DTYPE_PENALTY if dtype_penalty is None
                              else float(dtype_penalty))
        self._by_geom: dict[tuple[str, str], BlockConfig] = {}
        kept: list[GeometryOutcome] = []
        for o in outcomes:
            geom = (o.shapes, o.dtype)
            if geom not in self._by_geom and max_entries is not None \
                    and len(self._by_geom) >= max_entries:
                continue
            self._by_geom.setdefault(geom, o.config)
            kept.append(o)
        self.outcomes = tuple(kept)
        self._demoted_by_geom: dict[tuple[str, str], BlockConfig] = {}
        kept_demoted: list[GeometryOutcome] = []
        for o in demoted:
            geom = (o.shapes, o.dtype)
            if geom in self._by_geom or geom in self._demoted_by_geom:
                continue
            self._demoted_by_geom[geom] = o.config
            kept_demoted.append(o)
        self.demoted = tuple(kept_demoted)

    # -- the old single-config view ---------------------------------------
    @property
    def primary(self) -> BlockConfig:
        """The hottest geometry's config — what PR 1's binding would have
        baked in; kept as the answer to shape-less `tuned_config(op)`."""
        return self.outcomes[0].config if self.outcomes else self.default

    # -- resolution ---------------------------------------------------------
    def resolve(self, args: Sequence[Any] | None = None, *,
                shapes: str | None = None, dtype: str | None = None
                ) -> tuple[BlockConfig, str]:
        """(config, how); how in {exact, nearest, near-dtype, demoted,
        default}.

        Geometry comes from ``args`` (arrays/tracers/ShapeDtypeStructs,
        bucketed like the profile records them) or an explicit
        (shapes, dtype) bucket pair.  With an explicit ``shapes`` string
        and ``dtype=None`` the lookup is *dtype-agnostic*: the bucket
        string carries no dtype, so the table matches any dtype, hottest
        entry first (it used to silently assume the hottest geometry's
        dtype, which mis-resolved explicit lookups whenever the table
        mixed dtypes).

        Candidate ranking on a miss: every structurally comparable tuned
        bucket competes — same-dtype candidates at their raw log2
        distance ("nearest"), dtype-crossing candidates at distance +
        ``DTYPE_PENALTY`` ("near-dtype"), and demoted bundle candidates
        at distance + ``DEMOTED_PENALTY`` (plus the dtype penalty when
        they also cross dtypes; "demoted").  A near-dtype winner must
        first pass ``validate`` for the borrowing dtype (VMEM re-check);
        a demoted winner must *always* pass ``validate`` for the
        borrowing call (it already failed at its own bucket once); a
        failed borrow falls through to the next-closest candidate, and
        only when nothing is comparable does the platform default apply.
        """
        if shapes is None:
            shapes, dtype = bucket_shapes(args or ())
        if dtype is None:
            for o in self.outcomes:           # hottest-first, any dtype
                if o.shapes == shapes:
                    return self._by_geom[(o.shapes, o.dtype)], "exact"
            best, best_d, best_how = None, None, "nearest"
            for (g_shapes, _), config in self._by_geom.items():
                d = bucket_distance(shapes, g_shapes)
                if d is not None and (best_d is None or d < best_d):
                    best, best_d, best_how = config, d, "nearest"
            for (g_shapes, g_dtype), config in self._demoted_by_geom.items():
                d = bucket_distance(shapes, g_shapes)
                if d is None or (best_d is not None
                                 and d + DEMOTED_PENALTY >= best_d):
                    continue
                # demoted candidates are suspect even on the dtype-agnostic
                # path: re-check feasibility at the QUERY shapes under the
                # candidate's own dtype (the best information available
                # when the caller supplied none)
                if self.validate is not None \
                        and not self.validate(config, shapes, g_dtype):
                    continue
                best, best_d, best_how = config, d + DEMOTED_PENALTY, \
                    "demoted"
            return (best, best_how) if best is not None \
                else (self.default, "default")
        hit = self._by_geom.get((shapes, dtype))
        if hit is not None:
            return hit, "exact"
        scored: list[tuple[float, int, str, str, BlockConfig]] = []
        for (g_shapes, g_dtype), config in self._by_geom.items():
            d = bucket_distance(shapes, g_shapes)
            if d is None:
                continue
            if g_dtype == dtype:
                scored.append((d, 0, g_shapes, "nearest", config))
            else:
                scored.append((d + self.dtype_penalty, 1, g_shapes,
                               "near-dtype", config))
        for (g_shapes, g_dtype), config in self._demoted_by_geom.items():
            d = bucket_distance(shapes, g_shapes)
            if d is None:
                continue
            penalty = DEMOTED_PENALTY + (self.dtype_penalty
                                         if g_dtype != dtype else 0.0)
            scored.append((d + penalty, 2, g_shapes, "demoted", config))
        scored.sort(key=lambda t: t[:3])
        for _, _, _, how, config in scored:
            if how in ("near-dtype", "demoted") and self.validate is not None \
                    and not self.validate(config, shapes, dtype):
                continue
            return config, how
        return self.default, "default"

    def __len__(self) -> int:
        return len(self._by_geom)

    def stats(self) -> dict[str, int]:
        """Table-shape counters: first-class entries, demoted candidates,
        cap (0 = unbounded), and total serialized bytes of the backing
        cache entries (summed from each outcome's size accounting)."""
        return {
            "table-entries": len(self._by_geom),
            "table-demoted": len(self._demoted_by_geom),
            "table-cap": self.max_entries or 0,
            "table-bytes": (sum(o.bytes for o in self.outcomes)
                            + sum(o.bytes for o in self.demoted)),
        }

    def __str__(self) -> str:
        n = len(self._by_geom)
        if n <= 1:
            return str(self.primary)
        return f"{self.primary} (+{n - 1} more geometr{'y' if n == 2 else 'ies'})"


class TunedDispatch:
    """Callable bound into the op table: per-call geometry -> tuned config.

    Wraps the chosen impl's raw fn.  Resolution happens at Python level
    (trace time under jit); ``stats`` counts one resolution per trace,
    so `sum(stats.values())` is the number of distinct compiled
    geometries and `stats["exact"]` of them ran under their own tuned
    entry.
    """

    def __init__(self, fn: Callable[..., Any], table: ConfigTable) -> None:
        self.fn = fn
        self.table = table
        self.stats = {path: 0 for path in DISPATCH_PATHS}
        self.__name__ = getattr(fn, "__name__", table.op)

    def __call__(self, *args, **kwargs):
        if kwargs.get("config") is None:
            config, how = self.table.resolve(args)
            self.stats[how] += 1
            kwargs["config"] = config
        else:
            self.stats["explicit"] += 1
        return self.fn(*args, **kwargs)

    @property
    def hit_rate(self) -> float:
        """Fraction of resolutions that found their exact tuned bucket."""
        total = sum(self.stats.values())
        return self.stats["exact"] / total if total else 0.0

    def __repr__(self) -> str:
        return f"TunedDispatch({self.table.op}, {len(self.table)} geometries)"


def consolidated_stats(dispatch: Any,
                       geometries: Sequence[GeometryOutcome] = ()
                       ) -> dict[str, int]:
    """One op's complete tuning-stats dict, under the pinned STATS_SCHEMA.

    ``dispatch`` is a TunedDispatch or any facade exposing ``.stats``
    (the per-path counters) and ``.table`` (the ConfigTable) — the
    profiled-binding wrapper forwards the counters but hides the
    instance, so launchers hand in a namespace view.

    The single consolidation point for everything `serve`/`train` print
    per op after an autotuned run: per-path resolution counters (from the
    dispatch), table shape/size (from the ConfigTable), and the bind-time
    lifecycle counters (eviction pressure, bundle import outcomes — from
    the SwapReport's geometries).  Every schema key is always present, so
    a new counter can only reach production output by joining the schema
    — never by being silently dropped from an ad hoc printout.
    """
    out = {path: int(dispatch.stats.get(path, 0)) for path in DISPATCH_PATHS}
    out.update(dispatch.table.stats())
    for counter in _COUNTED_STATUSES.values():
        out[counter] = 0
    for g in geometries:
        counter = _COUNTED_STATUSES.get(g.status)
        if counter is not None:
            out[counter] += 1
    return out
