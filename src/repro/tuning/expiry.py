"""Versioned cache expiry — evict tuned entries for kernels that changed.

Every `TuningCache` entry is keyed by the full ABI string of the kernel
it was measured against (``op/major:minor/digest``).  When a kernel's
ABI bumps — a minor bump for a compatible extension (e.g. `moe_gmm`
growing a k-loop and a ``block_k`` knob), or a major/digest change for
an incompatible one — the cached winner describes a kernel that no
longer exists at that version: its config may name knobs the new kernel
tunes differently, and its measurement says nothing about the new code.
A plain lookup would simply miss (the new key embeds the new ABI) and
the stale entry would sit in the file forever.

`expire_stale` sweeps the cache: any entry whose key names an op the
site currently declares, under an ABI string that differs from the
current declaration, is evicted.  The eviction is surfaced through the
binding's SwapReport (`tuning == "cache-expired-searched"`) so EXPERIMENTS
logs show which deployments re-paid search because a kernel changed,
and tombstoned in the cache so a concurrent save cannot resurrect it.

Entries for ops the site does not declare (other bundles, other kernel
sets sharing one cache file) are left alone — absence of a declaration
is not evidence of staleness.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Mapping

from repro.core.abi import AbiError, parse_abi
from repro.tuning.cache import TuningCache

__all__ = ["ExpiryReport", "expire_stale"]

log = logging.getLogger("repro.tuning")


@dataclasses.dataclass(frozen=True)
class ExpiryReport:
    """Outcome of one expiry sweep: which entries were evicted and why."""

    evicted: tuple[tuple[str, str], ...]   # (op name, encoded cache key)
    reasons: tuple[str, ...]               # parallel human-readable notes

    @property
    def ops(self) -> frozenset[str]:
        """Ops that lost at least one entry (their next bind re-searches)."""
        return frozenset(op for op, _ in self.evicted)

    def __len__(self) -> int:
        return len(self.evicted)

    def describe(self) -> str:
        if not self.evicted:
            return "expiry: cache clean (no stale ABI entries)"
        lines = [f"expiry: evicted {len(self.evicted)} stale entr"
                 f"{'y' if len(self.evicted) == 1 else 'ies'}"]
        for (op, key), why in zip(self.evicted, self.reasons):
            lines.append(f"  {op:<18} {why}   [{key}]")
        return "\n".join(lines)


def expire_stale(cache: TuningCache,
                 current_abis: Mapping[str, Any]) -> ExpiryReport:
    """Evict cache entries tuned against an ABI the site no longer declares.

    ``current_abis`` maps op name -> the ABI currently declared for it
    (AbiString or its string form) — typically
    ``{op: registry.decl(op).abi for op in ops_to_bind}``.  An entry is
    stale iff its key's ABI names one of those ops but differs from the
    current string in any component (minor bump included: the entry was
    measured on the older kernel revision).

    Mutates `cache` in place (evictions are tombstoned so `save` persists
    them); returns the report.  Keys that do not parse as ABI strings are
    skipped — a foreign or hand-edited entry is not this sweep's business.
    """
    current = {name: str(abi) for name, abi in current_abis.items()}
    evicted: list[tuple[str, str]] = []
    reasons: list[str] = []
    for encoded in list(cache.raw_keys()):
        abi_text = encoded.split("|", 1)[0]
        try:
            abi = parse_abi(abi_text)
        except AbiError:
            continue
        want = current.get(abi.name)
        if want is None or abi_text == want:
            continue
        cache.evict(encoded)
        evicted.append((abi.name, encoded))
        reasons.append(f"tuned for {abi_text}, site now declares {want}")
        log.info("tuning cache: expiring %s (tuned for %s, now %s)",
                 abi.name, abi_text, want)
    return ExpiryReport(evicted=tuple(evicted), reasons=tuple(reasons))
