"""Lifecycle sweeps over the tuning cache: ABI expiry and LRU pressure.

Two reasons a cache entry stops deserving its bytes: the kernel it was
measured on no longer exists at that revision (`expire_stale`), or the
cache is bounded and the entry is cold (`compact_lru`).  Both sweeps
tombstone their evictions so a concurrent save cannot resurrect them.

Versioned cache expiry — evict tuned entries for kernels that changed.

Every `TuningCache` entry is keyed by the full ABI string of the kernel
it was measured against (``op/major:minor/digest``).  When a kernel's
ABI bumps — a minor bump for a compatible extension (e.g. `moe_gmm`
growing a k-loop and a ``block_k`` knob), or a major/digest change for
an incompatible one — the cached winner describes a kernel that no
longer exists at that version: its config may name knobs the new kernel
tunes differently, and its measurement says nothing about the new code.
A plain lookup would simply miss (the new key embeds the new ABI) and
the stale entry would sit in the file forever.

`expire_stale` sweeps the cache: any entry whose key names an op the
site currently declares, under an ABI string that differs from the
current declaration, is evicted.  The eviction is surfaced through the
binding's SwapReport (`tuning == "cache-expired-searched"`) so EXPERIMENTS
logs show which deployments re-paid search because a kernel changed,
and tombstoned in the cache so a concurrent save cannot resurrect it.

Entries for ops the site does not declare (other bundles, other kernel
sets sharing one cache file) are left alone — absence of a declaration
is not evidence of staleness.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Mapping

from repro.core.abi import AbiError, parse_abi
from repro.tuning.cache import TuningCache

__all__ = ["ExpiryReport", "expire_stale", "PressureReport", "compact_lru"]

log = logging.getLogger("repro.tuning")


@dataclasses.dataclass(frozen=True)
class ExpiryReport:
    """Outcome of one expiry sweep: which entries were evicted and why."""

    evicted: tuple[tuple[str, str], ...]   # (op name, encoded cache key)
    reasons: tuple[str, ...]               # parallel human-readable notes

    @property
    def ops(self) -> frozenset[str]:
        """Ops that lost at least one entry (their next bind re-searches)."""
        return frozenset(op for op, _ in self.evicted)

    def __len__(self) -> int:
        return len(self.evicted)

    def describe(self) -> str:
        if not self.evicted:
            return "expiry: cache clean (no stale ABI entries)"
        lines = [f"expiry: evicted {len(self.evicted)} stale entr"
                 f"{'y' if len(self.evicted) == 1 else 'ies'}"]
        for (op, key), why in zip(self.evicted, self.reasons):
            lines.append(f"  {op:<18} {why}   [{key}]")
        return "\n".join(lines)


def expire_stale(cache: TuningCache,
                 current_abis: Mapping[str, Any]) -> ExpiryReport:
    """Evict cache entries tuned against an ABI the site no longer declares.

    ``current_abis`` maps op name -> the ABI currently declared for it
    (AbiString or its string form) — typically
    ``{op: registry.decl(op).abi for op in ops_to_bind}``.  An entry is
    stale iff its key's ABI names one of those ops but differs from the
    current string in any component (minor bump included: the entry was
    measured on the older kernel revision).

    Mutates `cache` in place (evictions are tombstoned so `save` persists
    them); returns the report.  Keys that do not parse as ABI strings are
    skipped — a foreign or hand-edited entry is not this sweep's business.
    """
    current = {name: str(abi) for name, abi in current_abis.items()}
    evicted: list[tuple[str, str]] = []
    reasons: list[str] = []
    for encoded in list(cache.raw_keys()):
        abi_text = encoded.split("|", 1)[0]
        try:
            abi = parse_abi(abi_text)
        except AbiError:
            continue
        want = current.get(abi.name)
        if want is None or abi_text == want:
            continue
        cache.evict(encoded)
        evicted.append((abi.name, encoded))
        reasons.append(f"tuned for {abi_text}, site now declares {want}")
        log.info("tuning cache: expiring %s (tuned for %s, now %s)",
                 abi.name, abi_text, want)
    return ExpiryReport(evicted=tuple(evicted), reasons=tuple(reasons))


# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PressureReport:
    """Outcome of one LRU compaction: what was shed, what survived."""

    evicted: tuple[tuple[str, str], ...]   # (op name, encoded cache key)
    kept: int                              # live entries after the sweep
    cap: int | None                        # entry-count cap (None = none)
    cap_bytes: int | None = None           # byte cap (None = none)
    kept_bytes: int | None = None          # live bytes after the sweep

    def __len__(self) -> int:
        return len(self.evicted)

    def _caps(self) -> str:
        parts = []
        if self.cap is not None:
            parts.append(f"cap {self.cap}")
        if self.cap_bytes is not None:
            parts.append(f"cap {self.cap_bytes}B")
        return ", ".join(parts) or "no cap"

    def describe(self) -> str:
        size = (f", {self.kept_bytes}B"
                if self.kept_bytes is not None else "")
        if not self.evicted:
            return (f"compact: cache within cap "
                    f"({self.kept} entr{'y' if self.kept == 1 else 'ies'}"
                    f"{size}; {self._caps()})")
        lines = [f"compact: evicted {len(self.evicted)} cold entr"
                 f"{'y' if len(self.evicted) == 1 else 'ies'} "
                 f"({self.kept} kept{size}, {self._caps()})"]
        for op, key in self.evicted:
            lines.append(f"  {op:<18} [{key}]")
        return "\n".join(lines)


def _key_op(encoded: str) -> str:
    """Op name out of an encoded cache key (the ABI's leading component)."""
    return encoded.split("|", 1)[0].split("/", 1)[0]


def compact_lru(cache: TuningCache, max_entries: int | None, *,
                max_bytes: int | None = None,
                profile: Any = None,
                protect: Mapping | frozenset | tuple = ()) -> PressureReport:
    """Shrink `cache` to ``max_entries`` live entries (and/or
    ``max_bytes`` serialized bytes — the ``entry_bytes`` accounting),
    coldest first.

    The eviction policy prefers *stale-profile* buckets: when a
    `WorkloadProfile` is given, entries whose (op, shape bucket, dtype)
    the profile no longer records go before entries traffic still hits,
    and within each class the oldest ``last_used`` loses first.  Keys in
    ``protect`` are never evicted (the caller pins, e.g., the geometries
    it just bound).  Evictions are tombstoned; the caller saves.

    This is the ``python -m repro.tuning.warm --compact`` GC and the
    library entry point for site cron jobs.
    """
    if max_entries is not None and max_entries < 0:
        raise ValueError(f"max_entries must be >= 0, got {max_entries}")
    if max_bytes is not None and max_bytes < 0:
        raise ValueError(f"max_bytes must be >= 0, got {max_bytes}")
    if max_entries is None and max_bytes is None:
        raise ValueError("compact_lru needs max_entries and/or max_bytes")
    prefer: tuple[str, ...] = ()
    if profile is not None and len(profile):
        recorded = {(geo.op, geo.shapes, geo.dtype)
                    for geo, _ in profile.top()}
        prefer = tuple(
            encoded for encoded in cache.raw_keys()
            if len(parts := encoded.split("|")) == 4
            and (_key_op(encoded), parts[2], parts[3]) not in recorded
        )
    evicted = cache.compact(max_entries, max_bytes=max_bytes,
                            protect=frozenset(protect), prefer=prefer)
    report = PressureReport(
        evicted=tuple((_key_op(k), k) for k in evicted),
        kept=len(cache), cap=max_entries,
        cap_bytes=max_bytes, kept_bytes=cache.total_bytes(),
    )
    if len(report):
        log.info(report.describe())
    return report
