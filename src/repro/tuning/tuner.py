"""OpTuner + TuningContext — deferred specialization at bind time.

An `OpTuner` is the hook a NATIVE implementation registers alongside its
callable: the config space, a canonical per-platform example workload,
and a feasibility predicate (VMEM working set, shape divisibility).  The
registry never imports this module; it just carries the hook and hands
it to whatever `TuningContext` the Runtime passes into `bind()` — the
same inversion the paper uses for site resources: the bundle declares
*what* can be specialized, the site decides *whether and when*.

`TuningContext.apply` resolves one bound impl.  Since the
geometry-dispatch redesign it no longer bakes a single config into the
callable: it resolves *every* relevant geometry — the profile's top-K
recorded buckets (or the canonical example when no traffic was
recorded), plus any further already-warmed cache entries for the same
(ABI, platform) — into a `ConfigTable`, and wraps the impl in a
`TunedDispatch` that buckets each call's operand shapes at trace time
and injects the matching entry (exact -> nearest bucket -> platform
default).  Per geometry, the outcome vocabulary is unchanged:

  cache hit            -> use the cached config            ("cache-hit")
  miss, op selected    -> search now, persist the winner   ("cache-miss-searched")
  miss after ABI expiry-> search now, persist the winner   ("cache-expired-searched")
  miss, not selected   -> platform-default config          ("cache-miss-default")
  search found nothing -> platform-default config          ("search-failed-default")
  miss, budget spent   -> platform-default config          ("search-budget-exhausted")
  bucket unsynthesizable-> platform-default config         ("unsynthesizable-default")

Every geometry's outcome is surfaced in the binding's SwapReport
(`SwapReport.geometries`), with `SwapReport.tuning` summarizing (the
shared status when all geometries agree, a "mixed(...)" breakdown
otherwise), so EXPERIMENTS logs show exactly which deployments ran
tuned, at which geometries, and from where.

Optional inputs close the tune-on-real-traffic loop:

  * ``profile`` — a `WorkloadProfile` of captured live geometries.  Ops
    with recorded traffic are keyed (and, on a miss, searched) on their
    top-K recorded buckets instead of the canonical example, so a cache
    pre-warmed by ``repro.tuning.warm`` from the same profile hits on
    every bucket at the next deploy — zero searches for a warmed,
    shape-polymorphic deployment.
  * ``current_abis`` — the site's currently declared ABI per op.  Stale
    cache entries (tuned against an older kernel revision) are expired
    up front (see expiry.py) and the re-search is labelled
    "cache-expired-searched" in the SwapReport.
  * ``search_budget`` / ``priority`` — cap on how many searches one bind
    may pay, and the profile-driven op ordering the Runtime derived
    (hottest first); the rank lands in `SwapReport.search_rank`.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Iterable, Mapping, Sequence

from repro.tuning.cache import CacheKey, TuningCache, bucket_shapes, platform_fingerprint
from repro.tuning.config import BlockConfig, default_config
from repro.tuning.dispatch import ConfigTable, GeometryOutcome, TunedDispatch
from repro.tuning.search import search

__all__ = ["OpTuner", "TuningContext", "TuneEvent", "TuneOutcome",
           "search_into_cache"]

log = logging.getLogger("repro.tuning")


def search_into_cache(
    cache: TuningCache,
    platform: Any,
    tuner: "OpTuner",
    fn: Callable[..., Any],
    args: tuple,
    key: CacheKey,
    *,
    extra_metrics: Mapping[str, Any] | None = None,
) -> tuple[BlockConfig, bool]:
    """Search the op's config space for `args`; persist the outcome at `key`.

    The single search-and-persist path shared by bind-time tuning
    (TuningContext.apply) and offline warming (repro.tuning.warm), so the
    two can never diverge in feasibility handling or persisted metrics.
    Returns (config, searched_ok); a search where nothing survives
    persists the platform default — the failed search is paid once, not
    per deploy — and returns searched_ok=False.
    """
    feasible = None
    if tuner.feasible is not None:
        feasible = lambda cfg: tuner.feasible(cfg, platform, args)  # noqa: E731
    result = search(
        lambda cfg: fn(*args, config=cfg),
        tuner.space,
        feasible=feasible,
        iters=tuner.iters,
        warmup=tuner.warmup,
    )
    if result.best is None:
        config = default_config(tuner.op, platform)
        metrics = {"search_failed": True}
        metrics.update(extra_metrics or {})
        cache.put(key, config, metrics=metrics)
        return config, False
    metrics = {
        "best_us": result.best_seconds * 1e6,
        "measured": len(result.measurements),
        "pruned": result.pruned,
        "failed": result.failed,
    }
    metrics.update(extra_metrics or {})
    cache.put(key, result.best, metrics)
    return result.best, True


@dataclasses.dataclass(frozen=True)
class OpTuner:
    """Registered next to a native impl: how to specialize it to a site.

    The impl's callable must accept a ``config=BlockConfig`` keyword; the
    context wraps it in a `TunedDispatch` that injects the per-geometry
    resolved config at trace time, so model code keeps calling the op
    with its ordinary arguments.

    Fields:
      op             logical op name (matches the registry declaration).
      space          name -> candidate values; the search enumerates the
                     cartesian product (see search.enumerate_space).
      example_args   platform -> concrete canonical workload, used when no
                     recorded geometry is available.
      feasible       (config, platform, args) -> bool pre-measurement
                     filter (VMEM budget, divisibility); exceptions count
                     as infeasible.
      iters/warmup   measurement repetitions (best-of-iters after warmup).
      example_specs  platform -> abstract workload (ShapeDtypeStructs):
                     lets the cache key be derived without materializing
                     the (possibly hundreds of MB) example arrays — a
                     warm-cache deploy then allocates nothing.
      args_from_shapes  (platform, shapes, dtype) -> args | None: rebuild
                     a concrete workload from a *recorded* shape bucket
                     (repro.tuning.profile encoding).  Returning None
                     means the bucket doesn't match this op's signature
                     and the caller falls back to the canonical example.
    """

    op: str
    space: Mapping[str, tuple[int, ...]]
    example_args: Callable[[Any], tuple]          # platform -> workload args
    feasible: Callable[[BlockConfig, Any, tuple], bool] | None = None
    iters: int = 2
    warmup: int = 1
    example_specs: Callable[[Any], tuple] | None = None
    args_from_shapes: Callable[[Any, str, str], tuple | None] | None = None

    def workload_spec(self, platform: Any) -> tuple:
        if self.example_specs is not None:
            return self.example_specs(platform)
        return self.example_args(platform)

    def cache_key(self, abi: str, platform: Any, args: Sequence[Any]) -> CacheKey:
        return CacheKey.from_args(abi, platform_fingerprint(platform), args)


@dataclasses.dataclass(frozen=True)
class TuneEvent:
    """One (op, geometry) tuning outcome during a bind (hit/miss record)."""

    op: str
    status: str
    key: str
    config: BlockConfig


@dataclasses.dataclass(frozen=True)
class TuneOutcome:
    """One op's aggregate tuning outcome — what the SwapReport records.

    ``status``/``config`` keep the PR 1 single-string view (summary
    status, primary config); ``geometries`` is the per-geometry
    breakdown the dispatch table was built from; ``search_rank`` is the
    op's position in the profile-driven search order (1 = hottest), or
    None when ordering was not profile-driven.
    """

    status: str
    config: str
    geometries: tuple[GeometryOutcome, ...] = ()
    search_rank: int | None = None


class TuningContext:
    """Carries the site cache (and optionally a workload profile) through
    one binding pass.

    Args:
      cache           the site's TuningCache (loaded by the caller).
      platform        the Platform being deployed onto (keys embed its
                      fingerprint, so caches never leak across hardware).
      ops             restricts which ops may *search* on a miss
                      (searching is the expensive part); cache lookups and
                      default fallbacks always apply.
      search_on_miss  False makes the context read-only — deploys never
                      pay search cost, they only replay what the site has
                      already tuned.
      profile         optional WorkloadProfile: ops with recorded traffic
                      are keyed (and searched) on their top-K observed
                      geometries instead of the canonical example.
      current_abis    optional op -> AbiString of the site's current
                      declarations; triggers an ABI-expiry sweep of the
                      cache at construction (see expiry.expire_stale).
      top_k           how many recorded geometries per op enter the
                      dispatch table (matches repro.tuning.warm's --top).
      search_budget   cap on how many searches this bind may pay; None is
                      unlimited.  Exhausted-budget misses bind the
                      platform default ("search-budget-exhausted").
      priority        op -> rank (1 = hottest) from profile-driven op
                      ordering; recorded in each TuneOutcome so the
                      SwapReport shows where the search budget went.

    After construction, ``expiry`` holds the sweep's ExpiryReport (or
    None) and ``events`` accumulates one TuneEvent per applied
    (op, geometry).
    """

    def __init__(
        self,
        cache: TuningCache,
        platform: Any,
        *,
        ops: Iterable[str] | None = None,
        search_on_miss: bool = True,
        profile: Any = None,
        current_abis: Mapping[str, Any] | None = None,
        top_k: int = 3,
        search_budget: int | None = None,
        priority: Mapping[str, int] | None = None,
    ) -> None:
        self.cache = cache
        self.platform = platform
        self.ops = None if ops is None else frozenset(ops)
        self.search_on_miss = search_on_miss
        self.profile = profile
        self.top_k = max(int(top_k), 1)
        self.search_budget = search_budget
        self.searches_spent = 0
        self.priority = dict(priority) if priority else None
        self.events: list[TuneEvent] = []
        self.expiry = None
        # (op, platform, shapes, dtype) of each evicted entry: a miss is
        # attributed to expiry only when THIS geometry lost its entry, so
        # first-time searches are never mislabelled as revision churn
        self._expired_geoms: set[tuple[str, str, str, str]] = set()
        if current_abis:
            from repro.tuning.expiry import expire_stale

            self.expiry = expire_stale(cache, current_abis)
            if len(self.expiry):
                log.info(self.expiry.describe())
                for op, encoded in self.expiry.evicted:
                    parts = encoded.split("|")
                    if len(parts) == 4:
                        self._expired_geoms.add((op, parts[1], parts[2], parts[3]))

    # ------------------------------------------------------------------ #
    def _key(self, impl: Any, shapes: str, dtype: str) -> CacheKey:
        return CacheKey(abi=str(impl.abi),
                        platform=platform_fingerprint(self.platform),
                        shapes=shapes, dtype=dtype)

    def _resolve_geometry(
        self, name: str, impl: Any, tuner: "OpTuner",
        shapes: str, dtype: str, count: float, *, profiled: bool,
    ) -> GeometryOutcome:
        """Hit/search/default decision for one (op, geometry) bucket.

        Key derivation is string-only — a cache-hit deploy allocates no
        workload arrays; synthesis of a geometry happens only when a miss
        actually triggers a search.
        """
        key = self._key(impl, shapes, dtype)
        expired = (name, key.platform, shapes, dtype) in self._expired_geoms
        config = self.cache.get(key)
        status = None
        if config is not None:
            status = "cache-hit"
        elif self.search_on_miss and (self.ops is None or name in self.ops):
            if self.search_budget is not None and \
                    self.searches_spent >= self.search_budget:
                config = default_config(name, self.platform)
                status = "search-budget-exhausted"
            else:
                args = None
                if profiled:
                    if tuner.args_from_shapes is not None:
                        args = tuner.args_from_shapes(self.platform, shapes, dtype)
                    if args is None:
                        log.warning(
                            "profiled geometry %r for op %s does not match "
                            "its signature; binding the platform default "
                            "for that bucket", shapes, name,
                        )
                        config = default_config(name, self.platform)
                        status = "unsynthesizable-default"
                else:
                    args = tuner.example_args(self.platform)
                if status is None:
                    self.searches_spent += 1
                    config, ok = search_into_cache(
                        self.cache, self.platform, tuner, impl.fn, args, key)
                    status = ("search-failed-default" if not ok
                              else "cache-expired-searched" if expired
                              else "cache-miss-searched")
        else:
            config = default_config(name, self.platform)
            status = "cache-expired-default" if expired else "cache-miss-default"
        self.events.append(TuneEvent(op=name, status=status, key=key.encode(),
                                     config=config))
        log.info("tune %-18s %-28s %s (%s)", name, shapes or "<scalar>",
                 status, config)
        return GeometryOutcome(shapes=shapes, dtype=dtype, status=status,
                               config=config, count=count)

    def apply(self, name: str, impl: Any) -> tuple[Any, TuneOutcome | None]:
        """Resolve one chosen impl; returns (impl', TuneOutcome | None).

        Impls without a tuner hook (references, untunable natives) pass
        through untouched (outcome None).  Otherwise the impl's fn is
        wrapped in a `TunedDispatch` over a `ConfigTable` holding:

          1. the profile's top-K recorded geometries for this op
             (or the canonical example when no traffic was recorded),
             each resolved hit/search/default as documented above;
          2. every further already-warmed cache entry under the same
             (ABI, platform fingerprint) — a cache warmed deeper than
             the profile's current top-K still binds hot.

        The model calls ``binding[op]`` unchanged; per-call geometry
        picks its entry at trace time (exact -> nearest -> default), and
        an explicit ``config=`` kwarg still wins inside the kernel.
        """
        tuner: OpTuner | None = getattr(impl, "tuner", None)
        if tuner is None:
            return impl, None
        geometries: list[tuple[str, str, float, bool]] = []
        if self.profile is not None:
            for geo, count in self.profile.top(op=name, k=self.top_k):
                geometries.append((geo.shapes, geo.dtype, float(count), True))
        if not geometries:
            shapes, dtype = bucket_shapes(tuner.workload_spec(self.platform))
            geometries.append((shapes, dtype, 0.0, False))
        outcomes = [
            self._resolve_geometry(name, impl, tuner, shapes, dtype, count,
                                   profiled=profiled)
            for shapes, dtype, count, profiled in geometries
        ]
        # a profile whose every bucket is foreign to this op must not leave
        # the op untuned: fall back to the canonical geometry, like PR 2 did
        if all(o.status == "unsynthesizable-default" for o in outcomes):
            shapes, dtype = bucket_shapes(tuner.workload_spec(self.platform))
            if (shapes, dtype) not in {(o.shapes, o.dtype) for o in outcomes}:
                outcomes.append(self._resolve_geometry(
                    name, impl, tuner, shapes, dtype, 0.0, profiled=False))
        # sweep: already-warmed entries beyond the profiled top-K bind too
        seen = {(o.shapes, o.dtype) for o in outcomes}
        for (shapes, dtype), config in sorted(
                self.cache.entries_for(str(impl.abi),
                                       platform_fingerprint(self.platform)).items()):
            if (shapes, dtype) in seen:
                continue
            outcomes.append(GeometryOutcome(shapes=shapes, dtype=dtype,
                                            status="cache-hit", config=config))
        table = ConfigTable(name, outcomes,
                            default=default_config(name, self.platform))
        statuses = [o.status for o in outcomes]
        if len(set(statuses)) == 1:
            summary = statuses[0]
        else:
            freq: dict[str, int] = {}
            for s in statuses:
                freq[s] = freq.get(s, 0) + 1
            summary = "mixed(" + ",".join(
                f"{s}:{n}" for s, n in sorted(freq.items())) + ")"
        rank = self.priority.get(name) if self.priority else None
        tuned = dataclasses.replace(
            impl, fn=TunedDispatch(impl.fn, table), config=table
        )
        return tuned, TuneOutcome(status=summary, config=str(table.primary),
                                  geometries=tuple(outcomes), search_rank=rank)

    # ------------------------------------------------------------------ #
    def flush(self) -> None:
        """Persist any new winners (atomic; no-op when nothing changed).

        Persistence failure must not kill a deployment that already holds
        a perfectly good binding — the site just pays the search again
        next time.  Mirrors the read side's corruption tolerance.
        """
        if not self.cache.dirty:
            return
        try:
            self.cache.save()
        except OSError as e:
            log.warning("could not persist tuning cache %s: %s (continuing; "
                        "this deployment is tuned, the next will re-search)",
                        self.cache.path, e)
