"""OpTuner + TuningContext — deferred specialization at bind time.

An `OpTuner` is the hook a NATIVE implementation registers alongside its
callable: the config space, a canonical per-platform example workload,
and a feasibility predicate (VMEM working set, shape divisibility).  The
registry never imports this module; it just carries the hook and hands
it to whatever `TuningContext` the Runtime passes into `bind()` — the
same inversion the paper uses for site resources: the bundle declares
*what* can be specialized, the site decides *whether and when*.

`TuningContext.apply` resolves one bound impl:

  cache hit            -> inject the cached config        ("cache-hit")
  miss, op selected    -> search now, persist the winner  ("cache-miss-searched")
  miss, not selected   -> platform-default config         ("cache-miss-default")
  search found nothing -> platform-default config         ("search-failed-default")

Every outcome is surfaced in the binding's SwapReport so EXPERIMENTS
logs show exactly which deployments ran tuned and from where.
"""

from __future__ import annotations

import dataclasses
import functools
import logging
from typing import Any, Callable, Iterable, Mapping, Sequence

from repro.tuning.cache import CacheKey, TuningCache, platform_fingerprint
from repro.tuning.config import BlockConfig, default_config
from repro.tuning.search import SearchResult, search

__all__ = ["OpTuner", "TuningContext", "TuneEvent"]

log = logging.getLogger("repro.tuning")


@dataclasses.dataclass(frozen=True)
class OpTuner:
    """Registered next to a native impl: how to specialize it to a site.

    The impl's callable must accept a ``config=BlockConfig`` keyword; the
    context injects the resolved config via functools.partial, so model
    code keeps calling the op with its ordinary arguments.
    """

    op: str
    space: Mapping[str, tuple[int, ...]]
    example_args: Callable[[Any], tuple]          # platform -> workload args
    feasible: Callable[[BlockConfig, Any, tuple], bool] | None = None
    iters: int = 2
    warmup: int = 1
    # platform -> abstract workload (ShapeDtypeStructs): lets the cache key
    # be derived without materializing the (possibly hundreds of MB) example
    # arrays — a warm-cache deploy then allocates nothing.
    example_specs: Callable[[Any], tuple] | None = None

    def workload_spec(self, platform: Any) -> tuple:
        if self.example_specs is not None:
            return self.example_specs(platform)
        return self.example_args(platform)

    def cache_key(self, abi: str, platform: Any, args: Sequence[Any]) -> CacheKey:
        return CacheKey.from_args(abi, platform_fingerprint(platform), args)


@dataclasses.dataclass(frozen=True)
class TuneEvent:
    """One op's tuning outcome during a bind (hit/miss/fallback record)."""

    op: str
    status: str
    key: str
    config: BlockConfig


class TuningContext:
    """Carries the site cache through one binding pass.

    ``ops`` restricts which ops may *search* on a miss (searching is the
    expensive part); cache lookups and default fallbacks always apply.
    ``search_on_miss=False`` makes the context read-only — deploys never
    pay search cost, they only replay what the site has already tuned.
    """

    def __init__(
        self,
        cache: TuningCache,
        platform: Any,
        *,
        ops: Iterable[str] | None = None,
        search_on_miss: bool = True,
    ) -> None:
        self.cache = cache
        self.platform = platform
        self.ops = None if ops is None else frozenset(ops)
        self.search_on_miss = search_on_miss
        self.events: list[TuneEvent] = []

    # ------------------------------------------------------------------ #
    def apply(self, name: str, impl: Any) -> tuple[Any, str, str]:
        """Resolve one chosen impl; returns (impl', status, config string).

        Impls without a tuner hook (references, untunable natives) pass
        through untouched with empty annotations.
        """
        tuner: OpTuner | None = getattr(impl, "tuner", None)
        if tuner is None:
            return impl, "", ""
        key = tuner.cache_key(str(impl.abi), self.platform,
                              tuner.workload_spec(self.platform))
        config = self.cache.get(key)
        if config is not None:
            status = "cache-hit"
        elif self.search_on_miss and (self.ops is None or name in self.ops):
            result = self._search(tuner, impl.fn, tuner.example_args(self.platform))
            if result.best is None:
                config = default_config(name, self.platform)
                status = "search-failed-default"
                # persist the fallback too: a site where every candidate
                # fails must not re-pay the failed search on every deploy
                self.cache.put(key, config, metrics={"search_failed": True})
            else:
                config = result.best
                status = "cache-miss-searched"
                self.cache.put(key, config, metrics={
                    "best_us": result.best_seconds * 1e6,
                    "measured": len(result.measurements),
                    "pruned": result.pruned,
                    "failed": result.failed,
                })
        else:
            config = default_config(name, self.platform)
            status = "cache-miss-default"
        self.events.append(TuneEvent(op=name, status=status, key=key.encode(),
                                     config=config))
        log.info("tune %-18s %s (%s)", name, status, config)
        tuned = dataclasses.replace(
            impl, fn=functools.partial(impl.fn, config=config), config=config
        )
        return tuned, status, str(config)

    # ------------------------------------------------------------------ #
    def _search(self, tuner: OpTuner, fn: Callable[..., Any],
                args: tuple) -> SearchResult:
        feasible = None
        if tuner.feasible is not None:
            feasible = lambda cfg: tuner.feasible(cfg, self.platform, args)  # noqa: E731
        return search(
            lambda cfg: fn(*args, config=cfg),
            tuner.space,
            feasible=feasible,
            iters=tuner.iters,
            warmup=tuner.warmup,
        )

    # ------------------------------------------------------------------ #
    def flush(self) -> None:
        """Persist any new winners (atomic; no-op when nothing changed).

        Persistence failure must not kill a deployment that already holds
        a perfectly good binding — the site just pays the search again
        next time.  Mirrors the read side's corruption tolerance.
        """
        if not self.cache.dirty:
            return
        try:
            self.cache.save()
        except OSError as e:
            log.warning("could not persist tuning cache %s: %s (continuing; "
                        "this deployment is tuned, the next will re-search)",
                        self.cache.path, e)
