"""OpTuner + TuningContext — deferred specialization at bind time.

An `OpTuner` is the hook a NATIVE implementation registers alongside its
callable: the config space, a canonical per-platform example workload,
and a feasibility predicate (VMEM working set, shape divisibility).  The
registry never imports this module; it just carries the hook and hands
it to whatever `TuningContext` the Runtime passes into `bind()` — the
same inversion the paper uses for site resources: the bundle declares
*what* can be specialized, the site decides *whether and when*.

`TuningContext.apply` resolves one bound impl:

  cache hit            -> inject the cached config        ("cache-hit")
  miss, op selected    -> search now, persist the winner  ("cache-miss-searched")
  miss after ABI expiry-> search now, persist the winner  ("cache-expired-searched")
  miss, not selected   -> platform-default config         ("cache-miss-default")
  search found nothing -> platform-default config         ("search-failed-default")

Every outcome is surfaced in the binding's SwapReport so EXPERIMENTS
logs show exactly which deployments ran tuned and from where.

Two optional inputs close the tune-on-real-traffic loop (PR 2):

  * ``profile`` — a `WorkloadProfile` of captured live geometries.  When
    the profile has observations for an op, the cache key (and, on a
    miss, the searched workload) comes from the *hottest recorded
    geometry* instead of the canonical example, so a cache pre-warmed by
    ``repro.tuning.warm`` from the same profile hits on the next deploy.
  * ``current_abis`` — the site's currently declared ABI per op.  Stale
    cache entries (tuned against an older kernel revision) are expired
    up front (see expiry.py) and the re-search is labelled
    "cache-expired-searched" in the SwapReport.
"""

from __future__ import annotations

import dataclasses
import functools
import logging
from typing import Any, Callable, Iterable, Mapping, Sequence

from repro.tuning.cache import CacheKey, TuningCache, bucket_shapes, platform_fingerprint
from repro.tuning.config import BlockConfig, default_config
from repro.tuning.search import search

__all__ = ["OpTuner", "TuningContext", "TuneEvent", "search_into_cache"]

log = logging.getLogger("repro.tuning")


def search_into_cache(
    cache: TuningCache,
    platform: Any,
    tuner: "OpTuner",
    fn: Callable[..., Any],
    args: tuple,
    key: CacheKey,
    *,
    extra_metrics: Mapping[str, Any] | None = None,
) -> tuple[BlockConfig, bool]:
    """Search the op's config space for `args`; persist the outcome at `key`.

    The single search-and-persist path shared by bind-time tuning
    (TuningContext.apply) and offline warming (repro.tuning.warm), so the
    two can never diverge in feasibility handling or persisted metrics.
    Returns (config, searched_ok); a search where nothing survives
    persists the platform default — the failed search is paid once, not
    per deploy — and returns searched_ok=False.
    """
    feasible = None
    if tuner.feasible is not None:
        feasible = lambda cfg: tuner.feasible(cfg, platform, args)  # noqa: E731
    result = search(
        lambda cfg: fn(*args, config=cfg),
        tuner.space,
        feasible=feasible,
        iters=tuner.iters,
        warmup=tuner.warmup,
    )
    if result.best is None:
        config = default_config(tuner.op, platform)
        metrics = {"search_failed": True}
        metrics.update(extra_metrics or {})
        cache.put(key, config, metrics=metrics)
        return config, False
    metrics = {
        "best_us": result.best_seconds * 1e6,
        "measured": len(result.measurements),
        "pruned": result.pruned,
        "failed": result.failed,
    }
    metrics.update(extra_metrics or {})
    cache.put(key, result.best, metrics)
    return result.best, True


@dataclasses.dataclass(frozen=True)
class OpTuner:
    """Registered next to a native impl: how to specialize it to a site.

    The impl's callable must accept a ``config=BlockConfig`` keyword; the
    context injects the resolved config via functools.partial, so model
    code keeps calling the op with its ordinary arguments.

    Fields:
      op             logical op name (matches the registry declaration).
      space          name -> candidate values; the search enumerates the
                     cartesian product (see search.enumerate_space).
      example_args   platform -> concrete canonical workload, used when no
                     recorded geometry is available.
      feasible       (config, platform, args) -> bool pre-measurement
                     filter (VMEM budget, divisibility); exceptions count
                     as infeasible.
      iters/warmup   measurement repetitions (best-of-iters after warmup).
      example_specs  platform -> abstract workload (ShapeDtypeStructs):
                     lets the cache key be derived without materializing
                     the (possibly hundreds of MB) example arrays — a
                     warm-cache deploy then allocates nothing.
      args_from_shapes  (platform, shapes, dtype) -> args | None: rebuild
                     a concrete workload from a *recorded* shape bucket
                     (repro.tuning.profile encoding).  Returning None
                     means the bucket doesn't match this op's signature
                     and the caller falls back to the canonical example.
    """

    op: str
    space: Mapping[str, tuple[int, ...]]
    example_args: Callable[[Any], tuple]          # platform -> workload args
    feasible: Callable[[BlockConfig, Any, tuple], bool] | None = None
    iters: int = 2
    warmup: int = 1
    example_specs: Callable[[Any], tuple] | None = None
    args_from_shapes: Callable[[Any, str, str], tuple | None] | None = None

    def workload_spec(self, platform: Any) -> tuple:
        if self.example_specs is not None:
            return self.example_specs(platform)
        return self.example_args(platform)

    def cache_key(self, abi: str, platform: Any, args: Sequence[Any]) -> CacheKey:
        return CacheKey.from_args(abi, platform_fingerprint(platform), args)


@dataclasses.dataclass(frozen=True)
class TuneEvent:
    """One op's tuning outcome during a bind (hit/miss/fallback record)."""

    op: str
    status: str
    key: str
    config: BlockConfig


class TuningContext:
    """Carries the site cache (and optionally a workload profile) through
    one binding pass.

    Args:
      cache           the site's TuningCache (loaded by the caller).
      platform        the Platform being deployed onto (keys embed its
                      fingerprint, so caches never leak across hardware).
      ops             restricts which ops may *search* on a miss
                      (searching is the expensive part); cache lookups and
                      default fallbacks always apply.
      search_on_miss  False makes the context read-only — deploys never
                      pay search cost, they only replay what the site has
                      already tuned.
      profile         optional WorkloadProfile: ops with recorded traffic
                      are keyed (and searched) on their hottest observed
                      geometry instead of the canonical example.
      current_abis    optional op -> AbiString of the site's current
                      declarations; triggers an ABI-expiry sweep of the
                      cache at construction (see expiry.expire_stale).

    After construction, ``expiry`` holds the sweep's ExpiryReport (or
    None) and ``events`` accumulates one TuneEvent per applied op.
    """

    def __init__(
        self,
        cache: TuningCache,
        platform: Any,
        *,
        ops: Iterable[str] | None = None,
        search_on_miss: bool = True,
        profile: Any = None,
        current_abis: Mapping[str, Any] | None = None,
    ) -> None:
        self.cache = cache
        self.platform = platform
        self.ops = None if ops is None else frozenset(ops)
        self.search_on_miss = search_on_miss
        self.profile = profile
        self.events: list[TuneEvent] = []
        self.expiry = None
        # (op, platform, shapes, dtype) of each evicted entry: a miss is
        # attributed to expiry only when THIS geometry lost its entry, so
        # first-time searches are never mislabelled as revision churn
        self._expired_geoms: set[tuple[str, str, str, str]] = set()
        if current_abis:
            from repro.tuning.expiry import expire_stale

            self.expiry = expire_stale(cache, current_abis)
            if len(self.expiry):
                log.info(self.expiry.describe())
                for op, encoded in self.expiry.evicted:
                    parts = encoded.split("|")
                    if len(parts) == 4:
                        self._expired_geoms.add((op, parts[1], parts[2], parts[3]))

    # ------------------------------------------------------------------ #
    def _key(self, impl: Any, shapes: str, dtype: str) -> CacheKey:
        return CacheKey(abi=str(impl.abi),
                        platform=platform_fingerprint(self.platform),
                        shapes=shapes, dtype=dtype)

    def apply(self, name: str, impl: Any) -> tuple[Any, str, str]:
        """Resolve one chosen impl; returns (impl', status, config string).

        Impls without a tuner hook (references, untunable natives) pass
        through untouched with empty annotations.  Key derivation is
        string-only — a cache-hit deploy allocates no workload arrays;
        synthesis of a profiled geometry happens only when a miss
        actually triggers a search.
        """
        tuner: OpTuner | None = getattr(impl, "tuner", None)
        if tuner is None:
            return impl, "", ""
        profiled = None
        if self.profile is not None and tuner.args_from_shapes is not None:
            top = self.profile.top(op=name, k=1)
            if top:
                profiled = top[0][0]
        if profiled is not None:
            key = self._key(impl, profiled.shapes, profiled.dtype)
        else:
            shapes, dtype = bucket_shapes(tuner.workload_spec(self.platform))
            key = self._key(impl, shapes, dtype)
        expired = (name, key.platform, key.shapes, key.dtype) in self._expired_geoms
        config = self.cache.get(key)
        if config is not None:
            status = "cache-hit"
        elif self.search_on_miss and (self.ops is None or name in self.ops):
            args = None
            if profiled is not None:
                args = tuner.args_from_shapes(self.platform, profiled.shapes,
                                              profiled.dtype)
                if args is None:
                    # recorded bucket doesn't match the op signature: fall
                    # back wholly to the canonical geometry — key and
                    # measurement must describe the same workload
                    log.warning(
                        "profiled geometry %r for op %s does not match its "
                        "signature; falling back to the canonical example",
                        profiled.shapes, name,
                    )
                    shapes, dtype = bucket_shapes(
                        tuner.workload_spec(self.platform))
                    key = self._key(impl, shapes, dtype)
                    config = self.cache.get(key)
            if config is not None:
                status = "cache-hit"
            else:
                if args is None:
                    args = tuner.example_args(self.platform)
                config, ok = search_into_cache(
                    self.cache, self.platform, tuner, impl.fn, args, key)
                if not ok:
                    status = "search-failed-default"
                else:
                    status = ("cache-expired-searched" if expired
                              else "cache-miss-searched")
        else:
            config = default_config(name, self.platform)
            status = "cache-expired-default" if expired else "cache-miss-default"
        self.events.append(TuneEvent(op=name, status=status, key=key.encode(),
                                     config=config))
        log.info("tune %-18s %s (%s)", name, status, config)
        tuned = dataclasses.replace(
            impl, fn=functools.partial(impl.fn, config=config), config=config
        )
        return tuned, status, str(config)

    # ------------------------------------------------------------------ #
    def flush(self) -> None:
        """Persist any new winners (atomic; no-op when nothing changed).

        Persistence failure must not kill a deployment that already holds
        a perfectly good binding — the site just pays the search again
        next time.  Mirrors the read side's corruption tolerance.
        """
        if not self.cache.dirty:
            return
        try:
            self.cache.save()
        except OSError as e:
            log.warning("could not persist tuning cache %s: %s (continuing; "
                        "this deployment is tuned, the next will re-search)",
                        self.cache.path, e)
