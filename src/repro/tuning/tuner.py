"""OpTuner + TuningContext — deferred specialization at bind time.

An `OpTuner` is the hook a NATIVE implementation registers alongside its
callable: the config space, a canonical per-platform example workload,
and a feasibility predicate (VMEM working set, shape divisibility).  The
registry never imports this module; it just carries the hook and hands
it to whatever `TuningContext` the Runtime passes into `bind()` — the
same inversion the paper uses for site resources: the bundle declares
*what* can be specialized, the site decides *whether and when*.

`TuningContext.apply` resolves one bound impl.  Since the
geometry-dispatch redesign it no longer bakes a single config into the
callable: it resolves *every* relevant geometry — the profile's top-K
recorded buckets (or the canonical example when no traffic was
recorded), plus any further already-warmed cache entries for the same
(ABI, platform) — into a `ConfigTable`, and wraps the impl in a
`TunedDispatch` that buckets each call's operand shapes at trace time
and injects the matching entry (exact -> nearest bucket -> platform
default).  Per geometry, the outcome vocabulary is unchanged:

  cache hit            -> use the cached config            ("cache-hit")
  miss, op selected    -> search now, persist the winner   ("cache-miss-searched")
  miss after ABI expiry-> search now, persist the winner   ("cache-expired-searched")
  miss, not selected   -> platform-default config          ("cache-miss-default")
  search found nothing -> platform-default config          ("search-failed-default")
  miss, budget spent   -> platform-default config          ("search-budget-exhausted")
  bucket unsynthesizable-> platform-default config         ("unsynthesizable-default")
  beyond the per-op cap-> entry shed, bucket not bound     ("cache-evicted-lru")

Every geometry's outcome is surfaced in the binding's SwapReport
(`SwapReport.geometries`), with `SwapReport.tuning` summarizing (the
shared status when all geometries agree, a "mixed(...)" breakdown
otherwise), so EXPERIMENTS logs show exactly which deployments ran
tuned, at which geometries, and from where.

Optional inputs close the tune-on-real-traffic loop:

  * ``profile`` — a `WorkloadProfile` of captured live geometries.  Ops
    with recorded traffic are keyed (and, on a miss, searched) on their
    top-K recorded buckets instead of the canonical example, so a cache
    pre-warmed by ``repro.tuning.warm`` from the same profile hits on
    every bucket at the next deploy — zero searches for a warmed,
    shape-polymorphic deployment.
  * ``current_abis`` — the site's currently declared ABI per op.  Stale
    cache entries (tuned against an older kernel revision) are expired
    up front (see expiry.py) and the re-search is labelled
    "cache-expired-searched" in the SwapReport.
  * ``search_budget`` / ``priority`` — cap on how many searches one bind
    may pay, and the profile-driven op ordering the Runtime derived
    (hottest first); the rank lands in `SwapReport.search_rank`.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Iterable, Mapping, Sequence

from repro.tuning.cache import CacheKey, TuningCache, bucket_shapes, platform_fingerprint
from repro.tuning.config import BlockConfig, default_config
from repro.tuning.dispatch import (
    ConfigTable,
    GeometryOutcome,
    TunedDispatch,
    _parse_bucket,
    calibrate_dtype_penalty,
)
from repro.tuning.search import search

__all__ = ["OpTuner", "TuningContext", "TuneEvent", "TuneOutcome",
           "search_into_cache", "bucket_validator"]

log = logging.getLogger("repro.tuning")

# Statuses whose geometry holds a live cache entry after resolution
# (search_into_cache persists even a failed search's default).  The
# per-op cap budgets THIS state: placeholder outcomes — budget spent,
# bucket unsynthesizable, search disabled — hold no entry, so they
# neither consume cap slots nor justify evicting measured state.
# "bundle-imported" is a cache hit whose entry arrived via a portable
# tuning bundle (and revalidated feasible on this platform): first-class
# entry-backed state, labelled for provenance only.
_BACKED_STATUSES = frozenset({
    "cache-hit", "cache-miss-searched", "cache-expired-searched",
    "search-failed-default", "bundle-imported",
})


def search_into_cache(
    cache: TuningCache,
    platform: Any,
    tuner: "OpTuner",
    fn: Callable[..., Any],
    args: tuple,
    key: CacheKey,
    *,
    extra_metrics: Mapping[str, Any] | None = None,
) -> tuple[BlockConfig, bool]:
    """Search the op's config space for `args`; persist the outcome at `key`.

    The single search-and-persist path shared by bind-time tuning
    (TuningContext.apply) and offline warming (repro.tuning.warm), so the
    two can never diverge in feasibility handling or persisted metrics.
    Returns (config, searched_ok); a search where nothing survives
    persists the platform default — the failed search is paid once, not
    per deploy — and returns searched_ok=False.
    """
    feasible = None
    if tuner.feasible is not None:
        feasible = lambda cfg: tuner.feasible(cfg, platform, args)  # noqa: E731
    result = search(
        lambda cfg: fn(*args, config=cfg),
        tuner.space,
        feasible=feasible,
        iters=tuner.iters,
        warmup=tuner.warmup,
    )
    if result.best is None:
        config = default_config(tuner.op, platform)
        metrics = {"search_failed": True}
        metrics.update(extra_metrics or {})
        cache.put(key, config, metrics=metrics)
        return config, False
    metrics = {
        "best_us": result.best_seconds * 1e6,
        "measured": len(result.measurements),
        "pruned": result.pruned,
        "failed": result.failed,
    }
    metrics.update(extra_metrics or {})
    cache.put(key, result.best, metrics)
    return result.best, True


def bucket_validator(tuner: "OpTuner", platform: Any):
    """(config, shapes, dtype) -> bool closure over the tuner's feasibility
    predicate, for dtype-crossing borrows in `ConfigTable.resolve`.

    Rebuilds the bucket as ShapeDtypeStructs carrying the *borrowing*
    call's dtype (the predicates only read shapes/dtypes, so nothing is
    allocated) and re-runs the VMEM/divisibility check — a config tuned
    for fp32 must re-qualify for the bf16 geometry before it is lent out.
    A composite quantized dtype ("float32+int8") rebuilds the first part
    in the base dtype and the rest in the quantized storage dtype — the
    conservative assignment: the only predicate that reads operand
    dtypes (quant_matmul's byte accounting) keys off a non-first arg,
    and sizing the others at 1 byte can only under-estimate VMEM for
    predicates that ignore dtype anyway.  Returns None when the tuner
    has no predicate (any structural borrow is admissible).
    """
    if tuner.feasible is None:
        return None

    def validate(config: BlockConfig, shapes: str, dtype: str) -> bool:
        import jax

        parts = _parse_bucket(shapes)
        if parts is None:
            return False
        base, _, quant = str(dtype).partition("+")
        try:
            args = tuple(
                jax.ShapeDtypeStruct(p, quant if (quant and i) else base)
                if p else 0
                for i, p in enumerate(parts)
            )
            return bool(tuner.feasible(config, platform, args))
        except Exception:
            return False

    return validate


@dataclasses.dataclass(frozen=True)
class OpTuner:
    """Registered next to a native impl: how to specialize it to a site.

    The impl's callable must accept a ``config=BlockConfig`` keyword; the
    context wraps it in a `TunedDispatch` that injects the per-geometry
    resolved config at trace time, so model code keeps calling the op
    with its ordinary arguments.

    Fields:
      op             logical op name (matches the registry declaration).
      space          name -> candidate values; the search enumerates the
                     cartesian product (see search.enumerate_space).
      example_args   platform -> concrete canonical workload, used when no
                     recorded geometry is available.
      feasible       (config, platform, args) -> bool pre-measurement
                     filter (VMEM budget, divisibility); exceptions count
                     as infeasible.
      iters/warmup   measurement repetitions (best-of-iters after warmup).
      example_specs  platform -> abstract workload (ShapeDtypeStructs):
                     lets the cache key be derived without materializing
                     the (possibly hundreds of MB) example arrays — a
                     warm-cache deploy then allocates nothing.
      args_from_shapes  (platform, shapes, dtype) -> args | None: rebuild
                     a concrete workload from a *recorded* shape bucket
                     (repro.tuning.profile encoding).  Returning None
                     means the bucket doesn't match this op's signature
                     and the caller falls back to the canonical example.
    """

    op: str
    space: Mapping[str, tuple[int, ...]]
    example_args: Callable[[Any], tuple]          # platform -> workload args
    feasible: Callable[[BlockConfig, Any, tuple], bool] | None = None
    iters: int = 2
    warmup: int = 1
    example_specs: Callable[[Any], tuple] | None = None
    args_from_shapes: Callable[[Any, str, str], tuple | None] | None = None

    def workload_spec(self, platform: Any) -> tuple:
        if self.example_specs is not None:
            return self.example_specs(platform)
        return self.example_args(platform)

    def cache_key(self, abi: str, platform: Any, args: Sequence[Any]) -> CacheKey:
        return CacheKey.from_args(abi, platform_fingerprint(platform), args)


@dataclasses.dataclass(frozen=True)
class TuneEvent:
    """One (op, geometry) tuning outcome during a bind (hit/miss record)."""

    op: str
    status: str
    key: str
    config: BlockConfig


@dataclasses.dataclass(frozen=True)
class TuneOutcome:
    """One op's aggregate tuning outcome — what the SwapReport records.

    ``status``/``config`` keep the PR 1 single-string view (summary
    status, primary config); ``geometries`` is the per-geometry
    breakdown the dispatch table was built from; ``search_rank`` is the
    op's position in the profile-driven search order (1 = hottest), or
    None when ordering was not profile-driven.
    """

    status: str
    config: str
    geometries: tuple[GeometryOutcome, ...] = ()
    search_rank: int | None = None


class TuningContext:
    """Carries the site cache (and optionally a workload profile) through
    one binding pass.

    Args:
      cache           the site's TuningCache (loaded by the caller).
      platform        the Platform being deployed onto (keys embed its
                      fingerprint, so caches never leak across hardware).
      ops             restricts which ops may *search* on a miss
                      (searching is the expensive part); cache lookups and
                      default fallbacks always apply.
      search_on_miss  False makes the context read-only — deploys never
                      pay search cost, they only replay what the site has
                      already tuned.
      profile         optional WorkloadProfile: ops with recorded traffic
                      are keyed (and searched) on their top-K observed
                      geometries instead of the canonical example.
      current_abis    optional op -> AbiString of the site's current
                      declarations; triggers an ABI-expiry sweep of the
                      cache at construction (see expiry.expire_stale).
      top_k           how many recorded geometries per op enter the
                      dispatch table (matches repro.tuning.warm's --top).
      search_budget   cap on how many searches this bind may pay; None is
                      unlimited.  Exhausted-budget misses bind the
                      platform default ("search-budget-exhausted").
      priority        op -> rank (1 = hottest) from profile-driven op
                      ordering; recorded in each TuneOutcome so the
                      SwapReport shows where the search budget went.
      bundle_report   optional bundle.ImportReport from a tuning-bundle
                      import that ran just before this bind: entries the
                      import *rejected* (structurally foreign buckets)
                      are surfaced as "bundle-rejected" geometries in the
                      op's SwapReport — reported, never bound — so the
                      EXPERIMENTS log shows exactly which shipped state
                      the target site could not use.
      max_entries     per-op dispatch-table cap (the bounded lifecycle
                      mode; Runtime.deploy(max_tuned_entries=) /
                      REPRO_TUNING_MAX_ENTRIES).  Each op binds at most
                      this many geometries — the hottest first — and any
                      further cached bucket is *evicted* under pressure:
                      tombstoned out of the cache and surfaced as
                      "cache-evicted-lru" in the SwapReport, so a
                      long-lived site serving shape-diverse traffic keeps
                      bounded tuning state instead of accreting every
                      bucket it ever saw.  None (default) is unbounded.

    After construction, ``expiry`` holds the sweep's ExpiryReport (or
    None) and ``events`` accumulates one TuneEvent per applied
    (op, geometry).
    """

    def __init__(
        self,
        cache: TuningCache,
        platform: Any,
        *,
        ops: Iterable[str] | None = None,
        search_on_miss: bool = True,
        profile: Any = None,
        current_abis: Mapping[str, Any] | None = None,
        top_k: int = 3,
        search_budget: int | None = None,
        priority: Mapping[str, int] | None = None,
        max_entries: int | None = None,
        bundle_report: Any = None,
    ) -> None:
        self.cache = cache
        self.platform = platform
        self.ops = None if ops is None else frozenset(ops)
        self.search_on_miss = search_on_miss
        self.profile = profile
        self.bundle_report = bundle_report
        self.top_k = max(int(top_k), 1)
        self.search_budget = search_budget
        self.max_entries = None if max_entries is None else max(int(max_entries), 1)
        self.searches_spent = 0
        self.priority = dict(priority) if priority else None
        self.events: list[TuneEvent] = []
        self.expiry = None
        # (op, platform, shapes, dtype) of each evicted entry: a miss is
        # attributed to expiry only when THIS geometry lost its entry, so
        # first-time searches are never mislabelled as revision churn
        self._expired_geoms: set[tuple[str, str, str, str]] = set()
        if current_abis:
            from repro.tuning.expiry import expire_stale

            self.expiry = expire_stale(cache, current_abis)
            if len(self.expiry):
                log.info(self.expiry.describe())
                for op, encoded in self.expiry.evicted:
                    parts = encoded.split("|")
                    if len(parts) == 4:
                        self._expired_geoms.add((op, parts[1], parts[2], parts[3]))

    # ------------------------------------------------------------------ #
    def _key(self, impl: Any, shapes: str, dtype: str) -> CacheKey:
        return CacheKey(abi=str(impl.abi),
                        platform=platform_fingerprint(self.platform),
                        shapes=shapes, dtype=dtype)

    def _resolve_geometry(
        self, name: str, impl: Any, tuner: "OpTuner",
        shapes: str, dtype: str, count: float, *, profiled: bool,
    ) -> GeometryOutcome:
        """Hit/search/default decision for one (op, geometry) bucket.

        Key derivation is string-only — a cache-hit deploy allocates no
        workload arrays; synthesis of a geometry happens only when a miss
        actually triggers a search.
        """
        key = self._key(impl, shapes, dtype)
        expired = (name, key.platform, shapes, dtype) in self._expired_geoms
        config = self.cache.get(key)
        status = None
        if config is not None:
            # provenance: a hit on an entry a tuning bundle shipped in (and
            # this platform revalidated) is labelled as such until a local
            # search re-measures the key
            status = ("bundle-imported"
                      if "bundle_origin" in self.cache.metrics(key)
                      else "cache-hit")
        elif self.search_on_miss and (self.ops is None or name in self.ops):
            if self.search_budget is not None and \
                    self.searches_spent >= self.search_budget:
                config = default_config(name, self.platform)
                status = "search-budget-exhausted"
            else:
                args = None
                if profiled:
                    if tuner.args_from_shapes is not None:
                        args = tuner.args_from_shapes(self.platform, shapes, dtype)
                    if args is None:
                        log.warning(
                            "profiled geometry %r for op %s does not match "
                            "its signature; binding the platform default "
                            "for that bucket", shapes, name,
                        )
                        config = default_config(name, self.platform)
                        status = "unsynthesizable-default"
                else:
                    args = tuner.example_args(self.platform)
                if status is None:
                    self.searches_spent += 1
                    config, ok = search_into_cache(
                        self.cache, self.platform, tuner, impl.fn, args, key)
                    status = ("search-failed-default" if not ok
                              else "cache-expired-searched" if expired
                              else "cache-miss-searched")
        else:
            config = default_config(name, self.platform)
            status = "cache-expired-default" if expired else "cache-miss-default"
        self.events.append(TuneEvent(op=name, status=status, key=key.encode(),
                                     config=config))
        log.info("tune %-18s %-28s %s (%s)", name, shapes or "<scalar>",
                 status, config)
        return GeometryOutcome(shapes=shapes, dtype=dtype, status=status,
                               config=config, count=count,
                               bytes=self.cache.entry_bytes(key))

    def _evict_under_pressure(
        self, name: str, impl: Any, shapes: str, dtype: str, count: float,
        config: BlockConfig,
    ) -> GeometryOutcome:
        """Shed one bucket beyond the per-op cap: tombstone its cache entry
        and report it as "cache-evicted-lru" (carrying the config it loses,
        so the EXPERIMENTS log records what a re-warm would have to redo)."""
        key = self._key(impl, shapes, dtype)
        nbytes = self.cache.entry_bytes(key)     # size it held, pre-eviction
        self.cache.evict(key)
        self.events.append(TuneEvent(op=name, status="cache-evicted-lru",
                                     key=key.encode(), config=config))
        log.info("tune %-18s %-28s cache-evicted-lru (cap %s)", name,
                 shapes or "<scalar>", self.max_entries)
        return GeometryOutcome(shapes=shapes, dtype=dtype,
                               status="cache-evicted-lru", config=config,
                               count=count, bytes=nbytes)

    def apply(self, name: str, impl: Any) -> tuple[Any, TuneOutcome | None]:
        """Resolve one chosen impl; returns (impl', TuneOutcome | None).

        Impls without a tuner hook (references, untunable natives) pass
        through untouched (outcome None).  Otherwise the impl's fn is
        wrapped in a `TunedDispatch` over a `ConfigTable` holding:

          1. the profile's top-K recorded geometries for this op
             (or the canonical example when no traffic was recorded),
             each resolved hit/search/default as documented above;
          2. every further already-warmed cache entry under the same
             (ABI, platform fingerprint) — a cache warmed deeper than
             the profile's current top-K still binds hot.

        The model calls ``binding[op]`` unchanged; per-call geometry
        picks its entry at trace time (exact -> nearest -> near-dtype ->
        default), and an explicit ``config=`` kwarg still wins inside the
        kernel.

        With ``max_entries`` set (the bounded lifecycle mode), the cap
        budgets the op's *entry-backed* state at K buckets: profiled
        candidates beyond the cap are never searched (their warmed
        entries may still bind when placeholder outcomes — budget spent,
        unsynthesizable — leave slots free), and every measured bucket
        beyond the K kept is evicted from the cache under pressure,
        surfaced as "cache-evicted-lru" geometries in the report (with
        the config it loses), so the SwapReport shows exactly which cold
        state the cap shed.
        """
        tuner: OpTuner | None = getattr(impl, "tuner", None)
        if tuner is None:
            return impl, None
        cap = self.max_entries
        geometries: list[tuple[str, str, float, bool]] = []
        if self.profile is not None:
            for geo, count in self.profile.top(op=name, k=self.top_k):
                geometries.append((geo.shapes, geo.dtype, float(count), True))
        if not geometries:
            shapes, dtype = bucket_shapes(tuner.workload_spec(self.platform))
            geometries.append((shapes, dtype, 0.0, False))
        overflow = [] if cap is None else geometries[cap:]
        geometries = geometries if cap is None else geometries[:cap]
        outcomes = [
            self._resolve_geometry(name, impl, tuner, shapes, dtype, count,
                                   profiled=profiled)
            for shapes, dtype, count, profiled in geometries
        ]
        # a profile whose every bucket is foreign to this op must not leave
        # the op untuned: fall back to the canonical geometry, like PR 2 did
        # — inserted FIRST, so a table cap trims the unsynthesizable
        # placeholders (all default configs), never the one real config
        if all(o.status == "unsynthesizable-default" for o in outcomes):
            shapes, dtype = bucket_shapes(tuner.workload_spec(self.platform))
            if (shapes, dtype) not in {(o.shapes, o.dtype) for o in outcomes}:
                outcomes.insert(0, self._resolve_geometry(
                    name, impl, tuner, shapes, dtype, 0.0, profiled=False))
        # sweep: every other already-warmed entry is a candidate for the
        # remaining entry-backed slots — profiled buckets beyond the cap
        # first (hottest first; never searched, but an existing entry may
        # still bind), then cold entries most-recently-used first, so a
        # cap keeps the hottest/still-warm state and sheds the rest
        fp = platform_fingerprint(self.platform)
        seen = {(o.shapes, o.dtype) for o in outcomes}
        entries = self.cache.entries_for(str(impl.abi), fp)
        pool: list[tuple[str, str, BlockConfig, float]] = []
        for shapes, dtype, count, _ in overflow:
            if (shapes, dtype) not in seen and (shapes, dtype) in entries:
                pool.append((shapes, dtype, entries[shapes, dtype], count))
                seen.add((shapes, dtype))
        cold = [(shapes, dtype, config, 0.0) for (shapes, dtype), config
                in entries.items() if (shapes, dtype) not in seen]
        cold.sort(key=lambda t: (-self.cache.last_used(
            self._key(impl, t[0], t[1])), t[0], t[1]))
        pool += cold
        slots = sum(o.status in _BACKED_STATUSES for o in outcomes)
        evicted: list[GeometryOutcome] = []
        bound_swept: list[tuple[str, str]] = []
        for shapes, dtype, config, count in pool:
            if cap is None or slots < cap:
                key = self._key(impl, shapes, dtype)
                status = ("bundle-imported"
                          if "bundle_origin" in self.cache.metrics(key)
                          else "cache-hit")
                outcomes.append(GeometryOutcome(
                    shapes=shapes, dtype=dtype, status=status, config=config,
                    count=count, bytes=self.cache.entry_bytes(key)))
                bound_swept.append((shapes, dtype))
                slots += 1
            else:
                evicted.append(self._evict_under_pressure(
                    name, impl, shapes, dtype, count, config))
        # refresh the recency of the swept entries this bind uses —
        # coldest first, so the fresh stamps PRESERVE their relative LRU
        # order instead of inverting it for the next eviction pass
        for shapes, dtype in reversed(bound_swept):
            self.cache.touch(self._key(impl, shapes, dtype))
        table_outcomes = outcomes
        if cap is not None:
            # entry-backed outcomes first: the table cap must keep every
            # real config and trim only default-config placeholders (whose
            # buckets then resolve via nearest/near-dtype, a strictly
            # better answer than a pinned shipped default)
            table_outcomes = (
                [o for o in outcomes if o.status in _BACKED_STATUSES]
                + [o for o in outcomes if o.status not in _BACKED_STATUSES])
        # demoted bundle candidates: configs a cross-site import could not
        # validate at their own bucket join the table's penalized pool
        # (never first-class, never against the cap) and the report.  A
        # still-demoted geometry that also resolved a placeholder outcome
        # (miss-default, budget spent — a local search would have upgraded
        # it and cleared the flag) sheds the placeholder: pinning the
        # shipped default at that bucket would shadow the validated borrow
        # with a strictly worse answer.
        dem_entries = self.cache.demoted_for(str(impl.abi), fp)
        demoted_outcomes = [
            GeometryOutcome(shapes=shapes, dtype=dtype,
                            status="bundle-demoted", config=config,
                            bytes=self.cache.entry_bytes(
                                self._key(impl, shapes, dtype)))
            for (shapes, dtype), config in sorted(dem_entries.items())
        ]
        if dem_entries:
            def shadows(o: GeometryOutcome) -> bool:
                return ((o.shapes, o.dtype) in dem_entries
                        and o.status not in _BACKED_STATUSES)

            outcomes = [o for o in outcomes if not shadows(o)]
            table_outcomes = [o for o in table_outcomes if not shadows(o)]
        # dtype-crossing borrow penalty: calibrated from this op's measured
        # cross-dtype timings when the cache holds any (same shape bucket,
        # different dtype, both with a best_us), else the fixed fallback
        measured: dict[tuple[str, str], float] = {}
        for geom in entries:
            us = self.cache.metrics(self._key(impl, *geom)).get("best_us")
            if us:
                measured[geom] = float(us)
        penalty = calibrate_dtype_penalty(measured)
        table = ConfigTable(name, table_outcomes,
                            default=default_config(name, self.platform),
                            validate=bucket_validator(tuner, self.platform),
                            max_entries=cap, demoted=demoted_outcomes,
                            dtype_penalty=penalty)
        outcomes = outcomes + evicted       # report shows what was shed
        outcomes += demoted_outcomes        # ...and what binds second-class
        if self.bundle_report is not None:   # ...and what the import refused
            outcomes += [
                GeometryOutcome(shapes=r.shapes, dtype=r.dtype,
                                status="bundle-rejected",
                                config=default_config(name, self.platform))
                for r in self.bundle_report.results
                if r.op == name and r.status == "rejected"
            ]
        statuses = [o.status for o in outcomes]
        if len(set(statuses)) == 1:
            summary = statuses[0]
        else:
            freq: dict[str, int] = {}
            for s in statuses:
                freq[s] = freq.get(s, 0) + 1
            summary = "mixed(" + ",".join(
                f"{s}:{n}" for s, n in sorted(freq.items())) + ")"
        rank = self.priority.get(name) if self.priority else None
        tuned = dataclasses.replace(
            impl, fn=TunedDispatch(impl.fn, table), config=table
        )
        return tuned, TuneOutcome(status=summary, config=str(table.primary),
                                  geometries=tuple(outcomes), search_rank=rank)

    # ------------------------------------------------------------------ #
    def flush(self) -> None:
        """Persist any new winners (atomic; no-op when nothing changed).

        Persistence failure must not kill a deployment that already holds
        a perfectly good binding — the site just pays the search again
        next time.  Mirrors the read side's corruption tolerance.
        """
        if not self.cache.dirty:
            return
        try:
            self.cache.save()
        except OSError as e:
            log.warning("could not persist tuning cache %s: %s (continuing; "
                        "this deployment is tuned, the next will re-search)",
                        self.cache.path, e)
