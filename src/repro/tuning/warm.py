"""repro.tuning.warm — pre-warm the site tuning cache from live traffic.

Closes the loop the profile subsystem opens: a deployment that ran with
``REPRO_PROFILE=1`` left a `WorkloadProfile` of the geometries real
traffic produced; this entry point replays the profile's hottest
geometries through the autotuner so the *next* deployment binds every
profiled op with a cache hit — the search cost is paid offline, against
observed workloads, instead of at deploy time against canonical examples.

    python -m repro.tuning.warm [--profile PATH] [--cache PATH]
                                [--platform NAME] [--top K] [--ops a,b]
                                [--decay FACTOR]
    python -m repro.tuning.warm --compact [--max-entries N] [--decay FACTOR]

Environment:
  REPRO_WORKLOAD_PROFILE  profile location (same default as capture).
  REPRO_TUNING_CACHE      cache location (same default as deploy).
  REPRO_PLATFORM          platform override; else device detection.
  REPRO_TUNING_MAX_ENTRIES  default bound for ``--compact``.

Per (op, geometry) outcome, printed and returned by `warm_cache`:
  warmed            searched and persisted a winner
  already-cached    an entry for this exact key exists; nothing to do
  search-failed     every candidate infeasible/raised; the platform
                    default was persisted so deploys don't re-pay this
  no-native-impl    the platform binds no tunable native for this op
  unsynthesizable   the recorded bucket doesn't match the op signature

Stale-ABI entries are expired before warming (see expiry.py), so a
kernel revision bump followed by a warm run yields a fully re-tuned
cache in one pass.

Every result also carries ``hot`` — whether the bucket now has a cache
entry under the exact key an autotuned deploy will derive for it, i.e.
whether the geometry-dispatched binding will resolve it with a cache
hit.  A warm run that leaves any considered bucket cold (no native
impl, unsynthesizable) says so explicitly rather than letting the next
deploy discover it.

``--decay FACTOR`` ages the profile before ranking (counts scaled by
FACTOR, sub-floor entries dropped, file rewritten): traffic recorded
after the decay lands at full weight, so shifted workloads re-rank the
buckets instead of being outvoted by stale history forever.

``--compact`` is the cache GC — the offline half of the bounded
tuning-state lifecycle: shrink the cache file to ``--max-entries`` (or
``REPRO_TUNING_MAX_ENTRIES``) live entries, evicting stale-profile
buckets first and then the coldest ``last_used``, tombstoned under the
same file lock deploys merge with.  Combine with ``--decay`` to age the
profile in the same maintenance pass.

``--selftest`` runs the whole capture -> warm -> redeploy loop against
temp files on the ``pod-sim`` platform (interpret-mode kernels, no TPU
needed) and exits non-zero unless the final shape-polymorphic deploy
binds EVERY captured bucket (2+ per op) with a cache hit — zero misses,
zero searches — and the dispatch resolves each live geometry exactly.
This is what the CI docs job executes.
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import sys
from pathlib import Path
from typing import Any, Iterable

from repro.tuning.cache import (
    CacheKey,
    TuningCache,
    platform_fingerprint,
    resolve_cache_path,
)
from repro.tuning.expiry import expire_stale
from repro.tuning.profile import WorkloadProfile, resolve_profile_path
from repro.tuning.tuner import search_into_cache

log = logging.getLogger("repro.tuning")

__all__ = ["WarmResult", "warm_cache", "main"]


@dataclasses.dataclass(frozen=True)
class WarmResult:
    """Outcome of warming one (op, recorded geometry) pair."""

    op: str
    shapes: str
    dtype: str
    count: float        # profile hit count for this geometry
    status: str         # warmed / already-cached / search-failed / ...
    config: str = ""    # winner (or persisted fallback), printable form
    hot: bool = False   # the bucket now binds cache-hit: an entry exists
    # under the exact key an autotuned deploy derives for this geometry


def _native_impl(registry: Any, op: str, platform: Any):
    """The tunable native bind() would choose for `op` on `platform`, or
    None — the single ABI source shared with deploy-time expiry (see
    OpDecl.tunable_native)."""
    try:
        decl = registry.decl(op)
    except KeyError:
        return None
    return decl.tunable_native(platform)


def warm_cache(
    profile: WorkloadProfile,
    cache: TuningCache,
    platform: Any,
    *,
    registry: Any = None,
    top_k: int = 3,
    ops: Iterable[str] | None = None,
) -> list[WarmResult]:
    """Search the top-`top_k` recorded geometries of every profiled op.

    Winners land in `cache` (caller saves); existing entries are left
    alone, so repeated warm runs are idempotent and cheap.  Stale-ABI
    entries are expired first.  Returns one WarmResult per considered
    (op, geometry), hottest first, each verified against the cache
    (``hot``): after a warm run every top-K bucket with a tunable native
    must bind cache-hit at the next deploy, and any that cannot is
    reported cold here instead of discovered there.
    """
    from repro.core.registry import global_registry
    from repro.kernels.ops import register_all

    reg = registry if registry is not None else register_all(global_registry)
    selected = None if ops is None else frozenset(ops)
    fingerprint = platform_fingerprint(platform)

    current_abis = {}
    for op in profile.ops():
        impl = _native_impl(reg, op, platform)
        if impl is not None:
            current_abis[op] = impl.abi
    report = expire_stale(cache, current_abis)
    if len(report):
        log.info(report.describe())

    results: list[WarmResult] = []
    for op in profile.ops():
        if selected is not None and op not in selected:
            continue
        impl = _native_impl(reg, op, platform)
        for geo, count in profile.top(op=op, k=top_k):
            if impl is None:
                results.append(WarmResult(op, geo.shapes, geo.dtype, count,
                                          "no-native-impl"))
                continue
            tuner = impl.tuner
            key = CacheKey(abi=str(impl.abi), platform=fingerprint,
                           shapes=geo.shapes, dtype=geo.dtype)
            cached = cache.get(key)
            if cached is not None:
                results.append(WarmResult(op, geo.shapes, geo.dtype, count,
                                          "already-cached", str(cached),
                                          hot=True))
                continue
            args = None
            if tuner.args_from_shapes is not None:
                args = tuner.args_from_shapes(platform, geo.shapes, geo.dtype)
            if args is None:
                results.append(WarmResult(op, geo.shapes, geo.dtype, count,
                                          "unsynthesizable"))
                continue
            config, ok = search_into_cache(
                cache, platform, tuner, impl.fn, args, key,
                extra_metrics={"warmed_from_profile": True,
                               "profile_count": count},
            )
            results.append(WarmResult(
                op, geo.shapes, geo.dtype, count,
                "warmed" if ok else "search-failed", str(config),
                hot=cache.get(key) is not None))
    cold = [r for r in results if not r.hot]
    if cold:
        log.warning("warm: %d bucket(s) remain cold (will not bind cache-hit): %s",
                    len(cold), ", ".join(f"{r.op}[{r.shapes}] {r.status}"
                                         for r in cold))
    return results


# --------------------------------------------------------------------------- #
def _selftest() -> int:   # pragma: no cover — runs as its own CI job
    # (`warm --selftest` in the docs workflow), not under pytest
    """capture (2+ buckets per op) -> warm -> one shape-polymorphic
    redeploy on pod-sim; 0 iff EVERY captured bucket binds cache-hit
    (zero misses, zero searches), the dispatch resolves each live
    geometry exactly, and the k-loop moe_gmm entries carry a searched
    block_k."""
    import tempfile

    import jax
    import jax.numpy as jnp

    from repro.core.bundle import Bundle
    from repro.core.platform import POD_SIM
    from repro.core.registry import OpRegistry
    from repro.core.runtime import Runtime
    from repro.kernels.ops import ABIS, register_all

    tmp = Path(tempfile.mkdtemp(prefix="repro-warm-selftest-"))
    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(tmp / "tuning.json"),
        "REPRO_WORKLOAD_PROFILE": str(tmp / "workload.json"),
    }
    ops = ("rmsnorm", "moe_gmm", "windowed_attention", "quant_matmul")
    bundle = Bundle(name="warm-selftest", tag="t", model_config={}, recipe={},
                    required_ops={op: str(ABIS[op]) for op in ops}, env={})

    # 1. capture: deploy with profiling on, run shape-polymorphic traffic —
    # two distinct geometries per op, like prefill vs decode microbatches
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c1 = rt.deploy(bundle, native_ops=True, autotune=False, profile=True)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    wgt = jax.random.normal(k2, (64,), jnp.float32)
    rms_geoms = []
    for rows in (60, 7):                       # buckets 64x64 and 8x64
        x = jax.random.normal(k1, (rows, 64), jnp.float32)
        rms_geoms.append((x, wgt))
        for _ in range(3):
            jax.block_until_ready(c1.binding["rmsnorm"](x, wgt))
    moe_geoms = []
    for t_rows, d in ((64, 64), (16, 32)):     # 64x64... and 16x32... buckets
        xt = jax.random.normal(k3, (t_rows, d), jnp.float32)
        wm = jax.random.normal(k2, (4, d, d), jnp.float32)
        gs = jnp.full((4,), t_rows // 4, jnp.int32)
        moe_geoms.append((xt, wm, gs))
        for _ in range(2):
            jax.block_until_ready(c1.binding["moe_gmm"](xt, wm, gs))
    win_geoms = []
    for sq, sk, h, kv, dh in ((32, 32, 2, 2, 32), (16, 32, 4, 2, 16)):
        kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(sq), 3)
        q = jax.random.normal(kq, (1, sq, h, dh), jnp.float32)
        kc = jax.random.normal(kk, (1, sk, kv, dh), jnp.float32)
        vc = jax.random.normal(kv_, (1, sk, kv, dh), jnp.float32)
        # the window is traced (it rides the bucket key as a scalar part),
        # so windowed buckets are structurally distinct from full attention
        win = jnp.asarray(16, jnp.int32)
        win_geoms.append((q, kc, vc, win))
        for _ in range(2):
            jax.block_until_ready(c1.binding["windowed_attention"](q, kc, vc, win))
    qmm_geoms = []
    for rows, d, f in ((64, 64, 64), (16, 32, 64)):   # quantized weight buckets
        kx, kw, ks = jax.random.split(jax.random.PRNGKey(rows), 3)
        xq = jax.random.normal(kx, (rows, d), jnp.float32)
        qw = jax.random.randint(kw, (d, f), -127, 128, jnp.int8)
        sc = jax.random.uniform(ks, (f,), jnp.float32, 0.01, 0.1)
        qmm_geoms.append((xq, qw, sc))
        for _ in range(2):
            jax.block_until_ready(c1.binding["quant_matmul"](xq, qw, sc))
    rt.cleanup()   # persists the profile

    profile = WorkloadProfile.load(tmp / "workload.json")
    if set(profile.ops()) != set(ops):
        print(f"FAIL: capture recorded {profile.ops()!r}, want {ops!r}")
        return 1
    for op in ops:
        if len(profile.top(op=op)) < 2:
            print(f"FAIL: capture recorded <2 buckets for {op}")
            return 1

    # 2. warm: replay the recorded geometries through the tuner
    cache = TuningCache.load(tmp / "tuning.json")
    results = warm_cache(profile, cache, POD_SIM,
                         registry=register_all(OpRegistry()))
    cache.save()
    for r in results:
        print(f"  warm {r.op:<10} {r.shapes:<24} x{r.count:<6g} "
              f"{r.status} ({r.config}) {'hot' if r.hot else 'COLD'}")
    if not all(r.hot for r in results):
        print("FAIL: warm left buckets cold (see above)")
        return 1
    for op in ops:
        warmed = [r for r in results if r.op == op and r.status == "warmed"]
        if len(warmed) < 2:
            print(f"FAIL: expected >=2 warmed buckets for {op}, "
                  f"got {len(warmed)}")
            return 1
    for r in results:
        if r.op == "moe_gmm" and "block_k=" not in r.config:
            print(f"FAIL: moe_gmm winner {r.config!r} has no block_k knob")
            return 1

    # 3. redeploy once: every captured bucket must bind cache-hit — the
    # geometry-dispatched binding carries all of them, with zero searches
    rt2 = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    c2 = rt2.deploy(bundle, native_ops=True, autotune=True)
    print(c2.describe())
    reports = {r.op: r for r in c2.binding.reports}
    for op in ops:
        rep = reports[op]
        if rep.tuning != "cache-hit":
            print(f"FAIL: {op} redeploy expected cache-hit, got {rep.tuning!r}")
            return 1
        if len(rep.geometries) < 2:
            print(f"FAIL: {op} bound {len(rep.geometries)} geometries, want >=2")
            return 1
        if any(g.status != "cache-hit" for g in rep.geometries):
            print(f"FAIL: {op} has non-hit geometries: "
                  f"{[(g.shapes, g.status) for g in rep.geometries]}")
            return 1

    # 4. drive both live geometries through each bound op: the dispatch
    # must resolve every one exactly (no nearest/default fallbacks)
    for op, geoms in (("rmsnorm", rms_geoms), ("moe_gmm", moe_geoms),
                      ("windowed_attention", win_geoms),
                      ("quant_matmul", qmm_geoms)):
        for args in geoms:
            jax.block_until_ready(c2.binding[op](*args))
        dispatch = c2.binding.impl(op).fn
        stats = getattr(dispatch, "stats", None)
        if not stats or stats["exact"] < len(geoms) or stats["nearest"] or \
                stats["default"]:
            print(f"FAIL: {op} dispatch stats {stats!r}; want every live "
                  f"geometry resolved exactly")
            return 1
        configs = {(g.shapes, g.dtype): str(g.config)
                   for g in reports[op].geometries}
        print(f"  dispatch {op}: {len(configs)} tuned geometries, "
              f"stats {stats}")
    rt2.cleanup()
    print(f"OK: {tmp} — one deploy bound every warmed bucket of every op "
          f"with zero misses and zero searches")
    return 0


def _compact(cache_path: Path, profile_path: Path,
             max_entries: int | None, *, max_bytes: int | None = None,
             decay: float | None = None) -> int:
    """The ``--compact`` GC: bound the cache file (entry count and/or
    serialized bytes), preferring to shed buckets the (optionally freshly
    decayed) profile no longer records."""
    from repro.core.env import (tuning_max_bytes_default,
                                tuning_max_entries_default)
    from repro.tuning.expiry import compact_lru

    if max_entries is None:
        max_entries = tuning_max_entries_default()
    if max_bytes is None:
        max_bytes = tuning_max_bytes_default()
    if (max_entries is None or max_entries < 1) and \
            (max_bytes is None or max_bytes < 1):
        print("--compact needs a bound: pass --max-entries N / --max-bytes B "
              "or set REPRO_TUNING_MAX_ENTRIES / REPRO_TUNING_MAX_BYTES")
        return 2
    if max_entries is not None and max_entries < 1:
        max_entries = None
    if max_bytes is not None and max_bytes < 1:
        max_bytes = None
    profile = WorkloadProfile.load(profile_path)
    if decay is not None and len(profile):
        before = len(profile)
        dropped = profile.decay(decay)
        profile.save()
        print(f"decayed profile by {decay:g}: {before} -> {len(profile)} "
              f"geometries ({dropped} aged out)")
    cache = TuningCache.load(cache_path)
    if not len(cache):
        print(f"nothing to compact: cache {cache_path} is empty or missing")
        return 0
    bytes_before = cache.total_bytes()
    report = compact_lru(cache, max_entries, max_bytes=max_bytes,
                         profile=profile if len(profile) else None)
    bytes_after = cache.total_bytes()
    cache.save()
    print(report.describe())
    caps = ", ".join(
        s for s in (f"cap {max_entries}" if max_entries else "",
                    f"cap {max_bytes}B" if max_bytes else "") if s)
    print(f"cache {cache_path}: {report.kept} entr"
          f"{'y' if report.kept == 1 else 'ies'} kept "
          f"({caps}, {len(report)} evicted, "
          f"~{bytes_before}B -> ~{bytes_after}B)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Pre-warm the tuning cache from a captured workload profile.")
    ap.add_argument("--profile", default=None,
                    help="workload profile path (default: REPRO_WORKLOAD_PROFILE)")
    ap.add_argument("--cache", default=None,
                    help="tuning cache path (default: REPRO_TUNING_CACHE)")
    ap.add_argument("--platform", default=None,
                    help="platform name (default: REPRO_PLATFORM / detection)")
    ap.add_argument("--top", type=int, default=3,
                    help="geometries to warm per op, hottest first")
    ap.add_argument("--ops", default=None,
                    help="comma-separated op filter (default: every profiled op)")
    ap.add_argument("--decay", type=float, default=None, metavar="FACTOR",
                    help="age profile counts by FACTOR in (0,1) before "
                         "ranking (and persist the aged profile): lets "
                         "shifted traffic re-rank the buckets")
    ap.add_argument("--compact", action="store_true",
                    help="GC the cache instead of warming: LRU-evict down "
                         "to --max-entries (stale-profile buckets first)")
    ap.add_argument("--max-entries", type=int, default=None, metavar="N",
                    help="bound for --compact (default: "
                         "REPRO_TUNING_MAX_ENTRIES)")
    ap.add_argument("--max-bytes", type=int, default=None, metavar="B",
                    help="serialized-size bound for --compact, in bytes "
                         "(default: REPRO_TUNING_MAX_BYTES)")
    ap.add_argument("--selftest", action="store_true",
                    help="run the capture->warm->redeploy loop on pod-sim")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    if args.selftest:
        return _selftest()

    profile_path = Path(args.profile) if args.profile else resolve_profile_path()
    cache_path = Path(args.cache) if args.cache else resolve_cache_path()

    if args.compact:
        return _compact(cache_path, profile_path, args.max_entries,
                        max_bytes=args.max_bytes, decay=args.decay)

    from repro.core.env import resolve_platform
    from repro.core.platform import PLATFORMS

    platform = (PLATFORMS[args.platform] if args.platform
                else resolve_platform())

    profile = WorkloadProfile.load(profile_path)
    if not len(profile):
        print(f"nothing to warm: profile {profile_path} is empty or missing "
              f"(deploy with REPRO_PROFILE=1 to capture workloads)")
        return 1
    if args.decay is not None:
        before = len(profile)
        dropped = profile.decay(args.decay)
        profile.save()
        print(f"decayed profile by {args.decay:g}: {before} -> {len(profile)} "
              f"geometries ({dropped} aged out)")
        if not len(profile):
            print("profile fully aged out; nothing to warm")
            return 0
    cache = TuningCache.load(cache_path)
    ops = [o.strip() for o in args.ops.split(",")] if args.ops else None
    results = warm_cache(profile, cache, platform, top_k=args.top, ops=ops)
    cache.save()
    for r in results:
        print(f"{r.op:<18} {r.shapes:<32} {r.dtype:<10} x{r.count:<6g} "
              f"{r.status:<16} {'hot ' if r.hot else 'COLD'} {r.config}")
    warmed = sum(r.status == "warmed" for r in results)
    hot = sum(r.hot for r in results)
    print(f"warmed {warmed} entr{'y' if warmed == 1 else 'ies'} "
          f"into {cache_path} ({len(cache)} total); "
          f"{hot}/{len(results)} considered buckets bind hot")
    return 0


if __name__ == "__main__":
    sys.exit(main())
