"""WorkloadProfile — live geometry capture for tune-on-real-traffic.

PR 1's cache tunes against *canonical* example shapes; real deployments
see whatever geometry real traffic produces.  This module records that
traffic: every invocation of a profiled op contributes its shape bucket
and dtype (the same bucketing scheme `CacheKey` uses, so a recorded
geometry and the cache key a later deploy computes for it are identical
strings) to a persistent JSON profile.  `repro.tuning.warm` then replays
the profile's hottest geometries through the tuner, so a site cache is
pre-warmed from observed workloads instead of the shipped examples.

Counting semantics under jit: a profiled op callable records at *trace*
time, so each distinct compiled geometry is counted once per trace, not
once per executed step — exactly the granularity the tuner needs (the
tuner specializes per geometry, not per call).  Eager invocations count
individually.  Counts therefore rank geometries by how often they are
(re)compiled/observed across deployments, and merge additively across
concurrent writers.

Decay/aging: counts accumulate forever, so a bucket that dominated last
month's traffic would outrank this week's hot geometry indefinitely.
:meth:`WorkloadProfile.decay` scales every count by a factor in (0, 1)
and drops entries that fall below a floor — run it before re-ranking
(``python -m repro.tuning.warm --decay 0.5``) so fresh traffic, recorded
at full weight, re-ranks the buckets after a shift.  Counts are floats
on disk for this reason (integers read back unchanged).

File properties mirror `TuningCache` (see cache.py): atomic writes,
versioned schema, corruption degrades to an empty profile with a warning,
`REPRO_WORKLOAD_PROFILE` overrides the default location.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import tempfile
from pathlib import Path
from typing import Any, Iterable, Mapping, Sequence

from repro.tuning.cache import bucket_shapes, file_lock

__all__ = [
    "PROFILE_SCHEMA_VERSION",
    "ENV_WORKLOAD_PROFILE",
    "GeometryKey",
    "WorkloadProfile",
    "resolve_profile_path",
    "profiled_binding",
]

log = logging.getLogger("repro.tuning")

PROFILE_SCHEMA_VERSION = 1
ENV_WORKLOAD_PROFILE = "REPRO_WORKLOAD_PROFILE"
_DEFAULT_PROFILE = Path("~/.cache/repro/workload.json")


def resolve_profile_path(env: Mapping[str, str] | None = None) -> Path:
    """Profile file location: REPRO_WORKLOAD_PROFILE override, else the
    per-user default (`~/.cache/repro/workload.json`)."""
    env = os.environ if env is None else env
    override = str(env.get(ENV_WORKLOAD_PROFILE, "")).strip()
    if override:
        return Path(override).expanduser()
    return _DEFAULT_PROFILE.expanduser()


@dataclasses.dataclass(frozen=True, order=True)
class GeometryKey:
    """(op, shape bucket, dtype) — one observed workload geometry.

    ``shapes`` and ``dtype`` use the exact encoding of
    `repro.tuning.cache.bucket_shapes`, so a GeometryKey plugs straight
    into a `CacheKey` without re-derivation.
    """

    op: str
    shapes: str
    dtype: str

    def encode(self) -> str:
        return "|".join((self.op, self.shapes, self.dtype))

    @classmethod
    def decode(cls, text: str) -> "GeometryKey":
        op, shapes, dtype = text.split("|", 2)
        return cls(op=op, shapes=shapes, dtype=dtype)

    @classmethod
    def from_args(cls, op: str, args: Sequence[Any]) -> "GeometryKey":
        shapes, dtype = bucket_shapes(args)
        return cls(op=op, shapes=shapes, dtype=dtype)


class WorkloadProfile:
    """Persistent map: GeometryKey -> hit count.

    Load with :meth:`load` (any file defect degrades to an empty profile),
    record geometries with :meth:`record`, rank them with :meth:`top`, and
    persist with :meth:`save`.  Saving merges *deltas* — only the counts
    accumulated since load are added to whatever is on disk — so several
    concurrently profiling processes sum instead of clobbering each other.
    """

    def __init__(self, path: str | os.PathLike,
                 counts: Mapping[str, float] | None = None) -> None:
        self.path = Path(path)
        self._counts: dict[str, float] = dict(counts or {})
        self._loaded: dict[str, float] = dict(self._counts)
        self._decayed = False   # decay rewrites the file wholesale on save

    # -- loading -----------------------------------------------------------
    @classmethod
    def load(cls, path: str | os.PathLike) -> "WorkloadProfile":
        """Read a profile file; any defect degrades to an empty profile.

        A bad profile must never kill a deployment — profiling is an
        observability feature, so corruption costs history, not uptime.
        """
        p = Path(path)
        try:
            raw = json.loads(p.read_text())
        except FileNotFoundError:
            return cls(p)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            log.warning("workload profile %s unreadable (%s); starting empty", p, e)
            return cls(p)
        if not isinstance(raw, dict) or raw.get("schema") != PROFILE_SCHEMA_VERSION:
            log.warning(
                "workload profile %s has schema %r (want %d); ignoring it",
                p, raw.get("schema") if isinstance(raw, dict) else None,
                PROFILE_SCHEMA_VERSION,
            )
            return cls(p)
        counts: dict[str, float] = {}
        for key, n in (raw.get("counts") or {}).items():
            try:
                GeometryKey.decode(key)
                n = float(n)
            except (ValueError, TypeError):
                log.warning("workload profile %s: dropping malformed entry %r", p, key)
                continue
            if n > 0:
                counts[key] = n
        return cls(p, counts)

    # -- recording ---------------------------------------------------------
    def record(self, op: str, args: Sequence[Any], *, weight: float = 1) -> GeometryKey:
        """Count one observation of `op` invoked with `args`.

        `args` may be concrete arrays, ShapeDtypeStructs, or jit tracers —
        anything with .shape/.dtype contributes to the bucket; scalars are
        skipped (see `bucket_shapes`).  Returns the recorded key.
        """
        key = GeometryKey.from_args(op, args)
        self._counts[key.encode()] = self._counts.get(key.encode(), 0) + weight
        return key

    # -- aging -------------------------------------------------------------
    def decay(self, factor: float, *, floor: float = 0.5) -> int:
        """Age every count by ``factor`` (0 < factor < 1), dropping entries
        that fall below ``floor``; returns how many were dropped.

        This is the re-ranking valve: traffic recorded *after* a decay
        lands at full weight, so a shifted workload overtakes stale
        history in a bounded number of deploy/decay cycles instead of
        never.  Decay marks the profile for a wholesale rewrite on
        :meth:`save` (a decayed value cannot be expressed as an additive
        delta); run it from the offline warm pass, not from concurrent
        live profilers.
        """
        if not (0.0 < factor < 1.0):
            raise ValueError(f"decay factor must be in (0, 1), got {factor!r}")
        aged = {k: n * factor for k, n in self._counts.items()}
        kept = {k: n for k, n in aged.items() if n >= floor}
        dropped = len(aged) - len(kept)
        self._counts = kept
        self._decayed = True
        return dropped

    # -- access ------------------------------------------------------------
    def count(self, key: GeometryKey) -> float:
        return self._counts.get(key.encode(), 0)

    def counts(self) -> dict[str, float]:
        """A copy of the raw encoded-key -> count map (the persisted form;
        what bundle export packages)."""
        return dict(self._counts)

    def ops(self) -> tuple[str, ...]:
        return tuple(sorted({GeometryKey.decode(k).op for k in self._counts}))

    def op_totals(self) -> dict[str, float]:
        """Total observations per op — the hotness ranking profile-driven
        ``autotune_ops`` selection spends its search budget by."""
        totals: dict[str, float] = {}
        for enc, n in self._counts.items():
            op = GeometryKey.decode(enc).op
            totals[op] = totals.get(op, 0) + n
        return totals

    def top(self, op: str | None = None, k: int | None = None
            ) -> list[tuple[GeometryKey, float]]:
        """Hottest geometries, most-counted first (ties broken by key for
        determinism).  `op` filters to one op; `k` truncates."""
        items = [(GeometryKey.decode(enc), n) for enc, n in self._counts.items()]
        if op is not None:
            items = [(g, n) for g, n in items if g.op == op]
        items.sort(key=lambda it: (-it[1], it[0]))
        return items if k is None else items[:k]

    @property
    def dirty(self) -> bool:
        return self._counts != self._loaded

    def __len__(self) -> int:
        return len(self._counts)

    # -- persistence -------------------------------------------------------
    def save(self) -> Path:
        """Atomically merge this process's new counts into the file.

        Re-reads the on-disk profile, adds only the counts recorded since
        load (delta merge — two profiling processes that both loaded the
        same baseline do not double-count it), then temp-file + os.replace
        like `TuningCache.save`.  The whole load-merge-replace runs under
        the same exclusive sidecar lock the cache uses, so concurrent
        profilers sum instead of losing a writer's delta.  After
        :meth:`decay` the file is instead replaced wholesale with the aged
        counts (a decayed value has no additive-delta form); the lock
        still serializes against concurrent save()s, but counts a live
        profiler recorded between this process's load and the decayed
        write are aged away with the history — run decay offline.  Raises
        OSError on unwritable paths; the Runtime wraps this in a warning
        because losing a profile flush must not kill the workload that
        produced it.
        """
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with file_lock(self.path.with_name(self.path.name + ".lock")):
            if self._decayed:
                merged = dict(self._counts)
            else:
                on_disk = WorkloadProfile.load(self.path)._counts
                merged = dict(on_disk)
                for key, n in self._counts.items():
                    delta = n - self._loaded.get(key, 0)
                    if delta > 0:
                        merged[key] = merged.get(key, 0) + delta
            payload = {"schema": PROFILE_SCHEMA_VERSION, "counts": merged}
            fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                       prefix=self.path.name, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        self._counts = merged
        self._loaded = dict(merged)
        self._decayed = False
        return self.path


def profiled_binding(binding: Any, profile: WorkloadProfile,
                     ops: Iterable[str] | None = None) -> Any:
    """Wrap an OpBinding so every op invocation records into `profile`.

    Returns a new binding with each callable replaced by a recording
    shim; reports and impl metadata are preserved.  Under jit the shim
    fires at trace time (see module docstring for why that is the right
    counting granularity).  `ops` restricts which ops are profiled;
    None profiles everything in the binding.
    """
    import dataclasses as _dc

    from repro.core.registry import OpBinding

    selected = None if ops is None else frozenset(ops)
    table = {}
    for name in binding:
        impl = binding.impl(name)
        if selected is not None and name not in selected:
            table[name] = impl
            continue

        def _wrap(fn, op):
            def recorded(*args, **kwargs):
                profile.record(op, args)
                return fn(*args, **kwargs)
            if hasattr(fn, "stats"):
                recorded.stats = fn.stats   # keep TunedDispatch hit-rate
                # counters reachable when profiling wraps an autotuned op
            return recorded

        table[name] = _dc.replace(impl, fn=_wrap(impl.fn, name))
    return OpBinding(table, list(binding.reports))
