"""repro.tuning — per-platform kernel autotuning with a persistent site cache.

The deferred-specialization layer: Pallas kernels declare a tunable
`BlockConfig`, native registrations carry an `OpTuner` hook, and at
deployment the Runtime's `TuningContext` resolves each op's config from
the site-local `TuningCache` (searching and persisting on first miss).
The bundle stays portable; the site contributes its tuned parameters —
the analogue of Shifter's site-specific bind mount.

PR 2 closes the tune-on-real-traffic loop (see docs/tuning.md):

  * `WorkloadProfile` (profile.py) captures live op geometries when a
    deployment runs with ``REPRO_PROFILE=1``;
  * ``python -m repro.tuning.warm`` replays the profile's hottest
    geometries through the tuner, pre-warming the cache offline;
  * `expire_stale` (expiry.py) evicts cache entries tuned against an
    older kernel ABI revision, forcing a clean re-search after a bump.

PR 3 makes the binding geometry-dispatched (dispatch.py): one bound op
carries a `ConfigTable` of *all* its warmed top-K geometries, and the
`TunedDispatch` callable resolves each call's shape bucket at trace
time (exact -> nearest bucket -> platform default) — one deployment,
many tuned configs, zero searches on a warmed shape-polymorphic path.

PR 5 makes the state portable (bundle.py): ``python -m
repro.tuning.bundle {export,import,verify}`` packages one site's cache +
profile + ABI manifest into a checksummed tarball; importing on another
site re-runs ``tuner.feasible`` per entry against the *target* platform
— feasible entries land first-class ("bundle-imported"), structurally
matched but infeasible (or revision-drifted) ones become *demoted*
dispatch candidates at `DEMOTED_PENALTY` distance ("bundle-demoted",
never bound raw), and corrupt/ABI-major-mismatched artifacts are
rejected atomically, leaving the target cache byte-identical.

PR 4 bounds the lifecycle: tuning state is managed, not append-only.
`REPRO_TUNING_MAX_ENTRIES` / ``deploy(max_tuned_entries=K)`` caps each
op's dispatch table at its K hottest buckets, LRU-evicting the rest
from the cache under pressure ("cache-evicted-lru" in the SwapReport;
``last_used`` stamps persist in the cache JSON); ``warm --compact``
GCs the file offline (`compact_lru`); and the resolve chain grows a
validated dtype-crossing borrow ("near-dtype"): bf16 traffic may use a
same-structure fp32 bucket's config at `DTYPE_PENALTY` distance once
it re-passes the VMEM feasibility check for the borrowing dtype.
"""

from repro.tuning.cache import (
    ENV_TUNING_CACHE,
    SCHEMA_VERSION,
    CacheKey,
    TuningCache,
    base_dtype,
    bucket_shapes,
    platform_fingerprint,
    resolve_cache_path,
)
from repro.tuning.config import BlockConfig, default_config
from repro.tuning.dispatch import (
    DEMOTED_PENALTY,
    DISPATCH_PATHS,
    DTYPE_PENALTY,
    STATS_SCHEMA,
    ConfigTable,
    GeometryOutcome,
    TunedDispatch,
    bucket_distance,
    calibrate_dtype_penalty,
    consolidated_stats,
)
from repro.tuning.expiry import (
    ExpiryReport,
    PressureReport,
    compact_lru,
    expire_stale,
)
from repro.tuning.profile import (
    ENV_WORKLOAD_PROFILE,
    PROFILE_SCHEMA_VERSION,
    GeometryKey,
    WorkloadProfile,
    profiled_binding,
    resolve_profile_path,
)
from repro.tuning.search import Measurement, SearchResult, enumerate_space, measure, search
from repro.tuning.tuner import (
    OpTuner,
    TuneEvent,
    TuneOutcome,
    TuningContext,
    bucket_validator,
)

# bundle.py is re-exported lazily (PEP 562): importing it eagerly here
# would make ``python -m repro.tuning.bundle`` warn about the module
# being initialized twice (runpy re-executes the CLI module after the
# package import already loaded it).
_BUNDLE_EXPORTS = (
    "BUNDLE_SCHEMA_VERSION", "ENV_TUNING_BUNDLE", "BundleFormatError",
    "ImportReport", "SiteFingerprint", "export_bundle", "import_bundle",
    "verify_bundle",
)


def __getattr__(name):
    if name in _BUNDLE_EXPORTS:
        from repro.tuning import bundle

        return getattr(bundle, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "ENV_TUNING_CACHE", "SCHEMA_VERSION", "CacheKey", "TuningCache",
    "bucket_shapes", "base_dtype", "platform_fingerprint",
    "resolve_cache_path",
    "BlockConfig", "default_config",
    "ConfigTable", "GeometryOutcome", "TunedDispatch", "bucket_distance",
    "DTYPE_PENALTY", "DEMOTED_PENALTY", "DISPATCH_PATHS", "STATS_SCHEMA",
    "consolidated_stats", "calibrate_dtype_penalty", "bucket_validator",
    "BUNDLE_SCHEMA_VERSION", "ENV_TUNING_BUNDLE", "BundleFormatError",
    "ImportReport", "SiteFingerprint", "export_bundle", "import_bundle",
    "verify_bundle",
    "ExpiryReport", "expire_stale", "PressureReport", "compact_lru",
    "ENV_WORKLOAD_PROFILE", "PROFILE_SCHEMA_VERSION", "GeometryKey",
    "WorkloadProfile", "profiled_binding", "resolve_profile_path",
    "Measurement", "SearchResult", "enumerate_space", "measure", "search",
    "OpTuner", "TuneEvent", "TuneOutcome", "TuningContext",
]
