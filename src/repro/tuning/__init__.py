"""repro.tuning — per-platform kernel autotuning with a persistent site cache.

The deferred-specialization layer: Pallas kernels declare a tunable
`BlockConfig`, native registrations carry an `OpTuner` hook, and at
deployment the Runtime's `TuningContext` resolves each op's config from
the site-local `TuningCache` (searching and persisting on first miss).
The bundle stays portable; the site contributes its tuned parameters —
the analogue of Shifter's site-specific bind mount.

PR 2 closes the tune-on-real-traffic loop (see docs/tuning.md):

  * `WorkloadProfile` (profile.py) captures live op geometries when a
    deployment runs with ``REPRO_PROFILE=1``;
  * ``python -m repro.tuning.warm`` replays the profile's hottest
    geometries through the tuner, pre-warming the cache offline;
  * `expire_stale` (expiry.py) evicts cache entries tuned against an
    older kernel ABI revision, forcing a clean re-search after a bump.

PR 3 makes the binding geometry-dispatched (dispatch.py): one bound op
carries a `ConfigTable` of *all* its warmed top-K geometries, and the
`TunedDispatch` callable resolves each call's shape bucket at trace
time (exact -> nearest bucket -> platform default) — one deployment,
many tuned configs, zero searches on a warmed shape-polymorphic path.

PR 4 bounds the lifecycle: tuning state is managed, not append-only.
`REPRO_TUNING_MAX_ENTRIES` / ``deploy(max_tuned_entries=K)`` caps each
op's dispatch table at its K hottest buckets, LRU-evicting the rest
from the cache under pressure ("cache-evicted-lru" in the SwapReport;
``last_used`` stamps persist in the cache JSON); ``warm --compact``
GCs the file offline (`compact_lru`); and the resolve chain grows a
validated dtype-crossing borrow ("near-dtype"): bf16 traffic may use a
same-structure fp32 bucket's config at `DTYPE_PENALTY` distance once
it re-passes the VMEM feasibility check for the borrowing dtype.
"""

from repro.tuning.cache import (
    ENV_TUNING_CACHE,
    SCHEMA_VERSION,
    CacheKey,
    TuningCache,
    bucket_shapes,
    platform_fingerprint,
    resolve_cache_path,
)
from repro.tuning.config import BlockConfig, default_config
from repro.tuning.dispatch import (
    DTYPE_PENALTY,
    ConfigTable,
    GeometryOutcome,
    TunedDispatch,
    bucket_distance,
)
from repro.tuning.expiry import (
    ExpiryReport,
    PressureReport,
    compact_lru,
    expire_stale,
)
from repro.tuning.profile import (
    ENV_WORKLOAD_PROFILE,
    PROFILE_SCHEMA_VERSION,
    GeometryKey,
    WorkloadProfile,
    profiled_binding,
    resolve_profile_path,
)
from repro.tuning.search import Measurement, SearchResult, enumerate_space, measure, search
from repro.tuning.tuner import (
    OpTuner,
    TuneEvent,
    TuneOutcome,
    TuningContext,
    bucket_validator,
)

__all__ = [
    "ENV_TUNING_CACHE", "SCHEMA_VERSION", "CacheKey", "TuningCache",
    "bucket_shapes", "platform_fingerprint", "resolve_cache_path",
    "BlockConfig", "default_config",
    "ConfigTable", "GeometryOutcome", "TunedDispatch", "bucket_distance",
    "DTYPE_PENALTY", "bucket_validator",
    "ExpiryReport", "expire_stale", "PressureReport", "compact_lru",
    "ENV_WORKLOAD_PROFILE", "PROFILE_SCHEMA_VERSION", "GeometryKey",
    "WorkloadProfile", "profiled_binding", "resolve_profile_path",
    "Measurement", "SearchResult", "enumerate_space", "measure", "search",
    "OpTuner", "TuneEvent", "TuneOutcome", "TuningContext",
]
