"""repro.tuning — per-platform kernel autotuning with a persistent site cache.

The deferred-specialization layer: Pallas kernels declare a tunable
`BlockConfig`, native registrations carry an `OpTuner` hook, and at
deployment the Runtime's `TuningContext` resolves each op's config from
the site-local `TuningCache` (searching and persisting on first miss).
The bundle stays portable; the site contributes its tuned parameters —
the analogue of Shifter's site-specific bind mount.

PR 2 closes the tune-on-real-traffic loop (see docs/tuning.md):

  * `WorkloadProfile` (profile.py) captures live op geometries when a
    deployment runs with ``REPRO_PROFILE=1``;
  * ``python -m repro.tuning.warm`` replays the profile's hottest
    geometries through the tuner, pre-warming the cache offline;
  * `expire_stale` (expiry.py) evicts cache entries tuned against an
    older kernel ABI revision, forcing a clean re-search after a bump.

PR 3 makes the binding geometry-dispatched (dispatch.py): one bound op
carries a `ConfigTable` of *all* its warmed top-K geometries, and the
`TunedDispatch` callable resolves each call's shape bucket at trace
time (exact -> nearest bucket -> platform default) — one deployment,
many tuned configs, zero searches on a warmed shape-polymorphic path.
"""

from repro.tuning.cache import (
    ENV_TUNING_CACHE,
    SCHEMA_VERSION,
    CacheKey,
    TuningCache,
    bucket_shapes,
    platform_fingerprint,
    resolve_cache_path,
)
from repro.tuning.config import BlockConfig, default_config
from repro.tuning.dispatch import (
    ConfigTable,
    GeometryOutcome,
    TunedDispatch,
    bucket_distance,
)
from repro.tuning.expiry import ExpiryReport, expire_stale
from repro.tuning.profile import (
    ENV_WORKLOAD_PROFILE,
    PROFILE_SCHEMA_VERSION,
    GeometryKey,
    WorkloadProfile,
    profiled_binding,
    resolve_profile_path,
)
from repro.tuning.search import Measurement, SearchResult, enumerate_space, measure, search
from repro.tuning.tuner import OpTuner, TuneEvent, TuneOutcome, TuningContext

__all__ = [
    "ENV_TUNING_CACHE", "SCHEMA_VERSION", "CacheKey", "TuningCache",
    "bucket_shapes", "platform_fingerprint", "resolve_cache_path",
    "BlockConfig", "default_config",
    "ConfigTable", "GeometryOutcome", "TunedDispatch", "bucket_distance",
    "ExpiryReport", "expire_stale",
    "ENV_WORKLOAD_PROFILE", "PROFILE_SCHEMA_VERSION", "GeometryKey",
    "WorkloadProfile", "profiled_binding", "resolve_profile_path",
    "Measurement", "SearchResult", "enumerate_space", "measure", "search",
    "OpTuner", "TuneEvent", "TuneOutcome", "TuningContext",
]
