"""repro.tuning — per-platform kernel autotuning with a persistent site cache.

The deferred-specialization layer: Pallas kernels declare a tunable
`BlockConfig`, native registrations carry an `OpTuner` hook, and at
deployment the Runtime's `TuningContext` resolves each op's config from
the site-local `TuningCache` (searching and persisting on first miss).
The bundle stays portable; the site contributes its tuned parameters —
the analogue of Shifter's site-specific bind mount.
"""

from repro.tuning.cache import (
    ENV_TUNING_CACHE,
    SCHEMA_VERSION,
    CacheKey,
    TuningCache,
    bucket_shapes,
    platform_fingerprint,
    resolve_cache_path,
)
from repro.tuning.config import BlockConfig, default_config
from repro.tuning.search import Measurement, SearchResult, enumerate_space, measure, search
from repro.tuning.tuner import OpTuner, TuneEvent, TuningContext

__all__ = [
    "ENV_TUNING_CACHE", "SCHEMA_VERSION", "CacheKey", "TuningCache",
    "bucket_shapes", "platform_fingerprint", "resolve_cache_path",
    "BlockConfig", "default_config",
    "Measurement", "SearchResult", "enumerate_space", "measure", "search",
    "OpTuner", "TuneEvent", "TuningContext",
]
