"""Portable tuning bundles — ship a site's tuned state as one artifact.

The paper's whole thesis is that software validated on commodity
hardware ships to a supercomputer as a portable artifact and adapts to
site resources at deploy time.  The tuning subsystem's state — cache
entries, workload profile, kernel ABI manifest — is the exact analogue
of that artifact, but until this module it was site-local: a laptop
could warm a cache, and a cluster could not use it.  A *tuning bundle*
packages one site's artifacts into a single checksummed tarball that a
different site imports through the same tombstone-clean merge path
deploys use, **revalidating every entry against the target platform**
instead of trusting foreign measurements or cold-searching from scratch:

  export   package the tuning cache (one platform fingerprint's worth),
           the workload profile, and the kernel ABI manifest into
           ``<out>.tgz`` with a versioned, checksummed ``manifest.json``.
  import   merge into the target site's cache atomically.  Per entry:
             * ``tuner.feasible`` re-passes on the TARGET platform
                        -> imported first-class ("bundle-imported" at bind)
             * structurally matched but infeasible here, or tuned on a
               drifted (minor) kernel revision
                        -> demoted: a near-config candidate the dispatch
                           may lend out at DEMOTED_PENALTY distance after
                           re-validating it for the borrowing call
                           ("bundle-demoted"), exactly like the near-dtype
                           borrow — never bound raw
             * bucket foreign to the op's signature
                        -> rejected per entry ("bundle-rejected"; reported,
                           not imported)
           Checksum/truncation/schema defects and ABI major or signature
           mismatches reject the WHOLE bundle with `BundleFormatError`
           before anything touches the cache — never a partial write; the
           target cache file stays byte-identical.
  verify   import into a scratch cache, replay the bundled profile
           through a bind, and assert zero-search exact dispatch for
           every imported bucket (and that demoted entries never bound
           first-class) — the conformance gate CI runs on pod-sim.

CLI:

    python -m repro.tuning.bundle export --out site.tgz [--cache PATH]
                                         [--profile PATH] [--platform NAME]
                                         [--ops a,b]
    python -m repro.tuning.bundle import site.tgz [--cache PATH]
                                         [--platform NAME]
    python -m repro.tuning.bundle verify site.tgz [--platform NAME] [--top K]

Deploy-side wiring: ``Runtime.deploy(tuning_bundle=PATH)`` (or
``REPRO_TUNING_BUNDLE``, or a ``Bundle.tuning_bundle`` reference baked
into the run bundle) auto-imports before binding, and the SwapReport's
geometries carry the bundle-imported/demoted/rejected provenance.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import io
import json
import logging
import os
import sys
import tarfile
import tempfile
from pathlib import Path
from typing import Any, Iterable, Mapping

from repro.core.abi import AbiError, parse_abi
from repro.tuning.cache import (
    SCHEMA_VERSION,
    CacheKey,
    TuningCache,
    platform_fingerprint,
    resolve_cache_path,
)
from repro.tuning.profile import (
    PROFILE_SCHEMA_VERSION,
    WorkloadProfile,
    resolve_profile_path,
)
from repro.tuning.tuner import bucket_validator

__all__ = [
    "BUNDLE_SCHEMA_VERSION",
    "ENV_TUNING_BUNDLE",
    "BundleFormatError",
    "SiteFingerprint",
    "EntryImport",
    "ImportReport",
    "export_bundle",
    "import_bundle",
    "verify_bundle",
    "KVHandoff",
    "KV_HANDOFF_SCHEMA_VERSION",
    "main",
]

log = logging.getLogger("repro.tuning")

BUNDLE_SCHEMA_VERSION = 1
ENV_TUNING_BUNDLE = "REPRO_TUNING_BUNDLE"
_KIND = "repro-tuning-bundle"
_MANIFEST = "manifest.json"
_CACHE_MEMBER = "tuning.json"
_PROFILE_MEMBER = "workload.json"


class BundleFormatError(ValueError):
    """The artifact is unusable as a whole: truncated, tampered (checksum
    mismatch), unknown schema, internally inconsistent, or ABI-incompatible
    with the target site.  Raised BEFORE any cache write — an import that
    sees this leaves the target byte-identical."""


def _default_registry():
    """The fully-populated global registry (same lazy import warm uses)."""
    from repro.core.registry import global_registry
    from repro.kernels.ops import register_all

    return register_all(global_registry)


def _vmem_budget() -> int:
    """The site's kernel-tile VMEM budget, for the fingerprint record."""
    try:
        from repro.kernels.ops import _VMEM_BUDGET

        return int(_VMEM_BUDGET)
    except ImportError:  # pragma: no cover - kernels always present here
        return 0


# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SiteFingerprint:
    """Identity of the site an artifact was tuned on.

    ``key`` is the exact string `platform_fingerprint` derives (and cache
    keys embed); the extra fields — device kind actually backing the JAX
    backend, and the VMEM budget feasibility was checked against — make
    the manifest self-describing for humans and for future stricter
    revalidation policies.
    """

    platform: str
    hardware: str
    backend: str
    device_kind: str
    vmem_budget: int

    @property
    def key(self) -> str:
        return f"{self.platform}/{self.hardware}/{self.backend}"

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SiteFingerprint":
        try:
            return cls(platform=str(d["platform"]), hardware=str(d["hardware"]),
                       backend=str(d["backend"]),
                       device_kind=str(d.get("device_kind", "")),
                       vmem_budget=int(d.get("vmem_budget", 0)))
        except (KeyError, TypeError, ValueError) as e:
            raise BundleFormatError(f"malformed fingerprint: {e}") from e

    @classmethod
    def capture(cls, platform: Any) -> "SiteFingerprint":
        import jax

        devices = jax.devices()
        return cls(
            platform=platform.name,
            hardware=platform.hardware.name,
            backend=jax.default_backend(),
            device_kind=devices[0].device_kind if devices else "",
            vmem_budget=_vmem_budget(),
        )


@dataclasses.dataclass(frozen=True)
class EntryImport:
    """Outcome of importing one bundled cache entry onto the target."""

    op: str
    shapes: str
    dtype: str
    status: str       # imported / demoted / rejected / already-present / skipped
    reason: str = ""
    key: str = ""     # encoded target cache key ("" when nothing was written)


@dataclasses.dataclass(frozen=True)
class ImportReport:
    """One bundle import, end to end: where from, where to, what happened."""

    source: str                          # bundle fingerprint key
    target: str                          # target fingerprint key
    results: tuple[EntryImport, ...]
    saved: bool                          # whether the cache file was written

    @property
    def cross_site(self) -> bool:
        return self.source != self.target

    def counts(self) -> dict[str, int]:
        out = {"imported": 0, "demoted": 0, "rejected": 0,
               "already-present": 0, "skipped": 0}
        for r in self.results:
            out[r.status] = out.get(r.status, 0) + 1
        return out

    def __len__(self) -> int:
        return len(self.results)

    def describe(self) -> str:
        c = self.counts()
        head = (f"bundle import {self.source} -> {self.target} "
                f"({'cross-site, revalidated' if self.cross_site else 'same site'}): "
                f"{c['imported']} imported, {c['demoted']} demoted, "
                f"{c['rejected']} rejected, {c['already-present']} already present"
                + (f", {c['skipped']} skipped" if c["skipped"] else ""))
        lines = [head]
        for r in self.results:
            note = f" ({r.reason})" if r.reason else ""
            lines.append(f"  {r.op:<18} {r.shapes or '<scalar>':<28} "
                         f"{r.dtype:<10} {r.status}{note}")
        return "\n".join(lines)


# ------------------------------------------------------------------ export --
def _sha256(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def export_bundle(
    out_path: str | os.PathLike,
    *,
    cache_path: str | os.PathLike,
    platform: Any,
    profile_path: str | os.PathLike | None = None,
    ops: Iterable[str] | None = None,
) -> tuple[Path, dict[str, Any]]:
    """Package this site's tuned state into a checksummed tarball.

    Only entries under the exporting platform's fingerprint travel (a
    bundle is ONE site's artifact; foreign-fingerprint entries in a
    shared cache file stay home).  Returns (path, manifest).  Raises
    ValueError when there is nothing to export, and BundleFormatError if
    the cache holds one op's entries under two different ABI strings (a
    malformed cache must not become a malformed artifact).
    """
    cache = TuningCache.load(cache_path)
    fp = SiteFingerprint.capture(platform)
    selected = None if ops is None else frozenset(ops)

    entries: dict[str, dict] = {}
    abis: dict[str, str] = {}
    for encoded in cache.raw_keys():
        parts = encoded.split("|")
        if len(parts) != 4 or parts[1] != fp.key:
            continue
        try:
            abi = parse_abi(parts[0])
        except AbiError:
            continue
        if selected is not None and abi.name not in selected:
            continue
        if abis.setdefault(abi.name, parts[0]) != parts[0]:
            raise BundleFormatError(
                f"cache holds op '{abi.name}' under two ABI strings "
                f"({abis[abi.name]} and {parts[0]}); expire before exporting"
            )
        entries[encoded] = cache.raw_entry(encoded)
    if not entries:
        raise ValueError(
            f"nothing to export: cache {cache_path} has no entries under "
            f"fingerprint {fp.key}"
        )

    cache_blob = json.dumps(
        {"schema": SCHEMA_VERSION, "entries": entries},
        indent=1, sort_keys=True,
    ).encode()

    profile_blob = None
    if profile_path is not None:
        profile = WorkloadProfile.load(profile_path)
        if len(profile):
            counts = {k: n for k, n in profile.counts().items()
                      if selected is None or k.split("|", 1)[0] in selected}
            if counts:
                profile_blob = json.dumps(
                    {"schema": PROFILE_SCHEMA_VERSION, "counts": counts},
                    indent=1, sort_keys=True,
                ).encode()

    # size accounting via the cache's own accessor, so the manifest number
    # can never diverge from what describe()/warm --compact report
    total_bytes = sum(cache.entry_bytes(k) for k in entries)
    checksums = {_CACHE_MEMBER: _sha256(cache_blob)}
    if profile_blob is not None:
        checksums[_PROFILE_MEMBER] = _sha256(profile_blob)
    manifest = {
        "schema": BUNDLE_SCHEMA_VERSION,
        "kind": _KIND,
        "fingerprint": fp.to_dict(),
        "abis": abis,
        "entries": {"count": len(entries), "total_bytes": total_bytes},
        "cache_schema": SCHEMA_VERSION,
        "checksums": checksums,
    }
    if profile_blob is not None:
        manifest["profile_schema"] = PROFILE_SCHEMA_VERSION
    manifest_blob = json.dumps(manifest, indent=1, sort_keys=True).encode()

    out = Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=out.parent, prefix=out.name, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as raw, tarfile.open(fileobj=raw, mode="w:gz") as tar:
            for name, blob in ((_MANIFEST, manifest_blob),
                               (_CACHE_MEMBER, cache_blob),
                               (_PROFILE_MEMBER, profile_blob)):
                if blob is None:
                    continue
                info = tarfile.TarInfo(name)
                info.size = len(blob)
                tar.addfile(info, io.BytesIO(blob))
        os.replace(tmp, out)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    log.info("exported tuning bundle %s: %d entr%s (~%dB) under %s",
             out, len(entries), "y" if len(entries) == 1 else "ies",
             total_bytes, fp.key)
    return out, manifest


# ------------------------------------------------------------------ reading --
def _read_bundle(path: str | os.PathLike
                 ) -> tuple[dict, dict[str, dict], dict[str, float]]:
    """Read + fully verify a bundle file in memory.

    Returns (manifest, entries, profile counts).  Every defect — a
    truncated tarball, a member whose bytes don't match the manifest
    checksum, an unknown schema version, an internally inconsistent
    entry set — raises BundleFormatError; nothing is trusted past its
    checksum.
    """
    p = Path(path)
    members: dict[str, bytes] = {}
    try:
        with tarfile.open(p, mode="r:gz") as tar:
            for name in (_MANIFEST, _CACHE_MEMBER, _PROFILE_MEMBER):
                try:
                    fh = tar.extractfile(name)
                except KeyError:
                    fh = None
                if fh is not None:
                    members[name] = fh.read()
    except (OSError, EOFError, tarfile.TarError) as e:
        raise BundleFormatError(f"unreadable bundle {p}: {e}") from e

    if _MANIFEST not in members:
        raise BundleFormatError(f"bundle {p} has no {_MANIFEST}")
    try:
        manifest = json.loads(members[_MANIFEST])
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise BundleFormatError(f"bundle {p}: malformed manifest: {e}") from e
    if not isinstance(manifest, dict) or manifest.get("kind") != _KIND:
        raise BundleFormatError(f"bundle {p} is not a {_KIND} artifact")
    if manifest.get("schema") != BUNDLE_SCHEMA_VERSION:
        raise BundleFormatError(
            f"bundle {p} has schema {manifest.get('schema')!r} "
            f"(this runtime understands {BUNDLE_SCHEMA_VERSION})"
        )

    checksums = manifest.get("checksums") or {}
    for name in (_CACHE_MEMBER, _PROFILE_MEMBER):
        want = checksums.get(name)
        have = members.get(name)
        if have is None and want is None:
            continue
        if have is None or want is None or _sha256(have) != want:
            raise BundleFormatError(
                f"bundle {p}: checksum mismatch on {name} "
                f"(corrupt or tampered artifact)"
            )
    if _CACHE_MEMBER not in members:
        raise BundleFormatError(f"bundle {p} carries no {_CACHE_MEMBER}")

    fp = SiteFingerprint.from_dict(manifest.get("fingerprint") or {})
    abis = manifest.get("abis")
    if not isinstance(abis, dict) or not abis:
        raise BundleFormatError(f"bundle {p}: manifest has no ABI table")

    try:
        raw_cache = json.loads(members[_CACHE_MEMBER])
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise BundleFormatError(f"bundle {p}: malformed cache member: {e}") from e
    if not isinstance(raw_cache, dict) \
            or raw_cache.get("schema") != SCHEMA_VERSION:
        raise BundleFormatError(
            f"bundle {p}: cache member has schema "
            f"{raw_cache.get('schema') if isinstance(raw_cache, dict) else None!r} "
            f"(want {SCHEMA_VERSION})"
        )
    from repro.tuning.config import BlockConfig

    entries: dict[str, dict] = {}
    for encoded, entry in (raw_cache.get("entries") or {}).items():
        parts = encoded.split("|")
        if len(parts) != 4:
            raise BundleFormatError(f"bundle {p}: malformed entry key {encoded!r}")
        if parts[1] != fp.key:
            raise BundleFormatError(
                f"bundle {p}: entry {encoded!r} is not under the manifest "
                f"fingerprint {fp.key}"
            )
        try:
            abi = parse_abi(parts[0])
            BlockConfig.from_dict(entry["config"])
        except (AbiError, KeyError, TypeError, ValueError) as e:
            raise BundleFormatError(
                f"bundle {p}: malformed entry {encoded!r}: {e}") from e
        if abis.get(abi.name) != parts[0]:
            raise BundleFormatError(
                f"bundle {p}: entry {encoded!r} disagrees with the manifest "
                f"ABI table ({abis.get(abi.name)!r})"
            )
        entries[encoded] = dict(entry)

    counts: dict[str, float] = {}
    if _PROFILE_MEMBER in members:
        try:
            raw_profile = json.loads(members[_PROFILE_MEMBER])
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise BundleFormatError(
                f"bundle {p}: malformed profile member: {e}") from e
        if not isinstance(raw_profile, dict) \
                or raw_profile.get("schema") != PROFILE_SCHEMA_VERSION:
            raise BundleFormatError(
                f"bundle {p}: profile member has an unknown schema")
        for key, n in (raw_profile.get("counts") or {}).items():
            try:
                counts[str(key)] = float(n)
            except (TypeError, ValueError) as e:
                raise BundleFormatError(
                    f"bundle {p}: malformed profile count {key!r}") from e
    return manifest, entries, counts


# ------------------------------------------------------------------ import --
def import_bundle(
    path: str | os.PathLike,
    *,
    cache_path: str | os.PathLike,
    platform: Any,
    registry: Any = None,
    _prefetched: tuple[dict, dict, dict] | None = None,
) -> ImportReport:
    """Merge a bundle into the target site's cache, revalidating per entry.

    All validation — artifact integrity, ABI compatibility, per-entry
    feasibility on the TARGET platform — happens on in-memory data before
    the first cache write, and the write itself is the cache's atomic
    load-merge-replace: a rejection at any stage leaves the target file
    byte-identical, and a crash mid-save leaves the previous file (never
    a torn one).  Re-importing the same bundle is a no-op (entries the
    target already holds are skipped, and an untouched cache is not even
    rewritten).
    """
    # _prefetched lets verify_bundle reuse its own _read_bundle result
    # instead of decompressing and checksumming the artifact a second time
    manifest, entries, _ = (_prefetched if _prefetched is not None
                            else _read_bundle(path))
    reg = registry if registry is not None else _default_registry()
    source_fp = SiteFingerprint.from_dict(manifest["fingerprint"])
    target_fp = platform_fingerprint(platform)
    same_site = source_fp.key == target_fp

    # -- ABI gate (whole-bundle): resolve each op's target impl ------------
    per_op: dict[str, tuple[Any, bool]] = {}   # op -> (impl | None, minor_drift)
    for op, abi_text in sorted(manifest["abis"].items()):
        try:
            got = parse_abi(abi_text)
        except AbiError as e:
            # the manifest has no self-checksum, and _read_bundle only
            # cross-checks abis entries that back cache entries — a
            # hand-edited table must reject the artifact, not crash the
            # deploy that promised to degrade cold
            raise BundleFormatError(
                f"manifest ABI table is malformed for op '{op}': {e}") from e
        try:
            impl = reg.decl(op).tunable_native(platform)
        except KeyError:
            impl = None
        if impl is None:
            per_op[op] = (None, False)
            continue
        want = parse_abi(str(impl.abi))
        if (got.name, got.major, got.digest) != (want.name, want.major,
                                                 want.digest):
            raise BundleFormatError(
                f"ABI incompatibility for op '{op}': bundle tuned against "
                f"{got}, site declares {want} (major/signature mismatch)"
            )
        per_op[op] = (impl, got.minor != want.minor)

    # -- per-entry revalidation (in memory, no writes yet) -----------------
    plan: list[tuple[float, CacheKey, Any, dict, bool, EntryImport]] = []
    results: list[EntryImport] = []
    for encoded, entry in sorted(entries.items()):
        parts = encoded.split("|")
        op, shapes, dtype = parse_abi(parts[0]).name, parts[2], parts[3]
        impl, minor_drift = per_op[op]
        if impl is None:
            results.append(EntryImport(op, shapes, dtype, "skipped",
                                       "no tunable native on target"))
            continue
        tuner = impl.tuner
        synth = tuner.args_from_shapes
        if synth is not None and synth(platform, shapes, dtype) is None:
            results.append(EntryImport(op, shapes, dtype, "rejected",
                                       "bucket does not match op signature"))
            continue
        from repro.tuning.config import BlockConfig

        config = BlockConfig.from_dict(entry["config"])
        demote, reason = False, ""
        if minor_drift:
            demote, reason = True, "tuned on a drifted kernel revision"
        elif not same_site:
            validator = bucket_validator(tuner, platform)
            if validator is not None and not validator(config, shapes, dtype):
                demote, reason = True, "infeasible on target platform"
        new_key = CacheKey(abi=str(impl.abi), platform=target_fp,
                           shapes=shapes, dtype=dtype)
        metrics = dict(entry.get("metrics") or {})
        metrics["bundle_origin"] = source_fp.key   # provenance: the bind
        # labels hits on this entry "bundle-imported" until a local search
        # re-measures the key
        if demote:
            metrics["bundle_demoted_reason"] = reason
        status = "demoted" if demote else "imported"
        plan.append((float(entry.get("last_used", 0.0)), new_key, config,
                     metrics, demote,
                     EntryImport(op, shapes, dtype, status, reason,
                                 new_key.encode())))

    # -- apply: oldest bundled recency first, so relative LRU order holds --
    target = TuningCache.load(cache_path)
    wrote = False
    for _, key, config, metrics, demote, record in sorted(
            plan, key=lambda t: (t[0], t[1].encode())):
        live = target.get(key, touch=False) is not None
        if live or (demote and target.is_demoted(key)):
            results.append(dataclasses.replace(
                record, status="already-present",
                reason="target already holds this key"))
            continue
        target.put(key, config, metrics=metrics, demoted=demote)
        results.append(record)
        wrote = True
    if wrote:
        target.save()
    report = ImportReport(source=source_fp.key, target=target_fp,
                          results=tuple(results), saved=wrote)
    log.info(report.describe())
    return report


# ------------------------------------------------------------------ verify --
def verify_bundle(
    path: str | os.PathLike,
    *,
    platform: Any,
    registry: Any = None,
    top_k: int = 3,
) -> tuple[int, list[str]]:
    """Conformance check: does this bundle actually save the target work?

    Imports into a scratch cache and replays the bundled profile through
    a *read-only* bind (zero searches by construction — the point is to
    prove none would be NEEDED), then asserts:

      * every imported bucket dispatches exactly (its own entry, not a
        neighbour or the shipped default);
      * no demoted entry bound first-class (demoted buckets legitimately
        re-search-and-upgrade on a real deploy; that is the designed
        adaptation cost, not a conformance failure);
      * no *coverage gap*: a profiled bucket that is neither imported,
        demoted, nor rejected would force a cold search at deploy time —
        the exact cost a bundle exists to eliminate.

    Returns (exit code, report lines); 0 iff every assertion held.
    """
    from repro.tuning.tuner import TuningContext

    prefetched = _read_bundle(path)
    manifest, _, counts = prefetched
    reg = registry if registry is not None else _default_registry()
    tmp = Path(tempfile.mkdtemp(prefix="repro-bundle-verify-"))
    report = import_bundle(path, cache_path=tmp / "tuning.json",
                           platform=platform, registry=reg,
                           _prefetched=prefetched)
    lines = [report.describe()]

    profile = WorkloadProfile(tmp / "workload.json", counts=counts)
    cache = TuningCache.load(tmp / "tuning.json")
    ops = [op for op in sorted(manifest["abis"])
           if per_op_ok(reg, op, platform)]
    if not ops:
        return 1, lines + ["FAIL: target site binds no tunable native for "
                           "any bundled op"]
    ctx = TuningContext(cache, platform, search_on_miss=False,
                        profile=profile if len(profile) else None,
                        top_k=top_k, bundle_report=report)
    binding = reg.bind(ops, platform, native=True, freeze=False, tuning=ctx)

    failures: list[str] = []
    by_status: dict[tuple[str, str, str], str] = {
        (r.op, r.shapes, r.dtype): r.status for r in report.results
    }
    reports = {r.op: r for r in binding.reports}
    for r in report.results:
        if r.op not in reports:
            continue          # 'skipped' entries: op not bound on this site
        table = binding.impl(r.op).config
        if r.status == "imported":
            cfg, how = table.resolve(shapes=r.shapes, dtype=r.dtype)
            if how != "exact":
                failures.append(
                    f"FAIL: imported bucket {r.op}[{r.shapes}/{r.dtype}] "
                    f"dispatches '{how}', want exact")
            else:
                lines.append(f"  ok {r.op:<18} {r.shapes or '<scalar>':<28} "
                             f"exact ({cfg})")
        elif r.status == "demoted":
            geoms = {(g.shapes, g.dtype): g.status
                     for g in reports[r.op].geometries}
            bound = geoms.get((r.shapes, r.dtype))
            if bound not in (None, "bundle-demoted", "bundle-rejected"):
                failures.append(
                    f"FAIL: demoted bucket {r.op}[{r.shapes}/{r.dtype}] "
                    f"bound as {bound!r}")
            cfg, how = table.resolve(shapes=r.shapes, dtype=r.dtype)
            if how == "exact":
                failures.append(
                    f"FAIL: demoted bucket {r.op}[{r.shapes}/{r.dtype}] "
                    f"resolves exact — it must never bind raw")
            else:
                lines.append(f"  ok {r.op:<18} {r.shapes or '<scalar>':<28} "
                             f"demoted -> '{how}'")
    # coverage gaps: a profiled bucket the bundle says nothing about will
    # cold-search at deploy time — exactly what a shipped artifact is
    # supposed to have paid for already
    for op in ops:
        for geo, n in profile.top(op=op, k=top_k):
            if (op, geo.shapes, geo.dtype) not in by_status:
                failures.append(
                    f"FAIL: profiled bucket {op}[{geo.shapes}/{geo.dtype}] "
                    f"(x{n:g}) is not covered by the bundle — a target "
                    f"deploy would pay a cold search for it")
    if ctx.searches_spent:   # read-only bind: impossible by construction
        failures.append(f"FAIL: replay paid {ctx.searches_spent} search(es)")
    if failures:
        return 1, lines + failures
    c = report.counts()
    lines.append(f"OK: {c['imported']} imported bucket(s) dispatch exactly, "
                 f"zero searches paid or needed"
                 + (f"; {c['demoted']} demoted entr"
                    f"{'y' if c['demoted'] == 1 else 'ies'} held back"
                    if c["demoted"] else ""))
    return 0, lines


def per_op_ok(reg: Any, op: str, platform: Any) -> bool:
    """True iff the target site binds a tunable native for `op`."""
    try:
        return reg.decl(op).tunable_native(platform) is not None
    except KeyError:
        return False


# -------------------------------------------------------------- KV handoff --
KV_HANDOFF_SCHEMA_VERSION = 1
_HANDOFF_KIND = "repro-kv-handoff"
_HANDOFF_STATE = "state.npz"


@dataclasses.dataclass(frozen=True)
class KVHandoff:
    """One slot's KV/SSM state in flight between serving replicas.

    The disaggregated fleet (repro.serving) migrates a finished prefill
    slot to a decode replica as an *artifact*, not a pointer: the pages
    the sender's ``PagedPool`` held for the slot (gathered in block-table
    order) plus the slot's SSM rows, serialized through the same
    checksummed-manifest path the tuning bundles use.  The receiver
    leases fresh pages from its own ``BlockAllocator`` and scatters the
    arrays in; nothing about the sender's page numbering survives the
    trip, which is exactly what makes the handoff portable between
    replicas with different pool occupancy.

    ``arrays`` maps cache-tree leaves to numpy arrays: ``"p{j}/k"`` /
    ``"p{j}/v"`` are ``(layers_in_part, pages_used, page_size, KV, Dh)``
    page stacks, ``"p{j}/state"`` / ``"p{j}/conv"`` are the slot's SSM
    rows.  ``next_pos`` counts tokens whose KV the pages hold (prompt
    plus any decoded-so-far tokens on a mid-decode migration).
    """

    rid: int
    source: str
    next_pos: int
    pages_used: int
    page_size: int
    arrays: Mapping[str, Any]

    def to_bytes(self) -> bytes:
        """Serialize as an in-memory tar.gz: manifest.json + state.npz.

        Same trust conventions as export_bundle: the manifest carries a
        sha256 per member plus per-array shape/dtype, so the receiver
        verifies everything before leasing a single page.
        """
        import numpy as np

        state = io.BytesIO()
        np.savez(state, **{k: np.asarray(v) for k, v in self.arrays.items()})
        state_blob = state.getvalue()
        manifest = {
            "schema": KV_HANDOFF_SCHEMA_VERSION,
            "kind": _HANDOFF_KIND,
            "rid": int(self.rid),
            "source": str(self.source),
            "next_pos": int(self.next_pos),
            "pages_used": int(self.pages_used),
            "page_size": int(self.page_size),
            "arrays": {k: [list(np.asarray(v).shape),
                           str(np.asarray(v).dtype)]
                       for k, v in self.arrays.items()},
            "checksums": {_HANDOFF_STATE: _sha256(state_blob)},
        }
        manifest_blob = json.dumps(manifest, indent=1, sort_keys=True).encode()
        out = io.BytesIO()
        with tarfile.open(fileobj=out, mode="w:gz") as tar:
            for name, blob in ((_MANIFEST, manifest_blob),
                               (_HANDOFF_STATE, state_blob)):
                info = tarfile.TarInfo(name)
                info.size = len(blob)
                tar.addfile(info, io.BytesIO(blob))
        return out.getvalue()

    @classmethod
    def from_bytes(cls, data: bytes) -> "KVHandoff":
        """Parse + fully verify a handoff artifact.

        Every defect — truncation, checksum mismatch, unknown schema,
        an array whose shape/dtype disagrees with the manifest — raises
        BundleFormatError before the receiver touches its pool.
        """
        import numpy as np

        members: dict[str, bytes] = {}
        try:
            with tarfile.open(fileobj=io.BytesIO(data), mode="r:gz") as tar:
                for name in (_MANIFEST, _HANDOFF_STATE):
                    try:
                        fh = tar.extractfile(name)
                    except KeyError:
                        fh = None
                    if fh is not None:
                        members[name] = fh.read()
        except (OSError, EOFError, tarfile.TarError) as e:
            raise BundleFormatError(f"unreadable KV handoff: {e}") from e
        if _MANIFEST not in members:
            raise BundleFormatError(f"KV handoff has no {_MANIFEST}")
        try:
            manifest = json.loads(members[_MANIFEST])
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise BundleFormatError(f"KV handoff: malformed manifest: {e}") from e
        if not isinstance(manifest, dict) \
                or manifest.get("kind") != _HANDOFF_KIND:
            raise BundleFormatError(f"not a {_HANDOFF_KIND} artifact")
        if manifest.get("schema") != KV_HANDOFF_SCHEMA_VERSION:
            raise BundleFormatError(
                f"KV handoff schema {manifest.get('schema')!r} "
                f"(this runtime understands {KV_HANDOFF_SCHEMA_VERSION})"
            )
        blob = members.get(_HANDOFF_STATE)
        want = (manifest.get("checksums") or {}).get(_HANDOFF_STATE)
        if blob is None or want is None or _sha256(blob) != want:
            raise BundleFormatError(
                "KV handoff: checksum mismatch on state.npz "
                "(corrupt or tampered artifact)"
            )
        try:
            with np.load(io.BytesIO(blob)) as npz:
                arrays = {k: npz[k] for k in npz.files}
        except Exception as e:
            raise BundleFormatError(f"KV handoff: unreadable state.npz: {e}") from e
        declared = manifest.get("arrays")
        if not isinstance(declared, dict) or set(declared) != set(arrays):
            raise BundleFormatError(
                "KV handoff: state.npz members disagree with the manifest"
            )
        for name, (shape, dtype) in declared.items():
            arr = arrays[name]
            if list(arr.shape) != list(shape) or str(arr.dtype) != dtype:
                raise BundleFormatError(
                    f"KV handoff: array {name} is {arr.shape}/{arr.dtype}, "
                    f"manifest declares {shape}/{dtype}"
                )
        try:
            meta = {k: int(manifest[k]) for k in
                    ("rid", "next_pos", "pages_used", "page_size")}
        except (KeyError, TypeError, ValueError) as e:
            raise BundleFormatError(f"KV handoff: malformed metadata: {e}") from e
        if meta["page_size"] < 1 or meta["pages_used"] < 1 \
                or meta["next_pos"] < 1 \
                or meta["pages_used"] * meta["page_size"] < meta["next_pos"]:
            raise BundleFormatError(
                f"KV handoff: inconsistent geometry {meta!r} "
                f"(pages cannot hold the declared positions)"
            )
        return cls(rid=meta["rid"], source=str(manifest.get("source", "?")),
                   next_pos=meta["next_pos"], pages_used=meta["pages_used"],
                   page_size=meta["page_size"], arrays=arrays)


# --------------------------------------------------------------------- CLI --
def _resolve_platform(name: str | None):
    from repro.core.env import resolve_platform
    from repro.core.platform import PLATFORMS

    return PLATFORMS[name] if name else resolve_platform()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Export/import/verify portable tuning bundles.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    ex = sub.add_parser("export", help="package this site's tuned state")
    ex.add_argument("--out", required=True, help="bundle path to write (.tgz)")
    ex.add_argument("--cache", default=None,
                    help="tuning cache path (default: REPRO_TUNING_CACHE)")
    ex.add_argument("--profile", default=None,
                    help="workload profile path (default: "
                         "REPRO_WORKLOAD_PROFILE)")
    ex.add_argument("--platform", default=None,
                    help="platform name (default: REPRO_PLATFORM / detection)")
    ex.add_argument("--ops", default=None,
                    help="comma-separated op filter (default: every op with "
                         "entries)")

    im = sub.add_parser("import", help="merge a bundle into the site cache")
    im.add_argument("bundle", help="bundle path")
    im.add_argument("--cache", default=None,
                    help="tuning cache path (default: REPRO_TUNING_CACHE)")
    im.add_argument("--platform", default=None,
                    help="platform name (default: REPRO_PLATFORM / detection)")

    ve = sub.add_parser("verify", help="conformance-check a bundle "
                                       "(scratch import + zero-search replay)")
    ve.add_argument("bundle", help="bundle path")
    ve.add_argument("--platform", default=None,
                    help="platform name (default: REPRO_PLATFORM / detection)")
    ve.add_argument("--top", type=int, default=3,
                    help="profile geometries per op to replay")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    platform = _resolve_platform(args.platform)

    if args.cmd == "export":
        cache_path = Path(args.cache) if args.cache else resolve_cache_path()
        profile_path = (Path(args.profile) if args.profile
                        else resolve_profile_path())
        ops = [o.strip() for o in args.ops.split(",")] if args.ops else None
        try:
            out, manifest = export_bundle(
                args.out, cache_path=cache_path, platform=platform,
                profile_path=profile_path, ops=ops)
        except (ValueError, OSError) as e:
            print(f"export failed: {e}")
            return 1
        e = manifest["entries"]
        print(f"exported {out}: {e['count']} entr"
              f"{'y' if e['count'] == 1 else 'ies'} (~{e['total_bytes']}B) "
              f"under {SiteFingerprint.from_dict(manifest['fingerprint']).key}"
              f"{' + workload profile' if 'profile_schema' in manifest else ''}")
        return 0

    if args.cmd == "import":
        cache_path = Path(args.cache) if args.cache else resolve_cache_path()
        try:
            report = import_bundle(args.bundle, cache_path=cache_path,
                                   platform=platform)
        except (BundleFormatError, OSError) as e:
            print(f"import rejected: {e}")
            print("the target cache was not modified")
            return 1
        print(report.describe())
        print(f"cache {cache_path}: "
              f"{'updated' if report.saved else 'unchanged (no-op import)'}")
        return 0

    # verify
    try:
        code, lines = verify_bundle(args.bundle, platform=platform,
                                    top_k=args.top)
    except (BundleFormatError, OSError) as e:
        print(f"verify rejected the bundle outright: {e}")
        return 1
    print("\n".join(lines))
    return code


if __name__ == "__main__":
    sys.exit(main())
