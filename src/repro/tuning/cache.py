"""Site-local tuning cache — the bind-mount of tuned kernel parameters.

The bundle stays portable; the *site* contributes its tuned block
configurations, exactly like Shifter's site-specific volume: a JSON file
keyed by (ABI string, platform fingerprint, input-shape bucket, dtype)
that survives process restarts, so the search cost is paid once per site
and amortized over every later deployment.

Properties:

  * atomic writes — a concurrent reader never sees a torn file (write to
    a temp file in the same directory, then os.replace);
  * versioned schema — a cache written by an incompatible version is
    ignored wholesale, falling back to the built-in defaults;
  * corruption-safe — unparseable files degrade to an empty cache with a
    warning, never an exception (a bad cache must not kill a deployment);
  * relocatable — REPRO_TUNING_CACHE overrides the default location.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import logging
import math
import os
import tempfile
from pathlib import Path
from typing import Any, Mapping, Sequence

from repro.tuning.config import BlockConfig

__all__ = [
    "SCHEMA_VERSION",
    "ENV_TUNING_CACHE",
    "CacheKey",
    "TuningCache",
    "resolve_cache_path",
    "platform_fingerprint",
    "bucket_shapes",
    "file_lock",
]


@contextlib.contextmanager
def file_lock(lock_path: Path):
    """Exclusive advisory lock for a load-merge-replace sequence (POSIX);
    on platforms without fcntl the merge still narrows the race.

    Shared by TuningCache.save and WorkloadProfile.save — any writer that
    re-reads, merges, and atomically replaces a site file must hold this
    across the whole sequence or a concurrent writer's merge is lost.
    """
    try:
        import fcntl
    except ImportError:
        yield
        return
    with open(lock_path, "w") as lf:
        fcntl.flock(lf, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lf, fcntl.LOCK_UN)

log = logging.getLogger("repro.tuning")

SCHEMA_VERSION = 1
ENV_TUNING_CACHE = "REPRO_TUNING_CACHE"
_DEFAULT_CACHE = Path("~/.cache/repro/tuning.json")


def resolve_cache_path(env: Mapping[str, str] | None = None) -> Path:
    """REPRO_TUNING_CACHE override, else the per-user default location."""
    env = os.environ if env is None else env
    override = str(env.get(ENV_TUNING_CACHE, "")).strip()
    if override:
        return Path(override).expanduser()
    return _DEFAULT_CACHE.expanduser()


def platform_fingerprint(platform: Any) -> str:
    """Identity of the site a tuned config is valid for.

    Platform name + hardware name + the actually-present JAX backend:
    the same pod-sim cache entry must not be replayed on a real TPU.
    """
    import jax

    return f"{platform.name}/{platform.hardware.name}/{jax.default_backend()}"


def _bucket(n: int) -> int:
    """Round a dimension up to the next power of two (1 stays 1)."""
    return 1 if n <= 1 else 1 << math.ceil(math.log2(n))


def bucket_shapes(args: Sequence[Any]) -> tuple[str, str]:
    """(shape-bucket string, dtype) of a workload's array arguments.

    Bucketing to powers of two lets nearby geometries share one tuned
    entry instead of re-searching per exact shape; scalars and Python
    ints (step counters etc.) carry no geometry and are skipped.
    """
    shapes = []
    dtype = "none"
    for a in args:
        shape = getattr(a, "shape", None)
        if shape is None or not hasattr(a, "dtype"):
            continue
        if dtype == "none":
            dtype = str(a.dtype)
        shapes.append("x".join(str(_bucket(int(d))) for d in shape) or "scalar")
    return ",".join(shapes), dtype


@dataclasses.dataclass(frozen=True, order=True)
class CacheKey:
    """(ABI, platform fingerprint, shape bucket, dtype) — the lookup key."""

    abi: str
    platform: str
    shapes: str
    dtype: str

    def encode(self) -> str:
        return "|".join((self.abi, self.platform, self.shapes, self.dtype))

    @classmethod
    def from_args(cls, abi: str, platform: Any, args: Sequence[Any]) -> "CacheKey":
        shapes, dtype = bucket_shapes(args)
        fp = platform if isinstance(platform, str) else platform_fingerprint(platform)
        return cls(abi=abi, platform=fp, shapes=shapes, dtype=dtype)


class TuningCache:
    """JSON-backed persistent map: CacheKey -> (BlockConfig, metrics)."""

    def __init__(self, path: str | os.PathLike,
                 entries: Mapping[str, dict] | None = None) -> None:
        self.path = Path(path)
        self._entries: dict[str, dict] = dict(entries or {})
        self._evicted: set[str] = set()   # tombstones: keep save() from
        # resurrecting expired entries out of the on-disk copy
        self.dirty = False

    # -- loading -----------------------------------------------------------
    @classmethod
    def load(cls, path: str | os.PathLike) -> "TuningCache":
        """Read a cache file; any defect degrades to an empty cache."""
        p = Path(path)
        try:
            raw = json.loads(p.read_text())
        except FileNotFoundError:
            return cls(p)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            log.warning("tuning cache %s unreadable (%s); starting empty", p, e)
            return cls(p)
        if not isinstance(raw, dict) or raw.get("schema") != SCHEMA_VERSION:
            log.warning(
                "tuning cache %s has schema %r (want %d); ignoring it",
                p, raw.get("schema") if isinstance(raw, dict) else None,
                SCHEMA_VERSION,
            )
            return cls(p)
        entries: dict[str, dict] = {}
        for key, entry in (raw.get("entries") or {}).items():
            try:
                BlockConfig.from_dict(entry["config"])
            except Exception:
                log.warning("tuning cache %s: dropping malformed entry %r", p, key)
                continue
            entries[key] = entry
        return cls(p, entries)

    # -- access ------------------------------------------------------------
    def get(self, key: CacheKey) -> BlockConfig | None:
        entry = self._entries.get(key.encode())
        if entry is None:
            return None
        return BlockConfig.from_dict(entry["config"])

    def metrics(self, key: CacheKey) -> dict:
        entry = self._entries.get(key.encode())
        return dict(entry.get("metrics", {})) if entry else {}

    def put(self, key: CacheKey, config: BlockConfig,
            metrics: Mapping[str, Any] | None = None) -> None:
        self._entries[key.encode()] = {
            "config": config.to_dict(),
            "metrics": dict(metrics or {}),
        }
        self._evicted.discard(key.encode())
        self.dirty = True

    def raw_keys(self) -> tuple[str, ...]:
        """Encoded keys of every live entry (see CacheKey.encode)."""
        return tuple(self._entries)

    def entries_for(self, abi: str, platform: str
                    ) -> dict[tuple[str, str], BlockConfig]:
        """All tuned geometries of one (ABI, platform fingerprint):
        (shape bucket, dtype) -> config.  The geometry-dispatch binding
        sweeps this so a cache warmed deeper than the profile's current
        top-K still binds every entry hot."""
        out: dict[tuple[str, str], BlockConfig] = {}
        for encoded, entry in self._entries.items():
            parts = encoded.split("|")
            if len(parts) == 4 and parts[0] == abi and parts[1] == platform:
                out[(parts[2], parts[3])] = BlockConfig.from_dict(entry["config"])
        return out

    def evict(self, key: "CacheKey | str") -> bool:
        """Remove an entry and tombstone it so save() cannot resurrect it
        from the on-disk copy.  Returns True if the entry existed."""
        encoded = key if isinstance(key, str) else key.encode()
        existed = self._entries.pop(encoded, None) is not None
        self._evicted.add(encoded)
        if existed:
            self.dirty = True
        return existed

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: CacheKey) -> bool:
        return key.encode() in self._entries

    # -- persistence ---------------------------------------------------------
    def save(self) -> Path:
        """Atomically write the cache (temp file + rename, same filesystem).

        The whole load-merge-replace runs under an exclusive sidecar lock:
        two deployments that tuned *different* ops concurrently both keep
        their winners.  On a same-key conflict this process's entry wins —
        last writer's measurement, both valid.  Entries evicted in this
        process (ABI expiry, see expiry.py) are tombstoned and stay gone
        even if the on-disk copy still holds them.

        Raises OSError on unwritable paths; TuningContext.flush downgrades
        that to a warning because a failed persist must not kill a
        deployment that already holds a good binding.
        """
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with file_lock(self.path.with_name(self.path.name + ".lock")):
            on_disk = TuningCache.load(self.path)
            if on_disk._entries:
                kept = {k: v for k, v in on_disk._entries.items()
                        if k not in self._evicted}
                self._entries = {**kept, **self._entries}
            payload = {"schema": SCHEMA_VERSION, "entries": self._entries}
            fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                       prefix=self.path.name, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        self.dirty = False
        return self.path
