"""Site-local tuning cache — the bind-mount of tuned kernel parameters.

The bundle stays portable; the *site* contributes its tuned block
configurations, exactly like Shifter's site-specific volume: a JSON file
keyed by (ABI string, platform fingerprint, input-shape bucket, dtype)
that survives process restarts, so the search cost is paid once per site
and amortized over every later deployment.

Properties:

  * atomic writes — a concurrent reader never sees a torn file (write to
    a temp file in the same directory, then os.replace);
  * versioned schema — a cache written by an incompatible version is
    ignored wholesale, falling back to the built-in defaults;
  * corruption-safe — unparseable files degrade to an empty cache with a
    warning, never an exception (a bad cache must not kill a deployment);
  * relocatable — REPRO_TUNING_CACHE overrides the default location;
  * bounded (optional) — ``max_entries`` turns the cache from append-only
    into a managed LRU: every `get` hit stamps the entry's ``last_used``
    (persisted in the JSON, so recency survives redeploys), and
    :meth:`compact` evicts down to the cap, coldest first.  ``max_bytes``
    bounds the serialized size the same way (the ``entry_bytes``
    accounting; ``REPRO_TUNING_MAX_BYTES`` is the env trigger).  See
    expiry.compact_lru for the profile-aware sweep and
    ``python -m repro.tuning.warm --compact`` for the offline GC.

Entries may additionally be *demoted* (``put(..., demoted=True)``): a
demoted entry keeps its bytes, recency, and eviction exposure, but
:meth:`get` and :meth:`entries_for` skip it — it never binds first-class.
Demotion is how a cross-site tuning-bundle import (see bundle.py) keeps
a config that failed the target platform's feasibility re-check as a
*near-config candidate*: the dispatch layer may still lend it out at a
distance penalty after re-validating it for the borrowing call, and a
fresh local search (`put` without the flag) upgrades the key wholesale.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import logging
import math
import os
import tempfile
import time
from pathlib import Path
from typing import Any, Iterable, Mapping, Sequence

from repro.tuning.config import BlockConfig

__all__ = [
    "SCHEMA_VERSION",
    "ENV_TUNING_CACHE",
    "CacheKey",
    "TuningCache",
    "resolve_cache_path",
    "platform_fingerprint",
    "bucket_shapes",
    "base_dtype",
    "file_lock",
]


@contextlib.contextmanager
def file_lock(lock_path: Path):
    """Exclusive advisory lock for a load-merge-replace sequence (POSIX);
    on platforms without fcntl the merge still narrows the race.

    Shared by TuningCache.save and WorkloadProfile.save — any writer that
    re-reads, merges, and atomically replaces a site file must hold this
    across the whole sequence or a concurrent writer's merge is lost.
    """
    try:
        import fcntl
    except ImportError:
        yield
        return
    with open(lock_path, "w") as lf:
        fcntl.flock(lf, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lf, fcntl.LOCK_UN)

log = logging.getLogger("repro.tuning")

SCHEMA_VERSION = 1
ENV_TUNING_CACHE = "REPRO_TUNING_CACHE"
_DEFAULT_CACHE = Path("~/.cache/repro/tuning.json")


def resolve_cache_path(env: Mapping[str, str] | None = None) -> Path:
    """REPRO_TUNING_CACHE override, else the per-user default location."""
    env = os.environ if env is None else env
    override = str(env.get(ENV_TUNING_CACHE, "")).strip()
    if override:
        return Path(override).expanduser()
    return _DEFAULT_CACHE.expanduser()


def platform_fingerprint(platform: Any) -> str:
    """Identity of the site a tuned config is valid for.

    Platform name + hardware name + the actually-present JAX backend:
    the same pod-sim cache entry must not be replayed on a real TPU.
    """
    import jax

    return f"{platform.name}/{platform.hardware.name}/{jax.default_backend()}"


def _bucket(n: int) -> int:
    """Round a dimension up to the next power of two (1 stays 1)."""
    return 1 if n <= 1 else 1 << math.ceil(math.log2(n))


def _is_quant_dtype(dt: str) -> bool:
    """1-byte quantized storage dtypes (int8 code points, fp8 grids)."""
    return dt in ("int8", "uint8") or dt.startswith("float8")


def base_dtype(dtype: str) -> str:
    """Full-precision component of a (possibly composite) bucket dtype:
    ``"float32+int8" -> "float32"``, plain dtypes pass through.  What
    the bucket validator and feasibility re-checks rebuild non-quantized
    args in."""
    return str(dtype).partition("+")[0]


def bucket_shapes(args: Sequence[Any]) -> tuple[str, str]:
    """(shape-bucket string, dtype) of a workload's array arguments.

    Bucketing to powers of two lets nearby geometries share one tuned
    entry instead of re-searching per exact shape; scalars and Python
    ints (step counters etc.) carry no geometry and are skipped.

    The dtype is the first array arg's; when a *later* array arg is a
    quantized storage dtype (int8/fp8) differing from it, the bucket
    dtype becomes the composite ``"<base>+<quant>"`` — a quantized-KV
    decode and its fp32 twin must not share one tuned entry (the
    quantized kernel moves a quarter of the bytes, so its block sweet
    spot differs).  Integer positional args (pos vectors, block tables,
    group sizes) are int32, not 1-byte, so they never trip the suffix.
    """
    shapes = []
    dtype = "none"
    quant = None
    for a in args:
        shape = getattr(a, "shape", None)
        if shape is None or not hasattr(a, "dtype"):
            continue
        dt = str(a.dtype)
        if dtype == "none":
            dtype = dt
        elif quant is None and dt != dtype and _is_quant_dtype(dt):
            quant = dt
        shapes.append("x".join(str(_bucket(int(d))) for d in shape) or "scalar")
    if quant is not None:
        dtype = f"{dtype}+{quant}"
    return ",".join(shapes), dtype


@dataclasses.dataclass(frozen=True, order=True)
class CacheKey:
    """(ABI, platform fingerprint, shape bucket, dtype) — the lookup key."""

    abi: str
    platform: str
    shapes: str
    dtype: str

    def encode(self) -> str:
        return "|".join((self.abi, self.platform, self.shapes, self.dtype))

    @classmethod
    def from_args(cls, abi: str, platform: Any, args: Sequence[Any]) -> "CacheKey":
        shapes, dtype = bucket_shapes(args)
        fp = platform if isinstance(platform, str) else platform_fingerprint(platform)
        return cls(abi=abi, platform=fp, shapes=shapes, dtype=dtype)


class TuningCache:
    """JSON-backed persistent map: CacheKey -> (BlockConfig, metrics).

    ``max_entries`` (optional) bounds the cache: :meth:`save` compacts the
    merged result down to the cap so the file can never grow past it, and
    :meth:`compact` may be called explicitly (deploy-time pressure, the
    ``warm --compact`` GC).  Every live entry carries a ``last_used``
    stamp — refreshed by `get` hits and `put`s, persisted in the JSON —
    which is the LRU order eviction walks.
    """

    def __init__(self, path: str | os.PathLike,
                 entries: Mapping[str, dict] | None = None,
                 max_entries: int | None = None,
                 max_bytes: int | None = None) -> None:
        self.path = Path(path)
        self._entries: dict[str, dict] = dict(entries or {})
        self._evicted: set[str] = set()   # tombstones: keep save() from
        # resurrecting expired entries out of the on-disk copy
        self._loaded_keys: frozenset[str] = frozenset(self._entries)
        self._touched: set[str] = set()   # keys put() in THIS process: the
        # only ones save() may (re)introduce to a file another process has
        # already evicted them from — so cross-process tombstones hold
        self._last_stamp = 0.0
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.dirty = False

    def _stamp(self) -> float:
        """Wall-clock recency stamp, strictly increasing in-process (LRU
        ordering must hold even when time.time() resolution ties)."""
        now = time.time()
        if now <= self._last_stamp:
            now = self._last_stamp + 1e-6
        self._last_stamp = now
        return now

    # -- loading -----------------------------------------------------------
    @classmethod
    def load(cls, path: str | os.PathLike) -> "TuningCache":
        """Read a cache file; any defect degrades to an empty cache."""
        p = Path(path)
        try:
            raw = json.loads(p.read_text())
        except FileNotFoundError:
            return cls(p)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            log.warning("tuning cache %s unreadable (%s); starting empty", p, e)
            return cls(p)
        if not isinstance(raw, dict) or raw.get("schema") != SCHEMA_VERSION:
            log.warning(
                "tuning cache %s has schema %r (want %d); ignoring it",
                p, raw.get("schema") if isinstance(raw, dict) else None,
                SCHEMA_VERSION,
            )
            return cls(p)
        entries: dict[str, dict] = {}
        for key, entry in (raw.get("entries") or {}).items():
            try:
                BlockConfig.from_dict(entry["config"])
            except Exception:
                log.warning("tuning cache %s: dropping malformed entry %r", p, key)
                continue
            entries[key] = entry
        return cls(p, entries)

    # -- access ------------------------------------------------------------
    def get(self, key: CacheKey, *, touch: bool = True) -> BlockConfig | None:
        """Config at `key`, stamping ``last_used`` on the hit (persisted on
        the next save, so LRU recency survives redeploys).  ``touch=False``
        peeks without refreshing — eviction sweeps must not make an entry
        look hot by inspecting it.  Demoted entries (bundle imports that
        failed the local feasibility re-check) are *not* returned: they
        must never bind first-class, only via the dispatch layer's
        penalized candidate pool (see demoted_for)."""
        entry = self._entries.get(key.encode())
        if entry is None or entry.get("demoted"):
            return None
        if touch:
            entry["last_used"] = self._stamp()
            self.dirty = True
        return BlockConfig.from_dict(entry["config"])

    def is_demoted(self, key: "CacheKey | str") -> bool:
        """True iff an entry exists at `key` AND carries the demotion flag."""
        encoded = key if isinstance(key, str) else key.encode()
        entry = self._entries.get(encoded)
        return bool(entry is not None and entry.get("demoted"))

    def touch(self, key: "CacheKey | str") -> None:
        """Refresh an entry's ``last_used`` without decoding its config
        (the geometry-dispatch sweep binds entries wholesale)."""
        encoded = key if isinstance(key, str) else key.encode()
        entry = self._entries.get(encoded)
        if entry is not None:
            entry["last_used"] = self._stamp()
            self.dirty = True

    def last_used(self, key: "CacheKey | str") -> float:
        """Recency stamp of an entry (0.0 when absent or never stamped —
        pre-lifecycle caches sort coldest, which is the right bias)."""
        encoded = key if isinstance(key, str) else key.encode()
        entry = self._entries.get(encoded)
        return float(entry.get("last_used", 0.0)) if entry else 0.0

    def metrics(self, key: CacheKey) -> dict:
        entry = self._entries.get(key.encode())
        return dict(entry.get("metrics", {})) if entry else {}

    def put(self, key: CacheKey, config: BlockConfig,
            metrics: Mapping[str, Any] | None = None, *,
            demoted: bool = False) -> None:
        """Insert/replace an entry.  ``demoted=True`` marks it second-class
        (skipped by get/entries_for; see module docstring) — a later plain
        put at the same key clears the flag, i.e. a local measurement
        upgrades a demoted bundle import to a first-class entry."""
        entry = {
            "config": config.to_dict(),
            "metrics": dict(metrics or {}),
            "last_used": self._stamp(),
        }
        if demoted:
            entry["demoted"] = True
        self._entries[key.encode()] = entry
        self._evicted.discard(key.encode())
        self._touched.add(key.encode())
        self.dirty = True

    def raw_keys(self) -> tuple[str, ...]:
        """Encoded keys of every live entry (see CacheKey.encode)."""
        return tuple(self._entries)

    def raw_entry(self, key: "CacheKey | str") -> dict | None:
        """A copy of one entry's raw persisted form (config/metrics/
        last_used/demoted) — what bundle export packages verbatim."""
        encoded = key if isinstance(key, str) else key.encode()
        entry = self._entries.get(encoded)
        return dict(entry) if entry is not None else None

    def entries_for(self, abi: str, platform: str
                    ) -> dict[tuple[str, str], BlockConfig]:
        """All first-class tuned geometries of one (ABI, platform
        fingerprint): (shape bucket, dtype) -> config.  The geometry-
        dispatch binding sweeps this so a cache warmed deeper than the
        profile's current top-K still binds every entry hot.  Demoted
        entries are excluded — they only ever dispatch through the
        penalized candidate pool (see demoted_for)."""
        out: dict[tuple[str, str], BlockConfig] = {}
        for encoded, entry in self._entries.items():
            parts = encoded.split("|")
            if len(parts) == 4 and parts[0] == abi and parts[1] == platform \
                    and not entry.get("demoted"):
                out[(parts[2], parts[3])] = BlockConfig.from_dict(entry["config"])
        return out

    def demoted_for(self, abi: str, platform: str
                    ) -> dict[tuple[str, str], BlockConfig]:
        """Demoted geometries of one (ABI, platform fingerprint) — the
        near-config candidate pool a bundle import left behind (configs
        that failed the target's feasibility re-check at their own bucket
        but may re-qualify for a smaller live geometry)."""
        out: dict[tuple[str, str], BlockConfig] = {}
        for encoded, entry in self._entries.items():
            parts = encoded.split("|")
            if len(parts) == 4 and parts[0] == abi and parts[1] == platform \
                    and entry.get("demoted"):
                out[(parts[2], parts[3])] = BlockConfig.from_dict(entry["config"])
        return out

    def entry_bytes(self, key: "CacheKey | str") -> int:
        """Approximate serialized size of one entry (compact JSON bytes of
        its value, key included) — the unit the size accounting reports in
        OpBinding.describe(), ``warm --compact``, and bundle manifests."""
        encoded = key if isinstance(key, str) else key.encode()
        entry = self._entries.get(encoded)
        if entry is None:
            return 0
        blob = json.dumps({encoded: entry}, sort_keys=True,
                          separators=(",", ":"))
        return len(blob.encode())

    def total_bytes(self) -> int:
        """Approximate serialized bytes of every live entry (see
        entry_bytes)."""
        return sum(self.entry_bytes(encoded) for encoded in self._entries)

    def evict(self, key: "CacheKey | str") -> bool:
        """Remove an entry and tombstone it so save() cannot resurrect it
        from the on-disk copy.  Returns True if the entry existed."""
        encoded = key if isinstance(key, str) else key.encode()
        existed = self._entries.pop(encoded, None) is not None
        self._evicted.add(encoded)
        self._touched.discard(encoded)
        if existed:
            self.dirty = True
        return existed

    def compact(self, max_entries: int | None = None, *,
                max_bytes: int | None = None,
                protect: Iterable[str] = (),
                prefer: Iterable[str] = ()) -> list[str]:
        """Evict (tombstoned) down to the caps; returns evicted keys.

        Two independent caps, both enforced by one sweep: ``max_entries``
        bounds the entry count, ``max_bytes`` bounds the serialized size
        (the ``entry_bytes`` accounting — what the file costs on disk, so
        a site can budget the cache in storage terms rather than guessing
        an entry count).  Eviction order is the lifecycle policy's
        mechanics: keys in ``prefer`` go first (the caller marks
        stale-profile buckets there — see expiry.compact_lru), then
        coldest ``last_used``; keys in ``protect`` are never evicted,
        even if that leaves the cache over a cap.  A cap of None falls
        back to ``self.max_entries``/``self.max_bytes``; no caps at all
        is a no-op (the append-only pre-lifecycle behaviour).
        """
        cap = self.max_entries if max_entries is None else max_entries
        byte_cap = self.max_bytes if max_bytes is None else max_bytes
        sizes = {k: self.entry_bytes(k) for k in self._entries}
        live_bytes = sum(sizes.values())

        def over() -> bool:
            if cap is not None and len(self._entries) > cap:
                return True
            return byte_cap is not None and live_bytes > byte_cap

        if (cap is None and byte_cap is None) or not over():
            return []
        protect = frozenset(protect)
        prefer = frozenset(prefer)
        victims = sorted(
            (k for k in self._entries if k not in protect),
            key=lambda k: (k not in prefer,
                           float(self._entries[k].get("last_used", 0.0)), k),
        )
        evicted: list[str] = []
        for k in victims:
            if not over():
                break
            self.evict(k)
            live_bytes -= sizes[k]
            evicted.append(k)
        return evicted

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: CacheKey) -> bool:
        return key.encode() in self._entries

    # -- persistence ---------------------------------------------------------
    def save(self) -> Path:
        """Atomically write the cache (temp file + rename, same filesystem).

        The whole load-merge-replace runs under an exclusive sidecar lock:
        two deployments that tuned *different* ops concurrently both keep
        their winners.  On a same-key conflict a key this process *wrote*
        wins (last writer's measurement, both valid); a key it merely
        loaded keeps the disk copy — possibly re-measured by a concurrent
        process — folding in this process's ``last_used`` stamp when that
        is the fresher recency signal.  Tombstones merge cleanly
        in both directions: entries evicted in this process (ABI expiry,
        LRU pressure) stay gone even if the on-disk copy still holds them,
        and entries another process evicted while we ran stay gone unless
        this process re-``put`` them (a fresh measurement legitimately
        resurrects; a mere load-time copy must not).  When ``max_entries``
        is set, the merged result is compacted before writing, so the
        file never outgrows the cap through merges.

        Raises OSError on unwritable paths; TuningContext.flush downgrades
        that to a warning because a failed persist must not kill a
        deployment that already holds a good binding.
        """
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with file_lock(self.path.with_name(self.path.name + ".lock")):
            on_disk = TuningCache.load(self.path)
            if on_disk._entries:
                kept = {k: v for k, v in on_disk._entries.items()
                        if k not in self._evicted}
                merged = dict(kept)
                for k, v in self._entries.items():
                    if k in self._touched or k not in self._loaded_keys:
                        merged[k] = v     # our fresh measurement wins
                    elif k in kept:
                        # loaded copy: the disk entry may be fresher (a
                        # concurrent re-measure), so keep it — but fold in
                        # our recency stamp so a hit HERE keeps the entry
                        # hot for eviction ordering everywhere
                        ours = float(v.get("last_used", 0.0))
                        if ours > float(merged[k].get("last_used", 0.0)):
                            merged[k] = {**merged[k], "last_used": ours}
                    # else: we only loaded it and it vanished from disk — a
                    # concurrent process's tombstone; respect it
                self._entries = merged
            # an empty/missing/corrupt on-disk file is NOT a wipe of our
            # state: keep this process's entries wholesale (load() already
            # degrades corruption to empty, and a transient truncation
            # must not cascade into losing the whole warmed cache)
            if self.max_entries is not None or self.max_bytes is not None:
                self.compact(self.max_entries, max_bytes=self.max_bytes)
            payload = {"schema": SCHEMA_VERSION, "entries": self._entries}
            fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                       prefix=self.path.name, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        self._loaded_keys = frozenset(self._entries)
        self._touched.clear()
        # the persisted file now reflects the evictions: drop the
        # tombstones so a later save by this (long-lived) object cannot
        # keep killing a key another process legitimately re-measured
        self._evicted.clear()
        self.dirty = False
        return self.path
