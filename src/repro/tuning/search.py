"""Config-space search: enumerate, prune, measure, pick the winner.

The search is deliberately boring — exhaustive enumeration of a small
per-op space with feasibility pruning (VMEM working set, shape
divisibility) before anything is compiled, then timed best-of-k runs of
the survivors.  Exhaustive-over-pruned beats clever-over-huge at kernel
granularity: spaces are tens of points, a measurement is milliseconds,
and the result is cached per site anyway.

Everything here is interpret-mode safe: a "measurement" is whatever the
candidate callable does, so CPU CI tunes the interpreted kernel bodies
with the exact same machinery a TPU site uses on the real ones.
"""

from __future__ import annotations

import dataclasses
import itertools
import logging
import time
from typing import Any, Callable, Mapping, Sequence

import jax

from repro.tuning.config import BlockConfig

__all__ = ["Measurement", "SearchResult", "enumerate_space", "measure", "search"]

log = logging.getLogger("repro.tuning")


@dataclasses.dataclass(frozen=True)
class Measurement:
    config: BlockConfig
    seconds: float          # best-of-k wall clock per call


@dataclasses.dataclass(frozen=True)
class SearchResult:
    best: BlockConfig | None            # None if nothing survived
    best_seconds: float
    measurements: tuple[Measurement, ...]
    pruned: int                          # candidates rejected pre-measurement
    failed: int                          # candidates that raised while running

    def speedup_over(self, config: BlockConfig) -> float | None:
        """Measured best-time ratio vs `config`, if it was measured."""
        for m in self.measurements:
            if m.config == config and self.best_seconds > 0:
                return m.seconds / self.best_seconds
        return None


def enumerate_space(space: Mapping[str, Sequence[int]]) -> list[BlockConfig]:
    """Cartesian product of the per-parameter value lists."""
    names = sorted(space)
    configs = []
    for values in itertools.product(*(space[n] for n in names)):
        configs.append(BlockConfig.make(**dict(zip(names, values))))
    return configs


def measure(run: Callable[[], Any], *, iters: int = 2, warmup: int = 1) -> float:
    """Best-of-k seconds per call; `run` must block until the result is ready.

    Best-of (not median) because tuning wants the noise floor: scheduling
    jitter only ever adds time, so the minimum is the cleanest estimate of
    what the config can do.
    """
    for _ in range(warmup):
        jax.block_until_ready(run())
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, time.perf_counter() - t0)
    return best


def search(
    run_with: Callable[[BlockConfig], Any],
    space: Mapping[str, Sequence[int]],
    *,
    feasible: Callable[[BlockConfig], bool] | None = None,
    iters: int = 2,
    warmup: int = 1,
) -> SearchResult:
    """Measure every feasible config; return the fastest.

    `run_with(config)` executes the op once with that config (compiling on
    first use — compile time is excluded by the warmup run).  A candidate
    that raises is recorded as failed and skipped, so an over-eager space
    never aborts the search.
    """
    candidates = enumerate_space(space)
    pruned = 0
    if feasible is not None:
        kept = []
        for c in candidates:
            try:
                ok = feasible(c)
            except Exception:
                ok = False
            if ok:
                kept.append(c)
            else:
                pruned += 1
        candidates = kept
    measurements: list[Measurement] = []
    failed = 0
    for cfg in candidates:
        try:
            secs = measure(lambda: run_with(cfg), iters=iters, warmup=warmup)
        except Exception as e:
            failed += 1
            log.debug("candidate %s failed: %s", cfg, e)
            continue
        measurements.append(Measurement(config=cfg, seconds=secs))
    if not measurements:
        return SearchResult(best=None, best_seconds=float("inf"),
                            measurements=(), pruned=pruned, failed=failed)
    winner = min(measurements, key=lambda m: m.seconds)
    return SearchResult(best=winner.config, best_seconds=winner.seconds,
                        measurements=tuple(measurements), pruned=pruned,
                        failed=failed)
