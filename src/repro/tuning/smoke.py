"""Autotune smoke — the end-to-end proof the CI job runs on CPU.

Deploys a minimal bundle twice on the ``pod-sim`` platform (Pallas
kernels in interpret mode, so this needs no TPU):

  1st deploy  autotune=on, empty cache  -> rmsnorm is searched, the
              winner is persisted to REPRO_TUNING_CACHE
              (SwapReport.tuning == "cache-miss-searched")
  2nd deploy  fresh Runtime, same cache -> rmsnorm binds straight from
              the cache (SwapReport.tuning == "cache-hit")

Exits non-zero if any stage does not behave exactly as claimed.

Usage:  python -m repro.tuning.smoke [--cache PATH]
"""

from __future__ import annotations

import argparse
import sys
import tempfile
from pathlib import Path

from repro.core.bundle import Bundle
from repro.core.registry import OpRegistry
from repro.core.runtime import Runtime
from repro.kernels.ops import ABIS, register_all


def _bundle() -> Bundle:
    return Bundle(
        name="autotune-smoke", tag="latest", model_config={}, recipe={},
        required_ops={"rmsnorm": str(ABIS["rmsnorm"])}, env={},
    )


def _deploy_once(cache_path: Path) -> str:
    """One full deploy on pod-sim; returns rmsnorm's tuning status."""
    host_env = {
        "REPRO_PLATFORM": "pod-sim",
        "REPRO_TUNING_CACHE": str(cache_path),
    }
    rt = Runtime(registry=register_all(OpRegistry()), host_env=host_env)
    container = rt.deploy(_bundle(), native_ops=True, autotune=True,
                          autotune_ops=["rmsnorm"])
    print(container.describe())
    report = next(r for r in container.binding.reports if r.op == "rmsnorm")
    if not report.swapped or report.bound != "pallas-interpret":
        raise AssertionError(
            f"expected the interpret kernel to be swapped in, got: {report}"
        )
    rt.cleanup()
    return report.tuning


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cache", default=None,
                    help="tuning cache path (default: a fresh temp file)")
    args = ap.parse_args(argv)
    cache_path = Path(
        args.cache
        if args.cache
        else Path(tempfile.mkdtemp(prefix="repro-tune-")) / "tuning.json"
    )

    first = _deploy_once(cache_path)
    if first != "cache-miss-searched":
        print(f"FAIL: first deploy expected cache-miss-searched, got {first!r}")
        return 1
    if not cache_path.is_file() or cache_path.stat().st_size == 0:
        print(f"FAIL: no tuning cache written at {cache_path}")
        return 1

    second = _deploy_once(cache_path)
    if second != "cache-hit":
        print(f"FAIL: second deploy expected cache-hit, got {second!r}")
        return 1

    print(f"OK: tuned rmsnorm persisted to {cache_path} and replayed on redeploy")
    return 0


if __name__ == "__main__":
    sys.exit(main())
