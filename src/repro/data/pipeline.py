"""Deterministic, shardable, restartable data pipeline.

Synthetic token streams (the assignment trains on synthetic data) with the
properties a production loader needs and that the fault-tolerance layer
relies on:

  * **deterministic by (seed, step)** — a restarted job replays the exact
    batch sequence from its checkpointed step; no loader state to persist
    beyond one integer.
  * **host-shardable** — each data-parallel host materializes only its
    slice (`host_slice`), so 1000-node ingestion never funnels through one
    process.
  * **straggler-aware** — `skip_hosts` lets the supervisor drop a slow
    host's slice for a step and rebalance (see ft/straggler.py).

Batches match Model.input_specs: tokens/labels (+ frames / patch_embeds
stubs for the audio/vlm families).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["DataConfig", "SyntheticStream"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0


class SyntheticStream:
    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig, data_cfg: DataConfig):
        self.cfg = model_cfg
        self.shape = shape
        self.data_cfg = data_cfg
        if shape.global_batch % data_cfg.num_hosts:
            raise ValueError(
                f"global_batch {shape.global_batch} not divisible by "
                f"{data_cfg.num_hosts} hosts"
            )
        self.per_host = shape.global_batch // data_cfg.num_hosts

    # ------------------------------------------------------------------ #
    def batch_at(self, step: int, *, host_id: int | None = None) -> dict[str, np.ndarray]:
        """The (deterministic) batch for `step`, this host's slice."""
        host = self.data_cfg.host_id if host_id is None else host_id
        rng = np.random.default_rng(
            np.random.SeedSequence([self.data_cfg.seed, step, host])
        )
        cfg, b, s = self.cfg, self.per_host, self.shape.seq_len
        if cfg.is_enc_dec:
            return {
                "frames": rng.standard_normal((b, s, cfg.d_model), dtype=np.float32) * 0.1,
                "tokens": rng.integers(0, cfg.vocab_size, (b, s), dtype=np.int32),
                "labels": rng.integers(0, cfg.vocab_size, (b, s), dtype=np.int32),
            }
        if cfg.modality == "vision":
            p = cfg.n_patches
            st = s - p
            return {
                "patch_embeds": rng.standard_normal((b, p, cfg.d_model), dtype=np.float32) * 0.1,
                "tokens": rng.integers(0, cfg.vocab_size, (b, st), dtype=np.int32),
                "labels": rng.integers(0, cfg.vocab_size, (b, st), dtype=np.int32),
            }
        tokens = rng.integers(0, cfg.vocab_size, (b, s + 1), dtype=np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def global_batch_at(self, step: int, *, skip_hosts: frozenset[int] = frozenset()):
        """All hosts' slices concatenated (single-process runs / tests).

        Slices of skipped (straggler) hosts are replaced by the next healthy
        host's data so the batch shape — and therefore the compiled step —
        never changes.
        """
        healthy = [h for h in range(self.data_cfg.num_hosts) if h not in skip_hosts]
        if not healthy:
            raise RuntimeError("all hosts skipped")
        parts = []
        for h in range(self.data_cfg.num_hosts):
            src = h if h in healthy else healthy[h % len(healthy)]
            parts.append(self.batch_at(step, host_id=src))
        return {
            k: np.concatenate([p[k] for p in parts], axis=0) for k in parts[0]
        }

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
