"""AdamW with fp32 moments, global-norm clipping, ZeRO-friendly states.

The optimizer state mirrors the parameter tree leaf-for-leaf, so the same
sharding specs apply (moments inherit the FSDP/TP layout — ZeRO-1 falls
out of the sharding rules rather than being a separate code path).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update", "make_optimizer"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def adamw_init(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any,
    state: OptState,
    params: Any,
    cfg: AdamWConfig,
    lr_scale: jnp.ndarray | float = 1.0,
):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, count), {"grad_norm": gnorm}


def make_optimizer(cfg: AdamWConfig, lr_fn: Callable[[jnp.ndarray], jnp.ndarray] | None = None):
    """(init_fn, update_fn) pair used by the train-step factory."""

    def update(grads, state, params):
        scale = lr_fn(state.count) if lr_fn is not None else 1.0
        return adamw_update(grads, state, params, cfg, lr_scale=scale)

    return adamw_init, update
