"""Learning-rate schedules (as step -> scale multipliers)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant"]


def constant():
    return lambda step: jnp.ones((), jnp.float32)


def warmup_cosine(warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos

    return fn
