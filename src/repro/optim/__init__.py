from repro.optim.adamw import (
    AdamWConfig,
    OptState,
    adamw_init,
    adamw_update,
    global_norm,
    make_optimizer,
)
from repro.optim.schedule import constant, warmup_cosine

__all__ = [
    "AdamWConfig", "OptState", "adamw_init", "adamw_update", "global_norm",
    "make_optimizer", "constant", "warmup_cosine",
]
