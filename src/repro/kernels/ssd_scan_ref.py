"""Pure-jnp oracle for the Mamba-2 SSD chunked scan (arXiv:2405.21060).

Recurrence (per batch b, head h):
    state_t = exp(dt_t * A_h) * state_{t-1} + dt_t * B_t x_t^T
    y_t     = C_t . state_t

Chunked 'state-space duality' evaluation: intra-chunk term is a masked
attention-like matmul, inter-chunk term is a scan over chunk states —
this is also exactly the blocking the Pallas kernel uses on TPU.

Shapes:
  x:  (B, S, H, P)   dt: (B, S, H)   A: (H,)  (A < 0)
  Bm: (B, S, G, N)   Cm: (B, S, G, N)   (H % G == 0)
Returns (y (B,S,H,P), final_state (B,H,N,P)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_scan_ref", "ssd_decode_step_ref"]


def ssd_scan_ref(x, dt, A, Bm, Cm, *, chunk: int = 128, unroll: bool = False):
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    nc = s // chunk
    xc = xf.reshape(b, nc, chunk, h, p)
    dtc = dtf.reshape(b, nc, chunk, h)
    Bc = Bh.reshape(b, nc, chunk, h, n)
    Cc = Ch.reshape(b, nc, chunk, h, n)

    dA = dtc * A.astype(jnp.float32)              # log-decay per step (<0)
    dA_cum = jnp.cumsum(dA, axis=2)               # inclusive

    # -- intra-chunk (the 'duality' attention block) -------------------------
    diff = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]   # (b,c,i,j,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # mask BEFORE exp: masked entries have diff > 0 and may overflow to inf,
    # which poisons the backward (inf * 0 = nan in the where-grad).
    decay = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc)
    M = scores * decay * dtc[:, :, None, :, :]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", M, xc)

    # -- chunk states ---------------------------------------------------------
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)        # (b,c,q,h)
    weighted_B = (decay_to_end * dtc)[..., None] * Bc            # (b,c,q,h,n)
    chunk_states = jnp.einsum("bcqhn,bcqhp->bchnp", weighted_B, xc)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                   # (b,c,h)

    # -- inter-chunk recurrence ------------------------------------------------
    def step(state, inp):
        cs, cd = inp
        new = state * cd[:, :, None, None] + cs
        return new, state                                        # emit entering state

    init = jnp.zeros((b, h, n, p), jnp.float32)
    final, states_in = jax.lax.scan(
        step, init, (chunk_states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
        unroll=nc if unroll else 1,
    )
    states_in = states_in.swapaxes(0, 1)                          # (b,c,h,n,p)

    y_inter = jnp.einsum(
        "bcihn,bchnp->bcihp", Cc * jnp.exp(dA_cum)[..., None], states_in
    )
    y = (y_diag + y_inter).reshape(b, s, h, p).astype(x.dtype)
    return y, final.astype(jnp.float32)


def ssd_decode_step_ref(x, dt, A, Bm, Cm, state):
    """One-token state update.  x: (B,H,P), dt: (B,H), Bm/Cm: (B,G,N),
    state: (B,H,N,P).  Returns (y (B,H,P), new_state)."""
    b, h, p = x.shape
    g, n = Bm.shape[1], Bm.shape[2]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))  # (B,H)
    contrib = (dt.astype(jnp.float32)[..., None, None]
               * Bh[..., :, None] * x.astype(jnp.float32)[..., None, :])  # (B,H,N,P)
    new_state = state * dA[..., None, None] + contrib
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return y.astype(x.dtype), new_state
