"""Pure-jnp oracle for (GQA) attention — the portable 'MPICH' of attention.

Two evaluation strategies, numerically identical:
  * plain — materialized (Sq, Sk) scores; small sequences;
  * chunked — online-softmax over KV chunks (flash algorithm in jnp, each
    chunk rematerialized in backward): O(Sq * chunk) live memory, which is
    what keeps the 32k prefill cells inside HBM even on the reference path.

Shapes:
  q: (B, Sq, H,  Dh)
  k: (B, Sk, KV, Dh)
  v: (B, Sk, KV, Dh)     with H % KV == 0 (GQA group = H // KV)
Returns (B, Sq, H, Dh).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "chunk_attention_ref", "decode_attention_ref",
           "windowed_attention_ref"]

_NEG = -1e30


def _plain(q, k, v, causal, scale):
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    group = h // kv
    qg = q.reshape(b, sq, kv, group, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg * scale, k).astype(jnp.float32)
    if causal:
        sk = k.shape[1]
        # causal alignment for prefill: query i attends keys <= i + (sk - sq)
        qi = jnp.arange(sq)[:, None] + (sk - sq)
        ki = jnp.arange(sk)[None, :]
        scores = jnp.where(qi >= ki, scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, dh)


def _chunked(q, k, v, causal, scale, chunk, unroll=False):
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    group = h // kv
    nc = sk // chunk
    qg = (q.reshape(b, sq, kv, group, dh) * scale).astype(jnp.float32)
    kc = k.reshape(b, nc, chunk, kv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, kv, dh).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(sq) + (sk - sq)

    def body(carry, xs):
        m, l, acc = carry
        kch, vch, ci = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kch.astype(jnp.float32))
        if causal:
            k_pos = ci * chunk + jnp.arange(chunk)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None, None], s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vch.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    body = jax.checkpoint(body)   # flash backward: recompute chunk scores
    m0 = jnp.full((b, kv, group, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, kv, group, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, group, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nc)),
        unroll=nc if unroll else 1,   # dry-run: cost_analysis must see all
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: float | None = None,
    chunk_kv: int | None = None,
    unroll: bool = False,
) -> jnp.ndarray:
    scale = q.shape[-1] ** -0.5 if scale is None else scale
    sk = k.shape[1]
    if chunk_kv and sk > chunk_kv and sk % chunk_kv == 0:
        return _chunked(q, k, v, causal, scale, chunk_kv, unroll=unroll)
    return _plain(q, k, v, causal, scale)


def windowed_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    window: jnp.ndarray,
    *,
    scale: float | None = None,
) -> jnp.ndarray:
    """Sliding-window causal attention oracle.

    Query at global position g attends keys in (g - window, g] — the
    causal mask plus a lower bound `window` wide.  Global query positions
    follow the prefill alignment (query i sits at i + Sk - Sq), so with
    window >= Sk this is exactly `attention_ref(..., causal=True)`.
    window: () or (B,) int32 (broadcast over the batch when scalar).
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    group = h // kv
    scale = dh ** -0.5 if scale is None else scale
    qg = q.reshape(b, sq, kv, group, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg * scale, k).astype(jnp.float32)
    qi = jnp.arange(sq)[:, None] + (sk - sq)                 # (Sq, 1)
    ki = jnp.arange(sk)[None, :]                             # (1, Sk)
    w = jnp.broadcast_to(jnp.asarray(window, jnp.int32), (b,))
    mask = (ki <= qi)[None] & (ki > qi - w[:, None, None])   # (B, Sq, Sk)
    scores = jnp.where(mask[:, None, None], scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, dh)


def _dequant_cache(cache: jnp.ndarray, scale) -> jnp.ndarray:
    """Dequantize an int8/fp8 logical cache (B, S, KV, Dh) with a ()- or
    (B,)-shaped fp32 scale — the oracle of the in-kernel VMEM dequant."""
    s = jnp.asarray(scale, jnp.float32)
    if s.ndim:
        s = s.reshape(-1, 1, 1, 1)
    return cache.astype(jnp.float32) * s


def _gather_pages(pool: jnp.ndarray, block_table: jnp.ndarray) -> jnp.ndarray:
    """(P, page, KV, Dh) pool + (B, nblocks) table -> the logical
    (B, nblocks*page, KV, Dh) cache each batch row sees — the jnp oracle
    of the gather the Pallas index maps perform via DMA."""
    b, n = block_table.shape
    page = pool.shape[1]
    return pool[block_table].reshape(b, n * page, *pool.shape[2:])


def decode_attention_ref(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,
    block_table: jnp.ndarray | None = None,
    window: jnp.ndarray | None = None,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
    *,
    scale: float | None = None,
) -> jnp.ndarray:
    """Single-token attention against a (possibly longer) cache.

    q: (B, 1, H, Dh); caches: (B, Smax, KV, Dh); pos: () or (B,) int32 —
    the index of the new token, per batch row when vector (continuous
    batching: every slot at its own position); keys at positions > pos
    are masked (cache slots not yet written).  With `block_table`
    ((B, nblocks) int32) the caches are page pools (P, page, KV, Dh) and
    each row's logical cache is gathered through its table row first.
    `window` (() or (B,) int32) additionally masks keys at positions
    <= pos - window — the sliding-window decode: only the trailing
    `window` cache slots are attended.  With `k_scale`/`v_scale` (() or
    (B,) fp32) the caches are quantized (int8/fp8) and dequantized here
    before the math — the oracle of the kernel's in-VMEM dequant.
    """
    if block_table is not None:
        k_cache = _gather_pages(k_cache, block_table)
        v_cache = _gather_pages(v_cache, block_table)
    if k_scale is not None:
        k_cache = _dequant_cache(k_cache, k_scale)
        v_cache = _dequant_cache(v_cache, v_scale)
    b, _, h, dh = q.shape
    kv = k_cache.shape[2]
    group = h // kv
    scale = dh ** -0.5 if scale is None else scale
    pos = jnp.asarray(pos)
    lim = pos.reshape(-1, 1, 1, 1) if pos.ndim else pos
    qg = q.reshape(b, kv, group, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg * scale, k_cache).astype(jnp.float32)
    ki = jnp.arange(k_cache.shape[1])[None, None, None, :]
    valid = ki <= lim
    if window is not None:
        w = jnp.broadcast_to(jnp.asarray(window, jnp.int32), (b,))
        valid = valid & (ki > lim - w.reshape(-1, 1, 1, 1))
    scores = jnp.where(valid, scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def chunk_attention_ref(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,
    block_table: jnp.ndarray | None = None,
    window: jnp.ndarray | None = None,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
    *,
    scale: float | None = None,
) -> jnp.ndarray:
    """Chunked-prefill attention: C new queries against a partial cache.

    q: (B, C, H, Dh) — the chunk's queries, already rotary-encoded at
    global positions pos..pos+C-1; caches: (B, Smax, KV, Dh) with the
    chunk's keys/values already written at those positions.  Query i
    attends cache keys <= pos + i; everything later (unwritten slots,
    future in-chunk keys) is masked.  pos: () or (B,) int32.  With
    `block_table` ((B, nblocks) int32) the caches are page pools
    (P, page, KV, Dh), gathered per row as in `decode_attention_ref`.
    `window` (() or (B,) int32) additionally masks keys at positions
    <= pos + i - window: each chunk query attends its trailing `window`
    keys only.  `k_scale`/`v_scale` (() or (B,) fp32) mark quantized
    (int8/fp8) caches, dequantized here before the math.
    """
    if block_table is not None:
        k_cache = _gather_pages(k_cache, block_table)
        v_cache = _gather_pages(v_cache, block_table)
    if k_scale is not None:
        k_cache = _dequant_cache(k_cache, k_scale)
        v_cache = _dequant_cache(v_cache, v_scale)
    b, c, h, dh = q.shape
    kv = k_cache.shape[2]
    group = h // kv
    scale = dh ** -0.5 if scale is None else scale
    pos = jnp.asarray(pos)
    base = pos.reshape(-1, 1) if pos.ndim else pos[None, None]
    lim = base + jnp.arange(c)[None, :]                      # (B|1, C)
    qg = q.reshape(b, c, kv, group, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg * scale, k_cache).astype(jnp.float32)
    ki = jnp.arange(k_cache.shape[1])[None, None, :]
    valid = ki <= lim[..., None]                             # (B|1, C, S)
    if window is not None:
        w = jnp.broadcast_to(jnp.asarray(window, jnp.int32), (b,))
        valid = valid & (ki > (lim - w[:, None])[..., None])
    scores = jnp.where(valid[:, None, None], scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, c, h, dh).astype(q.dtype)
