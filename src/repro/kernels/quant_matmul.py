"""Quantized matmul — Pallas TPU kernel (int8/fp8 weights, fp32 math).

``y = (x @ dequantize(qw)) * 1`` where ``qw`` is a quantized [D, F]
weight with one float32 scale per *output channel* (the checkpoint's
per-channel schema, docs/quantization.md): dequantizing per-channel
along F commutes with the contraction over D, so the kernel streams the
1-byte weight from HBM, upcasts the tile in VMEM, contracts in fp32,
and applies the channel scales to the product — the memory-bound
serving matmul moves a quarter of the fp32 bytes.

Tiling: (block_m, D) activation tiles x (D, block_n) weight tiles; the
contraction dimension stays resident like the other narrow-D kernels
(rmsnorm, moe_gmm's degraded single k step).  block_m / block_n come
from the injected tuning ``config`` like every other swap op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.tuning.config import BlockConfig, default_config

__all__ = ["quant_matmul"]

_DEFAULTS = default_config("quant_matmul")


def _quant_matmul_kernel(x_ref, qw_ref, scale_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    w = qw_ref[...].astype(jnp.float32)
    acc = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = (acc * scale_ref[...][None, :]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "config", "interpret")
)
def quant_matmul(
    x: jnp.ndarray,
    qw: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    config: BlockConfig | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """x [T, D] float  @  qw [D, F] int8/fp8  *  scale [F] fp32 -> [T, F].

    Output dtype follows x (fp32 accumulation inside the kernel).
    """
    if x.ndim != 2 or qw.ndim != 2 or scale.ndim != 1:
        raise ValueError(
            f"quant_matmul wants x[T,D], qw[D,F], scale[F]; got "
            f"{x.shape}, {qw.shape}, {scale.shape}"
        )
    t, d = x.shape
    f = qw.shape[1]
    if qw.shape[0] != d or scale.shape[0] != f:
        raise ValueError(f"shape mismatch: x{x.shape} qw{qw.shape} "
                         f"scale{scale.shape}")
    cfg = config if config is not None else _DEFAULTS
    if block_m is None:
        block_m = cfg.get("block_m", _DEFAULTS["block_m"])
    if block_n is None:
        block_n = cfg.get("block_n", _DEFAULTS["block_n"])
    block_m = min(block_m, t)
    block_n = min(block_n, f)
    out = pl.pallas_call(
        _quant_matmul_kernel,
        grid=(pl.cdiv(t, block_m), pl.cdiv(f, block_n)),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, f), x.dtype),
        interpret=interpret,
    )(x, qw, scale)
    return out
