"""Grouped (ragged) expert matmul — Pallas TPU kernel (MegaBlocks-style).

The host wrapper pads each expert's token group to a multiple of block_m
(so every m-tile belongs to exactly one expert), builds the tile->expert
map, and prefetches it as a scalar array: the kernel's BlockSpec index_map
reads tile_expert[t] to fetch the right expert's weight tile — dynamic
expert selection with fully static shapes, the TPU-native equivalent of
CUDA gather-scatter grouped GEMM.

Grid: (num_tiles_m, F/block_n, D/block_k) with the k dimension minor —
TPU grids execute the minor dimension sequentially on a core, so the
fp32 accumulator lives in VMEM scratch and is carried across k steps
without HBM traffic (same revisiting pattern as flash_attention's kv
loop).  Each step is a (block_m, block_k) x (block_k, block_n) MXU
matmul; the output tile is written once, on the last k step.

VMEM per step: block_m*block_k + block_k*block_n + 2*block_m*block_n
fp32 (~2.1 MB at 128x128 tiles, block_k=2048) — independent of D, so
arbitrarily wide experts (D = 16k, 32k, ...) stay feasible and tunable:
``block_k`` is a searchable BlockConfig knob like block_m/block_n.  A
block_k that does not divide D degrades to gcd(block_k, D), preserving
correctness for any geometry the autotuner replays.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.tuning.config import BlockConfig, default_config

__all__ = ["moe_gmm", "padded_layout"]

_DEFAULTS = default_config("moe_gmm")   # single source of truth for fallbacks


def _gmm_kernel(te_ref, x_ref, w_ref, o_ref, acc_ref, *, k_steps):
    del te_ref  # consumed by the index_maps
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == k_steps - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def padded_layout(group_sizes: jnp.ndarray, total: int, block_m: int):
    """Static-shape padded layout for ragged groups.

    Returns (row_dest (T,), tile_expert (num_tiles,), padded_rows) where
    row_dest[i] is the destination row of sorted token i in the padded
    buffer and tile_expert[t] is the owning expert of m-tile t.  padded_rows
    is the static worst case: total + E * block_m.
    """
    e = group_sizes.shape[0]
    padded_sizes = ((group_sizes + block_m - 1) // block_m) * block_m
    group_pad_starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded_sizes)[:-1].astype(jnp.int32)]
    )
    group_starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)]
    )
    # expert id of each sorted row
    row_expert = jnp.sum(
        jnp.arange(total)[:, None] >= group_starts[None, :], axis=1
    ).astype(jnp.int32) - 1
    row_dest = (
        group_pad_starts[row_expert]
        + jnp.arange(total, dtype=jnp.int32)
        - group_starts[row_expert]
    )
    padded_rows = total + e * block_m  # static upper bound
    tiles = padded_rows // block_m
    tile_start = jnp.arange(tiles, dtype=jnp.int32) * block_m
    pad_ends = jnp.cumsum(padded_sizes).astype(jnp.int32)
    tile_expert = jnp.sum(
        tile_start[:, None] >= pad_ends[None, :], axis=1
    ).astype(jnp.int32)
    tile_expert = jnp.minimum(tile_expert, e - 1)  # trailing dummy tiles
    return row_dest, tile_expert, padded_rows


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "config", "interpret"),
)
def moe_gmm(
    x: jnp.ndarray,              # (T, D) sorted by expert
    w: jnp.ndarray,              # (E, D, F)
    group_sizes: jnp.ndarray,    # (E,) int32, sum == T
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    config: BlockConfig | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-group matmul y[i] = x[i] @ w[expert(i)], dropless.

    Tile knobs resolve explicit kwarg > ``config`` > shipped default
    (`default_config("moe_gmm")`).  ``block_k`` slices the contraction
    dimension D; values that exceed or do not divide D degrade to
    gcd(block_k, D) — a tuned config replayed on a different geometry
    stays correct, just possibly slower.
    """
    cfg = config if config is not None else _DEFAULTS
    if block_m is None:
        block_m = cfg.get("block_m", _DEFAULTS["block_m"])
    if block_n is None:
        block_n = cfg.get("block_n", _DEFAULTS["block_n"])
    if block_k is None:
        block_k = cfg.get("block_k", _DEFAULTS["block_k"])
    t, d = x.shape
    e, _, f = w.shape
    block_n = min(block_n, f)
    block_m_eff = min(block_m, max(t, 8))
    block_k_eff = math.gcd(min(block_k, d), d)
    k_steps = d // block_k_eff

    row_dest, tile_expert, padded_rows = padded_layout(group_sizes, t, block_m_eff)
    x_pad = jnp.zeros((padded_rows, d), x.dtype).at[row_dest].set(x)
    tiles = padded_rows // block_m_eff

    out_pad = pl.pallas_call(
        functools.partial(_gmm_kernel, k_steps=k_steps),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(tiles, pl.cdiv(f, block_n), k_steps),
            in_specs=[
                pl.BlockSpec(
                    (block_m_eff, block_k_eff),
                    lambda ti, ni, ki, te_ref: (ti, ki),
                ),
                pl.BlockSpec(
                    (1, block_k_eff, block_n),
                    lambda ti, ni, ki, te_ref: (te_ref[ti], ki, ni),
                ),
            ],
            out_specs=pl.BlockSpec(
                (block_m_eff, block_n), lambda ti, ni, ki, te_ref: (ti, ni)
            ),
            scratch_shapes=[
                pltpu.VMEM((block_m_eff, block_n), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((padded_rows, f), x.dtype),
        interpret=interpret,
    )(tile_expert, x_pad, w)
    return out_pad[row_dest]
