"""Fused RMSNorm — Pallas TPU kernel.

Tiling: rows are blocked into (block_rows, D) VMEM tiles; the full feature
dimension stays resident so the reduction never leaves VMEM.  fp32 math,
input-dtype store.  D should be a multiple of 128 (lane width); the
assigned archs all satisfy this (smallest is whisper's 512).

block_rows comes from (in order) the explicit kwarg, the injected
``config`` (a tuning.BlockConfig, normally bound by the autotuner at
deployment), or the built-in default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.tuning.config import BlockConfig, default_config

__all__ = ["rmsnorm"]

_DEFAULTS = default_config("rmsnorm")   # single source of truth for fallbacks


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eps", "block_rows", "config", "interpret")
)
def rmsnorm(
    x: jnp.ndarray,
    weight: jnp.ndarray,
    *,
    eps: float = 1e-6,
    block_rows: int | None = None,
    config: BlockConfig | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    if block_rows is None:
        cfg = config if config is not None else _DEFAULTS
        block_rows = cfg.get("block_rows", _DEFAULTS["block_rows"])
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    rows = x2.shape[0]
    block_rows = min(block_rows, rows)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(pl.cdiv(rows, block_rows),),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out.reshape(orig_shape)
