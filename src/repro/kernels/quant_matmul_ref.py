"""Pure-jnp oracle for the quantized matmul swap op."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["quant_matmul_ref"]


def quant_matmul_ref(x: jnp.ndarray, qw: jnp.ndarray,
                     scale: jnp.ndarray) -> jnp.ndarray:
    """x [T, D] float @ qw [D, F] int8/fp8, scale [F] fp32 per output
    channel -> [T, F] in x's dtype (fp32 math, like the kernel)."""
    w = qw.astype(jnp.float32) * scale.astype(jnp.float32)[None, :]
    return (x.astype(jnp.float32) @ w).astype(x.dtype)
