"""Flash attention (causal, GQA) — Pallas TPU kernel.

TPU adaptation of the flash algorithm: the grid is (batch, q_heads,
q_blocks, kv_blocks) with the kv dimension minor — TPU grids execute the
minor dimension sequentially on a core, so the running softmax state
(m, l, acc) lives in VMEM scratch and is carried across kv steps without
HBM traffic.  Block shapes default to (128, head_dim): MXU-aligned and
small enough that q/k/v tiles + scratch fit VMEM for head_dim <= 256.

Causal blocks strictly above the diagonal are skipped with pl.when — for
long sequences this halves the executed grid.  An optional kv_len scalar
(SMEM) masks unwritten cache slots, which makes the same kernel serve
decode (Sq == 1) against a partially filled cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.tuning.config import BlockConfig, default_config

__all__ = ["flash_attention"]

_DEFAULTS = default_config("attention")   # single source of truth for fallbacks

_NEG_INF = -1e30


def _flash_kernel(
    kvlen_ref,      # SMEM (1,) int32
    q_ref,          # (1, bq, 1, dh)
    k_ref,          # (1, bk, 1, dh)
    v_ref,          # (1, bk, 1, dh)
    o_ref,          # (1, bq, 1, dh)
    m_ref,          # scratch (bq,)
    l_ref,          # scratch (bq,)
    acc_ref,        # scratch (bq, dh)
    *,
    causal: bool,
    scale: float,
    block_q: int,
    block_k: int,
    kv_blocks: int,
    q_offset: int,  # sk - sq, aligns causal diagonal for prefill
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Skip fully-masked blocks (strictly above the causal diagonal).
    run = jnp.bool_(True)
    if causal:
        run = (ik * block_k) <= (iq * block_q + q_offset + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        mask = k_pos < kvlen_ref[0]
        if causal:
            mask = mask & (k_pos <= q_pos + q_offset)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "config",
                     "interpret"),
)
def flash_attention(
    q: jnp.ndarray,                  # (B, Sq, H, Dh)
    k: jnp.ndarray,                  # (B, Sk, KV, Dh)
    v: jnp.ndarray,
    kv_len: jnp.ndarray | None = None,   # () int32; None -> Sk
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int | None = None,
    block_k: int | None = None,
    config: BlockConfig | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    cfg = config if config is not None else _DEFAULTS
    if block_q is None:
        block_q = cfg.get("block_q", _DEFAULTS["block_q"])
    if block_k is None:
        block_k = cfg.get("block_k", _DEFAULTS["block_k"])
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    assert h % kv == 0, f"GQA requires H % KV == 0, got {h} % {kv}"
    group = h // kv
    scale = dh ** -0.5 if scale is None else scale
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    q_blocks = pl.cdiv(sq, block_q)
    kv_blocks = pl.cdiv(sk, block_k)
    kv_len = jnp.asarray(sk if kv_len is None else kv_len, jnp.int32).reshape(1)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        kv_blocks=kv_blocks,
        q_offset=sk - sq,
    )
    grid = (b, h, q_blocks, kv_blocks)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, block_q, 1, dh), lambda bi, hi, iq, ik, kvl: (bi, iq, hi, 0)
                ),
                pl.BlockSpec(
                    (1, block_k, 1, dh),
                    lambda bi, hi, iq, ik, kvl: (bi, ik, hi // group, 0),
                ),
                pl.BlockSpec(
                    (1, block_k, 1, dh),
                    lambda bi, hi, iq, ik, kvl: (bi, ik, hi // group, 0),
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, block_q, 1, dh), lambda bi, hi, iq, ik, kvl: (bi, iq, hi, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, dh), q.dtype),
        interpret=interpret,
    )(kv_len, q, k, v)
    return out
