"""Flash attention (causal, GQA) — Pallas TPU kernel.

TPU adaptation of the flash algorithm: the grid is (batch, q_heads,
q_blocks, kv_blocks) with the kv dimension minor — TPU grids execute the
minor dimension sequentially on a core, so the running softmax state
(m, l, acc) lives in VMEM scratch and is carried across kv steps without
HBM traffic.  Block shapes default to (128, head_dim): MXU-aligned and
small enough that q/k/v tiles + scratch fit VMEM for head_dim <= 256.

Causal blocks strictly above the diagonal are skipped with pl.when — for
long sequences this halves the executed grid.  Two scalar rows ride in
SMEM (prefetched, per batch element): `kv_len` masks unwritten cache
slots and `q_start` dynamically re-anchors the causal diagonal.  Between
them the same kernel serves all three serving geometries:

  * prefill  — kv_len = Sk, q_start = Sk - Sq (the static diagonal);
  * decode   — Sq == 1 against a partially filled cache, per-batch
    kv_len = pos + 1 (continuous batching: every slot at its own
    position in one call);
  * chunked prefill — Sq == C chunk queries starting at global position
    `q_start` against the cache: query i attends keys <= q_start + i,
    keys past kv_len masked.

**Paged KV cache** (``block_tables`` + ``page_size``): k/v may instead be
page *pools* of shape (P, page, KV, Dh) addressed through a per-batch
block table (B, nblocks) — logical position p of row b lives in page
``block_tables[b, p // page]`` at offset ``p % page``.  The table rides
in the same SMEM meta as kv_len/q_start (rows 2.., transposed to
(nblocks, B)) and the k/v BlockSpec index maps resolve the physical page
per grid step, so the DMA itself performs the gather — the kernel body
is unchanged, masking stays in logical coordinates.  block_k is clamped
to divide the page (gcd) so no tile ever straddles a page boundary.
Unallocated table entries must still hold a valid page index (the
serving engine points them at a reserved park page): their DMAs are
issued even when the kv_len mask discards every lane.

**Sliding window** (``window``): with a traced per-batch window width W
a third scalar row joins the SMEM meta — the *window start*
``ws = kv_len - Sq - W + 1`` — and query i attends only keys in
``[ws + i, ...]`` on top of the causal/kv_len masks.  k-blocks wholly
below the q-block's minimum window start are skipped with the same
pl.when heuristic that already skips unwritten cache suffixes, so long-KV
decode executes O(W) kv steps instead of O(kv_len).  The formula anchors
queries to the end of the written prefix, which is exactly where all
three serving geometries put them (decode: the one query sits at
kv_len-1; chunk: queries at kv_len-C .. kv_len-1; prefill: kv_len = Sk,
q_start = Sk - Sq).  W >= kv_len degenerates to the ordinary masks —
bit-identical output, every block still run.  Out-of-window pages may be
reused (parked) by the serving engine: their scores are masked to -inf
before the softmax, so stale contents are inert.

**Quantized KV cache** (``k_scale``/``v_scale``): k/v (contiguous or
paged pools) may be stored int8 or fp8 with per-batch float32
dequantization scales.  The scales ride the same int32 SMEM meta as
kv_len/q_start/ws — their fp32 bits reinterpreted via
``jax.lax.bitcast_convert_type`` on the way in and bitcast back inside
the kernel — so the scalar-prefetch ABI stays single-dtype.  Tiles are
dequantized in VMEM after the DMA: the cache streams from HBM at one
byte per element, the softmax math stays fp32
(docs/quantization.md pins the per-format error envelopes).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.tuning.config import BlockConfig, default_config

__all__ = ["flash_attention"]

_DEFAULTS = default_config("attention")   # single source of truth for fallbacks

_NEG_INF = -1e30


def _flash_kernel(
    meta_ref,       # SMEM (2[+1][+2], B) int32: row 0 kv_len, row 1 q_start,
                    # then window start (windowed only), then the fp32
                    # k/v scales bitcast to int32 (quantized KV only)
    q_ref,          # (1, bq, 1, dh)
    k_ref,          # (1, bk, 1, dh)
    v_ref,          # (1, bk, 1, dh)
    o_ref,          # (1, bq, 1, dh)
    m_ref,          # scratch (bq,)
    l_ref,          # scratch (bq,)
    acc_ref,        # scratch (bq, dh)
    *,
    causal: bool,
    scale: float,
    block_q: int,
    block_k: int,
    kv_blocks: int,
    q_offset: int,      # sk - sq: static diagonal for the skip heuristic
    dyn_offset: bool,   # True when q_start is a traced value (chunk prefill)
    windowed: bool,     # True when meta carries a window-start row
    quantized: bool,    # True when meta carries bitcast k/v scale rows
):
    bi = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    kvl = meta_ref[0, bi]
    qs = meta_ref[1, bi]
    ws = meta_ref[2, bi] if windowed else None
    if quantized:
        # the scales ride the int32 SMEM meta bit-exactly: fp32 bits in,
        # fp32 bits out (docs/quantization.md, "kernel meta ABI")
        srow = 3 if windowed else 2
        ksc = jax.lax.bitcast_convert_type(meta_ref[srow, bi], jnp.float32)
        vsc = jax.lax.bitcast_convert_type(meta_ref[srow + 1, bi], jnp.float32)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Skip blocks that cannot contribute: past the written cache prefix,
    # (static diagonal only) strictly above the causal diagonal, or
    # (windowed) wholly before the q-block's earliest window start.
    run = (ik * block_k) < kvl
    if causal and not dyn_offset:
        run = run & ((ik * block_k) <= (iq * block_q + q_offset + block_q - 1))
    if windowed:
        # the q-block's first query (local row iq*bq) has the smallest
        # window start; a k-block whose last key is below it is dead for
        # every query in the tile — this traced skip is what turns a
        # long-KV decode into O(window) executed kv steps
        run = run & ((ik * block_k + block_k - 1) >= (iq * block_q + ws))

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if quantized:
            # dequantize the 1-byte cache tiles in VMEM: k/v stream from
            # HBM at a quarter of the fp32 bytes, math stays fp32
            k = k * ksc
            v = v * vsc
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        mask = k_pos < kvl
        if causal:
            mask = mask & (k_pos <= q_pos + qs)
        if windowed:
            # sliding window: query q_pos attends keys >= its own window
            # start ws + q_pos (the mirror image of the causal bound)
            mask = mask & (k_pos >= q_pos + ws)
        s = jnp.where(mask, s, _NEG_INF)
        # rows past kv_len may be out-of-bounds tile padding (garbage, NaN
        # in interpret mode); p is 0 there but 0 * NaN = NaN, so zero v too
        k_row = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)
        v = jnp.where(k_row < kvl, v, 0.0)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "config",
                     "interpret", "page_size"),
)
def flash_attention(
    q: jnp.ndarray,                  # (B, Sq, H, Dh)
    k: jnp.ndarray,                  # (B, Sk, KV, Dh) | paged (P, page, KV, Dh)
    v: jnp.ndarray,
    kv_len: jnp.ndarray | None = None,   # () or (B,) int32; None -> Sk
    q_start: jnp.ndarray | None = None,  # () or (B,) int32; None -> Sk - Sq
    *,
    window: jnp.ndarray | None = None,   # () or (B,) int32 width W; None -> full
    k_scale: jnp.ndarray | None = None,  # () or (B,) fp32; k is quantized (int8/fp8)
    v_scale: jnp.ndarray | None = None,  # () or (B,) fp32; v is quantized
    causal: bool = True,
    scale: float | None = None,
    block_q: int | None = None,
    block_k: int | None = None,
    config: BlockConfig | None = None,
    interpret: bool = False,
    block_tables: jnp.ndarray | None = None,  # (B, nblocks) int32 page ids
    page_size: int | None = None,             # tokens per page; None -> k.shape[1]
) -> jnp.ndarray:
    cfg = config if config is not None else _DEFAULTS
    if block_q is None:
        block_q = cfg.get("block_q", _DEFAULTS["block_q"])
    if block_k is None:
        block_k = cfg.get("block_k", _DEFAULTS["block_k"])
    b, sq, h, dh = q.shape
    paged = block_tables is not None
    if paged:
        page = k.shape[1] if page_size is None else page_size
        assert k.shape[1] == page, f"pool page {k.shape[1]} != page_size {page}"
        nblocks = block_tables.shape[1]
        sk = nblocks * page                  # logical KV extent
    else:
        sk = k.shape[1]
    kv = k.shape[2]
    assert h % kv == 0, f"GQA requires H % KV == 0, got {h} % {kv}"
    group = h // kv
    scale = dh ** -0.5 if scale is None else scale
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if paged:
        # a k/v tile must never straddle a page boundary: the index map
        # resolves ONE physical page per grid step
        block_k = math.gcd(min(block_k, page), page)
    q_blocks = pl.cdiv(sq, block_q)
    kv_blocks = pl.cdiv(sk, block_k)
    dyn_offset = q_start is not None
    kv_len = jnp.broadcast_to(
        jnp.asarray(sk if kv_len is None else kv_len, jnp.int32), (b,)
    )
    q_start = jnp.broadcast_to(
        jnp.asarray(sk - sq if q_start is None else q_start, jnp.int32), (b,)
    )
    windowed = window is not None
    quantized = k_scale is not None
    if quantized != (v_scale is not None):
        raise ValueError("quantized KV needs both k_scale and v_scale")
    rows = [kv_len, q_start]
    if windowed:
        # per-batch window start of the FIRST query: local query i's
        # window opens at ws + i.  Queries are anchored to the end of the
        # written prefix in every geometry (decode/chunk/prefill), so the
        # base is kv_len - sq.
        w = jnp.broadcast_to(jnp.asarray(window, jnp.int32), (b,))
        rows.append(kv_len - sq - w + 1)
    if quantized:
        # the dequantization scales ride the same int32 SMEM meta: fp32
        # bits reinterpreted, bitcast back inside the kernel (the meta
        # stack must stay single-dtype for jnp.stack)
        for s in (k_scale, v_scale):
            s32 = jnp.broadcast_to(jnp.asarray(s, jnp.float32), (b,))
            rows.append(jax.lax.bitcast_convert_type(s32, jnp.int32))
    meta = jnp.stack(rows)                       # (2 [+1] [+2], B) in SMEM
    tbl_row = len(rows)                          # first block-table meta row
    if paged:
        # block-table rows ride below the scalar rows: meta[tbl_row+j, bi]
        # is the physical page of row bi's j-th logical block
        meta = jnp.concatenate(
            [meta, block_tables.astype(jnp.int32).T], axis=0
        )                                        # (tbl_row + nblocks, B)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        kv_blocks=kv_blocks,
        q_offset=sk - sq,
        dyn_offset=dyn_offset,
        windowed=windowed,
        quantized=quantized,
    )
    if paged:
        bpp = page // block_k                    # k-tiles per page

        def kv_spec():
            return pl.BlockSpec(
                (1, block_k, 1, dh),
                # logical k-block ik lives in page meta[tbl_row + ik // bpp,
                # bi], tile ik % bpp within it — the DMA performs the gather
                lambda bi, hi, iq, ik, m: (m[tbl_row + ik // bpp, bi],
                                           ik % bpp, hi // group, 0),
            )
    else:
        def kv_spec():
            return pl.BlockSpec(
                (1, block_k, 1, dh),
                lambda bi, hi, iq, ik, kvl: (bi, ik, hi // group, 0),
            )
    grid = (b, h, q_blocks, kv_blocks)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, block_q, 1, dh), lambda bi, hi, iq, ik, kvl: (bi, iq, hi, 0)
                ),
                kv_spec(),
                kv_spec(),
            ],
            out_specs=pl.BlockSpec(
                (1, block_q, 1, dh), lambda bi, hi, iq, ik, kvl: (bi, iq, hi, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, dh), q.dtype),
        interpret=interpret,
    )(meta, q, k, v)
    return out
