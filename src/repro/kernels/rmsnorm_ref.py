"""Pure-jnp oracle for RMSNorm."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm_ref"]


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, *, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)
