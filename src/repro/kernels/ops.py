"""Op declarations + registration: the site's "library inventory".

Declares the ABI for every swappable logical op, registers the portable
reference implementation (what the Bundle ships) and the Pallas TPU
implementation (what the site bind-mounts in, gated on the
``pallas_kernels`` platform feature — absent on CPU hosts, so deployment
there keeps the references, exactly like Shifter on a system without the
vendor stack).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.core.abi import AbiString
from repro.core.registry import ImplKind, OpImpl, OpRegistry, global_registry
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention_ref import (
    attention_ref,
    chunk_attention_ref,
    decode_attention_ref,
    windowed_attention_ref,
)
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.moe_gmm_ref import moe_gmm_ref
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.quant_matmul_ref import quant_matmul_ref
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm_ref import rmsnorm_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan_ref import ssd_scan_ref
from repro.tuning import OpTuner

__all__ = ["ABIS", "OP_NAMES", "register_all", "default_binding", "tuners"]

# Canonical signatures: the structural part of the ABI string.  Changing a
# signature (or the semantic major version) makes old native kernels
# un-swappable — the registry will refuse, like Shifter on a libtool
# mismatch.
_SIGS = {
    "rmsnorm": {
        "args": ["x:[*,d]", "weight:[d]"],
        "kwargs": ["eps:float"],
        "semantics": "y = x/rms(x)*w, fp32 accumulation",
    },
    "attention": {
        "args": ["q:[b,sq,h,dh]", "k:[b,sk,kv,dh]", "v:[b,sk,kv,dh]"],
        "kwargs": ["causal:bool", "scale:float?"],
        "semantics": "softmax(qk^T*scale+causal_mask)v, GQA h%kv==0, fp32 softmax",
    },
    "decode_attention": {
        "args": ["q:[b,1,h,dh]", "k_cache:[b,smax,kv,dh]", "v_cache:[b,smax,kv,dh]", "pos:i32"],
        "kwargs": ["scale:float?"],
        "semantics": "single-token attention, cache slots > pos masked",
    },
    "windowed_attention": {
        "args": ["q:[b,sq,h,dh]", "k:[b,sk,kv,dh]", "v:[b,sk,kv,dh]", "window:i32"],
        "kwargs": ["scale:float?"],
        "semantics": ("sliding-window causal: query i attends keys in "
                      "(i-window, i], GQA h%kv==0, fp32 softmax"),
    },
    "chunk_attention": {
        "args": ["q:[b,c,h,dh]", "k_cache:[b,smax,kv,dh]", "v_cache:[b,smax,kv,dh]", "pos:i32"],
        "kwargs": ["scale:float?"],
        "semantics": "chunked prefill: query i attends cache keys <= pos+i",
    },
    "ssd_scan": {
        "args": ["x:[b,s,h,p]", "dt:[b,s,h]", "A:[h]", "B:[b,s,g,n]", "C:[b,s,g,n]"],
        "kwargs": ["chunk:int"],
        "semantics": "mamba2 SSD; returns (y, final_state[b,h,n,p] fp32)",
    },
    "moe_gmm": {
        "args": ["x:[t,d] sorted-by-expert", "w:[e,d,f]", "group_sizes:[e]"],
        "kwargs": [],
        # NB: this text feeds the signature digest, which must stay stable
        # across compatible revisions (a digest change strands every bundle
        # persisted under the old string) — behavioral refinements are
        # recorded as _ABI_MINORS bumps, not edits here.  Since minor 2 the
        # reference is dropless at decode scale (<=1k rows); above that it
        # remains the capacity-truncated baseline.
        "semantics": ("per-group matmul, groups partition rows of x; "
                      "capacity-truncated baseline, dropless native"),
    },
    "quant_matmul": {
        "args": ["x:[t,d]", "qw:[d,f] int8|fp8", "scale:[f] f32"],
        "kwargs": [],
        "semantics": ("y = x @ (qw * scale[None,:]) per output channel, "
                      "fp32 accumulation, output in x's dtype"),
    },
}

# Minor revisions: compatible extensions of a kernel (libtool "revision").
# A bump here leaves old bundles deployable (provider minor >= required
# minor) but expires the op's tuning-cache entries — they were measured
# on the previous kernel revision (see tuning/expiry.py).
#   moe_gmm 1: grew the k-loop contraction (block_k knob, D > 8k feasible)
#   moe_gmm 2: reference is dropless below _EXACT_ROWS_MAX rows (the
#              geometry-dependent capacity drop broke prefill/decode
#              consistency — docs/kernels.md)
#   decode_attention 1: pos may be (B,) as well as scalar — continuous
#              batching decodes every slot at its own position in one
#              call (the kernel grew per-batch kv_len rows in SMEM)
#   decode_attention 2 / chunk_attention 1: optional trailing
#              block_tables arg — k/v may be page pools (P, page, KV, Dh)
#              gathered through a per-batch block table; the kernel grew
#              per-batch block-index rows in the same SMEM meta
#              (docs/kernels.md "block-gather meta ABI")
#   decode_attention 3 / chunk_attention 2: optional trailing window arg
#              (traced () or (B,) i32) — sliding-window attention: keys
#              at logical positions <= pos - window (decode) /
#              <= pos + i - window (chunk) are masked, and whole
#              out-of-window k-blocks are skipped; the kernel grew a
#              per-batch window-start row in the same SMEM meta
#              (docs/kernels.md "window meta ABI")
#   decode_attention 4 / chunk_attention 3: optional trailing
#              k_scale/v_scale args (traced () or (B,) f32) — k/v caches
#              may be int8/fp8 quantized pools, dequantized in-kernel
#              after the VMEM upcast; the scales ride the same SMEM meta
#              as the kv_len/window rows, fp32 bits bitcast to int32
#              (docs/quantization.md "scale meta ABI")
_ABI_MINORS = {"moe_gmm": 2, "decode_attention": 4, "chunk_attention": 3}

ABIS: dict[str, AbiString] = {
    name: AbiString.make(name, sig, major=1, minor=_ABI_MINORS.get(name, 0))
    for name, sig in _SIGS.items()
}
OP_NAMES: tuple[str, ...] = tuple(sorted(ABIS))


# -- native call-convention adapters ----------------------------------------
def _native_attention(q, k, v, *, causal=True, scale=None, config=None,
                      interpret=False):
    return flash_attention(q, k, v, causal=causal, scale=scale, config=config,
                           interpret=interpret)


def _native_windowed_attention(q, k, v, window, *, scale=None, config=None,
                               interpret=False):
    # sliding-window causal prefill: the full-attention geometry plus a
    # traced window width — the wrapper adds the window-start meta row
    return flash_attention(q, k, v, window=window, causal=True, scale=scale,
                           config=config, interpret=interpret)


def _ref_windowed_attention(q, k, v, window, *, scale=None):
    return windowed_attention_ref(q, k, v, window, scale=scale)


def _native_decode_attention(q, k_cache, v_cache, pos, block_tables=None,
                             window=None, k_scale=None, v_scale=None, *,
                             scale=None, config=None, interpret=False):
    # decode = flash with Sq=1 over the written prefix of the cache; with
    # block_tables the caches are page pools and the kernel's index maps
    # gather pages (page size = the pool's second dim); with window only
    # the trailing `window` slots are attended (out-of-window pages may
    # already be parked); with k_scale/v_scale the pools are int8/fp8
    # and dequantized in-kernel after the VMEM upcast
    page = k_cache.shape[1] if block_tables is not None else None
    return flash_attention(
        q, k_cache, v_cache, kv_len=pos + 1, causal=False, scale=scale,
        window=window, k_scale=k_scale, v_scale=v_scale, config=config,
        interpret=interpret, block_tables=block_tables, page_size=page,
    )


def _ref_decode_attention(q, k_cache, v_cache, pos, block_tables=None,
                          window=None, k_scale=None, v_scale=None, *,
                          scale=None):
    return decode_attention_ref(q, k_cache, v_cache, pos, block_tables,
                                window, k_scale, v_scale, scale=scale)


def _native_chunk_attention(q, k_cache, v_cache, pos, block_tables=None,
                            window=None, k_scale=None, v_scale=None, *,
                            scale=None, config=None, interpret=False):
    # chunked prefill = flash with the causal diagonal re-anchored at pos:
    # query i (global position pos+i) sees cache keys <= pos+i, and the
    # kv_len mask hides slots past the chunk's own freshly written tail.
    page = k_cache.shape[1] if block_tables is not None else None
    return flash_attention(
        q, k_cache, v_cache, kv_len=pos + q.shape[1], q_start=pos,
        causal=True, scale=scale, window=window, k_scale=k_scale,
        v_scale=v_scale, config=config, interpret=interpret,
        block_tables=block_tables, page_size=page,
    )


def _ref_chunk_attention(q, k_cache, v_cache, pos, block_tables=None,
                         window=None, k_scale=None, v_scale=None, *,
                         scale=None):
    return chunk_attention_ref(q, k_cache, v_cache, pos, block_tables,
                               window, k_scale, v_scale, scale=scale)


def _ref_attention(q, k, v, *, causal=True, scale=None):
    # chunked (flash-in-jnp) automatically above 2k keys: same math, O(S)
    # live memory — the portable reference stays deployable at 32k.
    chunk = 1024 if k.shape[1] > 2048 else None
    return attention_ref(q, k, v, causal=causal, scale=scale, chunk_kv=chunk)


_REFS = {
    "rmsnorm": rmsnorm_ref,
    "attention": _ref_attention,
    "windowed_attention": _ref_windowed_attention,
    "decode_attention": _ref_decode_attention,
    "chunk_attention": _ref_chunk_attention,
    "ssd_scan": ssd_scan_ref,
    "moe_gmm": moe_gmm_ref,
    "quant_matmul": quant_matmul_ref,
}

_NATIVES = {
    "rmsnorm": functools.partial(rmsnorm, interpret=False),
    "attention": _native_attention,
    "windowed_attention": _native_windowed_attention,
    "decode_attention": _native_decode_attention,
    "chunk_attention": _native_chunk_attention,
    "ssd_scan": functools.partial(ssd_scan, interpret=False),
    "moe_gmm": functools.partial(moe_gmm, interpret=False),
    "quant_matmul": functools.partial(quant_matmul, interpret=False),
}

# interpret-mode variants: the Pallas kernel body executed by the HLO
# interpreter — numerically the real kernel, bindable on CPU simulation
# hosts (platform feature "pallas_interpret").
_NATIVES_INTERPRET = {
    "rmsnorm": functools.partial(rmsnorm, interpret=True),
    "attention": functools.partial(_native_attention, interpret=True),
    "windowed_attention": functools.partial(_native_windowed_attention,
                                            interpret=True),
    "decode_attention": functools.partial(_native_decode_attention, interpret=True),
    "chunk_attention": functools.partial(_native_chunk_attention, interpret=True),
    "ssd_scan": functools.partial(ssd_scan, interpret=True),
    "moe_gmm": functools.partial(moe_gmm, interpret=True),
    "quant_matmul": functools.partial(quant_matmul, interpret=True),
}

# -- autotuner hooks ---------------------------------------------------------
# Per-op config spaces + canonical workloads the TuningContext measures at
# bind time.  Example shapes are platform-scaled: small on cpu-host
# hardware (interpret mode runs the kernel body through the HLO
# interpreter — correctness-exact, orders of magnitude slower), full-size
# on real accelerators.  Feasibility pruning rejects candidates whose
# VMEM working set overflows or whose blocks don't fit the workload
# before anything is compiled.

_VMEM_BUDGET = 12 * 2**20   # bytes/core usable for kernel tiles (16M - headroom)


def _is_cpu(platform) -> bool:
    return platform.hardware.name == "cpu-host"


# Abstract workloads (ShapeDtypeStructs) are the single source of the
# example geometry: cache keys are derived from them without allocating
# anything; the _example_* materializers fill them in only when a search
# actually runs.

def _spec_rmsnorm(platform):
    rows, d = (128, 256) if _is_cpu(platform) else (8192, 4096)
    return (jax.ShapeDtypeStruct((rows, d), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32))


def _example_rmsnorm(platform):
    sx, sw = _spec_rmsnorm(platform)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return (jax.random.normal(k1, sx.shape, sx.dtype),
            jax.random.normal(k2, sw.shape, sw.dtype))


def _feasible_rmsnorm(cfg, platform, args):
    # the kernel flattens leading dims to rows and clamps block_rows, so
    # profiled rank-3 activations (B, S, D) are tunable too; keep at least
    # the smallest space value alive for sub-tile row counts
    shape = args[0].shape
    rows, d = math.prod(shape[:-1]), shape[-1]
    br = cfg["block_rows"]
    return (br <= max(rows, 8)
            and (3 * min(br, rows) * d + d) * 4 <= _VMEM_BUDGET)


def _spec_attention(platform):
    b, s, h, kv, dh = (1, 64, 2, 2, 64) if _is_cpu(platform) else (4, 2048, 16, 4, 128)
    return (jax.ShapeDtypeStruct((b, s, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, s, kv, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, s, kv, dh), jnp.float32))


def _example_attention(platform):
    specs = _spec_attention(platform)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    return tuple(jax.random.normal(k, s.shape, s.dtype)
                 for k, s in zip(ks, specs))


def _feasible_attention(cfg, platform, args):
    sq, dh = args[0].shape[1], args[0].shape[3]
    sk = args[1].shape[1]
    bq, bk = cfg["block_q"], cfg["block_k"]
    vmem = (2 * bq * dh + 2 * bk * dh + bq * bk + 2 * bq) * 4
    return bq <= sq and bk <= sk and vmem <= _VMEM_BUDGET


def _spec_windowed(platform):
    # the full-attention geometry plus a traced window width; the canonical
    # window is Sk // 4 — small enough that the skip heuristic matters,
    # large enough to span several k-blocks
    q, k, v = _spec_attention(platform)
    return (q, k, v, jax.ShapeDtypeStruct((), jnp.int32))


def _example_windowed(platform):
    q, k, v, _ = _spec_windowed(platform)
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    w = jnp.asarray(max(1, k.shape[1] // 4), jnp.int32)
    return (jax.random.normal(ks[0], q.shape, q.dtype),
            jax.random.normal(ks[1], k.shape, k.dtype),
            jax.random.normal(ks[2], v.shape, v.dtype),
            w)


def _feasible_windowed(cfg, platform, args):
    # identical working set to full attention: the window narrows which
    # k-blocks run, not their shapes
    return _feasible_attention(cfg, platform, args)


def _spec_decode(platform):
    b, smax, h, kv, dh = (1, 64, 2, 2, 64) if _is_cpu(platform) else (8, 4096, 16, 4, 128)
    return (jax.ShapeDtypeStruct((b, 1, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, smax, kv, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, smax, kv, dh), jnp.float32),
            smax // 2)


def _example_decode(platform):
    sq, sk, sv, pos = _spec_decode(platform)
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    return (jax.random.normal(ks[0], sq.shape, sq.dtype),
            jax.random.normal(ks[1], sk.shape, sk.dtype),
            jax.random.normal(ks[2], sv.shape, sv.dtype),
            pos)


def _paged_geom(args):
    """(page, logical_smax) when args carry a block table, else None.

    The bucket validator rebuilds scalar parts as python ints, so an
    array-ness check (has .shape, rank 2) is the paged discriminator —
    a contiguous call's 5th arg is the scalar pos / absent."""
    if len(args) >= 5:
        shp = getattr(args[4], "shape", None)
        if shp is not None and len(shp) == 2:
            page = args[1].shape[1]
            return page, shp[1] * page
    return None


def _feasible_decode(cfg, platform, args):
    smax, dh = args[1].shape[1], args[1].shape[3]
    bk = cfg["block_k"]
    paged = _paged_geom(args)
    if paged is not None:
        page, smax = paged
        # block_k > page would be gcd-clamped to the page size inside the
        # kernel — reject so distinct configs never alias one measurement
        if bk > page:
            return False
    return bk <= smax and (2 * dh + 2 * bk * dh + bk + 2) * 4 <= _VMEM_BUDGET


def _spec_chunk(platform):
    # C-token chunk mid-way through a max_len cache — the serving
    # prefill geometry (chunk C minor to batch, cache at full Smax)
    b, c, smax, h, kv, dh = (1, 16, 64, 2, 2, 64) if _is_cpu(platform) \
        else (1, 256, 4096, 16, 4, 128)
    return (jax.ShapeDtypeStruct((b, c, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, smax, kv, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, smax, kv, dh), jnp.float32),
            smax // 2)


def _example_chunk(platform):
    sq, sk, sv, pos = _spec_chunk(platform)
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    return (jax.random.normal(ks[0], sq.shape, sq.dtype),
            jax.random.normal(ks[1], sk.shape, sk.dtype),
            jax.random.normal(ks[2], sv.shape, sv.dtype),
            pos)


def _feasible_chunk(cfg, platform, args):
    c, dh = args[0].shape[1], args[0].shape[3]
    smax = args[1].shape[1]
    bq, bk = cfg["block_q"], cfg["block_k"]
    paged = _paged_geom(args)
    if paged is not None:
        page, smax = paged
        if bk > page:
            return False
    vmem = (2 * bq * dh + 2 * bk * dh + bq * bk + 2 * bq) * 4
    return bq <= c and bk <= smax and vmem <= _VMEM_BUDGET


def _spec_ssd(platform):
    b, s, h, p, g, n = (1, 64, 2, 16, 1, 16) if _is_cpu(platform) else (2, 2048, 8, 64, 1, 64)
    return (jax.ShapeDtypeStruct((b, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, s, h), jnp.float32),
            jax.ShapeDtypeStruct((h,), jnp.float32),
            jax.ShapeDtypeStruct((b, s, g, n), jnp.float32),
            jax.ShapeDtypeStruct((b, s, g, n), jnp.float32))


def _example_ssd(platform):
    sx, sdt, sa, sb, sc = _spec_ssd(platform)
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    return (jax.random.normal(ks[0], sx.shape, sx.dtype) * 0.3,
            jax.nn.softplus(jax.random.normal(ks[1], sdt.shape, sdt.dtype)),
            -jnp.exp(jax.random.normal(ks[2], sa.shape, sa.dtype) * 0.3),
            jax.random.normal(ks[3], sb.shape, sb.dtype) * 0.3,
            jax.random.normal(ks[4], sc.shape, sc.dtype) * 0.3)


def _feasible_ssd(cfg, platform, args):
    s, p = args[0].shape[1], args[0].shape[3]
    n = args[3].shape[3]
    q = cfg["chunk"]
    vmem = (q * p + 2 * q * n + q * q + n * p) * 4
    return q <= s and s % q == 0 and vmem <= _VMEM_BUDGET


def _spec_moe(platform):
    t, d, e, f = (128, 64, 4, 64) if _is_cpu(platform) else (8192, 2048, 8, 2048)
    return (jax.ShapeDtypeStruct((t, d), jnp.float32),
            jax.ShapeDtypeStruct((e, d, f), jnp.float32),
            jax.ShapeDtypeStruct((e,), jnp.int32))


def _example_moe(platform):
    sx, sw, sg = _spec_moe(platform)
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    t, e = sx.shape[0], sg.shape[0]
    return (jax.random.normal(ks[0], sx.shape, sx.dtype),
            jax.random.normal(ks[1], sw.shape, sw.dtype),
            jnp.full((e,), t // e, sg.dtype))


def _feasible_moe(cfg, platform, args):
    t, d = args[0].shape
    f = args[1].shape[2]
    bm, bn, bk = cfg["block_m"], cfg["block_n"], cfg["block_k"]
    # the kernel degrades block_k to gcd(block_k, d), so narrow experts
    # (d below the space minimum of 64) stay searchable — keep at least
    # the smallest bk value alive and budget VMEM at the effective size
    bk_eff = math.gcd(min(bk, d), d)
    # x tile + w tile + fp32 accumulator scratch + out tile; D itself no
    # longer appears — the k-loop makes VMEM independent of expert width.
    # bm mirrors the kernel's clamp to max(t, 8): tiny-token geometries
    # keep the smallest tile searchable instead of pruning everything
    vmem = (bm * bk_eff + bk_eff * bn + 2 * bm * bn) * 4
    return (bm <= max(t, 8) and bn <= f and bk <= max(d, 64)
            and vmem <= _VMEM_BUDGET)


def _spec_quant_matmul(platform):
    # the serving-matmul geometry: a decode/chunk activation against a
    # per-channel int8 weight (fp8 buckets reuse the same tuned entries
    # modulo the dtype suffix on the bucket key)
    t, d, f = (64, 64, 64) if _is_cpu(platform) else (256, 4096, 4096)
    return (jax.ShapeDtypeStruct((t, d), jnp.float32),
            jax.ShapeDtypeStruct((d, f), jnp.int8),
            jax.ShapeDtypeStruct((f,), jnp.float32))


def _example_quant_matmul(platform):
    sx, sw, ss = _spec_quant_matmul(platform)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    return (jax.random.normal(ks[0], sx.shape, sx.dtype),
            jax.random.randint(ks[1], sw.shape, -127, 128,
                               jnp.int32).astype(sw.dtype),
            jax.random.uniform(ks[2], ss.shape, ss.dtype, 0.001, 0.02))


def _feasible_quant_matmul(cfg, platform, args):
    t, d = args[0].shape
    f = args[1].shape[1]
    bm, bn = cfg["block_m"], cfg["block_n"]
    qbytes = jnp.dtype(args[1].dtype).itemsize
    # fp32 x tile + 1-byte weight tile + fp32 scale slice + fp32 out tile;
    # the full D contraction stays resident like rmsnorm's row
    vmem = bm * d * 4 + d * bn * qbytes + bn * 4 + bm * bn * 4
    return bm <= max(t, 8) and bn <= f and vmem <= _VMEM_BUDGET


_TUNERS: dict[str, OpTuner] = {
    "rmsnorm": OpTuner(
        op="rmsnorm",
        space={"block_rows": (8, 16, 32, 64, 128, 256, 512)},
        example_args=_example_rmsnorm, feasible=_feasible_rmsnorm,
        example_specs=_spec_rmsnorm,
    ),
    "attention": OpTuner(
        op="attention",
        space={"block_q": (16, 32, 64, 128, 256),
               "block_k": (16, 32, 64, 128, 256)},
        example_args=_example_attention, feasible=_feasible_attention,
        example_specs=_spec_attention,
    ),
    "windowed_attention": OpTuner(
        op="windowed_attention",
        # same space as attention, but the sweet spot differs: block_k
        # larger than the window wastes the skip, so the tuner usually
        # lands on smaller k-tiles than full attention does
        space={"block_q": (16, 32, 64, 128, 256),
               "block_k": (16, 32, 64, 128, 256)},
        example_args=_example_windowed, feasible=_feasible_windowed,
        example_specs=_spec_windowed,
    ),
    "decode_attention": OpTuner(
        op="decode_attention",
        space={"block_k": (16, 32, 64, 128, 256, 512)},
        example_args=_example_decode, feasible=_feasible_decode,
        example_specs=_spec_decode,
    ),
    "chunk_attention": OpTuner(
        op="chunk_attention",
        space={"block_q": (16, 32, 64, 128, 256),
               "block_k": (16, 32, 64, 128, 256)},
        example_args=_example_chunk, feasible=_feasible_chunk,
        example_specs=_spec_chunk,
    ),
    "ssd_scan": OpTuner(
        op="ssd_scan",
        space={"chunk": (8, 16, 32, 64, 128, 256)},
        example_args=_example_ssd, feasible=_feasible_ssd,
        example_specs=_spec_ssd,
    ),
    "moe_gmm": OpTuner(
        op="moe_gmm",
        space={"block_m": (8, 16, 32, 64, 128, 256),
               "block_n": (8, 16, 32, 64, 128, 256),
               "block_k": (64, 128, 256, 512, 1024, 2048)},
        example_args=_example_moe, feasible=_feasible_moe,
        example_specs=_spec_moe,
    ),
    "quant_matmul": OpTuner(
        op="quant_matmul",
        space={"block_m": (8, 16, 32, 64, 128, 256),
               "block_n": (8, 16, 32, 64, 128, 256)},
        example_args=_example_quant_matmul, feasible=_feasible_quant_matmul,
        example_specs=_spec_quant_matmul,
    ),
}


# -- profile-geometry synthesizers -------------------------------------------
# repro.tuning.warm (and a profile-aware TuningContext) replays *recorded*
# shape buckets, not the canonical examples above.  Each synthesizer turns
# one recorded (shapes, dtype) bucket back into concrete workload args; a
# bucket whose structure does not match the op's signature returns None
# and the caller skips it (a foreign or corrupted profile entry must not
# abort warming).

def _parse_bucket(shapes: str) -> list[tuple[int, ...]] | None:
    try:
        return [
            () if part == "scalar" else tuple(int(n) for n in part.split("x"))
            for part in shapes.split(",") if part
        ]
    except ValueError:
        return None


def _normal(key, shape, dtype):
    dt = jnp.dtype(dtype)
    if dt == jnp.int8:
        # quantized code points span the symmetric clip range
        return jax.random.randint(key, shape, -127, 128, jnp.int32).astype(dt)
    if jnp.issubdtype(dt, jnp.integer):
        return jax.random.randint(key, shape, 0, 8, dt)
    if dt.itemsize == 1:
        # fp8 storage: sample in fp32, snap to the fp8 grid
        return jax.random.normal(key, shape).astype(dt)
    return jax.random.normal(key, shape, dt)


def _split_dtype(dtype: str) -> tuple[str, str | None]:
    """Split a composite bucket dtype "float32+int8" into (base, quant).

    The "+<storage dtype>" suffix is how quantized-KV calls bucket
    separately from full-precision ones (repro.tuning.bucket_shapes);
    plain buckets return (dtype, None)."""
    base, _, quant = str(dtype).partition("+")
    return base, (quant or None)


def _synth_rmsnorm(platform, shapes, dtype):
    parts = _parse_bucket(shapes)
    if not parts or len(parts) != 2 or len(parts[0]) < 1 or len(parts[1]) != 1:
        return None
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return (_normal(k1, parts[0], dtype), _normal(k2, parts[1], dtype))


def _synth_attention(platform, shapes, dtype):
    parts = _parse_bucket(shapes)
    if not parts or len(parts) != 3 or any(len(p) != 4 for p in parts):
        return None
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    return tuple(_normal(k, p, dtype) for k, p in zip(ks, parts))


def _attn_cache_parts(shapes, quantized=False):
    """Normalize a decode/chunk attention bucket to its array parts.

    Returns ``(parts, windowed)`` where parts is [q, k_cache, v_cache]
    (contiguous) or [q, pool_k, pool_v, block_table] (paged).  pos
    carries no geometry — recorded as a "scalar" part (traced 0-d), a
    1-d (B,) vector (continuous batching), or absent (python int) —
    drop it whichever way it appears.  The block table is always 2-d, so
    rank disambiguates; a trailing rank-0 part *after* pos/table is the
    traced sliding-window width (ABI decode/1:3, chunk/1:2) — this is
    how "window rides the bucket key": windowed calls bucket separately
    from full-attention calls and warm to their own tuned entries.

    ``quantized`` (the caller reads it off the bucket dtype's "+int8"/
    "+float8*" suffix — the authoritative signal, since a scale part is
    shaped exactly like a traced pos) strips the trailing k/v dequant
    scale pair (ABI decode/1:4, chunk/1:3) before the tail parse."""
    parts = _parse_bucket(shapes)
    if not parts or len(parts) < 3 or any(len(p) != 4 for p in parts[:3]):
        return None
    tail = parts[3:]
    if quantized:
        if len(tail) < 2 or any(len(p) > 1 for p in tail[-2:]):
            return None                  # scale pair missing/misshapen
        tail = tail[:-2]
    if tail and len(tail[0]) <= 1:       # traced pos: () or (B,)
        tail = tail[1:]
    table = None
    if tail and len(tail[0]) == 2:       # paged block table
        table = tail[0]
        tail = tail[1:]
    windowed = bool(tail) and len(tail[0]) <= 1
    if windowed:
        tail = tail[1:]
    if tail:                             # unrecognized residue
        return None
    return parts[:3] + ([table] if table is not None else []), windowed


def _synth_window(logical: int):
    """Representative traced window for a resynthesized windowed bucket:
    a quarter of the logical extent, so the measurement exercises the
    out-of-window block skip (the value itself never reaches the bucket
    key — only its 0-d "scalar" shape does)."""
    return jnp.asarray(max(1, logical // 4), jnp.int32)


def _synth_scales(parts, windowed, quantized):
    """Optional trailing (window, k_scale, v_scale) args for a
    resynthesized attention bucket, in adapter positional order.  The
    scale values are representative dequant magnitudes — like the window
    width they never reach the bucket key, only their 0-d shapes do."""
    tail = ()
    logical = (parts[3][1] * parts[1][1]) if len(parts) == 4 else parts[1][1]
    if windowed:
        tail += (_synth_window(logical),)
    if quantized:
        if not windowed:
            tail += (None,)              # hold the window slot
        sc = jnp.asarray(0.02, jnp.float32)
        tail += (sc, sc)
    return tail


def _synth_decode(platform, shapes, dtype):
    base, quant = _split_dtype(dtype)
    quantized = quant is not None
    norm = _attn_cache_parts(shapes, quantized=quantized)
    if norm is None:
        return None
    parts, windowed = norm
    kv_dt = quant if quantized else base
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = _normal(ks[0], parts[0], base)
    k = _normal(ks[1], parts[1], kv_dt)
    v = _normal(ks[2], parts[2], kv_dt)
    if len(parts) == 4:
        npages, page = parts[1][0], parts[1][1]
        b, nblocks = parts[3]
        bt = jax.random.randint(ks[3], (b, nblocks), 0, max(npages, 1),
                                jnp.int32)
        logical = nblocks * page
        args = (q, k, v, logical // 2, bt)
    else:
        logical = parts[1][1]
        args = (q, k, v, logical // 2, None)
    tail = _synth_scales(parts, windowed, quantized)
    if tail:
        return args + tail
    return args[:4] if args[4] is None else args


def _synth_chunk(platform, shapes, dtype):
    # same bucket structure as decode: q/k_cache/v_cache (+ optional
    # trailing "scalar" for a traced pos, + block table when paged,
    # + trailing "scalar" window when windowed, + trailing scale pair
    # when quantized); resynthesize pos mid-cache
    base, quant = _split_dtype(dtype)
    quantized = quant is not None
    norm = _attn_cache_parts(shapes, quantized=quantized)
    if norm is None:
        return None
    parts, windowed = norm
    kv_dt = quant if quantized else base
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    q = _normal(ks[0], parts[0], base)
    k = _normal(ks[1], parts[1], kv_dt)
    v = _normal(ks[2], parts[2], kv_dt)
    c = parts[0][1]
    if len(parts) == 4:
        npages, page = parts[1][0], parts[1][1]
        b, nblocks = parts[3]
        logical = nblocks * page
        if logical < c:
            return None      # chunk cannot fit the logical window
        bt = jax.random.randint(ks[3], (b, nblocks), 0, max(npages, 1),
                                jnp.int32)
        pos = max(0, min(logical - c, logical // 2))
        args = (q, k, v, pos, bt)
    else:
        logical = parts[1][1]
        args = (q, k, v, logical // 2, None)
    tail = _synth_scales(parts, windowed, quantized)
    if tail:
        return args + tail
    return args[:4] if args[4] is None else args


def _synth_windowed(platform, shapes, dtype):
    parts = _parse_bucket(shapes)
    if (not parts or len(parts) != 4 or any(len(p) != 4 for p in parts[:3])
            or parts[3] != ()):
        return None
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q, k, v = (_normal(kk, p, dtype) for kk, p in zip(ks, parts[:3]))
    return (q, k, v, _synth_window(parts[1][1]))


def _synth_ssd(platform, shapes, dtype):
    parts = _parse_bucket(shapes)
    if (not parts or len(parts) != 5 or len(parts[0]) != 4
            or len(parts[1]) != 3 or len(parts[2]) != 1
            or len(parts[3]) != 4 or len(parts[4]) != 4):
        return None
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    dt = jnp.dtype(dtype)
    return (jax.random.normal(ks[0], parts[0], dt) * 0.3,
            jax.nn.softplus(jax.random.normal(ks[1], parts[1], dt)),
            -jnp.exp(jax.random.normal(ks[2], parts[2], dt) * 0.3),
            jax.random.normal(ks[3], parts[3], dt) * 0.3,
            jax.random.normal(ks[4], parts[4], dt) * 0.3)


def _synth_moe(platform, shapes, dtype):
    parts = _parse_bucket(shapes)
    if (not parts or len(parts) != 3 or len(parts[0]) != 2
            or len(parts[1]) != 3 or len(parts[2]) != 1):
        return None
    (t, _), (e, d, f), _ = parts
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    # distribute all t rows (t//e per expert would be all-zeros when
    # e > t, measuring an empty workload)
    base, rem = divmod(t, max(e, 1))
    gs = jnp.array([base + (i < rem) for i in range(e)], jnp.int32)
    return (_normal(ks[0], (t, d), dtype),
            _normal(ks[1], (e, d, f), dtype),
            gs)


def _synth_quant_matmul(platform, shapes, dtype):
    parts = _parse_bucket(shapes)
    if (not parts or len(parts) != 3 or len(parts[0]) != 2
            or len(parts[1]) != 2 or len(parts[2]) != 1
            or parts[0][1] != parts[1][0] or parts[1][1] != parts[2][0]):
        return None
    base, quant = _split_dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    return (_normal(ks[0], parts[0], base),
            _normal(ks[1], parts[1], quant if quant is not None else base),
            jax.random.uniform(ks[2], parts[2], jnp.float32, 0.001, 0.02))


_SYNTHS = {
    "rmsnorm": _synth_rmsnorm,
    "attention": _synth_attention,
    "windowed_attention": _synth_windowed,
    "decode_attention": _synth_decode,
    "chunk_attention": _synth_chunk,
    "ssd_scan": _synth_ssd,
    "moe_gmm": _synth_moe,
    "quant_matmul": _synth_quant_matmul,
}

for _name, _synth in _SYNTHS.items():
    _TUNERS[_name] = dataclasses.replace(_TUNERS[_name], args_from_shapes=_synth)


def tuners() -> dict[str, OpTuner]:
    """The per-op tuner hooks (shared by the TPU and interpret impls)."""
    return dict(_TUNERS)


_registered: set[int] = set()


def register_all(registry: OpRegistry | None = None) -> OpRegistry:
    """Populate a registry with every op (idempotent per registry)."""
    reg = registry if registry is not None else global_registry
    if id(reg) in _registered and reg is global_registry:
        return reg
    for name in OP_NAMES:
        reg.declare(ABIS[name])
        reg.register(
            OpImpl(abi=ABIS[name], kind=ImplKind.REFERENCE, fn=_REFS[name],
                   provider="jnp-ref")
        )
        reg.register(
            OpImpl(abi=ABIS[name], kind=ImplKind.NATIVE, fn=_NATIVES[name],
                   requires_feature="pallas_kernels",
                   requires_device_kind="tpu", provider="pallas-tpu",
                   tuner=_TUNERS.get(name))
        )
        reg.register(
            OpImpl(abi=ABIS[name], kind=ImplKind.NATIVE,
                   fn=_NATIVES_INTERPRET[name],
                   requires_feature="pallas_interpret",
                   provider="pallas-interpret", tuner=_TUNERS.get(name))
        )
    _registered.add(id(reg))
    return reg


def default_binding():
    """Reference-only binding for code running outside a Runtime (smoke
    tests, oracles).  Uses the real registry path with swap disabled."""
    from repro.core.platform import LAPTOP

    reg = register_all()
    return reg.bind(OP_NAMES, LAPTOP, native=False, freeze=False)


def measurement_binding():
    """Dry-run cost binding: identical math to the references, but with
    every internal lax.scan UNROLLED — XLA's cost_analysis counts a while
    body once regardless of trip count, so rolled chunk loops (chunked
    attention, SSD inter-chunk scan) silently undercount FLOPs/bytes."""

    def attention_u(q, k, v, *, causal=True, scale=None):
        chunk = 1024 if k.shape[1] > 2048 else None
        return attention_ref(q, k, v, causal=causal, scale=scale,
                             chunk_kv=chunk, unroll=True)

    def ssd_u(x, dt, A, Bm, Cm, *, chunk=128):
        return ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk, unroll=True)

    table = dict(default_binding())
    table["attention"] = attention_u
    table["ssd_scan"] = ssd_u
    return table
