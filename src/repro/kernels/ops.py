"""Op declarations + registration: the site's "library inventory".

Declares the ABI for every swappable logical op, registers the portable
reference implementation (what the Bundle ships) and the Pallas TPU
implementation (what the site bind-mounts in, gated on the
``pallas_kernels`` platform feature — absent on CPU hosts, so deployment
there keeps the references, exactly like Shifter on a system without the
vendor stack).
"""

from __future__ import annotations

import functools

from repro.core.abi import AbiString
from repro.core.registry import ImplKind, OpImpl, OpRegistry, global_registry
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention_ref import attention_ref, decode_attention_ref
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.moe_gmm_ref import moe_gmm_ref
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm_ref import rmsnorm_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan_ref import ssd_scan_ref

__all__ = ["ABIS", "OP_NAMES", "register_all", "default_binding"]

# Canonical signatures: the structural part of the ABI string.  Changing a
# signature (or the semantic major version) makes old native kernels
# un-swappable — the registry will refuse, like Shifter on a libtool
# mismatch.
_SIGS = {
    "rmsnorm": {
        "args": ["x:[*,d]", "weight:[d]"],
        "kwargs": ["eps:float"],
        "semantics": "y = x/rms(x)*w, fp32 accumulation",
    },
    "attention": {
        "args": ["q:[b,sq,h,dh]", "k:[b,sk,kv,dh]", "v:[b,sk,kv,dh]"],
        "kwargs": ["causal:bool", "scale:float?"],
        "semantics": "softmax(qk^T*scale+causal_mask)v, GQA h%kv==0, fp32 softmax",
    },
    "decode_attention": {
        "args": ["q:[b,1,h,dh]", "k_cache:[b,smax,kv,dh]", "v_cache:[b,smax,kv,dh]", "pos:i32"],
        "kwargs": ["scale:float?"],
        "semantics": "single-token attention, cache slots > pos masked",
    },
    "ssd_scan": {
        "args": ["x:[b,s,h,p]", "dt:[b,s,h]", "A:[h]", "B:[b,s,g,n]", "C:[b,s,g,n]"],
        "kwargs": ["chunk:int"],
        "semantics": "mamba2 SSD; returns (y, final_state[b,h,n,p] fp32)",
    },
    "moe_gmm": {
        "args": ["x:[t,d] sorted-by-expert", "w:[e,d,f]", "group_sizes:[e]"],
        "kwargs": [],
        "semantics": ("per-group matmul, groups partition rows of x; "
                      "capacity-truncated baseline, dropless native"),
    },
}

ABIS: dict[str, AbiString] = {
    name: AbiString.make(name, sig, major=1, minor=0) for name, sig in _SIGS.items()
}
OP_NAMES: tuple[str, ...] = tuple(sorted(ABIS))


# -- native call-convention adapters ----------------------------------------
def _native_attention(q, k, v, *, causal=True, scale=None, interpret=False):
    return flash_attention(q, k, v, causal=causal, scale=scale,
                           interpret=interpret)


def _native_decode_attention(q, k_cache, v_cache, pos, *, scale=None,
                             interpret=False):
    # decode = flash with Sq=1 over the written prefix of the cache
    return flash_attention(
        q, k_cache, v_cache, kv_len=pos + 1, causal=False, scale=scale,
        interpret=interpret,
    )


def _ref_decode_attention(q, k_cache, v_cache, pos, *, scale=None):
    return decode_attention_ref(q, k_cache, v_cache, pos, scale=scale)


def _ref_attention(q, k, v, *, causal=True, scale=None):
    # chunked (flash-in-jnp) automatically above 2k keys: same math, O(S)
    # live memory — the portable reference stays deployable at 32k.
    chunk = 1024 if k.shape[1] > 2048 else None
    return attention_ref(q, k, v, causal=causal, scale=scale, chunk_kv=chunk)


_REFS = {
    "rmsnorm": rmsnorm_ref,
    "attention": _ref_attention,
    "decode_attention": _ref_decode_attention,
    "ssd_scan": ssd_scan_ref,
    "moe_gmm": moe_gmm_ref,
}

_NATIVES = {
    "rmsnorm": functools.partial(rmsnorm, interpret=False),
    "attention": _native_attention,
    "decode_attention": _native_decode_attention,
    "ssd_scan": functools.partial(ssd_scan, interpret=False),
    "moe_gmm": functools.partial(moe_gmm, interpret=False),
}

# interpret-mode variants: the Pallas kernel body executed by the HLO
# interpreter — numerically the real kernel, bindable on CPU simulation
# hosts (platform feature "pallas_interpret").
_NATIVES_INTERPRET = {
    "rmsnorm": functools.partial(rmsnorm, interpret=True),
    "attention": functools.partial(_native_attention, interpret=True),
    "decode_attention": functools.partial(_native_decode_attention, interpret=True),
    "ssd_scan": functools.partial(ssd_scan, interpret=True),
    "moe_gmm": functools.partial(moe_gmm, interpret=True),
}

_registered: set[int] = set()


def register_all(registry: OpRegistry | None = None) -> OpRegistry:
    """Populate a registry with every op (idempotent per registry)."""
    reg = registry if registry is not None else global_registry
    if id(reg) in _registered and reg is global_registry:
        return reg
    for name in OP_NAMES:
        reg.declare(ABIS[name])
        reg.register(
            OpImpl(abi=ABIS[name], kind=ImplKind.REFERENCE, fn=_REFS[name],
                   provider="jnp-ref")
        )
        reg.register(
            OpImpl(abi=ABIS[name], kind=ImplKind.NATIVE, fn=_NATIVES[name],
                   requires_feature="pallas_kernels",
                   requires_device_kind="tpu", provider="pallas-tpu")
        )
        reg.register(
            OpImpl(abi=ABIS[name], kind=ImplKind.NATIVE,
                   fn=_NATIVES_INTERPRET[name],
                   requires_feature="pallas_interpret",
                   provider="pallas-interpret")
        )
    _registered.add(id(reg))
    return reg


def default_binding():
    """Reference-only binding for code running outside a Runtime (smoke
    tests, oracles).  Uses the real registry path with swap disabled."""
    from repro.core.platform import LAPTOP

    reg = register_all()
    return reg.bind(OP_NAMES, LAPTOP, native=False, freeze=False)


def measurement_binding():
    """Dry-run cost binding: identical math to the references, but with
    every internal lax.scan UNROLLED — XLA's cost_analysis counts a while
    body once regardless of trip count, so rolled chunk loops (chunked
    attention, SSD inter-chunk scan) silently undercount FLOPs/bytes."""

    def attention_u(q, k, v, *, causal=True, scale=None):
        chunk = 1024 if k.shape[1] > 2048 else None
        return attention_ref(q, k, v, causal=causal, scale=scale,
                             chunk_kv=chunk, unroll=True)

    def ssd_u(x, dt, A, Bm, Cm, *, chunk=128):
        return ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk, unroll=True)

    table = dict(default_binding())
    table["attention"] = attention_u
    table["ssd_scan"] = ssd_u
    return table
