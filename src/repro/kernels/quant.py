"""Shared quantization numerics: int8 / fp8 formats, one set of rules.

Every reduced-precision consumer in the tree — the DCN gradient
compressor in ``distributed/collectives.py``, the quantized matmul and
quantized-KV attention kernels, the checkpoint's per-channel weight
scales, and the serving engine's int8 KV pools — quantizes through this
module, so the numerics the quantization-conformance grid pins are the
numerics every layer actually runs.

Two formats (docs/quantization.md):

  * ``int8`` — symmetric linear: ``scale = amax / 127``, values clipped
    to [-127, 127] (note: -128 is never produced, so negation is exact).
    Round-trip error is bounded by ``scale / 2`` per element — the
    hypothesis property in tests/test_kernels_property.py.
  * ``fp8`` — jnp.float8_e4m3fn (simulated on hosts without fp8
    hardware): ``scale = amax / 448`` (the e4m3fn max-normal), then a
    cast through the fp8 grid.  Relative error ~2^-3 near amax; the
    conformance grid pins the looser envelope.

Scales are always float32 and always strictly positive (the ``EPS``
floor), so dequantization never divides by zero and the attention
kernels can bitcast them through int32 SMEM meta rows losslessly.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "EPS",
    "FORMATS",
    "FP8_DTYPE",
    "FP8_MAX",
    "INT8_MAX",
    "compress_int8",
    "decompress_int8",
    "dequantize",
    "quantize",
    "quantize_per_channel",
    "storage_dtype",
]

INT8_MAX = 127.0
# max normal of float8_e4m3fn (S.1110.111 = 448)
FP8_MAX = 448.0
# amax floor: keeps every scale strictly positive (an all-zero tensor
# quantizes to zeros with a tiny, harmless scale instead of NaNs)
EPS = 1e-12

FORMATS = ("int8", "fp8")

FP8_DTYPE = jnp.float8_e4m3fn


def storage_dtype(fmt: str):
    """The cache/checkpoint storage dtype of a format (1 byte each)."""
    if fmt == "int8":
        return jnp.int8
    if fmt == "fp8":
        return FP8_DTYPE
    raise ValueError(f"unknown quantization format {fmt!r}")


def _scale_from_amax(amax: jnp.ndarray, fmt: str) -> jnp.ndarray:
    top = INT8_MAX if fmt == "int8" else FP8_MAX
    if fmt not in FORMATS:
        raise ValueError(f"unknown quantization format {fmt!r}")
    return (jnp.maximum(amax, EPS) / top).astype(jnp.float32)


def quantize(x: jnp.ndarray, fmt: str = "int8",
             scale: jnp.ndarray | None = None):
    """Whole-tensor quantization: ``(q, scale)`` with a single scalar
    scale (derived from amax unless a calibrated one is passed)."""
    if scale is None:
        scale = _scale_from_amax(jnp.max(jnp.abs(x)), fmt)
    else:
        scale = jnp.asarray(scale, jnp.float32)
    y = x.astype(jnp.float32) / scale
    if fmt == "int8":
        q = jnp.clip(jnp.round(y), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    else:
        q = jnp.clip(y, -FP8_MAX, FP8_MAX).astype(FP8_DTYPE)
    return q, scale


def quantize_per_channel(x: jnp.ndarray, axis: int = -1, fmt: str = "int8"):
    """Per-channel quantization along ``axis``: ``(q, scale)`` where
    ``scale`` has ``x``'s shape with ``axis`` removed (one scale per
    output channel — the checkpoint weight-scale schema)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = _scale_from_amax(amax, fmt)
    y = x.astype(jnp.float32) / scale
    if fmt == "int8":
        q = jnp.clip(jnp.round(y), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    else:
        q = jnp.clip(y, -FP8_MAX, FP8_MAX).astype(FP8_DTYPE)
    return q, jnp.squeeze(scale, axis=axis)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, axis: int = -1,
               dtype=jnp.float32) -> jnp.ndarray:
    """Invert quantize/quantize_per_channel.  ``scale`` may be a scalar
    (whole-tensor) or a per-channel vector matched to ``axis``."""
    scale = jnp.asarray(scale, jnp.float32)
    if scale.ndim and q.ndim > scale.ndim:
        scale = jnp.expand_dims(scale, axis=axis)
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_int8(x: jnp.ndarray):
    """Whole-tensor symmetric int8 with a scalar scale — the DCN
    gradient compressor (extracted from distributed/collectives.py;
    the hierarchical all-reduce sums int32 and rescales by the pmax'd
    scale, so a conservative shared scale is exactly what it needs)."""
    return quantize(x, "int8")


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return dequantize(q, scale, dtype=dtype)
