"""Portable reference for the grouped (ragged) expert matmul.

`jax.lax.ragged_dot` is the obvious oracle but its portable decomposition
is a dense all-experts contraction — O(E/topk) wasted FLOPs and an
(T, E, F) intermediate (measured: 45x FLOPs and 861 GB temp on the
moonshot train cell).  The production-grade portable reference is the
capacity-factor formulation every TPU MoE stack ships:

    rows of each group are packed into (E, C, D) slots, C = cf * T / E;
    one batched matmul (E, C, D) x (E, D, F); overflow rows are dropped
    (their output is 0 — they pass through the residual unchanged).

FLOPs = cf x ideal; live memory = cf x tokens.  The Pallas kernel
(`moe_gmm.py`) is dropless — strictly more capable, same interface
(ABI minor bump), numerically identical whenever no group overflows C.

Capacity is a function of T, which makes the drop set *geometry
dependent*: a decode microbatch (T = batch x top_k rows) computes a
much smaller C than the prefill that filled its cache, so the same
token could be dropped in one phase and kept in the other — the
moonshot prefill/decode divergence (see docs/kernels.md, "Dropless
reference at decode scale").  Below ``_EXACT_ROWS_MAX`` rows the
capacity formulation saves nothing (the packing bookkeeping dominates)
and its drops are at their most likely (C ~ 1-2 slots), so the
reference switches to the dropless ragged_dot oracle there (when no
explicit ``capacity_factor`` is passed — asking for capacity semantics
always gets them): decode and small prefill are always exact, matching
the dropless native kernel.  At larger T the capacity path is unchanged
— the documented portable trade-off — and production deployments swap
in the dropless Pallas kernel anyway.

`moe_gmm_exact` keeps the ragged_dot oracle for small-shape tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["moe_gmm_ref", "moe_gmm_exact", "DEFAULT_CAPACITY_FACTOR"]

DEFAULT_CAPACITY_FACTOR = 1.25

# Row count at or below which the reference is dropless (exact ragged_dot).
# The dense decomposition of ragged_dot costs O(T*E*D*F) portable FLOPs vs
# the capacity path's O(cf*T*D*F); at T <= 1024 that overhead is dwarfed by
# the packing/scatter bookkeeping it replaces, and geometry-dependent drops
# at tiny per-group capacities are exactly what breaks prefill/decode
# consistency.
_EXACT_ROWS_MAX = 1024


def moe_gmm_exact(x: jnp.ndarray, w: jnp.ndarray, group_sizes: jnp.ndarray) -> jnp.ndarray:
    """Dropless oracle via jax core ragged_dot (tests / tiny shapes only)."""
    return jax.lax.ragged_dot(x, w.astype(x.dtype), group_sizes.astype(jnp.int32))


def moe_gmm_ref(
    x: jnp.ndarray,              # (T, D) sorted by expert
    w: jnp.ndarray,              # (E, D, F)
    group_sizes: jnp.ndarray,    # (E,) int32, sum == T
    *,
    capacity_factor: float | None = None,
) -> jnp.ndarray:
    """capacity_factor=None (the binding's call convention) picks the
    dropless exact path at <= _EXACT_ROWS_MAX rows and the default
    capacity factor above; an explicit value always runs the capacity
    formulation — callers asking for capacity semantics get them at any
    scale."""
    t, d = x.shape
    e, _, f = w.shape
    if capacity_factor is None:
        if t <= _EXACT_ROWS_MAX:
            return moe_gmm_exact(x, w, group_sizes)
        capacity_factor = DEFAULT_CAPACITY_FACTOR
    cap = max(int(capacity_factor * t / e + 0.999), 1)

    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)]
    )
    # row i belongs to expert ei at intra-group offset oi
    idx = jnp.arange(t, dtype=jnp.int32)
    ei = (jnp.sum(idx[:, None] >= starts[None, :], axis=1) - 1).astype(jnp.int32)
    oi = idx - starts[ei]
    keep = oi < cap

    # pack into capacity slots; dropped rows route to a trash slot
    slot = jnp.where(keep, ei * cap + oi, e * cap)
    packed = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(x)
    packed = packed[: e * cap].reshape(e, cap, d)

    y = jnp.einsum("ecd,edf->ecf", packed, w.astype(x.dtype))
    y_flat = jnp.concatenate(
        [y.reshape(e * cap, f), jnp.zeros((1, f), y.dtype)], axis=0
    )
    return y_flat[slot] * keep[:, None].astype(y.dtype)
