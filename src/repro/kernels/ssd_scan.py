"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation of the SSD blocking: the grid is (batch, heads, chunks)
with chunks minor (sequential), and the inter-chunk SSM state (N, P) lives
in fp32 VMEM scratch carried across chunk steps — the recurrence never
round-trips HBM.  Per chunk the kernel computes the intra-chunk "dual"
attention block (Q x Q masked matmul -> MXU) and the state in/out terms,
exactly mirroring ssd_scan_ref's math.

Block shapes: chunk Q defaults to 128 (MXU aligned); VMEM per step is
O(Q*P + Q*N + Q*Q + N*P) fp32 — ~0.5 MB for Q=128, P=64, N=128.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.tuning.config import BlockConfig, default_config

__all__ = ["ssd_scan"]

_DEFAULTS = default_config("ssd_scan")   # single source of truth for fallbacks


def _ssd_kernel(
    x_ref,       # (1, 1, 1, Q, P)
    dt_ref,      # (1, 1, 1, Q)
    a_ref,       # (1,)
    b_ref,       # (1, 1, 1, Q, N)
    c_ref,       # (1, 1, 1, Q, N)
    y_ref,       # (1, 1, 1, Q, P)
    st_ref,      # (1, 1, N, P)   final state (last write wins)
    state_ref,   # scratch (N, P) fp32
    *,
    chunk: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)                # ()
    bm = b_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)

    dA = dt * a                                     # (Q,) log decay
    dA_cum = jnp.cumsum(dA)                         # inclusive

    # intra-chunk dual form
    diff = dA_cum[:, None] - dA_cum[None, :]        # (Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(qi >= kj, diff, -jnp.inf))  # mask pre-exp
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (Q, Q)
    m = scores * decay * dt[None, :]
    y = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (Q, P)

    # contribution of the carried state
    state = state_ref[...]                          # (N, P)
    c_decay = cm * jnp.exp(dA_cum)[:, None]
    y = y + jax.lax.dot_general(
        c_decay, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update: decay to end of chunk
    decay_to_end = jnp.exp(dA_cum[-1] - dA_cum)     # (Q,)
    wb = bm * (decay_to_end * dt)[:, None]          # (Q, N)
    new_state = state * jnp.exp(dA_cum[-1]) + jax.lax.dot_general(
        wb, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (N, P)
    state_ref[...] = new_state

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    st_ref[0, 0] = new_state                        # last chunk's write survives


@functools.partial(jax.jit, static_argnames=("chunk", "config", "interpret"))
def ssd_scan(
    x: jnp.ndarray,     # (B, S, H, P)
    dt: jnp.ndarray,    # (B, S, H)
    A: jnp.ndarray,     # (H,)
    Bm: jnp.ndarray,    # (B, S, G, N)
    Cm: jnp.ndarray,    # (B, S, G, N)
    *,
    chunk: int | None = None,
    config: BlockConfig | None = None,
    interpret: bool = False,
):
    b, s, h, p = x.shape
    if chunk is None:
        cfg = config if config is not None else _DEFAULTS
        chunk = min(cfg.get("chunk", _DEFAULTS["chunk"]), s)
        if s % chunk:
            # a tuned/default tile that doesn't divide this sequence degrades
            # to the largest common divisor instead of tripping the assert
            chunk = math.gcd(chunk, s)
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    nc = s // chunk
    heads_per_group = h // g

    # (B, H, NC, Q, ...) layouts so the chunk axis is a clean grid dim
    xr = x.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, p)
    dtr = dt.transpose(0, 2, 1).reshape(b, h, nc, chunk)
    br = Bm.transpose(0, 2, 1, 3).reshape(b, g, nc, chunk, n)
    cr = Cm.transpose(0, 2, 1, 3).reshape(b, g, nc, chunk, n)

    y, st = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec(
                (1, 1, 1, chunk, n),
                lambda bi, hi, ci: (bi, hi // heads_per_group, ci, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, 1, chunk, n),
                lambda bi, hi, ci: (bi, hi // heads_per_group, ci, 0, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, chunk, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, A, br, cr)

    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    return y, st
