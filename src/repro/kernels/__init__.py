# Site-optimized compute layer: Pallas TPU kernels (+ jnp oracles) for the
# hot spots the framework's op-substitution runtime swaps in — flash
# attention, fused rmsnorm, mamba2 SSD scan, grouped expert matmul.

from repro.kernels.ops import ABIS, OP_NAMES, default_binding, register_all

__all__ = ["ABIS", "OP_NAMES", "default_binding", "register_all"]
