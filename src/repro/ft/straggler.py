"""Straggler detection + mitigation policy.

At thousands of hosts the slowest machine sets the step time.  The
detector keeps an EMA of per-host step durations and flags hosts that
exceed `threshold` x the fleet median for `patience` consecutive steps.
Mitigation is a *plan*, applied by the training loop:

  * ``redistribute`` — the data pipeline re-sources the straggler's batch
    slice from healthy hosts (SyntheticStream.global_batch_at(skip_hosts=…))
    so the compiled step shape never changes;
  * ``evict`` — persistent stragglers are handed to the supervisor, which
    treats them like failures (restart / elastic downscale).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

__all__ = ["StragglerConfig", "StragglerDetector", "MitigationPlan"]


@dataclasses.dataclass(frozen=True)
class StragglerConfig:
    threshold: float = 2.0       # x median
    patience: int = 3            # consecutive flagged steps before action
    evict_after: int = 10        # flagged steps before eviction
    ema: float = 0.5


@dataclasses.dataclass(frozen=True)
class MitigationPlan:
    skip_hosts: frozenset[int]
    evict_hosts: frozenset[int]

    @property
    def clean(self) -> bool:
        return not self.skip_hosts and not self.evict_hosts


class StragglerDetector:
    def __init__(self, num_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.num_hosts = num_hosts
        self.cfg = cfg
        self._ema: dict[int, float] = {}
        self._flags: dict[int, int] = defaultdict(int)

    def observe(self, durations: dict[int, float]) -> MitigationPlan:
        """Feed one step's per-host durations; get the mitigation plan.

        Hosts beyond the constructed ``num_hosts`` are tracked as soon as
        they appear in ``durations`` — an elastic pool (repro.serving)
        grows past its initial size, and a late-joining replica must be
        judged against the same fleet median as everyone else.
        """
        for h, d in durations.items():
            prev = self._ema.get(h, d)
            self._ema[h] = self.cfg.ema * d + (1 - self.cfg.ema) * prev
        med = float(np.median(list(self._ema.values())))
        skip, evict = set(), set()
        for h in sorted(set(range(self.num_hosts)) | set(self._ema)):
            ema = self._ema.get(h)
            if ema is not None and med > 0 and ema > self.cfg.threshold * med:
                self._flags[h] += 1
            else:
                self._flags[h] = 0
            if self._flags[h] >= self.cfg.evict_after:
                evict.add(h)
            elif self._flags[h] >= self.cfg.patience:
                skip.add(h)
        return MitigationPlan(frozenset(skip), frozenset(evict))

    def forget(self, host: int) -> None:
        """Drop a departed host's EMA/flags so a dead replica's stale
        duration cannot keep skewing the fleet median (and a later
        replica reusing the id starts with a clean record)."""
        self._ema.pop(host, None)
        self._flags.pop(host, None)
