from repro.ft.elastic import RescalePlan, rescale_plan
from repro.ft.straggler import MitigationPlan, StragglerConfig, StragglerDetector
from repro.ft.supervisor import Decision, DecisionKind, Supervisor, SupervisorConfig

__all__ = [
    "RescalePlan", "rescale_plan",
    "MitigationPlan", "StragglerConfig", "StragglerDetector",
    "Decision", "DecisionKind", "Supervisor", "SupervisorConfig",
]
