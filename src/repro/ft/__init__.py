from repro.ft.elastic import PoolPlan, RescalePlan, pool_rescale_plan, rescale_plan
from repro.ft.straggler import MitigationPlan, StragglerConfig, StragglerDetector
from repro.ft.supervisor import Decision, DecisionKind, Supervisor, SupervisorConfig

__all__ = [
    "RescalePlan", "rescale_plan", "PoolPlan", "pool_rescale_plan",
    "MitigationPlan", "StragglerConfig", "StragglerDetector",
    "Decision", "DecisionKind", "Supervisor", "SupervisorConfig",
]
