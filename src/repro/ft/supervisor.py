"""Cluster supervisor: heartbeats, failure handling, restart decisions.

A deterministic, clock-injected simulation of the control plane a real
deployment runs next to the job (the workload-manager integration Shifter
lists as requirement #5).  The supervisor:

  * tracks per-host heartbeats; a host silent for > `heartbeat_timeout`
    is declared dead;
  * on death, decides between **restart-in-place** (spare capacity
    available) and **elastic downscale** (continue on fewer hosts via
    ft/elastic.py), always resuming from the last published checkpoint
    (checkpoint/manifest.py's atomic LATEST pointer);
  * feeds straggler eviction (ft/straggler.py) through the same path.

Unit-testable: time is an argument, not a syscall.
"""

from __future__ import annotations

import dataclasses
import enum

__all__ = ["SupervisorConfig", "Supervisor", "Decision", "DecisionKind"]


class DecisionKind(enum.Enum):
    NONE = "none"
    RESTART = "restart"            # same world size, from last checkpoint
    DOWNSCALE = "downscale"        # smaller world, reshard on restore
    ABORT = "abort"                # below min_hosts


@dataclasses.dataclass(frozen=True)
class Decision:
    kind: DecisionKind
    world_size: int
    restore_step: int | None
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    heartbeat_timeout: float = 30.0
    min_hosts: int = 1
    spare_hosts: int = 0           # hot spares for restart-in-place


class Supervisor:
    def __init__(self, num_hosts: int, cfg: SupervisorConfig = SupervisorConfig()):
        self.cfg = cfg
        self.num_hosts = num_hosts
        self.spares = cfg.spare_hosts
        self._last_beat: dict[int, float] = {h: 0.0 for h in range(num_hosts)}
        self._dead: set[int] = set()
        self.last_checkpoint_step: int | None = None
        self.events: list[str] = []

    # -- inputs ------------------------------------------------------------
    def heartbeat(self, host: int, now: float) -> None:
        if host not in self._dead:
            self._last_beat[host] = now

    def register(self, host: int, now: float) -> None:
        """Start tracking a host that joined after construction (elastic
        scale-out: the serving fleet provisions replicas at runtime).
        Registering an evicted/dead id revives it — the caller is
        declaring a fresh process behind the same id."""
        if host not in self._last_beat:
            self.num_hosts += 1
        self._last_beat[host] = now
        self._dead.discard(host)
        self.events.append(f"t={now:.1f} register host {host}")

    def dead_hosts(self) -> frozenset[int]:
        """Hosts currently declared dead or evicted.  The serving fleet
        diffs consecutive polls against this to find newly-lost replicas
        (Decision speaks training-world restart/downscale language; a
        replica pool only needs the membership delta)."""
        return frozenset(self._dead)

    def checkpoint_published(self, step: int) -> None:
        self.last_checkpoint_step = step

    def evict(self, host: int, now: float, reason: str = "straggler") -> None:
        if host not in self._dead:
            self._dead.add(host)
            self.events.append(f"t={now:.1f} evict host {host} ({reason})")

    # -- control loop --------------------------------------------------------
    def poll(self, now: float) -> Decision:
        newly_dead = [
            h
            for h, t in self._last_beat.items()
            if h not in self._dead and now - t > self.cfg.heartbeat_timeout
        ]
        for h in newly_dead:
            self._dead.add(h)
            self.events.append(f"t={now:.1f} host {h} missed heartbeat")

        alive = self.num_hosts - len(self._dead)
        if not newly_dead and alive == self.num_hosts:
            return Decision(DecisionKind.NONE, alive, None)
        if alive < self.cfg.min_hosts:
            return Decision(
                DecisionKind.ABORT, alive, self.last_checkpoint_step,
                reason=f"only {alive} hosts alive < min {self.cfg.min_hosts}",
            )
        if not newly_dead:
            return Decision(DecisionKind.NONE, alive, None)
        dead_now = len(newly_dead)
        if self.spares >= dead_now:
            self.spares -= dead_now
            for h in newly_dead:
                self._dead.discard(h)       # replaced by a spare
                self._last_beat[h] = now
            return Decision(
                DecisionKind.RESTART, self.num_hosts, self.last_checkpoint_step,
                reason=f"replaced {dead_now} host(s) from spares",
            )
        return Decision(
            DecisionKind.DOWNSCALE, alive, self.last_checkpoint_step,
            reason=f"{dead_now} host(s) lost, no spares",
        )
