"""Elastic rescaling: re-mesh + reshard-on-restore plans.

Checkpoints record logical layout only (checkpoint/manifest.py), so a
restart may use ANY device count.  This module picks the new mesh shape
for a changed world size and produces the sharding function for
restore_checkpoint — together they are the whole elasticity mechanism:

    plan = rescale_plan(n_devices_now, target_axes)
    params, step = restore_checkpoint(dir, skeleton,
                                      sharding_fn=plan.sharding_fn(schema))

Policy: keep the model axis as requested while it divides the device
count (TP degree is an algorithmic choice); absorb all remaining devices
into data (and pod) — losing a host costs DP ways, never a re-partition
of the model math.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.distributed.sharding import ShardingRules, param_specs
from repro.models.schema import leaf_items

__all__ = ["RescalePlan", "rescale_plan", "PoolPlan", "pool_rescale_plan"]


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]

    def build_mesh(self, devices=None) -> jax.sharding.Mesh:
        devices = list(devices if devices is not None else jax.devices())
        n = int(np.prod(self.mesh_shape))
        arr = np.array(devices[:n], dtype=object).reshape(self.mesh_shape)
        return jax.sharding.Mesh(arr, self.mesh_axes)

    def sharding_fn(self, schema: dict, rules: ShardingRules, devices=None):
        mesh = self.build_mesh(devices)
        specs = {p: s for p, s in leaf_items(param_specs(schema, rules, mesh))}

        def fn(path: str, arr):
            spec = specs.get(path)
            if spec is None:
                return None
            return NamedSharding(mesh, spec)

        return fn


def rescale_plan(
    num_devices: int,
    *,
    model: int = 1,
    pods: int = 1,
) -> RescalePlan:
    """Largest data axis that fits: devices = pods * data * model."""
    while model > 1 and num_devices % model:
        model //= 2
    denom = model * pods
    if num_devices % denom:
        pods = 1
        denom = model
    data = num_devices // denom
    if data < 1:
        raise ValueError(f"cannot fit model={model} pods={pods} in {num_devices} devices")
    if pods > 1:
        return RescalePlan((pods, data, model), ("pod", "data", "model"))
    if model > 1:
        return RescalePlan((data, model), ("data", "model"))
    return RescalePlan((data,), ("data",))


@dataclasses.dataclass(frozen=True)
class PoolPlan:
    """Replica-pool sizing decision (the serving-fleet analogue of
    RescalePlan: world size in replicas, not devices)."""

    current: int
    target: int
    reason: str

    @property
    def delta(self) -> int:
        return self.target - self.current

    def describe(self) -> str:
        arrow = "->" if self.delta else "=="
        return (f"rescale: decode pool {self.current} {arrow} {self.target} "
                f"({self.reason})")


def pool_rescale_plan(
    current: int,
    *,
    demand: int,
    slots_per_replica: int,
    min_replicas: int = 1,
    max_replicas: int = 8,
) -> PoolPlan:
    """Size a decode pool to its queue pressure.

    ``demand`` counts decode work items in flight or waiting (the fleet's
    not-yet-done requests); the target is the smallest pool whose slots
    cover that demand, clamped to [min_replicas, max_replicas].  Growing
    is the elastic half of the paper's thesis at fleet scale — a new
    replica warm-starts from a tuning bundle, so the plan's cost is
    provisioning, never a cold search.  The caller applies hysteresis on
    shrink (a momentary dip must not thrash the pool).
    """
    if slots_per_replica < 1:
        raise ValueError(f"slots_per_replica must be >= 1, got {slots_per_replica}")
    if min_replicas < 0 or max_replicas < min_replicas:
        raise ValueError(f"bad clamp [{min_replicas}, {max_replicas}]")
    need = -(-demand // slots_per_replica) if demand > 0 else 0
    target = max(min_replicas, min(max_replicas, need))
    if target > current:
        reason = (f"demand {demand} items needs {need} x "
                  f"{slots_per_replica}-slot replicas")
    elif target < current:
        reason = f"demand {demand} items fits {target}"
    else:
        reason = f"steady at demand {demand}"
    return PoolPlan(current=current, target=target, reason=reason)
