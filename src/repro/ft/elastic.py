"""Elastic rescaling: re-mesh + reshard-on-restore plans.

Checkpoints record logical layout only (checkpoint/manifest.py), so a
restart may use ANY device count.  This module picks the new mesh shape
for a changed world size and produces the sharding function for
restore_checkpoint — together they are the whole elasticity mechanism:

    plan = rescale_plan(n_devices_now, target_axes)
    params, step = restore_checkpoint(dir, skeleton,
                                      sharding_fn=plan.sharding_fn(schema))

Policy: keep the model axis as requested while it divides the device
count (TP degree is an algorithmic choice); absorb all remaining devices
into data (and pod) — losing a host costs DP ways, never a re-partition
of the model math.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.distributed.sharding import ShardingRules, param_specs
from repro.models.schema import leaf_items

__all__ = ["RescalePlan", "rescale_plan"]


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]

    def build_mesh(self, devices=None) -> jax.sharding.Mesh:
        devices = list(devices if devices is not None else jax.devices())
        n = int(np.prod(self.mesh_shape))
        arr = np.array(devices[:n], dtype=object).reshape(self.mesh_shape)
        return jax.sharding.Mesh(arr, self.mesh_axes)

    def sharding_fn(self, schema: dict, rules: ShardingRules, devices=None):
        mesh = self.build_mesh(devices)
        specs = {p: s for p, s in leaf_items(param_specs(schema, rules, mesh))}

        def fn(path: str, arr):
            spec = specs.get(path)
            if spec is None:
                return None
            return NamedSharding(mesh, spec)

        return fn


def rescale_plan(
    num_devices: int,
    *,
    model: int = 1,
    pods: int = 1,
) -> RescalePlan:
    """Largest data axis that fits: devices = pods * data * model."""
    while model > 1 and num_devices % model:
        model //= 2
    denom = model * pods
    if num_devices % denom:
        pods = 1
        denom = model
    data = num_devices // denom
    if data < 1:
        raise ValueError(f"cannot fit model={model} pods={pods} in {num_devices} devices")
    if pods > 1:
        return RescalePlan((pods, data, model), ("pod", "data", "model"))
    if model > 1:
        return RescalePlan((data, model), ("data", "model"))
    return RescalePlan((data,), ("data",))
