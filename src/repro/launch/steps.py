"""Step factories: jit-able train/prefill/decode with full shardings.

This is the deployment glue between the portable Model (hardware-agnostic)
and a concrete mesh: parameter/optimizer/cache/batch shardings all come
from the injected rules — the Model itself never names a mesh axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (
    BASELINE_RULES,
    ShardingRules,
    batch_spec,
    cache_specs,
    param_shardings,
)
from repro.models.layers import ParallelCtx
from repro.models.model import Model, build_model
from repro.optim import AdamWConfig, OptState, adamw_init, make_optimizer

__all__ = ["DeployOptions", "Deployment", "make_deployment"]


@dataclasses.dataclass(frozen=True)
class DeployOptions:
    remat: str | None = None          # override cfg.remat
    seq_shard: bool = False           # SP: shard residual seq dim on model
    rules: ShardingRules = BASELINE_RULES
    donate: bool = True
    moe_oracle: bool = False
    scan_unroll: bool = False         # dry-run: unroll layer scan so
                                      # cost_analysis sees every layer
    moe_token_chunks: int = 1         # MoE peak-memory knob (see models/moe)
    loss_seq_chunks: int = 1          # sequence-chunked cross-entropy
    grad_accum: int = 1               # microbatches per step (activation
                                      # peak ~1/M at the cost of an fp32
                                      # grad accumulator, params x 4B)
    head_padding: bool = True         # group-aligned TP head padding
    cache_seq_shard: bool = True      # seq-sharded KV caches (vs head_dim)
    kv_quantize: str | None = None    # int8/fp8 KV cache (serving)
    adamw: AdamWConfig = AdamWConfig()


@dataclasses.dataclass
class Deployment:
    model: Model
    mesh: jax.sharding.Mesh
    shape: ShapeConfig
    options: DeployOptions
    param_sharding: Any
    opt_sharding: Any
    batch_sharding: Any

    # jitted entry points (built lazily per kind)
    train_step: Any = None
    prefill_step: Any = None
    decode_step: Any = None

    def abstract_state(self):
        params = self.model.abstract_params()
        opt = OptState(
            m=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
            v=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
            count=jax.ShapeDtypeStruct((), jnp.int32),
        )
        return params, opt

    def abstract_batch(self):
        return self.model.input_specs(self.shape)


def _batch_shardings(model: Model, shape: ShapeConfig, mesh, options) -> Any:
    baxes = batch_spec(shape.global_batch, mesh)
    b = baxes or None

    def ns(spec):
        return NamedSharding(mesh, spec)

    specs = model.input_specs(shape)
    out: dict[str, Any] = {}
    for name, sds in specs.items():
        if name == "cache":
            ctree = cache_specs(sds, shape.global_batch, mesh,
                                seq_shard=options.cache_seq_shard)
            out["cache"] = jax.tree.map(
                lambda s: ns(s), ctree, is_leaf=lambda x: isinstance(x, P)
            )
        elif name == "pos":
            out["pos"] = ns(P())
        else:
            rank = len(sds.shape)
            out[name] = ns(P(b, *([None] * (rank - 1))))
    return out


def make_deployment(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: jax.sharding.Mesh,
    *,
    options: DeployOptions = DeployOptions(),
    binding=None,
) -> Deployment:
    if options.remat is not None:
        cfg = dataclasses.replace(cfg, remat=options.remat)
    axis_names = set(mesh.axis_names)
    pctx = ParallelCtx(
        mesh=mesh,
        batch_axes=tuple(a for a in ("pod", "data") if a in axis_names),
        model_axis="model" if "model" in axis_names else None,
        seq_shard=options.seq_shard,
    )
    if binding is None and options.scan_unroll:
        from repro.kernels.ops import measurement_binding

        binding = measurement_binding()
    model = build_model(
        cfg, binding=binding, pctx=pctx,
        moe_oracle=options.moe_oracle, scan_unroll=options.scan_unroll,
        moe_token_chunks=options.moe_token_chunks,
        loss_seq_chunks=options.loss_seq_chunks,
        head_pad_multiple=None if options.head_padding else 1,
        kv_quantize=options.kv_quantize,
    )

    pspec = param_shardings(model.schema(), options.rules, mesh)
    opt_sharding = OptState(
        m=pspec, v=pspec, count=NamedSharding(mesh, P())
    )
    bshard = _batch_shardings(model, shape, mesh, options)

    dep = Deployment(
        model=model,
        mesh=mesh,
        shape=shape,
        options=options,
        param_sharding=pspec,
        opt_sharding=opt_sharding,
        batch_sharding=bshard,
    )

    scalar = NamedSharding(mesh, P())
    if shape.kind == "train":
        init_fn, update_fn = make_optimizer(options.adamw)
        accum = options.grad_accum

        def train_step(params, opt_state, batch):
            if accum <= 1:
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss_fn, has_aux=True
                )(params, batch)
            else:
                micro = jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                    batch,
                )

                def mb(acc, b):
                    (l, m), g = jax.value_and_grad(
                        model.loss_fn, has_aux=True
                    )(params, b)
                    acc = jax.tree.map(
                        lambda a, gi: a + gi.astype(jnp.float32), acc, g
                    )
                    return acc, (l, m)

                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                grads, (losses, metricss) = jax.lax.scan(
                    mb, acc0, micro,
                    unroll=accum if options.scan_unroll else 1,
                )
                grads = jax.tree.map(lambda a: a / accum, grads)
                loss = losses.mean()
                metrics = jax.tree.map(lambda m: m.mean(), metricss)
            new_params, new_opt, stats = update_fn(grads, opt_state, params)
            return new_params, new_opt, {**metrics, **stats}

        dep.train_step = jax.jit(
            train_step,
            in_shardings=(pspec, opt_sharding, bshard),
            out_shardings=(pspec, opt_sharding, None),
            donate_argnums=(0, 1) if options.donate else (),
        )
    elif shape.kind == "prefill":
        cache_tree = model.abstract_cache(shape.global_batch, shape.seq_len)
        cshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            cache_specs(cache_tree, shape.global_batch, mesh,
                        seq_shard=options.cache_seq_shard),
            is_leaf=lambda x: isinstance(x, P),
        )
        dep.prefill_step = jax.jit(
            model.prefill,
            in_shardings=(pspec, bshard),
            out_shardings=(scalar_logits(mesh, shape), cshard),
        )
    else:  # decode
        dep.decode_step = jax.jit(
            model.decode,
            in_shardings=(
                pspec,
                bshard["token"],
                bshard["cache"],
                bshard["pos"],
            ),
            out_shardings=(scalar_logits(mesh, shape), bshard["cache"]),
            donate_argnums=(2,) if options.donate else (),
        )
    return dep


def scalar_logits(mesh, shape: ShapeConfig):
    """(B, V) logits: batch over DP axes, vocab over model when divisible."""
    baxes = batch_spec(shape.global_batch, mesh)
    return NamedSharding(mesh, P(baxes or None, None))
