"""Roofline report generator: experiments/dryrun/*.json -> markdown tables.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline_report [--dir experiments/dryrun]
      [--label baseline] [--mesh single|multi|both]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

__all__ = ["load_cells", "render_table", "main"]


def load_cells(directory: Path, label: str | None = None,
               mesh: str | None = None) -> list[dict]:
    cells = []
    for f in sorted(Path(directory).glob("*.json")):
        r = json.loads(f.read_text())
        if label and r.get("label") != label:
            continue
        if mesh == "single" and r.get("mesh") != "16x16":
            continue
        if mesh == "multi" and r.get("mesh") != "2x16x16":
            continue
        cells.append(r)
    return cells


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def render_table(cells: list[dict]) -> str:
    head = ("| arch | shape | mesh | status | compute | memory | collective | "
            "bound | useful | temp/dev | fits 16G |\n"
            "|---|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        if r["status"] == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP "
                f"| — | — | — | — | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR "
                f"| — | — | — | — | — | — | — |"
            )
            continue
        ro = r["roofline"]
        mem = r["memory"]
        temp = mem.get("temp_size_in_bytes", 0)
        args = mem.get("argument_size_in_bytes", 0)
        peak = temp + args
        useful = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {_fmt_s(ro['compute_s'])} | {_fmt_s(ro['memory_s'])} "
            f"| {_fmt_s(ro['collective_s'])} | {ro['dominant']} "
            f"| {useful and round(useful, 3)} | {temp / 1e9:.1f}G "
            f"| {'yes' if peak <= 16e9 else 'NO'} |"
        )
    return head + "\n".join(rows) + "\n"


def summarize(cells: list[dict]) -> str:
    ok = [c for c in cells if c["status"] == "ok"]
    skip = [c for c in cells if c["status"] == "skipped"]
    err = [c for c in cells if c["status"] not in ("ok", "skipped")]
    lines = [
        f"cells: {len(cells)} total, {len(ok)} ok, {len(skip)} skipped "
        f"(assignment long_500k rule), {len(err)} errors",
    ]
    if ok:
        by_bound: dict[str, int] = {}
        for c in ok:
            by_bound[c["roofline"]["dominant"]] = by_bound.get(
                c["roofline"]["dominant"], 0) + 1
        lines.append(f"dominant terms: {by_bound}")
        worst = min(
            (c for c in ok if c.get("useful_flops_ratio")),
            key=lambda c: c["useful_flops_ratio"],
        )
        lines.append(
            f"worst useful-flops ratio: {worst['arch']} x {worst['shape']} "
            f"({worst['useful_flops_ratio']:.3f})"
        )
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--label", default="baseline")
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    args = ap.parse_args(argv)
    mesh = None if args.mesh == "both" else args.mesh
    cells = load_cells(Path(args.dir), label=args.label, mesh=mesh)
    print(render_table(cells))
    print(summarize(cells))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
