"""Named sharding-rule variants for the §Perf hillclimb.

Each variant is a hypothesis about the dominant roofline term; dryrun.py
selects one with --rules and records the before/after in EXPERIMENTS.md.
"""

from __future__ import annotations

from repro.distributed.sharding import BASELINE_RULES, ShardingRules

__all__ = ["get_rules", "VARIANTS"]

# baseline: TP on parallel dims, FSDP storage over (pod, data) for embed,
# experts over data.
VARIANTS: dict[str, ShardingRules] = {
    "baseline": BASELINE_RULES,
    # no FSDP: replicate dense weights across DP (memory-hungry; isolates the
    # cost of per-layer FSDP all-gathers)
    "no_fsdp": ShardingRules(
        tuple(r for r in BASELINE_RULES.rules if r[0] not in ("embed",))
    ),
    # FSDP over data only (pod axis replicated — cheaper cross-pod traffic,
    # more memory per chip)
    "fsdp_data_only": BASELINE_RULES.with_override(("embed", ("data",))),
    # experts sharded over (pod, data) too: halves expert storage per chip in
    # multi-pod at the cost of cross-pod gathers
    "experts_pod_data": BASELINE_RULES.with_override(("experts", ("pod", "data"))),
}


def get_rules(name: str) -> ShardingRules:
    if name not in VARIANTS:
        raise KeyError(f"unknown rules variant {name!r}; known: {sorted(VARIANTS)}")
    return VARIANTS[name]
