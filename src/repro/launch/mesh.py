"""Production mesh construction (assignment-mandated geometry).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first backend init,
which dryrun.py configures before any jax import).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_compat_mesh"]


def make_compat_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    # jax.sharding.AxisType landed after 0.4.37; Auto is the default
    # there anyway, so omit the kwarg on versions that lack it
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_host_mesh(*, data: int | None = None, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices this host actually has (tests,
    examples, the 'cluster' platform)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    assert data * model <= n, (data, model, n)
    if model > 1:
        return make_compat_mesh((data, model), ("data", "model"))
    return make_compat_mesh((data,), ("data",))
