"""Training driver — the `shifter --image=<bundle> train` of this framework.

Runs the full paper workflow on whatever devices exist: pull the bundle
from the gateway cache, deploy it through the Runtime (op swap + mesh
injection), then run the fault-tolerant training loop:

  * deterministic data pipeline (restart replays from the checkpoint step)
  * async single-manifest checkpoints with atomic LATEST pointer
  * automatic restore (+ reshard, if the device count changed) on startup
  * straggler observation hooks (simulated timings on CPU)

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
      --steps 50 --reduced --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.core import Bundle, Runtime, global_registry
from repro.data import DataConfig, SyntheticStream
from repro.kernels.ops import OP_NAMES, register_all
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import DeployOptions, make_deployment
from repro.optim import OptState, adamw_init

__all__ = ["main", "train_loop", "make_bundle"]


def make_bundle(arch: str, *, reduced: bool = False) -> Bundle:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    register_all()
    return Bundle(
        name=cfg.name,
        tag="latest",
        model_config=cfg.to_dict(),
        recipe={"optimizer": "adamw", "lr": 3e-4},
        required_ops={op: str(global_registry.decl(op).abi) for op in OP_NAMES},
        env={"REPRO_BUNDLE_KIND": "train"},
    )


def train_loop(
    dep,
    stream: SyntheticStream,
    *,
    steps: int,
    ckpt_dir: Path | None,
    ckpt_every: int = 50,
    start_step: int = 0,
    params=None,
    opt_state=None,
    log_every: int = 10,
):
    model = dep.model
    if params is None:
        params = jax.device_put(
            model.init(jax.random.PRNGKey(0)), dep.param_sharding
        )
    if opt_state is None:
        opt_state = jax.device_put(adamw_init(params), dep.opt_sharding)

    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    losses = []
    t_start = time.time()
    for step in range(start_step, steps):
        batch = jax.device_put(stream.global_batch_at(step), dep.batch_sharding)
        params, opt_state, metrics = dep.train_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t_start
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"({dt / max(step - start_step + 1, 1):.2f}s/step)", flush=True)
        if ckpt and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.save(steps, {"params": params, "opt": opt_state})
        ckpt.wait()
    return params, opt_state, losses


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale config of the same family")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--native-ops", action="store_true")
    ap.add_argument("--data-mesh", type=int, default=0,
                    help="data-parallel ways (0 = all local devices)")
    ap.add_argument("--profile", action="store_true",
                    help="capture op geometries into REPRO_WORKLOAD_PROFILE "
                         "(feed repro.tuning.warm; or set REPRO_PROFILE=1)")
    ap.add_argument("--autotune", action="store_true",
                    help="resolve kernel configs from the site tuning cache "
                         "(or set REPRO_AUTOTUNE=1)")
    ap.add_argument("--max-tuned-entries", type=int, default=None, metavar="K",
                    help="per-op cap on the geometry-dispatch table; cold "
                         "cached buckets beyond it are LRU-evicted "
                         "(or set REPRO_TUNING_MAX_ENTRIES)")
    ap.add_argument("--tuning-bundle", default=None, metavar="PATH",
                    help="portable tuning bundle to import before binding "
                         "(python -m repro.tuning.bundle export; or set "
                         "REPRO_TUNING_BUNDLE)")
    args = ap.parse_args(argv)

    bundle = make_bundle(args.arch, reduced=args.reduced)
    runtime = Runtime()
    mesh = make_host_mesh(data=args.data_mesh or None)
    container = runtime.deploy(bundle, native_ops=args.native_ops, mesh=mesh,
                               profile=True if args.profile else None,
                               autotune=True if args.autotune else None,
                               max_tuned_entries=args.max_tuned_entries,
                               tuning_bundle=args.tuning_bundle)
    print(container.describe())

    from repro.configs.base import ModelConfig

    cfg = ModelConfig.from_dict(container.bundle.model_config)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    dep = make_deployment(
        cfg, shape, container.mesh,
        options=DeployOptions(donate=True),
        binding=container.binding,
    )
    stream = SyntheticStream(cfg, shape, DataConfig(seed=0))

    start_step, params, opt_state = 0, None, None
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        model = dep.model
        skeleton = {
            "params": model.abstract_params(),
            "opt": OptState(
                m=jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                    model.abstract_params(),
                ),
                v=jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                    model.abstract_params(),
                ),
                count=jax.ShapeDtypeStruct((), jnp.int32),
            ),
        }
        skeleton = jax.tree.map(
            lambda s: np.zeros(s.shape, s.dtype), skeleton,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        restored, start_step = restore_checkpoint(args.ckpt_dir, skeleton)
        params = jax.device_put(restored["params"], dep.param_sharding)
        opt_state = jax.device_put(restored["opt"], dep.opt_sharding)
        print(f"restored checkpoint at step {start_step}")

    train_loop(
        dep, stream,
        steps=args.steps,
        ckpt_dir=Path(args.ckpt_dir) if args.ckpt_dir else None,
        ckpt_every=args.ckpt_every,
        start_step=start_step,
        params=params,
        opt_state=opt_state,
    )
    if container.workload is not None:
        print(f"captured {len(container.workload)} op geometries -> "
              f"{container.workload.path} (warm with: python -m repro.tuning.warm)")
    from repro.launch.serve import print_dispatch_stats

    print_dispatch_stats(container)
    runtime.cleanup()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
